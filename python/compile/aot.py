"""AOT export: train (or reuse) DiT-tiny, lower the L2 graphs (with their L1
Pallas kernels) to HLO text, and emit cross-language test vectors.

Run once via ``make artifacts``; the Rust binary is self-contained afterwards.

Artifacts
---------
  dit_weights.npz            trained DiT-tiny parameters
  loss_curve.csv             training loss log (EXPERIMENTS.md)
  eps_batch_{N}.hlo.txt      CFG denoiser: (x[N,256], t[N], y[N], g) -> eps
  solver_step_{T}.hlo.txt    one ParaTAA round: combine + residuals + TAA
  testvec_schedule.json      DDIM/DDPM coefficients (pins rust/schedule)
  testvec_gmm.json           analytic GMM eps cases (pins rust/model/gmm)
  testvec_taa.json           TAA update cases (pins rust/solver/update)
  testvec_dit.json           trained-model eps cases (pins rust/runtime)

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset, gmm, model, schedule, solver_ref, train
from .kernels import ref
from .kernels.banded_combine import banded_combine
from .kernels.taa_update import row_grams, taa_apply

EPS_BATCH_SIZES = [1, 5, 10, 25, 50, 100]
SOLVER_STEPS = [25, 50, 100]
HIST_COLS = 2  # paper m=3 => 2 difference columns


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the trained weights are
    # baked into the graph as constants, and the default printer elides
    # them as `constant({...})`, silently corrupting the artifact.
    return comp.as_hlo_text(print_large_constants=True)


def export_eps_batch(params, out_dir: str) -> None:
    def fn(x, t, y, guidance):
        return (model.eps_cfg(params, x, t, y, guidance),)

    for n in EPS_BATCH_SIZES:
        spec_x = jax.ShapeDtypeStruct((n, model.DIM), jnp.float32)
        spec_t = jax.ShapeDtypeStruct((n,), jnp.int32)
        spec_y = jax.ShapeDtypeStruct((n,), jnp.int32)
        spec_g = jax.ShapeDtypeStruct((), jnp.float32)
        lowered = jax.jit(fn).lower(spec_x, spec_t, spec_y, spec_g)
        path = os.path.join(out_dir, f"eps_batch_{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"  wrote {path}")


def solver_step_fn(xs_ext, eps_ext, x_win, s_mat, b_mat, xi_comb,
                   s1_mat, b1_mat, xi1_comb, dX, dF, mask, fp_mask, lam):
    """One parallel update round (L2 graph around the L1 kernels).

    Shapes: xs_ext/eps_ext [T+1, D]; x_win/xi_comb/xi1_comb [W, D];
    s/b matrices [W, T+1]; dX/dF [mc, W, D]; mask/fp_mask [W]; lam scalar.
    Returns (x_new [W, D], R [W, D], r1 [W]).
    """
    f_k = banded_combine(s_mat, xs_ext, b_mat, eps_ext, xi_comb)
    f_1 = banded_combine(s1_mat, xs_ext, b1_mat, eps_ext, xi1_comb)
    r_vec = (f_k - x_win) * mask[:, None]
    r1 = jnp.sum((x_win - f_1) ** 2 * mask[:, None], axis=1)
    g_rows, b_rows = row_grams(dF, r_vec)
    G, Bv = ref.suffix_scan_ref(g_rows, b_rows)
    gamma = ref.cramer_solve_ref(G, Bv, lam)
    gamma = gamma * (1.0 - fp_mask)[:, None]
    x_new = taa_apply(x_win, r_vec, dX, dF, gamma, mask)
    return x_new, r_vec, r1


def export_solver_step(out_dir: str) -> None:
    d = model.DIM
    for t_steps in SOLVER_STEPS:
        w = t_steps
        c = t_steps + 1
        f32 = jnp.float32
        specs = [
            jax.ShapeDtypeStruct((c, d), f32),            # xs_ext
            jax.ShapeDtypeStruct((c, d), f32),            # eps_ext
            jax.ShapeDtypeStruct((w, d), f32),            # x_win
            jax.ShapeDtypeStruct((w, c), f32),            # s_mat
            jax.ShapeDtypeStruct((w, c), f32),            # b_mat
            jax.ShapeDtypeStruct((w, d), f32),            # xi_comb
            jax.ShapeDtypeStruct((w, c), f32),            # s1_mat
            jax.ShapeDtypeStruct((w, c), f32),            # b1_mat
            jax.ShapeDtypeStruct((w, d), f32),            # xi1_comb
            jax.ShapeDtypeStruct((HIST_COLS, w, d), f32),  # dX
            jax.ShapeDtypeStruct((HIST_COLS, w, d), f32),  # dF
            jax.ShapeDtypeStruct((w,), f32),              # mask
            jax.ShapeDtypeStruct((w,), f32),              # fp_mask
            jax.ShapeDtypeStruct((), f32),                # lam
        ]
        lowered = jax.jit(solver_step_fn).lower(*specs)
        path = os.path.join(out_dir, f"solver_step_{t_steps}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"  wrote {path}")


def export_testvec_schedule(out_dir: str) -> None:
    out = {}
    for steps, eta, name in [(10, 0.0, "ddim10"), (10, 1.0, "ddpm10"), (25, 0.0, "ddim25")]:
        cs = schedule.sampler_coeffs(steps, eta)
        out[name] = {
            "steps": steps,
            "eta": eta,
            "a": cs["a"].tolist(),
            "b": cs["b"].tolist(),
            "c": cs["c"].tolist(),
            "train_t": cs["train_t"].tolist(),
            "g2": cs["g2"].tolist(),
        }
    betas = schedule.linear_betas()
    abars = schedule.alpha_bars(betas)
    out["schedule"] = {
        "betas_sample": {str(i): betas[i] for i in [0, 1, 499, 999]},
        "abars_sample": {str(i): abars[i] for i in [0, 1, 499, 999]},
    }
    _write_json(os.path.join(out_dir, "testvec_schedule.json"), out)


def export_testvec_gmm(out_dir: str) -> None:
    rng = np.random.default_rng(1234)
    k, d = 3, 6
    means = (2.0 * rng.random((k, d)) - 1.0).astype(np.float32)
    data_std = 0.2
    betas = schedule.linear_betas()
    abars = schedule.alpha_bars(betas)
    cases = []
    for t in [0, 100, 500, 999]:
        for guidance in [1.0, 5.0]:
            x = rng.standard_normal(d).astype(np.float32)
            weights = np.zeros(k, np.float32)
            weights[t % k] = 1.0
            e = gmm.eps_cfg(x, abars[t], weights, means, data_std, guidance)
            cases.append(
                {
                    "x": x.tolist(),
                    "train_t": t,
                    "weights": weights.tolist(),
                    "guidance": guidance,
                    "eps": e.tolist(),
                }
            )
    _write_json(
        os.path.join(out_dir, "testvec_gmm.json"),
        {"means": means.tolist(), "data_std": data_std, "cases": cases},
    )


def export_testvec_taa(out_dir: str) -> None:
    rng = np.random.default_rng(77)
    w, d, mc = 5, 4, 2
    dX = rng.standard_normal((mc, w, d)).astype(np.float32)
    dF = rng.standard_normal((mc, w, d)).astype(np.float32)
    x = rng.standard_normal((w, d)).astype(np.float32)
    R = rng.standard_normal((w, d)).astype(np.float32)
    lam = 1e-4
    # numpy mirror of the TAA update (same math as rust solver/update.rs).
    g_rows = np.einsum("awd,bwd->wab", dF.astype(np.float64), dF.astype(np.float64))
    b_rows = np.einsum("awd,wd->wa", dF.astype(np.float64), R.astype(np.float64))
    G = np.cumsum(g_rows[::-1], axis=0)[::-1]
    Bv = np.cumsum(b_rows[::-1], axis=0)[::-1]
    gamma = np.zeros((w, mc))
    for p in range(w):
        A = G[p] + lam * (1.0 + np.trace(G[p]) / mc) * np.eye(mc)
        gamma[p] = np.linalg.solve(A, Bv[p])
    x_new = x + R - np.einsum("wm,mwd->wd", gamma, (dX + dF).astype(np.float64)).astype(np.float32)
    _write_json(
        os.path.join(out_dir, "testvec_taa.json"),
        {
            "w": w,
            "d": d,
            "mc": mc,
            "lam": lam,
            "dX": dX.reshape(-1).tolist(),
            "dF": dF.reshape(-1).tolist(),
            "x": x.reshape(-1).tolist(),
            "R": R.reshape(-1).tolist(),
            "gamma": gamma.reshape(-1).tolist(),
            "x_new": x_new.reshape(-1).tolist(),
        },
    )


def export_testvec_dit(params, out_dir: str) -> None:
    rng = np.random.default_rng(4321)
    fn = jax.jit(lambda x, t, y, g: model.eps_cfg(params, x, t, y, g))
    cases = []
    for t, y, guidance in [(0, 0, 1.0), (500, 3, 5.0), (999, 7, 2.0), (250, 8, 1.0)]:
        x = rng.standard_normal((1, model.DIM)).astype(np.float32)
        e = np.asarray(fn(jnp.asarray(x), jnp.array([t], jnp.int32), jnp.array([y], jnp.int32), jnp.float32(guidance)))
        cases.append(
            {
                "x": x[0].tolist(),
                "train_t": t,
                "y": y,
                "guidance": guidance,
                "eps": e[0].tolist(),
            }
        )
    _write_json(os.path.join(out_dir, "testvec_dit.json"), {"cases": cases})


def _write_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f)
    print(f"  wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=3000)
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    weights_path = os.path.join(out_dir, "dit_weights.npz")
    if os.path.exists(weights_path) and not args.retrain:
        print(f"loading cached weights from {weights_path}")
        params = train.load_params(weights_path)
    else:
        print(f"training DiT-tiny for {args.train_steps} steps ...")
        t0 = time.time()
        params, log = train.train(steps=args.train_steps, verbose=True)
        print(f"training done in {time.time()-t0:.0f}s, final loss {log[-1][1]:.5f}")
        train.save_params(weights_path, params)
        with open(os.path.join(out_dir, "loss_curve.csv"), "w") as f:
            f.write("step,loss\n")
            for s, l in log:
                f.write(f"{s},{l}\n")
        print(f"  wrote {weights_path}")

    print("exporting eps_batch artifacts ...")
    export_eps_batch(params, out_dir)
    print("exporting solver_step artifacts ...")
    export_solver_step(out_dir)
    print("exporting test vectors ...")
    export_testvec_schedule(out_dir)
    export_testvec_gmm(out_dir)
    export_testvec_taa(out_dir)
    export_testvec_dit(params, out_dir)
    # Stamp for make's incremental check.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print("AOT export complete.")


if __name__ == "__main__":
    main()
