"""L2: DiT-tiny — a small diffusion transformer in pure JAX.

Architecture (a faithfully scaled-down DiT, Peebles & Xie 2023):
  16x16x1 image -> 4x4 patchify -> 16 tokens x dim 64
  -> N_BLOCKS adaLN-zero transformer blocks (4 heads, Pallas attention)
  -> adaLN final layer -> unpatchify -> eps prediction [B, 256].

Conditioning: sinusoidal timestep embedding + class embedding table
(N_CLASSES + 1 entries; the last is the CFG null class). Classifier-free
guidance is applied *inside* the exported graph (two batched forward passes),
so the Rust hot path makes exactly one device call per parallel round.

Everything is pure functions over a params pytree (no flax), which keeps the
AOT export trivial: ``jax.jit(lambda x,t,y,g: eps_cfg(params, ...))`` closes
over the trained weights and bakes them into the HLO as constants.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import attention

SIDE = 16
PATCH = 4
N_TOKENS = (SIDE // PATCH) ** 2          # 16
PATCH_DIM = PATCH * PATCH                # 16
DIM = SIDE * SIDE                        # 256
HIDDEN = 64
HEADS = 4
HEAD_DIM = HIDDEN // HEADS               # 16
MLP_HIDDEN = 4 * HIDDEN                  # 256
N_BLOCKS = 2
N_CLASSES = 8
NULL_CLASS = N_CLASSES                   # CFG null token
FREQ_DIM = 64


def _dense_init(key, fan_in, fan_out, scale=1.0):
    w = jax.random.normal(key, (fan_in, fan_out)) * scale / np.sqrt(fan_in)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros(fan_out, jnp.float32)}


def init_params(seed: int = 0):
    """Initialize the DiT-tiny parameter pytree."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 64)
    ki = iter(keys)
    params = {
        "patch_embed": _dense_init(next(ki), PATCH_DIM, HIDDEN),
        "pos_embed": jax.random.normal(next(ki), (N_TOKENS, HIDDEN)) * 0.02,
        "class_embed": jax.random.normal(next(ki), (N_CLASSES + 1, HIDDEN)) * 0.02,
        "time_mlp1": _dense_init(next(ki), FREQ_DIM, HIDDEN),
        "time_mlp2": _dense_init(next(ki), HIDDEN, HIDDEN),
        "blocks": [],
        "final_mod": _dense_init(next(ki), HIDDEN, 2 * HIDDEN, scale=0.0),
        "final_out": _dense_init(next(ki), HIDDEN, PATCH_DIM, scale=0.0),
    }
    for _ in range(N_BLOCKS):
        params["blocks"].append(
            {
                # adaLN-zero modulation: (shift, scale, gate) x 2 sublayers,
                # zero-init so each block starts as identity.
                "mod": _dense_init(next(ki), HIDDEN, 6 * HIDDEN, scale=0.0),
                "qkv": _dense_init(next(ki), HIDDEN, 3 * HIDDEN),
                "proj": _dense_init(next(ki), HIDDEN, HIDDEN),
                "mlp1": _dense_init(next(ki), HIDDEN, MLP_HIDDEN),
                "mlp2": _dense_init(next(ki), MLP_HIDDEN, HIDDEN),
            }
        )
    return params


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _layernorm(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6)


def _timestep_embedding(t):
    """Sinusoidal embedding of integer training timesteps. t: [B] -> [B, FREQ_DIM]."""
    half = FREQ_DIM // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def _patchify(x):
    """[B, 256] image -> [B, 16 tokens, 16 patch-dim]."""
    b = x.shape[0]
    img = x.reshape(b, SIDE, SIDE)
    img = img.reshape(b, SIDE // PATCH, PATCH, SIDE // PATCH, PATCH)
    img = img.transpose(0, 1, 3, 2, 4)  # [B, gh, gw, PATCH, PATCH]
    return img.reshape(b, N_TOKENS, PATCH_DIM)


def _unpatchify(tok):
    """[B, 16, 16] tokens -> [B, 256] image."""
    b = tok.shape[0]
    g = SIDE // PATCH
    img = tok.reshape(b, g, g, PATCH, PATCH)
    img = img.transpose(0, 1, 3, 2, 4)  # [B, g, PATCH, g, PATCH]
    return img.reshape(b, DIM)


def _block(p, x, c):
    """One adaLN-zero DiT block. x: [B, N, H]; c: [B, H] conditioning."""
    mod = _dense(p["mod"], jax.nn.silu(c))  # [B, 6H]
    sh_a, sc_a, g_a, sh_m, sc_m, g_m = jnp.split(mod, 6, axis=-1)
    # Attention sublayer.
    h = _layernorm(x) * (1 + sc_a[:, None, :]) + sh_a[:, None, :]
    qkv = _dense(p["qkv"], h)  # [B, N, 3H]
    b, n, _ = qkv.shape
    qkv = qkv.reshape(b, n, 3, HEADS, HEAD_DIM).transpose(2, 0, 3, 1, 4)
    q, k, v = qkv[0], qkv[1], qkv[2]  # [B, heads, N, head_dim]
    att = attention(q, k, v)  # Pallas kernel (L1)
    att = att.transpose(0, 2, 1, 3).reshape(b, n, HIDDEN)
    x = x + g_a[:, None, :] * _dense(p["proj"], att)
    # MLP sublayer.
    h = _layernorm(x) * (1 + sc_m[:, None, :]) + sh_m[:, None, :]
    h = _dense(p["mlp2"], jax.nn.gelu(_dense(p["mlp1"], h)))
    return x + g_m[:, None, :] * h


def eps_raw(params, x, t, y):
    """Unguided eps prediction. x: [B, 256]; t, y: [B] int32 -> [B, 256]."""
    tok = _dense(params["patch_embed"], _patchify(x)) + params["pos_embed"][None]
    temb = _dense(
        params["time_mlp2"],
        jax.nn.silu(_dense(params["time_mlp1"], _timestep_embedding(t))),
    )
    yemb = params["class_embed"][y]
    c = temb + yemb
    for bp in params["blocks"]:
        tok = _block(bp, tok, c)
    mod = _dense(params["final_mod"], jax.nn.silu(c))
    sh, sc = jnp.split(mod, 2, axis=-1)
    tok = _layernorm(tok) * (1 + sc[:, None, :]) + sh[:, None, :]
    return _unpatchify(_dense(params["final_out"], tok))


def eps_cfg(params, x, t, y, guidance):
    """Classifier-free-guided eps: one fused graph with a doubled batch.

    eps = eps_null + guidance * (eps_y - eps_null). guidance is a traced
    scalar, so the same artifact serves every guidance strength.
    """
    b = x.shape[0]
    x2 = jnp.concatenate([x, x], axis=0)
    t2 = jnp.concatenate([t, t], axis=0)
    y2 = jnp.concatenate([y, jnp.full_like(y, NULL_CLASS)], axis=0)
    both = eps_raw(params, x2, t2, y2)
    eps_c, eps_u = both[:b], both[b:]
    return eps_u + guidance * (eps_c - eps_u)
