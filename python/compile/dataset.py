"""Synthetic 16x16 shape dataset — build-time substrate.

The template generation rule is integer-exact and mirrored bit-for-bit by
``rust/src/model/templates.rs``; the cross-language test vectors pin the two.

Training samples are ``template(class) + data_std * N(0, I)`` — i.e. exactly
the template-GMM that ``compile/gmm.py`` (and ``rust/src/model/gmm.rs``)
scores analytically. DiT-tiny therefore *learns* the distribution whose score
we also know in closed form, which gives the experiments an absolute
reference for every quality metric.
"""

from __future__ import annotations

import numpy as np

SIDE = 16
DIM = SIDE * SIDE
N_CLASSES = 8
FG = 0.8
BG = -0.8
DATA_STD = 0.15

CLASS_NAMES = [
    "circle", "square", "cross", "hstripes", "vstripes", "diag", "ring", "checker",
]


def template(class_id: int) -> np.ndarray:
    """Template image for a class (row-major float32, length DIM)."""
    c = class_id % N_CLASSES
    img = np.full(DIM, BG, dtype=np.float32)
    s = SIDE
    for y in range(s):
        for x in range(s):
            cx = 2 * x - (s - 1)
            cy = 2 * y - (s - 1)
            r2 = cx * cx + cy * cy
            if c == 0:
                on = r2 <= 121
            elif c == 1:
                on = abs(cx) <= 9 and abs(cy) <= 9
            elif c == 2:
                on = abs(cx) <= 3 or abs(cy) <= 3
            elif c == 3:
                on = (y // 2) % 2 == 0
            elif c == 4:
                on = (x // 2) % 2 == 0
            elif c == 5:
                on = abs(x - y) <= 2 or abs(x + y - (s - 1)) <= 2
            elif c == 6:
                on = 49 <= r2 <= 169
            else:  # 7
                on = ((x // 4) + (y // 4)) % 2 == 0
            if on:
                img[y * s + x] = FG
    return img


def all_templates() -> np.ndarray:
    """``[N_CLASSES, DIM]`` stack of all templates."""
    return np.stack([template(c) for c in range(N_CLASSES)])


def make_batch(rng: np.random.Generator, batch: int) -> tuple[np.ndarray, np.ndarray]:
    """Draw a training batch: (images [batch, DIM], labels [batch])."""
    labels = rng.integers(0, N_CLASSES, size=batch)
    temps = all_templates()[labels]
    noise = rng.standard_normal((batch, DIM)).astype(np.float32)
    return temps + DATA_STD * noise, labels.astype(np.int32)
