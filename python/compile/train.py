"""Build-time training of DiT-tiny via denoising score matching.

Standard DDPM objective: for x0 ~ data, t ~ U{0..999}, eps ~ N(0, I),
  x_t = sqrt(abar_t) x0 + sqrt(1 - abar_t) eps,
  loss = || eps_theta(x_t, t, y) - eps ||^2,
with 10% CFG class dropout (label -> NULL_CLASS).

Adam is implemented inline (optax is not available in the build image).
The loss curve is logged to ``artifacts/loss_curve.csv`` and summarized in
EXPERIMENTS.md — this is the end-to-end "train a real model" leg of the
reproduction pipeline.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, model, schedule


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "step": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh_scale = 1.0 / (1 - b1**step)
    vh_scale = 1.0 / (1 - b2**step)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mh_scale) / (jnp.sqrt(v_ * vh_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "step": step}


def make_loss_fn(abars: jnp.ndarray):
    def loss_fn(params, x0, y, t, noise):
        ab = abars[t][:, None]
        xt = jnp.sqrt(ab) * x0 + jnp.sqrt(1.0 - ab) * noise
        pred = model.eps_raw(params, xt, t, y)
        return jnp.mean((pred - noise) ** 2)

    return loss_fn


def train(
    steps: int = 3000,
    batch: int = 128,
    lr: float = 2e-3,
    seed: int = 0,
    log_every: int = 50,
    verbose: bool = True,
):
    """Train DiT-tiny; returns (params, loss_log) where loss_log is a list of
    (step, loss) tuples."""
    betas = schedule.linear_betas()
    abars = jnp.asarray(schedule.alpha_bars(betas), jnp.float32)
    params = model.init_params(seed)
    opt = adam_init(params)
    loss_fn = make_loss_fn(abars)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    update = jax.jit(lambda p, g, s, lr_: adam_update(p, g, s, lr_))

    rng = np.random.default_rng(seed)
    log: list[tuple[int, float]] = []
    t0 = time.time()
    for step in range(1, steps + 1):
        x0, y = dataset.make_batch(rng, batch)
        # 10% CFG dropout.
        drop = rng.random(batch) < 0.1
        y = np.where(drop, model.NULL_CLASS, y).astype(np.int32)
        t = rng.integers(0, schedule.TRAIN_STEPS, size=batch).astype(np.int32)
        noise = rng.standard_normal((batch, model.DIM)).astype(np.float32)
        # Cosine LR decay with short warmup.
        warm = min(step / 100.0, 1.0)
        decay = 0.5 * (1 + np.cos(np.pi * step / steps))
        cur_lr = lr * warm * (0.1 + 0.9 * decay)
        loss, grads = grad_fn(params, jnp.asarray(x0), jnp.asarray(y), jnp.asarray(t), jnp.asarray(noise))
        params, opt = update(params, grads, opt, cur_lr)
        if step % log_every == 0 or step == 1:
            log.append((step, float(loss)))
            if verbose:
                print(f"step {step:5d}  loss {float(loss):.5f}  lr {cur_lr:.2e}  ({time.time()-t0:.0f}s)")
    return params, log


def flatten_params(params, prefix=""):
    """Flatten the pytree into {dotted.name: np.ndarray} for npz storage."""
    out = {}
    if isinstance(params, dict):
        for k, v in params.items():
            out.update(flatten_params(v, f"{prefix}{k}."))
    elif isinstance(params, (list, tuple)):
        for i, v in enumerate(params):
            out.update(flatten_params(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = np.asarray(params)
    return out


def unflatten_params(flat: dict):
    """Inverse of flatten_params for the DiT-tiny layout."""
    params = model.init_params(0)

    def assign(tree, path, value):
        key = path[0]
        if isinstance(tree, list):
            key = int(key)
        if len(path) == 1:
            tree[key] = jnp.asarray(value)
        else:
            assign(tree[key], path[1:], value)

    for name, value in flat.items():
        assign(params, name.split("."), value)
    return params


def save_params(path: str, params):
    np.savez(path, **flatten_params(params))


def load_params(path: str):
    with np.load(path) as npz:
        return unflatten_params({k: npz[k] for k in npz.files})
