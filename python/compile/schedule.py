"""Noise schedule + DDIM(eta) sampler coefficients — mirror of
``rust/src/schedule/``. Used by training (forward process), by the AOT
export, and to emit the cross-language test vectors that pin the Rust
implementation to this one.
"""

from __future__ import annotations

import numpy as np

TRAIN_STEPS = 1000


def linear_betas(train_steps: int = TRAIN_STEPS) -> np.ndarray:
    lo, hi = 1e-4, 0.02
    n = train_steps
    return (lo + (hi - lo) * np.arange(n) / (n - 1)).astype(np.float64)


def alpha_bars(betas: np.ndarray) -> np.ndarray:
    return np.cumprod(1.0 - betas)


def subset_timesteps(train_steps: int, steps: int) -> np.ndarray:
    stride = train_steps // steps
    return np.arange(steps) * stride


def g2(betas: np.ndarray, t: np.ndarray | int) -> np.ndarray:
    """VP-SDE diffusion coefficient g^2 at training timestep(s) t."""
    return betas[t] * len(betas)


def sampler_coeffs(steps: int, eta: float, train_steps: int = TRAIN_STEPS):
    """DDIM(eta) coefficients a[t], b[t] (t=1..T; index 0 unused), c[t]
    (t=0..T-1), train_t[t] (t=1..T), matching the Rust convention exactly.

    Returns a dict of float64 numpy arrays.
    """
    betas = linear_betas(train_steps)
    abars = alpha_bars(betas)
    taus = subset_timesteps(train_steps, steps)
    a = np.zeros(steps + 1)
    b = np.zeros(steps + 1)
    c = np.zeros(steps)
    train_t = np.zeros(steps + 1, dtype=np.int64)
    g2v = np.zeros(steps)
    for t in range(1, steps + 1):
        tau_hi = taus[t - 1]
        ab_hi = abars[tau_hi]
        ab_lo = abars[taus[t - 2]] if t >= 2 else 1.0
        a_t = np.sqrt(ab_lo / ab_hi)
        if t >= 2:
            sigma = eta * np.sqrt((1 - ab_lo) / (1 - ab_hi)) * np.sqrt(1 - ab_hi / ab_lo)
        else:
            sigma = 0.0
        b_t = np.sqrt(max(1 - ab_lo - sigma * sigma, 0.0)) - a_t * np.sqrt(1 - ab_hi)
        a[t] = a_t
        b[t] = b_t
        c[t - 1] = sigma
        train_t[t] = tau_hi
        g2v[t - 1] = g2(betas, tau_hi)
    return {"a": a, "b": b, "c": c, "train_t": train_t, "g2": g2v}


def abar_products(a: np.ndarray, i: int, s: int) -> float:
    """ā_{i,s} = prod_{j=i}^{s} a_j (1 when s < i)."""
    if s < i:
        return 1.0
    return float(np.prod(a[i : s + 1]))
