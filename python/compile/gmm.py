"""Template-GMM analytic score — mirror of ``rust/src/model/gmm.rs``.

Data: p0(x | w) = sum_i w_i N(mu_i, s^2 I) with template means. Under the VP
forward process at signal level abar:

    p_t(x | w) = sum_i w_i N(sqrt(abar) mu_i, (abar s^2 + 1 - abar) I)
    eps(x, t, w) = sqrt(1-abar)/v * (x - sum_i post_i(x) sqrt(abar) mu_i)

Used to (a) emit cross-language test vectors pinning the Rust GMM, and
(b) serve as the exact-score reference in the python solver tests.
"""

from __future__ import annotations

import numpy as np

from . import dataset


def log_posterior(x, abar, weights, means, data_std):
    """Component log-posteriors and marginal log-likelihood (up to const).

    x: [D]; weights: [K]; means: [K, D]. Returns (log_post [K], lse).
    """
    v = abar * data_std**2 + (1.0 - abar)
    diff = x[None, :] - np.sqrt(abar) * means  # [K, D]
    d2 = np.sum(diff * diff, axis=1)
    with np.errstate(divide="ignore"):
        logits = np.where(weights > 0, np.log(np.maximum(weights, 1e-300)), -np.inf) - d2 / (2 * v)
    mx = np.max(logits)
    lse = mx + np.log(np.sum(np.exp(logits - mx)))
    return logits - lse, lse


def eps_single(x, abar, weights, means, data_std):
    """Exact eps for one item under dense component weights."""
    v = abar * data_std**2 + (1.0 - abar)
    log_post, _ = log_posterior(x, abar, weights, means, data_std)
    post = np.exp(log_post)
    mean_mu = np.sqrt(abar) * (post @ means)
    return (np.sqrt(1.0 - abar) / v * (x - mean_mu)).astype(np.float32)


def eps_cfg(x, abar, weights, means, data_std, guidance):
    """Classifier-free-guided eps (uncond = uniform weights)."""
    k = means.shape[0]
    e_c = eps_single(x, abar, weights, means, data_std)
    if abs(guidance - 1.0) < 1e-9:
        return e_c
    e_u = eps_single(x, abar, np.full(k, 1.0 / k), means, data_std)
    return e_u + guidance * (e_c - e_u)


def sd_analog_means() -> np.ndarray:
    """The SD-analog component means (the shape templates)."""
    return dataset.all_templates()


SD_ANALOG_STD = 0.15
