"""Pallas fused attention kernel (L1) — the DiT-tiny compute hot-spot.

GPU papers fuse QK^T->softmax->V into one CUDA kernel over threadblocks; the
TPU/Pallas rethink (DESIGN.md §Hardware-Adaptation) tiles the (batch*heads)
axis over the Pallas grid and keeps each tile's [TB, N, Dh] blocks resident
in VMEM. At DiT-tiny sizes (N=16, Dh=16, TB=64) a tile is ~200 KB — well
under the ~16 MB VMEM budget — and both matmuls are MXU-shaped.

PERF (EXPERIMENTS.md §Perf, L1 iteration 1): interpret-mode pallas_call costs
~0.35 ms of interpreter overhead *per grid step*, so the original
one-(batch,head)-per-step layout made eps_batch_100 cost 570 ms (1600 grid
steps). Tiling TB=64 pairs per step cuts the grid to ~25 steps for the same
math. On real TPU hardware the same change improves MXU occupancy: a single
[16,16]x[16,16] matmul underfills the 128x128 systolic array, while the
batched tile keeps 64 of them in flight per step.

Lowered with ``interpret=True``: the CPU PJRT client cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md), so the kernel runs through
the Pallas interpreter while keeping the identical block structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# (batch*heads) pairs processed per grid step.
TILE_BH = 64


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps the grid exact)."""
    for cand in range(min(n, target), 0, -1):
        if n % cand == 0:
            return cand
    return n


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    # One grid step = TB (batch, head) pairs; refs are [TB, N, Dh] in VMEM.
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    dh = q.shape[-1]
    scores = jnp.einsum("bnd,bmd->bnm", q, k) / jnp.sqrt(jnp.float32(dh))
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.einsum("bnm,bmd->bnd", probs, v)


@jax.custom_vjp
def attention(q, k, v):
    """Fused attention. q,k,v: [B, H, N, Dh] float32 -> [B, H, N, Dh].

    Forward runs the Pallas kernel; the backward pass (training only — the
    AOT inference artifacts never differentiate) uses the jnp reference via
    custom_vjp, since interpret-mode pallas_call does not support
    reverse-mode autodiff.
    """
    return _attention_pallas(q, k, v)


def _attention_pallas(q, k, v):
    b, h, n, dh = q.shape
    bh = b * h
    tb = _pick_block(bh, TILE_BH)
    grid = (bh // tb,)
    qf = q.reshape(bh, n, dh)
    kf = k.reshape(bh, n, dh)
    vf = v.reshape(bh, n, dh)
    spec = pl.BlockSpec((tb, n, dh), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        _attn_kernel,
        out_shape=jax.ShapeDtypeStruct((bh, n, dh), q.dtype),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, n, dh)


def _attention_fwd(q, k, v):
    return _attention_pallas(q, k, v), (q, k, v)


def _attention_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(ref.attention_ref, q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)
