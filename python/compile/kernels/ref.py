"""Pure-jnp oracles for every Pallas kernel (the L1 correctness contract).

Each function here is the mathematical definition the corresponding kernel
in this package must reproduce; ``python/tests/test_kernels.py`` sweeps
shapes with hypothesis and asserts allclose.
"""

from __future__ import annotations

import jax.numpy as jnp


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention_ref(q, k, v):
    """Scaled-dot-product attention. q,k,v: [B, H, N, Dh] -> [B, H, N, Dh]."""
    dh = q.shape[-1]
    scores = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(jnp.float32(dh))
    probs = _softmax(scores)
    return jnp.einsum("bhnm,bhmd->bhnd", probs, v)


def banded_combine_ref(s_mat, x_ext, b_mat, eps, xi_comb):
    """Order-k combine (eq. 9 as dense banded matrices):

    F = S @ x_ext + B @ eps + xi_comb
    s_mat,b_mat: [W, C]; x_ext,eps: [C, D]; xi_comb: [W, D] -> [W, D].
    """
    return s_mat @ x_ext + b_mat @ eps + xi_comb


def row_grams_ref(dF, R):
    """Per-row history Grams and projections (pre-suffix-scan):

    g[w] = dF[:, w, :] @ dF[:, w, :].T   (m x m)
    b[w] = dF[:, w, :] @ R[w]            (m)
    dF: [m, W, D]; R: [W, D] -> (g: [W, m, m], b: [W, m]).
    """
    g = jnp.einsum("awd,bwd->wab", dF, dF)
    b = jnp.einsum("awd,wd->wa", dF, R)
    return g, b


def suffix_scan_ref(g, b):
    """Reverse (suffix) cumulative sums over the window axis:

    G[t] = sum_{j>=t} g[j];  Bv[t] = sum_{j>=t} b[j].
    """
    G = jnp.cumsum(g[::-1], axis=0)[::-1]
    Bv = jnp.cumsum(b[::-1], axis=0)[::-1]
    return G, Bv


def taa_apply_ref(x, R, dX, dF, gamma, mask):
    """The TAA state update given per-row coefficients γ (Thm 3.2):

    x_new[w] = x[w] + mask[w] * (R[w] - sum_h gamma[w,h]*(dX[h,w]+dF[h,w]))
    x,R: [W, D]; dX,dF: [m, W, D]; gamma: [W, m]; mask: [W].
    """
    corr = jnp.einsum("wm,mwd->wd", gamma, dX + dF)
    return x + mask[:, None] * (R - corr)


def cramer_solve_ref(G, b, lam):
    """Batched ridge solve (G + scale*I) γ = b for m ≤ 3 via Cramer's rule
    (no LAPACK custom-calls — keeps the lowered HLO loadable by XLA 0.5.1).
    Ridge is scale-aware: lam * (1 + trace(G)/m), matching the Rust solver.
    G: [W, m, m]; b: [W, m] -> [W, m].
    """
    m = G.shape[-1]
    tr = jnp.trace(G, axis1=-2, axis2=-1)
    scale = lam * (1.0 + tr / m)
    A = G + scale[:, None, None] * jnp.eye(m, dtype=G.dtype)[None]
    if m == 1:
        return b / A[:, 0, 0][:, None]
    if m == 2:
        det = A[:, 0, 0] * A[:, 1, 1] - A[:, 0, 1] * A[:, 1, 0]
        g0 = (b[:, 0] * A[:, 1, 1] - b[:, 1] * A[:, 0, 1]) / det
        g1 = (A[:, 0, 0] * b[:, 1] - A[:, 1, 0] * b[:, 0]) / det
        return jnp.stack([g0, g1], axis=-1)
    if m == 3:
        def det3(M):
            return (
                M[:, 0, 0] * (M[:, 1, 1] * M[:, 2, 2] - M[:, 1, 2] * M[:, 2, 1])
                - M[:, 0, 1] * (M[:, 1, 0] * M[:, 2, 2] - M[:, 1, 2] * M[:, 2, 0])
                + M[:, 0, 2] * (M[:, 1, 0] * M[:, 2, 1] - M[:, 1, 1] * M[:, 2, 0])
            )

        det = det3(A)
        cols = []
        for i in range(3):
            Ai = A.at[:, :, i].set(b)
            cols.append(det3(Ai) / det)
        return jnp.stack(cols, axis=-1)
    raise NotImplementedError(f"cramer_solve_ref supports m<=3, got {m}")
