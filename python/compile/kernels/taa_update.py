"""Pallas kernels for the Triangular Anderson Acceleration update (L1).

The TAA update (Theorem 3.2) needs, per window row t, the *suffix* Gram
G_t = Σ_{j≥t} ΔF_jᵀΔF_j and projection b_t = Σ_{j≥t} ΔF_jᵀR_j. We split the
work into the two shapes that map well onto the TPU:

1. ``row_grams`` — per-row m×m Grams and m-projections, embarrassingly
   parallel over the window (Pallas grid over W-tiles, D reduced in-tile).
   m ≤ 3, so a whole tile of Grams is a few hundred bytes of VMEM.
2. the reverse cumulative (suffix) sum — a bandwidth-trivial O(W·m²) scan
   left to XLA (`jnp.cumsum` on the reversed axis), which fuses with the
   surrounding graph; putting a sequential carry inside a Pallas grid would
   serialize the kernel for no bandwidth win at these sizes.
3. ``taa_apply`` — the masked state update
   x ← x + mask·(R − Σ_h γ_h(ΔX_h + ΔF_h)), elementwise over [W, D]
   (Pallas grid over W×D tiles).

The m×m ridge solve between (2) and (3) uses Cramer's rule in plain jnp
(`ref.cramer_solve_ref`) — deliberately *not* `jnp.linalg.solve`, whose
LAPACK custom-calls the XLA 0.5.1 text loader cannot resolve.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_block(n: int, target: int) -> int:
    for cand in range(min(n, target), 0, -1):
        if n % cand == 0:
            return cand
    return n


# --- 1. per-row Grams -------------------------------------------------------


def _row_gram_kernel(df_ref, r_ref, g_ref, b_ref):
    # df_ref: [m, BW, D], r_ref: [BW, D] -> g_ref: [BW, m, m], b_ref: [BW, m]
    df = df_ref[...]
    r = r_ref[...]
    g_ref[...] = jnp.einsum("awd,bwd->wab", df, df)
    b_ref[...] = jnp.einsum("awd,wd->wa", df, r)


def row_grams(dF, R):
    """Per-row Grams. dF: [m, W, D]; R: [W, D] -> ([W, m, m], [W, m])."""
    m, w, d = dF.shape
    bw = _pick_block(w, 32)
    grid = (w // bw,)
    return pl.pallas_call(
        _row_gram_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((w, m, m), dF.dtype),
            jax.ShapeDtypeStruct((w, m), dF.dtype),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bw, d), lambda i: (0, i, 0)),
            pl.BlockSpec((bw, d), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bw, m, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((bw, m), lambda i: (i, 0)),
        ),
        interpret=True,
    )(dF, R)


# --- 3. masked state update --------------------------------------------------


def _apply_kernel(x_ref, r_ref, dx_ref, df_ref, gamma_ref, mask_ref, o_ref):
    x = x_ref[...]
    r = r_ref[...]
    hist = dx_ref[...] + df_ref[...]  # [m, BW, BD]
    gamma = gamma_ref[...]  # [BW, m]
    corr = jnp.einsum("wm,mwd->wd", gamma, hist)
    mask = mask_ref[...][:, None]
    o_ref[...] = x + mask * (r - corr)


def taa_apply(x, R, dX, dF, gamma, mask):
    """x + mask·(R − Σ_h γ_h(ΔX_h+ΔF_h)).

    x, R: [W, D]; dX, dF: [m, W, D]; gamma: [W, m]; mask: [W] -> [W, D].
    """
    m, w, d = dX.shape
    bw = _pick_block(w, 32)
    bd = _pick_block(d, 128)
    grid = (w // bw, d // bd)
    return pl.pallas_call(
        _apply_kernel,
        out_shape=jax.ShapeDtypeStruct((w, d), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bw, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bw, bd), lambda i, j: (i, j)),
            pl.BlockSpec((m, bw, bd), lambda i, j: (0, i, j)),
            pl.BlockSpec((m, bw, bd), lambda i, j: (0, i, j)),
            pl.BlockSpec((bw, m), lambda i, j: (i, 0)),
            pl.BlockSpec((bw,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bw, bd), lambda i, j: (i, j)),
        interpret=True,
    )(x, R, dX, dF, gamma, mask)


# --- full update (kernels + scan + solve composed) ---------------------------


def taa_update(x, R, dX, dF, mask, lam, safeguard_row=None):
    """Complete TAA update over a window.

    x, R: [W, D]; dX, dF: [m, W, D]; mask: [W] (1.0 = active row);
    lam: ridge; safeguard_row: optional row index forced to the plain FP
    step (Theorem 3.6).  Returns x_new [W, D].
    """
    g, b = row_grams(dF, R)
    G, Bv = ref.suffix_scan_ref(g, b)
    gamma = ref.cramer_solve_ref(G, Bv, lam)
    if safeguard_row is not None:
        gamma = gamma * (1.0 - jax.nn.one_hot(safeguard_row, gamma.shape[0], dtype=gamma.dtype))[:, None]
    return taa_apply(x, R, dX, dF, gamma, mask)
