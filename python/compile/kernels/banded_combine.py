"""Pallas banded order-k combine kernel (L1) — eq. (9) as dense matmuls.

Computes F = S·x_ext + B·eps + ξ̄ over the window. Because S and B carry the
order-k band structure as *data*, one compiled artifact serves every k and
every boundary position (DESIGN.md §Hardware-Adaptation).

Tiling: grid over (window rows / BW, feature lanes / BD); each step loads an
[BW, C] strip of both banded matrices and a [C, BD] panel of the state/eps
stacks — the HBM→VMEM schedule a GPU implementation would express with
threadblocks. At W=100, C=101, D=256 the per-step VMEM footprint is
2·BW·C + 2·C·BD + 3·BW·BD floats ≈ 214 KB for BW=25, BD=128 — comfortably
inside VMEM, with BD=128 matching the MXU lane width.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(s_ref, x_ref, b_ref, e_ref, xi_ref, o_ref):
    s = s_ref[...]
    x = x_ref[...]
    b = b_ref[...]
    e = e_ref[...]
    o_ref[...] = jnp.dot(s, x) + jnp.dot(b, e) + xi_ref[...]


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is ≤ target (keeps the grid exact)."""
    for cand in range(min(n, target), 0, -1):
        if n % cand == 0:
            return cand
    return n


def banded_combine(s_mat, x_ext, b_mat, eps, xi_comb):
    """F = S @ x_ext + B @ eps + xi_comb.

    s_mat, b_mat: [W, C]; x_ext, eps: [C, D]; xi_comb: [W, D] -> [W, D].
    """
    w, c = s_mat.shape
    d = x_ext.shape[1]
    bw = _pick_block(w, 32)
    bd = _pick_block(d, 128)
    grid = (w // bw, d // bd)
    return pl.pallas_call(
        _combine_kernel,
        out_shape=jax.ShapeDtypeStruct((w, d), s_mat.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bw, c), lambda i, j: (i, 0)),  # S strip
            pl.BlockSpec((c, bd), lambda i, j: (0, j)),  # x_ext panel
            pl.BlockSpec((bw, c), lambda i, j: (i, 0)),  # B strip
            pl.BlockSpec((c, bd), lambda i, j: (0, j)),  # eps panel
            pl.BlockSpec((bw, bd), lambda i, j: (i, j)),  # xi tile
        ],
        out_specs=pl.BlockSpec((bw, bd), lambda i, j: (i, j)),
        interpret=True,
    )(s_mat, x_ext, b_mat, eps, xi_comb)
