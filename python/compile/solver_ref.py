"""Reference (numpy) implementation of the parallel solvers — the oracle
mirroring ``rust/src/solver/``: sequential rollout, order-k fixed point,
and Triangular Anderson Acceleration with safeguard and boundary clamping.

Semantics are kept in lockstep with the Rust driver so that the exported
test vectors (``aot.py``) pin both sides:
  * equations clamp t_k at the frozen boundary (first frozen state),
  * thresholds are eps_p = tol^2 * g2[p] * d,
  * paper's m counts the iterate window => m-1 difference columns,
  * the safeguard forces the top unconverged row to a plain FP step.
"""

from __future__ import annotations

import numpy as np


def sequential(coeffs, eps_fn, xi):
    """Roll out eq. (6). xi: [T+1, D]; returns xs: [T+1, D]."""
    a, b, c = coeffs["a"], coeffs["b"], coeffs["c"]
    train_t = coeffs["train_t"]
    steps = len(a) - 1
    d = xi.shape[1]
    xs = np.zeros((steps + 1, d), np.float32)
    xs[steps] = xi[steps]
    for t in range(steps, 0, -1):
        e = eps_fn(xs[t][None, :], np.array([train_t[t]]))[0]
        xs[t - 1] = a[t] * xs[t] + b[t] * e + c[t - 1] * xi[t - 1]
    return xs


def _abar(a, i, s):
    return 1.0 if s < i else float(np.prod(a[i : s + 1]))


def eval_fk(coeffs, xs, eps, xi, k, boundary, p):
    """F_p^{(k)} with boundary clamp — mirror of equations::eval_fk."""
    a, b, c = coeffs["a"], coeffs["b"], coeffs["c"]
    t = p + 1
    tk = min(t + k - 1, boundary)
    out = _abar(a, t, tk) * xs[tk].astype(np.float64)
    for j in range(t, tk + 1):
        ab = _abar(a, t, j - 1)
        out = out + (ab * b[j]) * eps[j] + (ab * c[j - 1]) * xi[j - 1]
    return out.astype(np.float32)


def solve_parallel(
    coeffs,
    eps_fn,
    xi,
    x_init,
    k,
    method="taa",
    m=3,
    lam=1e-4,
    tol=1e-3,
    s_max=200,
    safeguard=True,
):
    """Full-window parallel solve. Returns (xs, iterations, records).

    eps_fn(batch_x [N, D], batch_t [N]) -> [N, D].
    method: "fp" | "taa".
    """
    a, b, c = coeffs["a"], coeffs["b"], coeffs["c"]
    train_t, g2 = coeffs["train_t"], coeffs["g2"]
    steps = len(a) - 1
    d = xi.shape[1]
    xs = np.zeros((steps + 1, d), np.float32)
    xs[steps] = xi[steps]
    xs[:steps] = x_init
    eps = np.zeros((steps + 1, d), np.float32)
    thresholds = tol * tol * g2 * d

    hist_cols = 0 if method == "fp" else max(m - 1, 0)
    dX: list[np.ndarray] = []
    dF: list[np.ndarray] = []
    prev_x = None
    prev_r = None

    t2 = steps - 1
    records = []
    for it in range(1, s_max + 1):
        # One parallel round of eps.
        idx = np.arange(1, t2 + 2)
        eps[idx] = eps_fn(xs[idx], train_t[idx])
        # Residuals + front.
        r = xs[: t2 + 1] - (
            a[1 : t2 + 2, None] * xs[1 : t2 + 2]
            + b[1 : t2 + 2, None] * eps[1 : t2 + 2]
            + c[: t2 + 1, None] * xi[: t2 + 1]
        )
        rsq = np.sum(r.astype(np.float64) ** 2, axis=1)
        records.append(float(np.sum(rsq)))
        unconverged = np.nonzero(rsq > thresholds[: t2 + 1])[0]
        if len(unconverged) == 0:
            return xs, it, records
        t2 = int(unconverged[-1])
        boundary = t2 + 1

        # F^{(k)} and R over the active rows.
        f_vals = np.zeros((steps, d), np.float32)
        r_vals = np.zeros((steps, d), np.float32)
        for p in range(0, t2 + 1):
            f_vals[p] = eval_fk(coeffs, xs, eps, xi, k, boundary, p)
            r_vals[p] = f_vals[p] - xs[p]

        # History push.
        if hist_cols > 0 and prev_x is not None:
            dX.append(xs[:steps] - prev_x)
            dF.append(r_vals - prev_r)
            if len(dX) > hist_cols:
                dX.pop(0)
                dF.pop(0)
        if hist_cols > 0:
            prev_x = xs[:steps].copy()
            prev_r = r_vals.copy()

        # Update.
        if method == "fp" or not dX:
            xs[: t2 + 1] = f_vals[: t2 + 1]
        else:
            mcols = len(dX)
            dXs = np.stack(dX)  # [mcols, steps, d]
            dFs = np.stack(dF)
            # Suffix Grams (float64 accumulation like the Rust side).
            g_rows = np.einsum("awd,bwd->wab", dFs.astype(np.float64), dFs.astype(np.float64))
            b_rows = np.einsum("awd,wd->wa", dFs.astype(np.float64), r_vals.astype(np.float64))
            G = np.cumsum(g_rows[::-1], axis=0)[::-1]
            Bv = np.cumsum(b_rows[::-1], axis=0)[::-1]
            for p in range(0, t2 + 1):
                if safeguard and p == t2:
                    xs[p] = f_vals[p]
                    continue
                tr = np.trace(G[p])
                A = G[p] + lam * (1.0 + tr / mcols) * np.eye(mcols)
                try:
                    gamma = np.linalg.solve(A, Bv[p])
                except np.linalg.LinAlgError:
                    xs[p] = f_vals[p]
                    continue
                corr = np.einsum("m,md->d", gamma, (dXs[:, p] + dFs[:, p]).astype(np.float64))
                xs[p] = (xs[p] + r_vals[p] - corr).astype(np.float32)
    return xs, s_max, records
