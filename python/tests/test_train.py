"""Training smoke: loss decreases, save/load roundtrip."""

import numpy as np

from compile import train


def test_short_training_decreases_loss(tmp_path):
    params, log = train.train(steps=40, batch=32, lr=2e-3, verbose=False, log_every=10)
    losses = [l for _, l in log]
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"

    # save/load roundtrip preserves every tensor bit-exactly.
    p = tmp_path / "w.npz"
    train.save_params(str(p), params)
    loaded = train.load_params(str(p))
    flat_a = train.flatten_params(params)
    flat_b = train.flatten_params(loaded)
    assert flat_a.keys() == flat_b.keys()
    for k in flat_a:
        np.testing.assert_array_equal(flat_a[k], flat_b[k])
