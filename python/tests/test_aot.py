"""AOT export contract: HLO text is loadable-grade (full constants, tuple
return) and the solver_step graph matches the solver math."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot


def test_hlo_text_prints_large_constants():
    w = jnp.asarray(np.arange(4096, dtype=np.float32).reshape(64, 64))

    def fn(x):
        return (x @ w,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 64), jnp.float32))
    txt = aot.to_hlo_text(lowered)
    assert "constant({...})" not in txt, "weights must be printed in full"
    assert "ROOT" in txt


def test_solver_step_fn_fp_degenerate():
    # With zero history and fp_mask=0, the step must be the plain FP update
    # x_new = F = S x + B eps + xi on masked rows.
    rng = np.random.default_rng(0)
    t, w, d, mc = 6, 6, 8, 2
    c = t + 1
    f32 = jnp.float32
    xs = jnp.asarray(rng.standard_normal((c, d)), f32)
    eps = jnp.asarray(rng.standard_normal((c, d)), f32)
    x_win = jnp.asarray(np.asarray(xs[:w]))
    s = jnp.asarray(rng.standard_normal((w, c)), f32)
    b = jnp.asarray(rng.standard_normal((w, c)), f32)
    xi = jnp.asarray(rng.standard_normal((w, d)), f32)
    zeros = jnp.zeros((mc, w, d), f32)
    mask = jnp.ones((w,), f32)
    fp_mask = jnp.zeros((w,), f32)
    x_new, r_vec, r1 = aot.solver_step_fn(
        xs, eps, x_win, s, b, xi, s, b, xi, zeros, zeros, mask, fp_mask, jnp.float32(1e-4)
    )
    expect_f = np.asarray(s) @ np.asarray(xs) + np.asarray(b) @ np.asarray(eps) + np.asarray(xi)
    np.testing.assert_allclose(np.asarray(x_new), expect_f, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(r_vec), expect_f - np.asarray(x_win), atol=1e-4, rtol=1e-4)
    # r1 is the first-order residual norm per row (same matrices here).
    expect_r1 = np.sum((np.asarray(x_win) - expect_f) ** 2, axis=1)
    np.testing.assert_allclose(np.asarray(r1), expect_r1, atol=1e-3, rtol=1e-3)


def test_solver_step_fn_mask_freezes_rows():
    rng = np.random.default_rng(1)
    t, w, d, mc = 4, 4, 4, 2
    c = t + 1
    f32 = jnp.float32
    xs = jnp.asarray(rng.standard_normal((c, d)), f32)
    eps = jnp.asarray(rng.standard_normal((c, d)), f32)
    x_win = jnp.asarray(np.asarray(xs[:w]))
    s = jnp.asarray(rng.standard_normal((w, c)), f32)
    b = jnp.asarray(rng.standard_normal((w, c)), f32)
    xi = jnp.asarray(rng.standard_normal((w, d)), f32)
    hist = jnp.asarray(rng.standard_normal((mc, w, d)), f32)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0], f32)
    fp_mask = jnp.zeros((w,), f32)
    x_new, _, _ = aot.solver_step_fn(
        xs, eps, x_win, s, b, xi, s, b, xi, hist, hist, mask, fp_mask, jnp.float32(1e-4)
    )
    out = np.asarray(x_new)
    np.testing.assert_array_equal(out[1], np.asarray(x_win)[1])
    np.testing.assert_array_equal(out[3], np.asarray(x_win)[3])
    assert not np.allclose(out[0], np.asarray(x_win)[0])
