"""Schedule/coefficient identities (mirrors rust/src/schedule tests)."""

import numpy as np

from compile import schedule


def test_linear_betas_endpoints():
    b = schedule.linear_betas()
    assert abs(b[0] - 1e-4) < 1e-12
    assert abs(b[-1] - 0.02) < 1e-12
    assert np.all(np.diff(b) > 0)


def test_alpha_bar_telescopes():
    b = schedule.linear_betas()
    ab = schedule.alpha_bars(b)
    acc = 1.0
    for i in [0, 1, 10, 500, 999]:
        acc = np.prod(1.0 - b[: i + 1])
        assert abs(ab[i] - acc) < 1e-14


def test_ddim_signal_preservation():
    cs = schedule.sampler_coeffs(50, eta=0.0)
    b = schedule.linear_betas()
    ab = schedule.alpha_bars(b)
    taus = schedule.subset_timesteps(1000, 50)
    for t in range(1, 51):
        hi = ab[taus[t - 1]]
        lo = ab[taus[t - 2]] if t >= 2 else 1.0
        assert abs(cs["a"][t] * np.sqrt(hi) - np.sqrt(lo)) < 1e-12
        assert abs(cs["a"][t] * np.sqrt(1 - hi) + cs["b"][t] - np.sqrt(1 - lo)) < 1e-12


def test_ddpm_variance_preservation():
    cs = schedule.sampler_coeffs(100, eta=1.0)
    b = schedule.linear_betas()
    ab = schedule.alpha_bars(b)
    taus = schedule.subset_timesteps(1000, 100)
    for t in range(2, 101):
        hi, lo = ab[taus[t - 1]], ab[taus[t - 2]]
        direction = cs["a"][t] * np.sqrt(1 - hi) + cs["b"][t]
        total = direction**2 + cs["c"][t - 1] ** 2
        assert abs(total - (1 - lo)) < 1e-10


def test_eta_scales_noise():
    half = schedule.sampler_coeffs(50, eta=0.5)
    full = schedule.sampler_coeffs(50, eta=1.0)
    np.testing.assert_allclose(half["c"], 0.5 * full["c"], atol=1e-14)


def test_ddim_is_deterministic():
    cs = schedule.sampler_coeffs(25, eta=0.0)
    assert np.all(cs["c"] == 0.0)
