"""Template/dataset invariants (mirrors rust/src/model/templates.rs tests)."""

import numpy as np

from compile import dataset


def test_templates_binary_and_distinct():
    ts = dataset.all_templates()
    assert ts.shape == (8, 256)
    for i, t in enumerate(ts):
        assert set(np.unique(t)) <= {np.float32(dataset.FG), np.float32(dataset.BG)}
        fg = np.sum(t == dataset.FG)
        assert 10 < fg < 246, f"class {i}"
    for i in range(8):
        for j in range(i + 1, 8):
            assert np.sum(ts[i] != ts[j]) > 8


def test_circle_symmetry():
    t = dataset.template(0).reshape(16, 16)
    np.testing.assert_array_equal(t, t[:, ::-1])
    np.testing.assert_array_equal(t, t[::-1, :])


def test_make_batch_shapes_and_noise():
    rng = np.random.default_rng(0)
    x, y = dataset.make_batch(rng, 64)
    assert x.shape == (64, 256) and y.shape == (64,)
    assert y.min() >= 0 and y.max() < 8
    # samples should be near their templates
    temps = dataset.all_templates()
    d = np.linalg.norm(x - temps[y], axis=1)
    assert np.all(d < 5.0)  # E[d] = sqrt(256)*0.15 = 2.4


def test_class_wraps():
    np.testing.assert_array_equal(dataset.template(0), dataset.template(8))
