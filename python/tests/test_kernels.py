"""L1 correctness: Pallas kernels vs pure-jnp oracles, swept with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.banded_combine import banded_combine
from compile.kernels.taa_update import row_grams, taa_apply, taa_update

SETTINGS = dict(max_examples=20, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    n=st.sampled_from([4, 8, 16]),
    dh=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, n, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, b, h, n, dh) for _ in range(3))
    out = attention(q, k, v)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5, rtol=1e-5)


@settings(**SETTINGS)
@given(
    w=st.sampled_from([1, 7, 10, 25]),
    c=st.integers(1, 30),
    d=st.sampled_from([1, 8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_banded_combine_matches_ref(w, c, d, seed):
    rng = np.random.default_rng(seed)
    s, b = rand(rng, w, c), rand(rng, w, c)
    x, e = rand(rng, c, d), rand(rng, c, d)
    xi = rand(rng, w, d)
    out = banded_combine(s, x, b, e, xi)
    expect = ref.banded_combine_ref(s, x, b, e, xi)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 3),
    w=st.sampled_from([1, 5, 12]),
    d=st.sampled_from([1, 4, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_grams_matches_ref(m, w, d, seed):
    rng = np.random.default_rng(seed)
    dF = rand(rng, m, w, d)
    R = rand(rng, w, d)
    g, b = row_grams(dF, R)
    ge, be = ref.row_grams_ref(dF, R)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ge), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(be), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 3),
    w=st.sampled_from([1, 6, 10]),
    d=st.sampled_from([1, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_taa_apply_matches_ref(m, w, d, seed):
    rng = np.random.default_rng(seed)
    x, R = rand(rng, w, d), rand(rng, w, d)
    dX, dF = rand(rng, m, w, d), rand(rng, m, w, d)
    gamma = rand(rng, w, m)
    mask = jnp.asarray(rng.integers(0, 2, w), jnp.float32)
    out = taa_apply(x, R, dX, dF, gamma, mask)
    expect = ref.taa_apply_ref(x, R, dX, dF, gamma, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(m=st.integers(1, 3), w=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_cramer_solve_is_a_solve(m, w, seed):
    rng = np.random.default_rng(seed)
    # SPD Gram + ridge: verify (G + scale I) gamma == b.
    base = rng.standard_normal((w, m, m + 2))
    G = jnp.asarray(np.einsum("wmk,wnk->wmn", base, base), jnp.float32)
    b = rand(rng, w, m)
    lam = 1e-3
    gamma = ref.cramer_solve_ref(G, b, lam)
    tr = np.trace(np.asarray(G), axis1=-2, axis2=-1)
    scale = lam * (1 + tr / m)
    A = np.asarray(G) + scale[:, None, None] * np.eye(m)
    recon = np.einsum("wmn,wn->wm", A, np.asarray(gamma))
    np.testing.assert_allclose(recon, np.asarray(b), atol=1e-3, rtol=1e-3)


def test_attention_mask_free_softmax_rows_sum():
    # soft sanity: output of attention is a convex combination of v rows.
    rng = np.random.default_rng(0)
    q, k = rand(rng, 1, 1, 8, 4), rand(rng, 1, 1, 8, 4)
    v = jnp.ones((1, 1, 8, 4), jnp.float32)
    out = attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.ones((1, 1, 8, 4)), atol=1e-5)


def test_taa_update_zero_history_is_fp():
    rng = np.random.default_rng(1)
    w, d, m = 6, 8, 2
    x, R = rand(rng, w, d), rand(rng, w, d)
    zeros = jnp.zeros((m, w, d), jnp.float32)
    mask = jnp.ones((w,), jnp.float32)
    out = taa_update(x, R, zeros, zeros, mask, 1e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x + R), atol=1e-5)


def test_taa_update_safeguard_row_is_fp():
    rng = np.random.default_rng(2)
    w, d, m = 5, 4, 2
    x, R = rand(rng, w, d), rand(rng, w, d)
    dX, dF = rand(rng, m, w, d), rand(rng, m, w, d)
    mask = jnp.ones((w,), jnp.float32)
    out = taa_update(x, R, dX, dF, mask, 1e-4, safeguard_row=w - 1)
    np.testing.assert_allclose(
        np.asarray(out)[w - 1], np.asarray(x + R)[w - 1], atol=1e-5
    )
