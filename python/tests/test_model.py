"""DiT-tiny model contract tests (L2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


def test_shapes(params):
    x = jnp.zeros((4, model.DIM))
    t = jnp.array([0, 1, 500, 999], jnp.int32)
    y = jnp.array([0, 7, 8, 3], jnp.int32)
    assert model.eps_raw(params, x, t, y).shape == (4, model.DIM)
    assert model.eps_cfg(params, x, t, y, jnp.float32(5.0)).shape == (4, model.DIM)


def test_cfg_guidance_one_equals_conditional(params):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, model.DIM)), jnp.float32)
    t = jnp.array([100, 800], jnp.int32)
    y = jnp.array([2, 5], jnp.int32)
    cfg = model.eps_cfg(params, x, t, y, jnp.float32(1.0))
    raw = model.eps_raw(params, x, t, y)
    np.testing.assert_allclose(np.asarray(cfg), np.asarray(raw), atol=1e-5)


def test_cfg_null_class_collapses(params):
    # For y = NULL the guided output equals the unconditional one for any g.
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, model.DIM)), jnp.float32)
    t = jnp.array([400], jnp.int32)
    y = jnp.array([model.NULL_CLASS], jnp.int32)
    g5 = model.eps_cfg(params, x, t, y, jnp.float32(5.0))
    g1 = model.eps_cfg(params, x, t, y, jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(g5), np.asarray(g1), atol=1e-4)


def test_cfg_is_affine_in_guidance(params):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, model.DIM)), jnp.float32)
    t = jnp.array([300], jnp.int32)
    y = jnp.array([1], jnp.int32)
    e1 = np.asarray(model.eps_cfg(params, x, t, y, jnp.float32(1.0)))
    e3 = np.asarray(model.eps_cfg(params, x, t, y, jnp.float32(3.0)))
    e5 = np.asarray(model.eps_cfg(params, x, t, y, jnp.float32(5.0)))
    np.testing.assert_allclose(e5 - e3, 2 * (e3 - e1) / 2 * 2, atol=1e-4)


def test_different_classes_differ_after_blocks(params):
    # zero-init adaLN makes blocks near-identity at init, but the final
    # modulation still sees the class embedding; with trained weights the
    # difference is large. At init we only require determinism.
    x = jnp.zeros((1, model.DIM))
    t = jnp.array([500], jnp.int32)
    a = model.eps_raw(params, x, t, jnp.array([0], jnp.int32))
    b = model.eps_raw(params, x, t, jnp.array([0], jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_patchify_roundtrip():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, model.DIM)), jnp.float32)
    tok = model._patchify(x)
    assert tok.shape == (2, model.N_TOKENS, model.PATCH_DIM)
    back = model._unpatchify(tok)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_param_count_reasonable(params):
    n = sum(v.size for v in jax.tree_util.tree_leaves(params))
    assert 100_000 < n < 500_000
