"""Python solver reference: equivalence + convergence ordering."""

import numpy as np
import pytest

from compile import gmm, schedule, solver_ref


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(5)
    k, d = 3, 8
    means = (2.0 * rng.random((k, d)) - 1.0).astype(np.float32)
    std = 0.25
    betas = schedule.linear_betas()
    abars = schedule.alpha_bars(betas)
    weights = np.array([1.0, 0.0, 0.0], np.float32)

    def eps_fn(xs, ts):
        return np.stack(
            [gmm.eps_cfg(x, abars[t], weights, means, std, 2.0) for x, t in zip(xs, ts)]
        )

    steps = 16
    coeffs = schedule.sampler_coeffs(steps, eta=0.0)
    xi = rng.standard_normal((steps + 1, d)).astype(np.float32)
    x_init = rng.standard_normal((steps, d)).astype(np.float32)
    return coeffs, eps_fn, xi, x_init, d, steps


def test_fp_matches_sequential(setup):
    coeffs, eps_fn, xi, x_init, d, steps = setup
    seq = solver_ref.sequential(coeffs, eps_fn, xi)
    xs, iters, _ = solver_ref.solve_parallel(
        coeffs, eps_fn, xi, x_init, k=4, method="fp", tol=1e-4, s_max=100
    )
    assert iters < 100
    np.testing.assert_allclose(xs[0], seq[0], atol=5e-3, rtol=5e-2)


def test_taa_matches_sequential_and_is_faster(setup):
    coeffs, eps_fn, xi, x_init, d, steps = setup
    seq = solver_ref.sequential(coeffs, eps_fn, xi)
    xs_t, it_t, _ = solver_ref.solve_parallel(
        coeffs, eps_fn, xi, x_init, k=4, method="taa", m=3, tol=1e-4, s_max=100
    )
    _, it_f, _ = solver_ref.solve_parallel(
        coeffs, eps_fn, xi, x_init, k=4, method="fp", tol=1e-4, s_max=100
    )
    np.testing.assert_allclose(xs_t[0], seq[0], atol=5e-3, rtol=5e-2)
    # At T=16 both methods sit near the structural lower bound, so TAA's
    # advantage (paper Fig. 2, T=100) is not asserted strictly here — the
    # large-T ordering is covered by the Rust suite and the fig2 harness.
    assert it_t <= it_f + 3


def test_residuals_decrease(setup):
    coeffs, eps_fn, xi, x_init, d, steps = setup
    _, _, rec = solver_ref.solve_parallel(
        coeffs, eps_fn, xi, x_init, k=4, method="taa", m=3, tol=1e-4, s_max=100
    )
    assert rec[-1] < rec[0] * 1e-3


def test_order_k_equivalence_on_solution(setup):
    coeffs, eps_fn, xi, x_init, d, steps = setup
    seq = solver_ref.sequential(coeffs, eps_fn, xi)
    eps = np.zeros_like(seq)
    for t in range(1, steps + 1):
        eps[t] = eps_fn(seq[t][None], np.array([coeffs["train_t"][t]]))[0]
    for k in [1, 3, steps]:
        for p in range(steps):
            f = solver_ref.eval_fk(coeffs, seq, eps, xi, k, steps, p)
            np.testing.assert_allclose(f, seq[p], atol=1e-3, rtol=1e-2)
