//! Pure-analytic sweep: the whole ParaTAA stack on the exact GMM score with
//! no artifacts required — method × sampler × steps matrix with step-count
//! ratios. Useful as a fast sanity sweep of the full solver stack.

use parataa::figures::common::{method_config, ModelChoice, Scenario};
use parataa::model::Cond;
use parataa::schedule::SamplerKind;
use parataa::solver::{self, Method, Problem};
use parataa::util::table::Table;

fn main() {
    let mut t = Table::new(
        "Analytic GMM sweep: parallel rounds vs sequential steps",
        &["sampler", "steps", "method", "rounds", "ratio", "converged"],
    );
    for kind in [SamplerKind::Ddim, SamplerKind::Ddpm] {
        for steps in [25usize, 50, 100] {
            let scenario = Scenario::new(ModelChoice::Gmm, kind, steps);
            let coeffs = scenario.coeffs();
            for method in [Method::FixedPoint, Method::AndersonStd, Method::AndersonUpperTri, Method::Taa] {
                let mut rounds = 0usize;
                let mut conv = true;
                let n = 8;
                for seed in 0..n {
                    let problem =
                        Problem::new(&coeffs, &*scenario.model, Cond::Class(seed as usize % 8), seed);
                    let cfg = method_config(method, steps, None, scenario.guidance);
                    let r = solver::solve(&problem, &cfg);
                    rounds += r.iterations;
                    conv &= r.converged;
                }
                let mean = rounds as f64 / n as f64;
                t.push_row(vec![
                    kind.label(),
                    steps.to_string(),
                    method.label().to_string(),
                    format!("{mean:.1}"),
                    format!("{:.1}x", steps as f64 / mean),
                    conv.to_string(),
                ]);
            }
        }
    }
    println!("{}", t.to_ascii());
    t.write_csv("results/gmm_analytic.csv").unwrap();
    println!("wrote results/gmm_analytic.csv");
}
