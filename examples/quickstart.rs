//! Quickstart: sample one image with ParaTAA and verify it matches the
//! sequential sampler (Remark 5.3), on either backend.
//!
//!   cargo run --release --example quickstart              # analytic GMM
//!   cargo run --release --example quickstart -- dit       # trained DiT (needs `make artifacts`)

use parataa::figures::common::{method_config, ModelChoice, Scenario};
use parataa::metrics::{match_rmse, psnr};
use parataa::model::Cond;
use parataa::schedule::SamplerKind;
use parataa::solver::{self, Method, Problem};

fn main() {
    let model = std::env::args()
        .nth(1)
        .map(|s| ModelChoice::parse(&s))
        .unwrap_or(ModelChoice::Gmm);
    let steps = 100;
    let scenario = Scenario::new(model, SamplerKind::Ddim, steps);
    let coeffs = scenario.coeffs();
    println!("scenario: {} (guidance {})", scenario.label(), scenario.guidance);

    let problem = Problem::new(&coeffs, &*scenario.model, Cond::Class(0), 42);

    // Sequential baseline: 100 serial denoiser calls.
    let t0 = std::time::Instant::now();
    let seq = solver::sample_sequential(&problem, scenario.guidance);
    let seq_time = t0.elapsed();

    // ParaTAA: a handful of parallel rounds.
    let cfg = method_config(Method::Taa, steps, None, scenario.guidance);
    let t0 = std::time::Instant::now();
    let par = solver::solve(&problem, &cfg);
    let par_time = t0.elapsed();

    println!("sequential: {} steps in {seq_time:?}", seq.nfe);
    println!(
        "ParaTAA:    {} parallel rounds ({} NFE) in {par_time:?}  [{}x fewer steps]",
        par.iterations,
        par.total_nfe,
        steps / par.iterations.max(1)
    );
    let rmse = match_rmse(par.xs.row(0), seq.xs.row(0));
    println!("match: RMSE {rmse:.2e}, PSNR {:.1} dB — same image as sequential", psnr(par.xs.row(0), seq.xs.row(0)));
    assert!(par.converged, "solver did not converge");
    assert!(rmse < 0.05, "parallel/sequential mismatch too large");

    parataa::util::image::write_pgm("results/quickstart.pgm", par.xs.row(0), 16, 16).unwrap();
    println!("wrote results/quickstart.pgm");
}
