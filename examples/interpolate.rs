//! Trajectory-initialization interpolation (§5.3 / Fig. 5, 15): solve P1,
//! then solve nearby prompts P2 starting from P1's trajectory at varying
//! T_init, writing PGM strips that show the smooth source→target morph.
//!
//!   cargo run --release --example interpolate -- [dit|gmm]

use parataa::figures::common::{method_config, ModelChoice, Scenario};
use parataa::model::Cond;
use parataa::schedule::SamplerKind;
use parataa::solver::{self, init::init_from_trajectory, Method, Problem};
use parataa::util::image::{hstack, write_pgm};

fn main() {
    let model = std::env::args()
        .nth(1)
        .map(|s| ModelChoice::parse(&s))
        .unwrap_or(ModelChoice::Gmm);
    let steps = 50;
    let scenario = Scenario::new(model, SamplerKind::Ddim, steps);
    let coeffs = scenario.coeffs();
    let cfg = method_config(Method::Taa, steps, None, scenario.guidance);

    // P1: "circle"; P2: a blend drifting toward "ring".
    let p1 = Cond::Class(0);
    let donor_problem = Problem::new(&coeffs, &*scenario.model, p1.clone(), 3);
    let donor = solver::solve(&donor_problem, &cfg);
    println!("P1 solved in {} rounds", donor.iterations);

    for t_init in [steps, 4 * steps / 5, 7 * steps / 10, steps / 2] {
        let mut frames = vec![donor.xs.row(0).to_vec()];
        for blend in [0.2f32, 0.4, 0.6, 0.8, 1.0] {
            let p2 = p1.lerp(&Cond::Class(6), blend, 8);
            let mut problem = Problem::new(&coeffs, &*scenario.model, p2, 3);
            init_from_trajectory(&mut problem, donor.xs.clone(), donor_problem.xi.clone(), t_init);
            let r = solver::solve(&problem, &cfg);
            println!(
                "T_init={t_init} blend={blend:.1}: {} rounds (converged {})",
                r.iterations, r.converged
            );
            frames.push(r.xs.row(0).to_vec());
        }
        let (strip, w, h) = hstack(&frames, 16, 16, 2);
        let path = format!("results/interpolate_tinit{t_init}.pgm");
        write_pgm(&path, &strip, w, h).unwrap();
        println!("wrote {path}");
    }
}
