//! Streaming prefix delivery — watch a solve arrive incrementally.
//!
//! The triangular structure of the ParaTAA system means early denoising
//! timesteps (the x_T side) converge long before the full trajectory
//! does, and the Theorem 3.6 safeguard makes that front monotone: once a
//! row freezes it is final. `Coordinator::submit_streaming` exposes this
//! as a per-request chunk stream — the client receives the converged
//! prefix while the remaining rows are still being solved, and the final
//! chunk delivers the sample row itself.
//!
//! This example submits a few streaming requests, prints each chunk as it
//! lands, and then proves the three properties the streaming layer
//! guarantees:
//!
//! 1. at least one prefix chunk arrives **strictly before** the solve
//!    completes (round < final round);
//! 2. the chunks tile the trajectory `[0, steps)` exactly, top-down;
//! 3. the streamed states are **bit-identical** to a non-streaming run of
//!    the same request (observation never perturbs the solve).
//!
//!   cargo run --release --example serve_stream -- [n_requests] [steps]

use parataa::coordinator::{Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec};
use parataa::model::{gmm::GmmEps, Cond};
use parataa::schedule::{BetaSchedule, NoiseSchedule};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let n_requests: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(50);

    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model = Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()));
    let coord = Coordinator::start(
        model,
        CoordinatorConfig { workers: 2, drivers: 2, ..Default::default() },
    );

    let make_req = |i: usize| {
        let mut req = SampleRequest::parataa(
            Cond::Class(i % 8),
            100 + i as u64,
            SamplerSpec::ddim(steps),
        );
        req.guidance = 2.0; // the analytic score is stiffer than a trained net
        req
    };

    println!("streaming {n_requests} DDIM-{steps} requests ...");
    let threads: Vec<_> = (0..n_requests)
        .map(|i| {
            let handle = coord.submit_streaming(make_req(i));
            std::thread::spawn(move || {
                let t0 = Instant::now();
                let mut chunks = Vec::new();
                while let Some(c) = handle.next_chunk() {
                    println!(
                        "  req {i}: rows [{:>3}, {:>3}) after round {:>2} ({:>9.2?})",
                        c.rows.start,
                        c.rows.end,
                        c.round,
                        t0.elapsed(),
                    );
                    chunks.push(c);
                }
                (chunks, handle.wait().expect("streaming request failed"))
            })
        })
        .collect();

    let mut streamed = Vec::with_capacity(n_requests);
    for (i, t) in threads.into_iter().enumerate() {
        let (chunks, resp) = t.join().expect("consumer panicked");
        assert!(resp.converged, "req {i} did not converge");

        // (1) Some prefix landed strictly before the solve completed.
        let early = chunks.iter().filter(|c| c.round < resp.rounds).count();
        assert!(early >= 1, "req {i}: nothing streamed before completion");

        // (2) The chunks tile [0, steps) exactly, top-down.
        let mut expect_end = steps;
        for c in &chunks {
            assert_eq!(c.rows.end, expect_end, "req {i}: gap/overlap in the stream");
            expect_end = c.rows.start;
        }
        assert_eq!(expect_end, 0, "req {i}: stream never delivered the sample row");

        // The last chunk's first row IS the sample.
        let last = chunks.last().unwrap();
        assert_eq!(&last.states[..resp.sample.len()], &resp.sample[..]);
        println!(
            "req {i}: {} chunks ({early} before completion), {} rounds, {:?}",
            chunks.len(),
            resp.rounds,
            resp.latency
        );
        streamed.push(resp);
    }

    // (3) Bit-identical to the non-streaming path.
    let handles: Vec<_> = (0..n_requests).map(|i| coord.submit(make_req(i))).collect();
    for (i, h) in handles.into_iter().enumerate() {
        let plain = h.wait().expect("verification request failed");
        assert_eq!(plain.sample, streamed[i].sample, "req {i}: streaming changed the solve");
        assert_eq!(plain.rounds, streamed[i].rounds, "req {i}: round count drifted");
        assert_eq!(plain.nfe, streamed[i].nfe, "req {i}: NFE drifted");
    }
    println!("--- streaming verified: bit-identical to the blocking path ---");
    println!("{}", coord.metrics().report());
}
