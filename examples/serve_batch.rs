//! End-to-end serving driver (the DESIGN.md E2E validation run):
//! boot the coordinator over the trained DiT-tiny PJRT artifact (falls back
//! to the analytic GMM without artifacts), submit a mixed concurrent load,
//! and report latency/throughput. Results are recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example serve_batch -- [dit|gmm] [n_requests]

use parataa::coordinator::{Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec};
use parataa::figures::common::{ModelChoice, Scenario};
use parataa::model::Cond;
use parataa::schedule::SamplerKind;
use parataa::solver::Method;
use parataa::util::rng::Pcg64;

fn main() {
    let model = std::env::args()
        .nth(1)
        .map(|s| ModelChoice::parse(&s))
        .unwrap_or(ModelChoice::Gmm);
    let n_requests: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(32);
    let steps = 50;
    let scenario = Scenario::new(model, SamplerKind::Ddim, steps);
    println!("serving {} requests on {}", n_requests, scenario.label());

    // Stack: model -> coordinator round drivers. Every request is a
    // resumable SolverSession; two driver threads carry all of them,
    // merging their per-round eps batches into single device calls.
    let coord = Coordinator::start(
        scenario.model.clone(),
        CoordinatorConfig {
            workers: 2,
            drivers: 2,
            slot_budget: 4 * steps,
            ..Default::default()
        },
    );

    let mut rng = Pcg64::seeded(7);
    let t0 = std::time::Instant::now();

    // Phase 1: fresh prompts (concurrent).
    let phase1 = n_requests - n_requests / 4;
    let phase1_conds: Vec<Cond> =
        (0..phase1).map(|_| Cond::Class(rng.below(8) as usize)).collect();
    let handles: Vec<_> = (0..phase1)
        .map(|i| {
            let mut req = SampleRequest::parataa(
                phase1_conds[i].clone(),
                1000 + i as u64,
                SamplerSpec::ddim(steps),
            );
            req.guidance = scenario.guidance;
            req.use_trajectory_cache = true;
            // Mix methods: mostly ParaTAA, some FP for contrast.
            if i % 8 == 7 {
                req.method = Method::FixedPoint;
            }
            coord.submit(req)
        })
        .collect();
    let mut total_rounds = 0usize;
    let mut warm = 0usize;
    for h in handles {
        let r = h.wait().expect("request failed");
        assert!(r.converged);
        total_rounds += r.rounds;
        warm += r.warm_started as usize;
    }

    // Phase 2: the "user iterates on the prompt" pattern — same seeds,
    // slightly tweaked conditions; these hit the trajectory cache (§4.2).
    let handles: Vec<_> = (0..n_requests / 4)
        .map(|i| {
            let donor = i % phase1;
            let tweak = Cond::Class(rng.below(8) as usize);
            let mut req = SampleRequest::parataa(
                phase1_conds[donor].lerp(&tweak, 0.1, 8),
                1000 + donor as u64,
                SamplerSpec::ddim(steps),
            );
            req.guidance = scenario.guidance;
            req.use_trajectory_cache = true;
            coord.submit(req)
        })
        .collect();
    for h in handles {
        let r = h.wait().expect("request failed");
        assert!(r.converged);
        total_rounds += r.rounds;
        warm += r.warm_started as usize;
    }
    let wall = t0.elapsed();
    let m = coord.metrics();
    println!("--- E2E results ---");
    println!("{}", m.report());
    println!(
        "wall {wall:?} | {:.2} samples/s | mean rounds {:.1} | warm starts {warm}",
        n_requests as f64 / wall.as_secs_f64(),
        total_rounds as f64 / n_requests as f64,
    );
}
