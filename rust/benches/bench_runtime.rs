//! Runtime benchmarks.
//!
//! Part 1 (always runs): device-pool throughput sweep on the in-process
//! backend — rows/sec scaling over devices ∈ {1, 2, 4, 8}. This is the
//! multi-executor speedup the paper gets from sharding each window across
//! 8 GPUs, reproduced with CPU worker threads.
//!
//! Part 2 (`--features pjrt`, artifacts present): eps_batch latency per
//! compiled variant and the fused solver_step artifact. These are the
//! numbers behind Remark 5.1: on CPU a batch-N ε call costs ~N× a batch-1
//! call (no parallel hardware), so wall-clock speedup comes from *round
//! reduction* only; the per-variant latencies quantify that.

use parataa::model::gmm::GmmEps;
use parataa::model::{Cond, EpsModel};
use parataa::runtime::{DevicePool, PoolConfig};
use parataa::schedule::{BetaSchedule, NoiseSchedule};
use parataa::util::rng::Pcg64;
use parataa::util::stats::bench;
use std::sync::Arc;
use std::time::Duration;

fn bench_pool_sweep() {
    println!("--- device pool sweep (in-process backend, 256-dim GMM) ---");
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model: Arc<GmmEps> = Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()));
    let mut rng = Pcg64::seeded(7);

    let rows = 400; // 4×100-row shards at devices=4 (see pool::shard_size)
    let x = rng.gaussian_vec(rows * 256);
    let ts: Vec<usize> = (0..rows).map(|i| (i * 997) % 1000).collect();
    let conds: Vec<Cond> = (0..rows).map(|i| Cond::Class(i % 8)).collect();
    let mut out = vec![0.0f32; rows * 256];

    let mut base_rps = 0.0f64;
    for &devices in &[1usize, 2, 4, 8] {
        let pool = DevicePool::in_process(model.clone(), devices, PoolConfig::default())
            .expect("spawn pool");
        let eps = pool.eps_handle("pooled");
        let r = bench(
            &format!("pool eps_batch {rows} rows, devices={devices}"),
            Duration::from_millis(100),
            Duration::from_millis(600),
            || {
                eps.eps_batch(&x, &ts, &conds, 2.0, &mut out);
            },
        );
        let rps = rows as f64 / r.mean.as_secs_f64();
        if devices == 1 {
            base_rps = rps;
        }
        println!(
            "{}  ({:.0} rows/s, {:.2}x vs devices=1)",
            r.report(),
            rps,
            rps / base_rps.max(1e-9)
        );
    }
}

#[cfg(feature = "pjrt")]
fn bench_pjrt() {
    use parataa::runtime::{default_artifacts_dir, DeviceActor, EPS_BATCH_SIZES};

    let dir = default_artifacts_dir();
    if !dir.join("eps_batch_1.hlo.txt").exists() {
        println!("bench_runtime: artifacts missing, skipping PJRT section (run `make artifacts`)");
        return;
    }
    println!("--- PJRT artifact latencies ---");
    let actor = DeviceActor::spawn(&dir, 256).unwrap();
    let handle = actor.handle();
    let mut rng = Pcg64::seeded(2);

    for &n in EPS_BATCH_SIZES {
        let x = rng.gaussian_vec(n * 256);
        let t: Vec<i32> = (0..n as i32).map(|i| i * (999 / n.max(1) as i32)).collect();
        let y: Vec<i32> = (0..n as i32).map(|i| i % 8).collect();
        // warm (compiles on first call)
        let _ = handle.eps_batch(&x, &t, &y, 5.0).unwrap();
        let r = bench(
            &format!("pjrt eps_batch_{n}"),
            Duration::from_millis(100),
            Duration::from_millis(800),
            || {
                std::hint::black_box(handle.eps_batch(&x, &t, &y, 5.0).unwrap());
            },
        );
        println!("{}  ({:.1} items/ms)", r.report(), n as f64 / (r.mean.as_secs_f64() * 1e3));
    }

    // Fused solver-step artifact.
    if dir.join("solver_step_100.hlo.txt").exists() {
        use parataa::runtime::device::{SolverStepInputs, SOLVER_HIST_COLS};
        let (w, d) = (100usize, 256usize);
        let c = w + 1;
        let inputs = || SolverStepInputs {
            xs_ext: vec![0.1; c * d],
            eps_ext: vec![0.1; c * d],
            x_win: vec![0.1; w * d],
            s_mat: vec![0.01; w * c],
            b_mat: vec![0.01; w * c],
            xi_comb: vec![0.0; w * d],
            s1_mat: vec![0.01; w * c],
            b1_mat: vec![0.01; w * c],
            xi1_comb: vec![0.0; w * d],
            dx: vec![0.01; SOLVER_HIST_COLS * w * d],
            df: vec![0.01; SOLVER_HIST_COLS * w * d],
            mask: vec![1.0; w],
            fp_mask: vec![0.0; w],
            lam: 1e-4,
        };
        let _ = handle.solver_step(w, inputs()).unwrap();
        let r = bench(
            "pjrt solver_step_100 (fused round)",
            Duration::from_millis(100),
            Duration::from_millis(800),
            || {
                std::hint::black_box(handle.solver_step(w, inputs()).unwrap());
            },
        );
        println!("{}", r.report());
    }
}

fn main() {
    println!("=== bench_runtime ===");
    bench_pool_sweep();
    #[cfg(feature = "pjrt")]
    bench_pjrt();
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt feature disabled: artifact latency section skipped)");
}
