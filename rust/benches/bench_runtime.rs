//! PJRT runtime benchmarks: eps_batch latency per compiled variant and the
//! fused solver_step artifact. Skipped when artifacts are absent.
//!
//! These are the numbers behind Remark 5.1: on CPU a batch-N ε call costs
//! ~N× a batch-1 call (no parallel hardware), so wall-clock speedup comes
//! from *round reduction* only; the per-variant latencies quantify that.

use parataa::runtime::{default_artifacts_dir, DeviceActor, EPS_BATCH_SIZES};
use parataa::util::rng::Pcg64;
use parataa::util::stats::bench;
use std::time::Duration;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("eps_batch_1.hlo.txt").exists() {
        println!("bench_runtime: artifacts missing, skipping (run `make artifacts`)");
        return;
    }
    println!("=== bench_runtime ===");
    let actor = DeviceActor::spawn(&dir, 256).unwrap();
    let handle = actor.handle();
    let mut rng = Pcg64::seeded(2);

    for &n in EPS_BATCH_SIZES {
        let x = rng.gaussian_vec(n * 256);
        let t: Vec<i32> = (0..n as i32).map(|i| i * (999 / n.max(1) as i32)).collect();
        let y: Vec<i32> = (0..n as i32).map(|i| i % 8).collect();
        // warm (compiles on first call)
        let _ = handle.eps_batch(&x, &t, &y, 5.0).unwrap();
        let r = bench(
            &format!("pjrt eps_batch_{n}"),
            Duration::from_millis(100),
            Duration::from_millis(800),
            || {
                std::hint::black_box(handle.eps_batch(&x, &t, &y, 5.0).unwrap());
            },
        );
        println!("{}  ({:.1} items/ms)", r.report(), n as f64 / (r.mean.as_secs_f64() * 1e3));
    }

    // Fused solver-step artifact.
    if dir.join("solver_step_100.hlo.txt").exists() {
        use parataa::runtime::device::{SolverStepInputs, SOLVER_HIST_COLS};
        let (w, d) = (100usize, 256usize);
        let c = w + 1;
        let inputs = || SolverStepInputs {
            xs_ext: vec![0.1; c * d],
            eps_ext: vec![0.1; c * d],
            x_win: vec![0.1; w * d],
            s_mat: vec![0.01; w * c],
            b_mat: vec![0.01; w * c],
            xi_comb: vec![0.0; w * d],
            s1_mat: vec![0.01; w * c],
            b1_mat: vec![0.01; w * c],
            xi1_comb: vec![0.0; w * d],
            dx: vec![0.01; SOLVER_HIST_COLS * w * d],
            df: vec![0.01; SOLVER_HIST_COLS * w * d],
            mask: vec![1.0; w],
            fp_mask: vec![0.0; w],
            lam: 1e-4,
        };
        let _ = handle.solver_step(w, inputs()).unwrap();
        let r = bench(
            "pjrt solver_step_100 (fused round)",
            Duration::from_millis(100),
            Duration::from_millis(800),
            || {
                std::hint::black_box(handle.solver_step(w, inputs()).unwrap());
            },
        );
        println!("{}", r.report());
    }
}
