//! Runtime benchmarks.
//!
//! Part 1 (always runs): thin wrapper over the shared `bench::` scenario
//! registry (group `pool`) — device-pool throughput on the in-process
//! backend over devices ∈ {1, 2, 4, 8}, the multi-executor speedup the
//! paper gets from sharding each window across 8 GPUs, reproduced with CPU
//! worker threads. `parataa bench` runs the same scenarios and writes the
//! JSON report with the per-device counter breakdown.
//!
//! Part 2 (`--features pjrt`, artifacts present): eps_batch latency per
//! compiled variant and the fused solver_step artifact. These are the
//! numbers behind Remark 5.1: on CPU a batch-N ε call costs ~N× a batch-1
//! call (no parallel hardware), so wall-clock speedup comes from *round
//! reduction* only; the per-variant latencies quantify that. This part
//! stays outside the registry because the default build cannot compile it.

use parataa::bench::{run_and_print, BenchOpts};

#[cfg(feature = "pjrt")]
fn bench_pjrt() {
    use parataa::bench::run_timed;
    use parataa::runtime::{default_artifacts_dir, DeviceActor, EPS_BATCH_SIZES};
    use parataa::util::rng::Pcg64;
    use std::time::Duration;

    let dir = default_artifacts_dir();
    if !dir.join("eps_batch_1.hlo.txt").exists() {
        println!("bench_runtime: artifacts missing, skipping PJRT section (run `make artifacts`)");
        return;
    }
    println!("--- PJRT artifact latencies ---");
    let actor = DeviceActor::spawn(&dir, 256).unwrap();
    let handle = actor.handle();
    let mut rng = Pcg64::seeded(2);

    for &n in EPS_BATCH_SIZES {
        let x = rng.gaussian_vec(n * 256);
        let t: Vec<i32> = (0..n as i32).map(|i| i * (999 / n.max(1) as i32)).collect();
        let y: Vec<i32> = (0..n as i32).map(|i| i % 8).collect();
        // warm (compiles on first call)
        let _ = handle.eps_batch(&x, &t, &y, 5.0).unwrap();
        let r = run_timed(
            &format!("pjrt eps_batch_{n}"),
            Duration::from_millis(100),
            Duration::from_millis(800),
            || {
                std::hint::black_box(handle.eps_batch(&x, &t, &y, 5.0).unwrap());
            },
        );
        println!("{}  ({:.1} items/ms)", r.report(), n as f64 / (r.mean_s * 1e3));
    }

    // Fused solver-step artifact.
    if dir.join("solver_step_100.hlo.txt").exists() {
        use parataa::runtime::device::{SolverStepInputs, SOLVER_HIST_COLS};
        let (w, d) = (100usize, 256usize);
        let c = w + 1;
        let inputs = || SolverStepInputs {
            xs_ext: vec![0.1; c * d],
            eps_ext: vec![0.1; c * d],
            x_win: vec![0.1; w * d],
            s_mat: vec![0.01; w * c],
            b_mat: vec![0.01; w * c],
            xi_comb: vec![0.0; w * d],
            s1_mat: vec![0.01; w * c],
            b1_mat: vec![0.01; w * c],
            xi1_comb: vec![0.0; w * d],
            dx: vec![0.01; SOLVER_HIST_COLS * w * d],
            df: vec![0.01; SOLVER_HIST_COLS * w * d],
            mask: vec![1.0; w],
            fp_mask: vec![0.0; w],
            lam: 1e-4,
        };
        let _ = handle.solver_step(w, inputs()).unwrap();
        let r = run_timed(
            "pjrt solver_step_100 (fused round)",
            Duration::from_millis(100),
            Duration::from_millis(800),
            || {
                std::hint::black_box(handle.solver_step(w, inputs()).unwrap());
            },
        );
        println!("{}", r.report());
    }
}

fn main() {
    println!("=== bench_runtime (registry group: pool) ===");
    run_and_print("pool", &BenchOpts::full());
    #[cfg(feature = "pjrt")]
    bench_pjrt();
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt feature disabled: artifact latency section skipped)");
}
