//! Coordinator-layer benchmarks — thin wrapper over the shared `bench::`
//! scenario registry (groups `coordinator` and `cache`): channel/queue
//! overhead, batcher coalescing cost, service latency percentiles under
//! concurrent load, and trajectory-cache warm-start savings. `parataa
//! bench` runs the same scenarios and writes the JSON report.

use parataa::bench::{run_and_print, BenchOpts};

fn main() {
    println!("=== bench_coordinator (registry groups: coordinator, cache) ===");
    let opts = BenchOpts::full();
    run_and_print("coordinator", &opts);
    run_and_print("cache", &opts);
}
