//! Coordinator-layer benchmarks: channel/queue overhead, batcher coalescing
//! gain, and service throughput under concurrent load (GMM model, so the
//! numbers isolate L3 costs from real device time).

use parataa::coordinator::{
    Batcher, BatcherConfig, Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec,
};
use parataa::model::gmm::GmmEps;
use parataa::model::{Cond, EpsModel};
use parataa::schedule::{BetaSchedule, NoiseSchedule};
use parataa::util::rng::Pcg64;
use parataa::util::stats::bench;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn gmm() -> Arc<GmmEps> {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()))
}

fn main() {
    println!("=== bench_coordinator ===");
    let model = gmm();
    let mut rng = Pcg64::seeded(3);

    // Raw channel round-trip (the per-round queueing overhead floor).
    {
        let (tx, rx) = parataa::util::channel::bounded::<u64>(16);
        let t = std::thread::spawn(move || while rx.recv().is_some() {});
        let r = bench("channel send (uncontended)", Duration::from_millis(50), Duration::from_millis(300), || {
            tx.send(1).unwrap();
        });
        println!("{}", r.report());
        tx.close();
        t.join().unwrap();
    }

    // Batcher overhead: direct model call vs through the batcher, 25 items.
    {
        let n = 25;
        let x = rng.gaussian_vec(n * 256);
        let ts: Vec<usize> = (0..n).map(|i| i * 39).collect();
        let conds = vec![Cond::Class(1); n];
        let mut out = vec![0.0f32; n * 256];
        let r = bench("gmm eps 25 items (direct)", Duration::from_millis(100), Duration::from_millis(600), || {
            model.eps_batch(&x, &ts, &conds, 2.0, &mut out);
        });
        println!("{}", r.report());
        let batcher = Batcher::spawn(model.clone(), BatcherConfig::default());
        let handle = batcher.eps_handle(256, "batched");
        let r = bench("gmm eps 25 items (via batcher)", Duration::from_millis(100), Duration::from_millis(600), || {
            handle.eps_batch(&x, &ts, &conds, 2.0, &mut out);
        });
        println!("{}", r.report());
    }

    // Service throughput under load, with and without the batcher.
    for (label, use_batcher) in [("direct", false), ("batched", true)] {
        let coord = if use_batcher {
            let batcher = Batcher::spawn(model.clone(), BatcherConfig::default());
            let eps = Arc::new(batcher.eps_handle(256, "batched"));
            std::mem::forget(batcher); // keep alive for the run
            Coordinator::start(eps, CoordinatorConfig { workers: 4, ..Default::default() })
        } else {
            Coordinator::start(model.clone(), CoordinatorConfig { workers: 4, ..Default::default() })
        };
        let n_req = 24;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_req)
            .map(|i| {
                let mut req = SampleRequest::parataa(
                    Cond::Class(i % 8),
                    i as u64,
                    SamplerSpec::ddim(25),
                );
                req.guidance = 2.0;
                coord.submit(req)
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "service {n_req} reqs DDIM-25 ({label:7}): {dt:?}  ({:.1} req/s)  {}",
            n_req as f64 / dt.as_secs_f64(),
            coord.metrics().report()
        );
    }
}
