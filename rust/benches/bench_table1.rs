//! End-to-end Table-1 bench — thin wrapper over the shared `bench::`
//! registry, filtered to the `table1_*` scenarios (Sequential vs FP vs
//! FP+ vs ParaTAA wall-clock/rounds per scenario, analytic SDa model).
//!
//! The registry covers only the zero-dep analytic scenarios; for DiT
//! timings build with `--features pjrt`, run `make artifacts`, and use
//! `parataa table1` — which also writes the full paper table (with
//! quality columns) as CSV. The JSON form of the numbers measured here
//! comes from `parataa bench`.

use parataa::bench::{run_and_print, BenchOpts};

fn main() {
    println!("=== bench_table1 (registry group: solver, table1_* only) ===");
    let mut opts = BenchOpts::full();
    opts.filter = Some("table1".to_string());
    run_and_print("solver", &opts);
}
