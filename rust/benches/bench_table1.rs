//! End-to-end Table-1 bench: per-scenario wall-clock of Sequential vs FP vs
//! FP+ vs ParaTAA. A reduced-sample version of `parataa table1` suitable
//! for `cargo bench`; the full harness regenerates the complete table.
//!
//! DiT scenarios require `make artifacts`; without them only the analytic
//! SDa columns run.

use parataa::figures::common::{fp_plus_k, method_config, ModelChoice, Scenario};
use parataa::model::Cond;
use parataa::schedule::SamplerKind;
use parataa::solver::{self, Method, Problem};
use parataa::util::rng::Pcg64;
use parataa::util::stats::Summary;
use parataa::util::table::Table;

fn main() {
    println!("=== bench_table1 (reduced; full table via `parataa table1`) ===");
    let have_artifacts = cfg!(feature = "pjrt")
        && parataa::runtime::default_artifacts_dir()
            .join("eps_batch_1.hlo.txt")
            .exists();
    let models = if have_artifacts {
        vec![ModelChoice::Dit, ModelChoice::Gmm]
    } else {
        println!("(artifacts missing: DiT columns skipped)");
        vec![ModelChoice::Gmm]
    };

    let n = 6; // seeds per cell
    let mut t = Table::new(
        "Table 1 (bench): mean rounds + wall-clock per scenario/method",
        &["scenario", "method", "rounds", "time_ms", "speedup_x"],
    );
    for model in models {
        for (kind, steps) in [
            (SamplerKind::Ddim, 25),
            (SamplerKind::Ddim, 50),
            (SamplerKind::Ddim, 100),
            (SamplerKind::Ddpm, 100),
        ] {
            let scenario = Scenario::new(model, kind, steps);
            let coeffs = scenario.coeffs();
            let mut rng = Pcg64::seeded(42);

            // Sequential baseline.
            let mut seq_time = Summary::new();
            for seed in 0..n {
                let problem =
                    Problem::new(&coeffs, &*scenario.model, Cond::Class(rng.below(8) as usize), seed);
                let t0 = std::time::Instant::now();
                std::hint::black_box(solver::sample_sequential(&problem, scenario.guidance));
                seq_time.push(t0.elapsed().as_secs_f64());
            }
            t.push_row(vec![
                scenario.label(),
                "Sequential".into(),
                format!("{steps}"),
                format!("{:.1}", seq_time.mean() * 1e3),
                "1.00".into(),
            ]);

            for (label, method, k) in [
                ("FP", Method::FixedPoint, Some(steps)),
                ("FP+", Method::FixedPoint, Some(fp_plus_k(steps))),
                ("ParaTAA", Method::Taa, None),
            ] {
                let mut time = Summary::new();
                let mut rounds = Summary::new();
                for seed in 0..n {
                    let problem = Problem::new(
                        &coeffs,
                        &*scenario.model,
                        Cond::Class(rng.below(8) as usize),
                        seed,
                    );
                    let cfg = method_config(method, steps, k, scenario.guidance);
                    let t0 = std::time::Instant::now();
                    let r = solver::solve(&problem, &cfg);
                    time.push(t0.elapsed().as_secs_f64());
                    rounds.push(r.iterations as f64);
                }
                t.push_row(vec![
                    scenario.label(),
                    label.into(),
                    format!("{:.1}", rounds.mean()),
                    format!("{:.1}", time.mean() * 1e3),
                    format!("{:.2}", seq_time.mean() / time.mean()),
                ]);
            }
            eprintln!("  {} done", scenario.label());
        }
    }
    println!("{}", t.to_ascii());
    t.write_csv("results/bench_table1.csv").ok();
}
