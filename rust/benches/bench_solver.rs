//! Solver benchmarks — thin wrapper over the shared `bench::` scenario
//! registry (group `solver`): the suffix-Gram scan and TAA-update
//! micro-kernels plus the Table-1 regime solves. `parataa bench` runs the
//! same scenarios and additionally writes the JSON report; use
//! `parataa bench --only table1` etc. for machine-readable output.

use parataa::bench::{run_and_print, BenchOpts};

fn main() {
    println!("=== bench_solver (registry group: solver) ===");
    run_and_print("solver", &BenchOpts::full());
}
