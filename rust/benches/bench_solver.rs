//! Micro-benchmarks of the solver hot paths: the suffix-Gram scan, the TAA
//! update, and full FP/TAA rounds on the analytic model (no device cost),
//! isolating L3 overhead from ε_θ time.

use parataa::figures::common::{method_config, ModelChoice, Scenario};
use parataa::linalg::suffix_grams;
use parataa::model::Cond;
use parataa::schedule::SamplerKind;
use parataa::solver::{self, history::History, update::apply_update, Method, Problem};
use parataa::util::rng::Pcg64;
use parataa::util::stats::bench;
use std::time::Duration;

fn main() {
    let warm = Duration::from_millis(100);
    let measure = Duration::from_millis(600);
    let mut rng = Pcg64::seeded(1);

    println!("=== bench_solver ===");

    // Suffix-Gram scan at Table-1 scale (W=100, D=256, m=2).
    for (w, d, m) in [(25usize, 256usize, 2usize), (100, 256, 2), (100, 1024, 4)] {
        let slots: Vec<Vec<f32>> = (0..m).map(|_| rng.gaussian_vec(w * d)).collect();
        let refs: Vec<&[f32]> = slots.iter().map(|s| s.as_slice()).collect();
        let res = rng.gaussian_vec(w * d);
        let r = bench(
            &format!("suffix_grams W={w} D={d} m={m}"),
            warm,
            measure,
            || {
                std::hint::black_box(suffix_grams(&refs, &res, w, d, 0));
            },
        );
        println!("{}", r.report());
    }

    // Full TAA update (grams + solves + correction).
    for (w, d) in [(25usize, 256usize), (100, 256)] {
        let m = 2;
        let mut history = History::new(m, w, d);
        for _ in 0..m {
            let dx = rng.gaussian_vec(w * d);
            let df = rng.gaussian_vec(w * d);
            history.push(&dx, &df);
        }
        let f_vals = rng.gaussian_vec(w * d);
        let xs0 = rng.gaussian_vec(w * d);
        let r_vals: Vec<f32> = f_vals.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
        let mut xs = xs0.clone();
        let r = bench(&format!("taa_update W={w} D={d}"), warm, measure, || {
            xs.copy_from_slice(&xs0);
            apply_update(
                Method::Taa,
                &mut xs,
                &f_vals,
                &r_vals,
                &history,
                0,
                w - 1,
                w,
                d,
                1e-4,
                true,
            );
            std::hint::black_box(&xs);
        });
        println!("{}", r.report());
    }

    // Whole solves on the analytic model: L3 cost per scenario.
    for (method, label) in [(Method::FixedPoint, "FP"), (Method::Taa, "ParaTAA")] {
        let scenario = Scenario::new(ModelChoice::Gmm, SamplerKind::Ddim, 50);
        let coeffs = scenario.coeffs();
        let mut seed = 0u64;
        let r = bench(&format!("solve DDIM-50 gmm {label}"), warm, measure, || {
            seed += 1;
            let problem = Problem::new(&coeffs, &*scenario.model, Cond::Class(0), seed);
            let cfg = method_config(method, 50, None, scenario.guidance);
            std::hint::black_box(solver::solve(&problem, &cfg));
        });
        println!("{}", r.report());
    }
}
