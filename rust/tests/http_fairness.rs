//! Fairness + quota property tests for the multi-tenant admission layer
//! (ISSUE 10).
//!
//! The load-bearing properties run against the *pure deterministic* core
//! ([`FairQueue`], [`TokenBucket`]) with injected clocks and explicit pop
//! order, ≥ 64 randomized cases each — no sockets, no sleeps, no flakes:
//!
//! - **weighted shares** — under saturation, each tenant's grant share
//!   tracks its weight within a documented tolerance (±2 grants + 5%);
//! - **bounded batch delay** — a waiting batch request is granted within
//!   `batch_every + 1` grants, no matter how interactive traffic arrives;
//! - **quota soundness** — a token bucket never grants more than
//!   `burst + rate·elapsed`, and its `Retry-After` hint is sufficient:
//!   waiting that long always yields a token.
//!
//! The blocking/threaded layers are then checked once each: [`FairGate`]
//! grant order matches the WFQ prediction, and over real HTTP an
//! over-quota tenant collects 429s while an in-quota tenant is completely
//! unaffected (quota isolation).

use parataa::coordinator::{Coordinator, CoordinatorConfig};
use parataa::model::gmm::GmmEps;
use parataa::schedule::{BetaSchedule, NoiseSchedule};
use parataa::serve::client;
use parataa::serve::tenant::TokenBucket;
use parataa::serve::{FairGate, FairQueue, HttpConfig, HttpServer, Priority, TenantRegistry};
use parataa::util::proplite::{forall, size_in};
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[test]
fn grant_shares_track_weights_under_saturation() {
    forall("wfq_weighted_shares", 64, |rng, case| {
        let n_tenants = size_in(rng, 2, 5);
        let weights: Vec<u32> = (0..n_tenants).map(|_| size_in(rng, 1, 8) as u32).collect();
        let grants_total = size_in(rng, 40, 120);
        // Saturation: every tenant has more queued work than could ever
        // be granted, pushed in a random interleaving.
        let mut q = FairQueue::new(4);
        let mut ticket = 0u64;
        let mut backlog: Vec<usize> = (0..n_tenants)
            .flat_map(|t| std::iter::repeat(t).take(grants_total))
            .collect();
        // Fisher–Yates with the case rng: arrival order must not matter.
        for i in (1..backlog.len()).rev() {
            backlog.swap(i, rng.below((i + 1) as u64) as usize);
        }
        for &t in &backlog {
            q.push(ticket, t, weights[t], Priority::Interactive);
            ticket += 1;
        }
        let mut got = vec![0usize; n_tenants];
        for _ in 0..grants_total {
            let (_, t) = q.pop().expect("saturated queue");
            got[t] += 1;
        }
        let total_weight: f64 = weights.iter().map(|&w| f64::from(w)).sum();
        for t in 0..n_tenants {
            let expected = grants_total as f64 * f64::from(weights[t]) / total_weight;
            let tolerance = 2.0 + 0.05 * grants_total as f64;
            if (got[t] as f64 - expected).abs() > tolerance {
                return Err(format!(
                    "case {case}: tenant {t} (weight {}) got {} of {grants_total} grants, \
                     expected {expected:.1} ± {tolerance:.1} (weights {weights:?})",
                    weights[t], got[t]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn batch_is_granted_within_the_documented_bound() {
    forall("wfq_batch_no_starvation", 64, |rng, case| {
        fn push_interactive(
            rng: &mut parataa::util::rng::Pcg64,
            q: &mut FairQueue,
            n: usize,
            t: &mut u64,
        ) {
            for _ in 0..n {
                q.push(*t, 0, size_in(rng, 1, 4) as u32, Priority::Interactive);
                *t += 1;
            }
        }
        let batch_every = size_in(rng, 1, 6);
        let mut q = FairQueue::new(batch_every);
        let mut next_ticket = 0u64;
        let initial = size_in(rng, 1, 10);
        push_interactive(rng, &mut q, initial, &mut next_ticket);
        // One batch ticket arrives into a busy queue; interactive traffic
        // keeps arriving adversarially after every grant.
        let batch_ticket = next_ticket;
        q.push(batch_ticket, 1, 1, Priority::Batch);
        next_ticket += 1;
        let mut waited = 0usize;
        loop {
            let burst = size_in(rng, 0, 3);
            push_interactive(rng, &mut q, burst, &mut next_ticket);
            let (t, _) = q.pop().expect("non-empty");
            if t == batch_ticket {
                break;
            }
            waited += 1;
            if waited > batch_every + 1 {
                return Err(format!(
                    "case {case}: batch ticket still waiting after {waited} grants \
                     (bound {batch_every} + 1)"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn token_bucket_never_overgrants_and_its_retry_hint_suffices() {
    forall("token_bucket_quota", 64, |rng, case| {
        let rate = 0.5 + rng.next_f64() * 20.0;
        let burst = size_in(rng, 1, 5) as u32;
        let mut bucket = TokenBucket::new(rate, burst);
        let mut now_ns = 0u64;
        let mut granted = 0usize;
        for step in 0..80 {
            now_ns += rng.below(400_000_000); // 0..400ms random gaps
            match bucket.try_take(now_ns) {
                Ok(()) => granted += 1,
                Err(retry_after) => {
                    if !retry_after.is_finite() || retry_after <= 0.0 {
                        return Err(format!(
                            "case {case} step {step}: bad Retry-After hint {retry_after}"
                        ));
                    }
                    // The hint must be sufficient: waiting exactly that
                    // long (plus 1ns of slack) yields a token.
                    let mut probe = bucket.clone();
                    let wait_ns = (retry_after * 1e9) as u64 + 1;
                    if probe.try_take(now_ns + wait_ns).is_err() {
                        return Err(format!(
                            "case {case} step {step}: waiting the hinted {retry_after}s \
                             did not yield a token"
                        ));
                    }
                }
            }
            // Quota soundness at every prefix of the schedule.
            let ceiling = f64::from(burst) + rate * (now_ns as f64 / 1e9) + 1e-6;
            if granted as f64 > ceiling {
                return Err(format!(
                    "case {case} step {step}: {granted} grants exceeds quota ceiling \
                     {ceiling:.3} (rate {rate:.3}, burst {burst})"
                ));
            }
        }
        Ok(())
    });
}

/// The threaded gate grants in WFQ order. Setup is race-free by
/// construction: one permit is held while all waiters enqueue (their
/// virtual finish times depend only on per-tenant arrival *counts*, not
/// on cross-tenant interleaving), so the release order is the WFQ
/// prediction: heavy (weight 4) tickets dominate the front of the line.
#[test]
fn fair_gate_releases_waiters_in_weighted_order() {
    let gate = Arc::new(FairGate::new(1, 100)); // batch bound irrelevant here
    let order = Arc::new(Mutex::new(Vec::new()));
    let blocker = gate.acquire(9, 1, Priority::Interactive).expect("blocker permit");
    let mut waiters = Vec::new();
    for (tenant, weight, n) in [(0usize, 4u32, 8usize), (1, 1, 8)] {
        for _ in 0..n {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            waiters.push(std::thread::spawn(move || {
                let permit = gate.acquire(tenant, weight, Priority::Interactive).unwrap();
                order.lock().unwrap().push(tenant);
                // Serialize service so the recorded order IS the grant
                // order (capacity is 1).
                drop(permit);
            }));
        }
    }
    // Let every waiter enqueue behind the held permit.
    std::thread::sleep(Duration::from_millis(300));
    drop(blocker);
    for w in waiters {
        w.join().unwrap();
    }
    let order = order.lock().unwrap();
    assert_eq!(order.len(), 16);
    let heavy_in_first_8 = order.iter().take(8).filter(|&&t| t == 0).count();
    // WFQ prediction: heavy vf = 0.25·k, light vf = 1.0·k → the first 8
    // grants hold ≥ 6 heavy even under worst-case tie-breaking.
    assert!(
        heavy_in_first_8 >= 6,
        "weight-4 tenant got only {heavy_in_first_8} of the first 8 grants: {order:?}"
    );
}

fn gmm() -> Arc<GmmEps> {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()))
}

#[test]
fn over_quota_tenant_collects_429s_while_others_are_unaffected() {
    let coord = Arc::new(Coordinator::start(
        gmm(),
        CoordinatorConfig { workers: 2, drivers: 2, ..Default::default() },
    ));
    // `limited` can make 2 requests, then is throttled for ~17 minutes;
    // `free` is unlimited. Configured mode also refuses unknown names.
    let tenants = Arc::new(
        TenantRegistry::from_spec(Some("limited:rps=0.001,burst=2;free:weight=2"))
            .expect("tenant spec"),
    );
    let server = HttpServer::start(
        Arc::clone(&coord),
        Arc::clone(&tenants),
        "127.0.0.1:0",
        HttpConfig::default(),
    )
    .expect("start server");
    let addr = server.local_addr();
    let body = r#"{"seed": 5, "sampler": {"steps": 8}, "cond": {"class": 2}}"#;

    let mut limited_ok = 0;
    let mut limited_throttled = 0;
    for _ in 0..6 {
        let r = client::post_json(addr, "/v1/sample", Some("limited"), body).unwrap();
        match r.status {
            200 => limited_ok += 1,
            429 => {
                limited_throttled += 1;
                let retry: u64 = r
                    .header("retry-after")
                    .expect("429 carries Retry-After")
                    .parse()
                    .expect("Retry-After is integral seconds");
                assert!(retry >= 1);
            }
            other => panic!("unexpected status {other}: {}", r.body),
        }
    }
    assert_eq!(limited_ok, 2, "burst=2 admits exactly two before the quota bites");
    assert_eq!(limited_throttled, 4);

    // The other tenant is completely unaffected by `limited`'s 429 storm.
    for i in 0..6 {
        let r = client::post_json(addr, "/v1/sample", Some("free"), body).unwrap();
        assert_eq!(r.status, 200, "free request {i} failed: {}", r.body);
    }
    // Unknown tenants are refused outright in configured mode.
    assert_eq!(
        client::post_json(addr, "/v1/sample", Some("ghost"), body).unwrap().status,
        403
    );

    let snap = tenants.snapshot();
    let get = |name: &str| snap.iter().find(|(n, _)| n == name).unwrap().1;
    let (limited, free) = (get("limited"), get("free"));
    assert_eq!((limited.admitted, limited.completed, limited.throttled), (2, 2, 4));
    assert_eq!((free.admitted, free.completed, free.failed, free.throttled), (6, 6, 0, 0));
    // Throttled requests never reached the coordinator: nothing failed,
    // nothing leaked.
    let m = coord.metrics();
    assert_eq!((m.completed, m.failed, m.sessions_in_flight), (8, 0, 0));
}
