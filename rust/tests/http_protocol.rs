//! HTTP protocol property tests (ISSUE 10): the serving front driven with
//! hostile, malformed, and well-formed wire input.
//!
//! Invariants pinned here:
//!
//! - **classified rejection** — every malformed request maps to a
//!   documented 4xx/5xx (or a clean connection drop), never a panic and
//!   never a leaked coordinator slot (`sessions_in_flight` returns to 0
//!   and the service still answers afterwards);
//! - **wire transparency** — a `SampleRequest` survives
//!   `request_to_json` → text → `request_from_json` field-for-field
//!   (floats bitwise), across ≥ 64 randomized requests;
//! - **parity oracle** — a sample served over HTTP is bit-identical to
//!   the same request submitted directly to the same coordinator: the
//!   transport adds zero numeric surface;
//! - **SSE framing** — the stream is `chunk`* then exactly one
//!   `done`/`error`; chunks tile the trajectory back to row 0 and at
//!   least one arrives strictly before completion.

use parataa::coordinator::{Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec};
use parataa::model::gmm::GmmEps;
use parataa::model::Cond;
use parataa::schedule::{BetaSchedule, NoiseSchedule, SamplerKind};
use parataa::serve::client::{self, SseConn};
use parataa::serve::wire;
use parataa::serve::{HttpConfig, HttpServer, TenantRegistry};
use parataa::solver::{
    AdaptiveWindow, DraftRefineConfig, Method, PararealConfig, SolveStrategy, WindowPolicy,
};
use parataa::util::json::parse;
use parataa::util::proplite::{f32_in, forall, size_in};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

fn gmm() -> Arc<GmmEps> {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()))
}

/// Server + coordinator with caps small enough to exercise 413/431/408
/// cheaply. Field order is the teardown order: server joins its accept
/// pool first, then the coordinator (its last `Arc` ref) drains.
struct Stack {
    server: HttpServer,
    coord: Arc<Coordinator>,
}

fn stack() -> Stack {
    let coord = Arc::new(Coordinator::start(
        gmm(),
        CoordinatorConfig { workers: 2, drivers: 2, ..Default::default() },
    ));
    let cfg = HttpConfig {
        max_header_bytes: 2 * 1024,
        max_body_bytes: 16 * 1024,
        read_timeout: Duration::from_millis(150),
        ..Default::default()
    };
    let server = HttpServer::start(
        Arc::clone(&coord),
        Arc::new(TenantRegistry::open()),
        "127.0.0.1:0",
        cfg,
    )
    .expect("start http server");
    Stack { server, coord }
}

fn body(seed: u64, steps: usize) -> String {
    format!(r#"{{"seed": {seed}, "sampler": {{"steps": {steps}}}, "cond": {{"class": 1}}}}"#)
}

/// Send raw bytes, half-close, read whatever comes back.
fn send_raw(addr: SocketAddr, raw: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let _ = s.write_all(raw);
    let _ = s.shutdown(std::net::Shutdown::Write);
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    String::from_utf8_lossy(&out).to_string()
}

fn status_of(response: &str) -> Option<u16> {
    response.strip_prefix("HTTP/1.1 ")?.split(' ').next()?.parse().ok()
}

#[test]
fn malformed_requests_are_classified_and_leak_nothing() {
    let st = stack();
    let addr = st.server.local_addr();
    // Statuses a hostile byte stream may legitimately earn. Anything else
    // (a 200, a 5xx other than 505/501, no parseable status line on a
    // non-empty reply) fails the property.
    const CLASSIFIED: &[u16] = &[400, 408, 413, 431, 501, 505, 404, 405];
    forall("malformed_http_is_classified", 64, |rng, case| {
        let raw: Vec<u8> = match rng.below(8) {
            // Pure fuzz: random bytes, random length (kept under the
            // header cap so the case can't stall on a huge send).
            0 => (0..size_in(rng, 1, 512)).map(|_| rng.next_u64() as u8).collect(),
            // Truncated request line / headers (mid-request EOF).
            1 => b"POST /v1/sample HTTP/1.1\r\nContent-Le".to_vec(),
            // Bad version.
            2 => b"GET /healthz HTTP/9.9\r\n\r\n".to_vec(),
            // Header without a colon.
            3 => b"GET /healthz HTTP/1.1\r\nBadHeader\r\n\r\n".to_vec(),
            // Unparseable Content-Length.
            4 => b"POST /v1/sample HTTP/1.1\r\nContent-Length: 12abc\r\n\r\n".to_vec(),
            // Chunked transfer encoding (501 by design).
            5 => b"POST /v1/sample HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
            // Declared body over the cap (413, body never read).
            6 => b"POST /v1/sample HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n".to_vec(),
            // Header block over the cap (431).
            _ => {
                let mut r = b"GET /healthz HTTP/1.1\r\n".to_vec();
                for i in 0..200 {
                    r.extend_from_slice(format!("X-Pad-{i}: {}\r\n", "y".repeat(64)).as_bytes());
                }
                r.extend_from_slice(b"\r\n");
                r
            }
        };
        let reply = send_raw(addr, &raw);
        if reply.is_empty() {
            // A connection drop with no reply is acceptable only for pure
            // fuzz input (it may have read as a clean close).
            return Ok(());
        }
        let status = status_of(&reply)
            .ok_or_else(|| format!("case {case}: unparseable reply {reply:?}"))?;
        if !CLASSIFIED.contains(&status) {
            return Err(format!("case {case}: unclassified status {status} for {raw:?}"));
        }
        Ok(())
    });
    // Nothing leaked: the service still answers, and no session slot is
    // held by any of the rejected requests.
    let ok = client::post_json(addr, "/v1/sample", None, &body(1, 8)).expect("service alive");
    assert_eq!(ok.status, 200, "service must survive the malformed storm: {}", ok.body);
    let m = st.coord.metrics();
    assert_eq!(m.sessions_in_flight, 0, "a malformed request leaked a session slot");
    assert_eq!(m.failed, 0, "malformed requests must be rejected before admission");
}

#[test]
fn well_formed_requests_roundtrip_bitwise() {
    forall("request_json_roundtrip", 64, |rng, case| {
        let steps = size_in(rng, 2, 60);
        let cond = match rng.below(3) {
            0 => Cond::Uncond,
            1 => Cond::Class(rng.below(8) as usize),
            _ => Cond::Weights((0..size_in(rng, 1, 6)).map(|_| rng.next_f32()).collect()),
        };
        let kind = match rng.below(3) {
            0 => SamplerKind::Ddim,
            1 => SamplerKind::Ddpm,
            _ => SamplerKind::Eta(rng.next_f64()),
        };
        let mut req =
            SampleRequest::parataa(cond, rng.next_u64() >> 12, SamplerSpec { kind, steps });
        req.guidance = f32_in(rng, 0.0, 12.0);
        req.method = [Method::FixedPoint, Method::AndersonStd, Method::AndersonUpperTri, Method::Taa]
            [rng.below(4) as usize];
        if rng.below(2) == 0 {
            req.k = Some(size_in(rng, 1, 6));
        }
        req.m = size_in(rng, 1, 6);
        if rng.below(2) == 0 {
            req.window = Some(size_in(rng, 1, steps));
        }
        if rng.below(2) == 0 {
            req.max_rounds = Some(size_in(rng, 1, 200));
        }
        req.use_trajectory_cache = rng.below(2) == 0;
        if rng.below(2) == 0 {
            req.window_policy = WindowPolicy::Adaptive(AdaptiveWindow::for_steps(steps));
        }
        req.strategy = match rng.below(3) {
            0 => SolveStrategy::PlainTaa,
            1 => SolveStrategy::DraftRefine(DraftRefineConfig {
                coarse_steps: size_in(rng, 1, steps),
                coarse_tol: rng.next_f64(),
                max_draft_rounds: size_in(rng, 1, 20),
            }),
            _ => SolveStrategy::Parareal(PararealConfig { stride: size_in(rng, 1, 8) }),
        };
        req.parallelism = size_in(rng, 1, 8);
        if rng.below(2) == 0 {
            req.deadline_ms = Some(rng.next_u64() >> 14);
        }

        let text = wire::request_to_json(&req)
            .map_err(|e| format!("case {case}: encode: {e}"))?
            .to_string();
        let json =
            parse(&text).map_err(|e| format!("case {case}: self-encoded JSON rejected: {e}"))?;
        let back = wire::request_from_json(&json)
            .map_err(|e| format!("case {case}: decode: {e} (wire {text})"))?;
        if back != req {
            return Err(format!("case {case}: roundtrip drift:\n  {req:?}\n  {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn http_sample_is_bitwise_identical_to_direct_submit() {
    let st = stack();
    let addr = st.server.local_addr();
    for (i, method) in
        [Method::Taa, Method::FixedPoint, Method::AndersonUpperTri].iter().enumerate()
    {
        let mut req = SampleRequest::parataa(
            Cond::Class(1 + i),
            90 + i as u64,
            SamplerSpec::ddim(12),
        );
        req.guidance = 2.0;
        req.method = *method;
        let direct = st.coord.submit(req.clone()).wait().expect("direct solve");
        let wire_body = wire::request_to_json(&req).unwrap().to_string();
        let resp = client::post_json(addr, "/v1/sample", Some("oracle"), &wire_body)
            .expect("http solve");
        assert_eq!(resp.status, 200, "http solve failed: {}", resp.body);
        let json = resp.json().expect("response json");
        let served: Vec<u32> = json
            .get("sample")
            .and_then(|s| s.as_f32_vec())
            .expect("sample array")
            .iter()
            .map(|x| x.to_bits())
            .collect();
        let want: Vec<u32> = direct.sample.iter().map(|x| x.to_bits()).collect();
        assert_eq!(served, want, "HTTP transport changed the sample bits ({method:?})");
        assert_eq!(json.get("rounds").and_then(|v| v.as_usize()), Some(direct.rounds));
        assert_eq!(json.get("nfe").and_then(|v| v.as_usize()), Some(direct.nfe));
    }
}

#[test]
fn sse_stream_tiles_the_trajectory_and_finishes_with_done() {
    let st = stack();
    let steps = 16;
    let conn = SseConn::open(st.server.local_addr(), Some("sse"), &body(7, steps))
        .expect("open sse stream");
    let events = conn.collect();
    assert!(!events.is_empty(), "stream produced no events");
    let done_at = events.iter().position(|e| e.event == "done").expect("no done event");
    assert_eq!(done_at, events.len() - 1, "done must be the final frame");
    let chunks = &events[..done_at];
    assert!(!chunks.is_empty(), "no chunk arrived before completion");
    assert!(chunks.iter().all(|e| e.event == "chunk"), "unexpected frame kind: {events:?}");
    let done = parse(&events[done_at].data).expect("done payload json");
    assert_eq!(done.get("converged").map(|v| matches!(v, parataa::util::json::Json::Bool(true))), Some(true));
    // Chunks tile the trajectory from the noise row back to the sample row.
    let mut expect_end = steps;
    for e in chunks {
        let j = parse(&e.data).expect("chunk json");
        let start = j.get("rows_start").and_then(|v| v.as_usize()).unwrap();
        let end = j.get("rows_end").and_then(|v| v.as_usize()).unwrap();
        assert_eq!(end, expect_end, "chunk gap/overlap");
        expect_end = start;
    }
    assert_eq!(expect_end, 0, "stream never reached the sample row");
    // And the streamed run is conserved in the metrics.
    let m = st.coord.metrics();
    assert_eq!((m.completed, m.sessions_in_flight), (1, 0));
}

#[test]
fn pipelined_requests_are_each_answered() {
    let st = stack();
    let mut s = TcpStream::connect(st.server.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Two requests in one segment; the second asks to close so the reply
    // stream has a definite end.
    s.write_all(
        b"GET /healthz HTTP/1.1\r\n\r\nGET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n",
    )
    .unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    assert_eq!(
        out.matches("HTTP/1.1 200 OK").count(),
        2,
        "both pipelined requests must be answered: {out:?}"
    );
}

#[test]
fn slow_loris_is_timed_out_with_408() {
    let st = stack();
    let mut s = TcpStream::connect(st.server.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Send half a request line and stall (no half-close: the socket stays
    // open, only idle). The 150 ms read timeout must classify this.
    s.write_all(b"POST /v1/sample HT").unwrap();
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    let reply = String::from_utf8_lossy(&out);
    assert_eq!(status_of(&reply), Some(408), "slow-loris reply: {reply:?}");
}

#[test]
fn routes_unknown_and_wrong_method_are_404_405() {
    let st = stack();
    let addr = st.server.local_addr();
    assert_eq!(client::get(addr, "/nope").unwrap().status, 404);
    let r = client::get(addr, "/v1/sample").unwrap();
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("POST"));
    assert_eq!(client::request(addr, "POST", "/metrics", &[], "").unwrap().status, 405);
}

#[test]
fn bad_json_bodies_are_400_with_a_reason() {
    let st = stack();
    let addr = st.server.local_addr();
    for bad in [
        "not json at all",
        r#"{"seed": 1}"#,
        r#"{"seed": -3, "sampler": {"steps": 8}}"#,
        r#"{"seed": 1, "sampler": {"steps": 8}, "method": "newton"}"#,
    ] {
        let r = client::post_json(addr, "/v1/sample", None, bad).unwrap();
        assert_eq!(r.status, 400, "body {bad:?} got {}: {}", r.status, r.body);
        assert!(r.json().unwrap().get("error").is_some(), "400 body must carry `error`");
    }
    assert_eq!(st.coord.metrics().sessions_in_flight, 0);
}

#[test]
fn deadline_header_wires_into_the_deadline_path_as_504() {
    let st = stack();
    let r = client::request(
        st.server.local_addr(),
        "POST",
        "/v1/sample",
        &[("X-Parataa-Deadline-Ms", "0")],
        &body(3, 8),
    )
    .unwrap();
    assert_eq!(r.status, 504, "an already-expired deadline must be 504: {}", r.body);
    assert_eq!(
        r.json().unwrap().get("kind").and_then(|k| k.as_str().map(str::to_string)),
        Some("deadline_exceeded".to_string())
    );
}

#[test]
fn metrics_endpoint_serves_valid_prometheus_text() {
    let st = stack();
    let addr = st.server.local_addr();
    let ok = client::post_json(addr, "/v1/sample", Some("acme"), &body(2, 8)).unwrap();
    assert_eq!(ok.status, 200);
    let m = client::get(addr, "/metrics").unwrap();
    assert_eq!(m.status, 200);
    let samples = parataa::trace::prom::validate(&m.body).expect("exposition must validate");
    assert!(samples > 10, "suspiciously few samples: {samples}");
    assert!(
        m.body.contains("parataa_tenant_requests_total{tenant=\"acme\",outcome=\"completed\"} 1"),
        "per-tenant breakdown missing:\n{}",
        m.body
    );
    let h = client::get(addr, "/healthz").unwrap();
    assert_eq!(h.status, 200);
    assert_eq!(
        h.json().unwrap().get("status").and_then(|s| s.as_str().map(str::to_string)),
        Some("ok".to_string())
    );
}
