//! Chaos tests (ISSUE 9): the full coordinator stack driven over
//! fault-injected device pools.
//!
//! Every test scripts a deterministic fault storm via
//! [`parataa::runtime::FaultSpec`] and asserts the service-level
//! invariants the robustness layer guarantees:
//!
//! - **conservation** — every admitted request resolves exactly once:
//!   completed + failed == admitted, no handle hangs;
//! - **bounded waits** — injected hangs are released by `shard_timeout`
//!   retries, the hang safety cap, or [`FaultControl::cancel`], never by
//!   test-harness timeout;
//! - **slot restoration** — the slot budget returns to its idle value
//!   after every storm (no leaked window rows);
//! - **bitwise degradation** — requests served by the sequential fallback
//!   produce exactly `sample_sequential`'s output;
//! - **classified errors** — failures surface with the right
//!   [`ErrorKind`], not as panics.

use parataa::coordinator::{
    Coordinator, CoordinatorConfig, RobustnessConfig, SampleRequest, SamplerSpec, ShedMode,
};
use parataa::model::gmm::GmmEps;
use parataa::model::Cond;
use parataa::runtime::{
    DevicePool, EpsBackend, FaultControl, FaultSpec, FaultyBackend, InProcessBackend, PoolConfig,
};
use parataa::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
use parataa::solver::{sample_sequential, Problem};
use parataa::util::error::ErrorKind;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn gmm() -> Arc<GmmEps> {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()))
}

/// A coordinator over `devices` fault-injected in-process backends with
/// the pool's retry/quarantine path on (shard timeout, output validation,
/// short hang cap). Returns the pool too: it must outlive the coordinator,
/// and tests tear down in the order `drop(coord)` → `control.cancel()` →
/// `drop(pool)` so hung workers release before the pool joins them.
fn chaos_stack(
    devices: usize,
    spec: &str,
    robustness: RobustnessConfig,
) -> (Coordinator, DevicePool, FaultControl) {
    let model = gmm();
    let spec = FaultSpec::parse(spec).expect("test fault spec").with_seed(7);
    let control = FaultControl::new();
    let backends: Vec<Box<dyn EpsBackend>> = (0..devices)
        .map(|dev| -> Box<dyn EpsBackend> {
            let inner: Box<dyn EpsBackend> = Box::new(InProcessBackend::new(model.clone()));
            Box::new(
                FaultyBackend::new(inner, dev, &spec, control.clone())
                    .with_hang_cap(Duration::from_millis(400)),
            )
        })
        .collect();
    let cfg = PoolConfig {
        shard_timeout: Some(Duration::from_millis(150)),
        validate_output: true,
        work_stealing: false, // deterministic device routing for the storms
        ..Default::default()
    };
    let pool = DevicePool::spawn(backends, cfg).expect("spawn chaos pool");
    let stats = pool.stats();
    let pooled = Arc::new(pool.eps_handle("pooled"));
    let coord = Coordinator::start(
        pooled,
        CoordinatorConfig { workers: 2, drivers: 2, devices, robustness, ..Default::default() },
    );
    coord.attach_pool(stats);
    (coord, pool, control)
}

fn req(seed: u64, steps: usize) -> SampleRequest {
    let mut r = SampleRequest::parataa(
        Cond::Class((seed % 8) as usize),
        seed,
        SamplerSpec::ddim(steps),
    );
    r.guidance = 2.0;
    r
}

/// The sequential oracle on the bare analytic model (bitwise what the
/// degraded path must produce — the pool layer is arithmetic-transparent).
fn oracle(seed: u64, steps: usize) -> Vec<f32> {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, steps);
    let model = gmm();
    let problem = Problem::new(&coeffs, &*model, Cond::Class((seed % 8) as usize), seed);
    sample_sequential(&problem, 2.0).xs.row(0).to_vec()
}

/// One scripted storm: run `n_req` requests through it, assert
/// conservation, bounded wall-clock, and slot restoration. Returns
/// (ok, failed, finite_samples).
fn run_storm(spec: &str, n_req: usize) -> (usize, usize, bool) {
    let (coord, pool, control) = chaos_stack(2, spec, RobustnessConfig::default());
    let idle_slots = coord.slots_available();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_req).map(|i| coord.submit(req(i as u64, 16))).collect();
    let mut ok = 0usize;
    let mut failed = 0usize;
    let mut finite = true;
    for h in handles {
        match h.wait() {
            Ok(r) => {
                ok += 1;
                finite &= r.sample.iter().all(|v| v.is_finite());
            }
            Err(_) => failed += 1,
        }
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(30),
        "storm `{spec}` took {elapsed:?} — waits must stay bounded"
    );
    assert_eq!(ok + failed, n_req, "storm `{spec}`: every request resolves exactly once");
    let snap = coord.metrics();
    assert_eq!(
        snap.completed + snap.failed,
        n_req as u64,
        "storm `{spec}`: metrics must conserve requests"
    );
    assert_eq!(
        coord.slots_available(),
        idle_slots,
        "storm `{spec}`: all slots must return to the budget"
    );
    drop(coord);
    control.cancel();
    drop(pool);
    (ok, failed, finite)
}

#[test]
fn error_storm_is_absorbed_by_retries() {
    // Device 1 errors on every shard from its 3rd call on; device 0 stays
    // healthy, so bounded retry onto it must absorb the whole storm.
    let (ok, failed, finite) = run_storm("1:error@2..", 8);
    assert_eq!(failed, 0, "a healthy peer device must absorb an erroring one");
    assert_eq!(ok, 8);
    assert!(finite);
}

#[test]
fn error_storm_quarantines_the_bad_device_and_counts_retries() {
    let (coord, pool, control) = chaos_stack(2, "1:error@2..", RobustnessConfig::default());
    let handles: Vec<_> = (0..8).map(|i| coord.submit(req(i as u64, 16))).collect();
    for h in handles {
        h.wait().expect("retries must absorb the erroring device");
    }
    let snap = coord.metrics();
    assert!(snap.retries_total >= 1, "injected errors must be retried");
    assert!(
        snap.devices_quarantined >= 1,
        "a persistently erroring device must be quarantined"
    );
    assert!(
        snap.devices.iter().any(|d| d.quarantined),
        "the pool snapshot must show the quarantined device"
    );
    drop(coord);
    control.cancel();
    drop(pool);
}

#[test]
fn slow_storm_preserves_output() {
    // A straggler device (30 ms per shard, calls 2..8): slow but correct,
    // so nothing should fail and no retries are *required* (the shard
    // timeout at 150 ms is above the injected delay).
    let (ok, failed, finite) = run_storm("1:slow=30@2..8", 6);
    assert_eq!(failed, 0);
    assert_eq!(ok, 6);
    assert!(finite);
}

#[test]
fn hang_storm_releases_via_timeout_and_quarantine() {
    // Device 1 wedges on every call. The shard timeout re-dispatches its
    // work to device 0 while the worker sits parked (released at teardown
    // by the cancel, or by the 400 ms safety cap), and quarantine stops
    // routing to it.
    let (ok, failed, finite) = run_storm("1:hang@0..", 4);
    assert_eq!(failed, 0, "hangs must be survived via timeout + healthy peer");
    assert_eq!(ok, 4);
    assert!(finite);
}

#[test]
fn corrupt_storm_never_reaches_clients() {
    // Device 1 NaN-corrupts every output from call 2 on. Output validation
    // must convert the corruption into retryable failures — clients only
    // ever see finite samples.
    let (ok, failed, finite) = run_storm("1:corrupt@2..", 6);
    assert_eq!(failed, 0);
    assert_eq!(ok, 6);
    assert!(finite, "NaN corruption must never surface in a served sample");
}

/// Satellite 1 regression: in the historical blocking pool mode (no
/// `shard_timeout`), a backend `Err` must fail the affected requests with
/// a classified error — not panic the round driver or wedge the service.
#[test]
fn erroring_backend_without_retry_fails_requests_cleanly() {
    let model = gmm();
    let spec = FaultSpec::parse("0:error").expect("spec");
    let control = FaultControl::new();
    let inner: Box<dyn EpsBackend> = Box::new(InProcessBackend::new(model));
    let backends: Vec<Box<dyn EpsBackend>> =
        vec![Box::new(FaultyBackend::new(inner, 0, &spec, control.clone()))];
    // Deliberately the historical default config: no retries, no timeout.
    let pool = DevicePool::spawn(backends, PoolConfig::default()).expect("spawn pool");
    let pooled = Arc::new(pool.eps_handle("pooled"));
    // No attach_pool: without pool stats the coordinator cannot see device
    // health, so nothing sheds — the error path itself is under test.
    let coord = Coordinator::start(
        pooled,
        CoordinatorConfig { workers: 1, drivers: 1, ..Default::default() },
    );
    let idle_slots = coord.slots_available();
    for i in 0..3 {
        let e = coord.submit(req(i, 8)).wait().expect_err("every round errors");
        assert!(
            matches!(e.kind(), ErrorKind::Retryable | ErrorKind::Terminal),
            "failure must carry a classified kind, got {:?}",
            e.kind()
        );
    }
    let snap = coord.metrics();
    assert_eq!(snap.failed, 3);
    assert_eq!(snap.completed, 0);
    assert_eq!(coord.slots_available(), idle_slots, "failed solves must release slots");
    drop(coord);
    drop(pool);
}

#[test]
fn degraded_responses_are_bitwise_sequential() {
    // Watermark 0.0 sheds every admission; the default shed mode degrades
    // to the sequential rollout, which must be bitwise the oracle.
    let rb = RobustnessConfig { shed_watermark: Some(0.0), ..Default::default() };
    let (coord, pool, control) = chaos_stack(2, "1:error@1000000..", rb);
    for seed in [0u64, 3, 5] {
        let r = coord.sample(req(seed, 16)).expect("degraded requests complete");
        assert!(r.degraded, "watermark 0.0 must degrade every request");
        assert_eq!(r.rounds, 16, "degraded rounds == sequential steps");
        assert_eq!(r.sample, oracle(seed, 16), "degraded output must be bitwise sequential");
    }
    let snap = coord.metrics();
    assert_eq!(snap.degraded_total, 3);
    assert_eq!(snap.failed, 0, "degradation is success, not failure");
    drop(coord);
    control.cancel();
    drop(pool);
}

#[test]
fn fail_mode_shedding_rejects_with_shed_kind() {
    let rb = RobustnessConfig {
        shed_watermark: Some(0.0),
        shed_mode: ShedMode::Fail,
        ..Default::default()
    };
    let (coord, pool, control) = chaos_stack(2, "1:error@1000000..", rb);
    let idle_slots = coord.slots_available();
    let e = coord.submit(req(0, 16)).wait().expect_err("fail mode rejects");
    assert_eq!(e.kind(), ErrorKind::Shed);
    assert_eq!(coord.metrics().shed_total, 1);
    assert_eq!(coord.slots_available(), idle_slots);
    drop(coord);
    control.cancel();
    drop(pool);
}

/// Review regression: when *every* pool device is quarantined, degraded
/// requests must be served by the pool-independent
/// `RobustnessConfig::fallback_model` — not routed back through the dead
/// pool, where the infallible pooled handle used to panic the intake
/// thread and turn "graceful degradation" into failures.
#[test]
fn all_devices_dead_degrades_via_fallback_model() {
    let rb = RobustnessConfig { fallback_model: Some(gmm()), ..Default::default() };
    let (coord, pool, control) = chaos_stack(2, "0:error,1:error", rb);
    // Early requests burn their retry budgets and fail terminally while
    // the pool quarantines both devices; once the no-healthy-devices
    // trigger fires, admission degrades onto the fallback model and
    // requests succeed bitwise. Readmission probes keep failing, so the
    // pool never recovers — degraded service is the steady state.
    let t0 = Instant::now();
    let mut degraded_ok = 0u64;
    let mut seed = 0u64;
    while degraded_ok < 3 {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "service never reached the degraded steady state \
             (degraded_ok={degraded_ok} after {seed} requests)"
        );
        match coord.sample(req(seed, 16)) {
            Ok(r) => {
                assert!(r.degraded, "with every device dead, success must mean degraded");
                assert_eq!(
                    r.sample,
                    oracle(seed, 16),
                    "fallback rollout must be bitwise the sequential oracle"
                );
                degraded_ok += 1;
            }
            Err(e) => assert!(
                matches!(e.kind(), ErrorKind::Terminal | ErrorKind::Retryable),
                "pre-quarantine failures must stay classified, got {:?}: {e}",
                e.kind()
            ),
        }
        seed += 1;
    }
    let snap = coord.metrics();
    assert!(snap.degraded_total >= 3);
    assert_eq!(snap.completed + snap.failed, seed, "every request resolves exactly once");
    drop(coord);
    control.cancel();
    drop(pool);
}

#[test]
fn expired_deadline_rejected_at_admission_under_faults() {
    let (coord, pool, control) = chaos_stack(2, "1:error@2..", RobustnessConfig::default());
    let idle_slots = coord.slots_available();
    let mut r = req(0, 16);
    r.deadline_ms = Some(0); // already expired when admission sees it
    let e = coord.submit(r).wait().expect_err("zero deadline cannot be met");
    assert_eq!(e.kind(), ErrorKind::DeadlineExceeded);
    assert_eq!(coord.metrics().deadline_misses, 1);
    assert_eq!(coord.slots_available(), idle_slots);
    // The service keeps serving afterwards.
    let ok = coord.sample(req(1, 16)).expect("service survives a deadline miss");
    assert!(ok.sample.iter().all(|v| v.is_finite()));
    drop(coord);
    control.cancel();
    drop(pool);
}

#[test]
fn mid_solve_deadline_expiry_fails_between_rounds() {
    // Both devices straggle 40 ms per shard, so every parallel round costs
    // ≥ 40 ms; a 30 ms deadline must expire after the first round — the
    // per-round sweep fails the session with DeadlineExceeded.
    let (coord, pool, control) =
        chaos_stack(2, "0:slow=40@0.., 1:slow=40@0..", RobustnessConfig::default());
    let idle_slots = coord.slots_available();
    let mut r = req(0, 32);
    r.deadline_ms = Some(30);
    let t0 = Instant::now();
    let e = coord.submit(r).wait().expect_err("deadline must expire mid-solve");
    assert_eq!(e.kind(), ErrorKind::DeadlineExceeded);
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "expiry must be prompt, not after the full solve"
    );
    assert!(coord.metrics().deadline_misses >= 1);
    assert_eq!(coord.slots_available(), idle_slots, "expired sessions release slots");
    drop(coord);
    control.cancel();
    drop(pool);
}

/// Satellite 3: `StreamHandle` consumers under shedding and deadline
/// expiry — streams must terminate (never hang), errors must be
/// classified, and slots must be released.
#[test]
fn stream_handles_terminate_under_shedding_and_deadlines() {
    // Fail-mode shed: stream ends immediately, wait() carries Shed.
    let rb = RobustnessConfig {
        shed_watermark: Some(0.0),
        shed_mode: ShedMode::Fail,
        ..Default::default()
    };
    let (coord, pool, control) = chaos_stack(2, "1:error@1000000..", rb);
    let idle_slots = coord.slots_available();
    let h = coord.submit_streaming(req(0, 16));
    assert!(h.next_chunk().is_none(), "a shed stream must end, not hang");
    assert_eq!(h.wait().expect_err("fail-mode shed rejects").kind(), ErrorKind::Shed);
    assert_eq!(coord.slots_available(), idle_slots);
    drop(coord);
    control.cancel();
    drop(pool);

    // Degrade-mode shed: exactly one full-trajectory chunk, then the
    // stream ends and the response reports the degraded solve.
    let rb = RobustnessConfig { shed_watermark: Some(0.0), ..Default::default() };
    let (coord, pool, control) = chaos_stack(2, "1:error@1000000..", rb);
    let h = coord.submit_streaming(req(1, 16));
    let chunk = h.next_chunk().expect("degraded stream delivers the trajectory");
    assert_eq!(chunk.rows, 0..16);
    assert_eq!(chunk.round, 0);
    assert!(h.next_chunk().is_none(), "exactly one chunk, then stream end");
    let resp = h.wait().expect("degraded stream completes");
    assert!(resp.degraded);
    assert_eq!(&chunk.states[..resp.sample.len()], &resp.sample[..]);
    drop(coord);
    control.cancel();
    drop(pool);

    // Expired deadline: stream ends, wait() carries DeadlineExceeded.
    let (coord, pool, control) = chaos_stack(2, "1:error@2..", RobustnessConfig::default());
    let idle_slots = coord.slots_available();
    let mut r = req(2, 16);
    r.deadline_ms = Some(0);
    let h = coord.submit_streaming(r);
    assert!(h.next_chunk().is_none(), "an expired stream must end, not hang");
    assert_eq!(
        h.wait().expect_err("expired deadline rejects").kind(),
        ErrorKind::DeadlineExceeded
    );
    assert_eq!(coord.slots_available(), idle_slots);
    drop(coord);
    control.cancel();
    drop(pool);
}

#[test]
fn faultless_wrapper_is_inert() {
    // A spec targeting a device index the pool doesn't have: the wrapper
    // must be a pure pass-through and the retry-mode pool must serve the
    // load exactly like a healthy deployment.
    let (ok, failed, finite) = run_storm("9:error@0..", 4);
    assert_eq!((ok, failed), (4, 0));
    assert!(finite);
}

// --- chaos over HTTP (ISSUE 10) -------------------------------------------
//
// The same fault-injected stacks, but driven through the serving front:
// the transport must surface classified statuses (never a hung socket),
// conserve every admitted request in the metrics, and propagate client
// disconnects into session cancellation.

/// Wrap a chaos stack in the HTTP front. Teardown order matters and is
/// the caller's job: drop(server) → drop(coord Arc) → control.cancel() →
/// drop(pool).
fn http_chaos_stack(
    devices: usize,
    spec: &str,
    robustness: RobustnessConfig,
) -> (parataa::serve::HttpServer, Arc<Coordinator>, DevicePool, FaultControl) {
    let (coord, pool, control) = chaos_stack(devices, spec, robustness);
    let coord = Arc::new(coord);
    let server = parataa::serve::HttpServer::start(
        Arc::clone(&coord),
        Arc::new(parataa::serve::TenantRegistry::open()),
        "127.0.0.1:0",
        parataa::serve::HttpConfig { accept_threads: 6, ..Default::default() },
    )
    .expect("start http front over chaos pool");
    (server, coord, pool, control)
}

fn wire_body(seed: u64, steps: usize) -> String {
    parataa::serve::wire::request_to_json(&req(seed, steps)).expect("encode").to_string()
}

#[test]
fn http_front_over_a_chaotic_pool_conserves_requests_and_slots() {
    // Device 1 errors from its 3rd shard on: the retry path re-dispatches
    // to device 0, so most requests succeed; any failure must surface as
    // a classified 5xx, and accounting must balance exactly.
    let (server, coord, pool, control) =
        http_chaos_stack(2, "1:error@2..", RobustnessConfig::default());
    let addr = server.local_addr();
    let idle_slots = coord.slots_available();
    let n_req = 16usize;
    let workers: Vec<_> = (0..n_req)
        .map(|i| {
            let tenant = if i % 2 == 0 { "even" } else { "odd" };
            std::thread::spawn(move || {
                let body = wire_body(i as u64, 16);
                parataa::serve::client::post_json(addr, "/v1/sample", Some(tenant), &body)
                    .expect("transport must answer even when the solve fails")
                    .status
            })
        })
        .collect();
    let mut ok = 0u64;
    let mut failed = 0u64;
    for w in workers {
        match w.join().expect("client thread") {
            200 => ok += 1,
            429 | 500 | 503 | 504 => failed += 1,
            other => panic!("unclassified status {other} out of a chaos run"),
        }
    }
    assert_eq!(ok + failed, n_req as u64, "every request got exactly one answer");
    let snap = coord.metrics();
    assert_eq!(
        snap.completed + snap.failed,
        n_req as u64,
        "metrics must conserve requests across the HTTP front"
    );
    assert_eq!(snap.completed, ok, "HTTP 200s must equal completed solves");
    assert_eq!(snap.sessions_in_flight, 0);
    assert_eq!(coord.slots_available(), idle_slots, "slots must return after the storm");
    drop(server);
    drop(coord);
    control.cancel();
    drop(pool);
}

#[test]
fn sse_streams_terminate_under_fault_storms() {
    // Hang + error storm behind the stream path: every stream must reach
    // a terminal frame (`done` or `error`) — no socket may hang open.
    let (server, coord, pool, control) =
        http_chaos_stack(2, "0:slow=40@0..,1:error@3..", RobustnessConfig::default());
    let addr = server.local_addr();
    let streams: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let conn = parataa::serve::client::SseConn::open(
                    addr,
                    Some("sse"),
                    &wire_body(20 + i, 16),
                )
                .expect("stream opens");
                conn.collect()
            })
        })
        .collect();
    for (i, s) in streams.into_iter().enumerate() {
        let events = s.join().expect("stream consumer");
        let last = events.last().unwrap_or_else(|| panic!("stream {i} emitted nothing"));
        assert!(
            last.event == "done" || last.event == "error",
            "stream {i} ended without a terminal frame: {events:?}"
        );
    }
    let snap = coord.metrics();
    assert_eq!(snap.completed + snap.failed, 4, "streams must be conserved");
    assert_eq!(snap.sessions_in_flight, 0);
    drop(server);
    drop(coord);
    control.cancel();
    drop(pool);
}

#[test]
fn mid_stream_client_disconnect_cancels_the_session_and_frees_slots() {
    // A deliberately long solve (96 steps, window 4, fixed-point: the
    // front advances a few rows per round → dozens of rounds and chunk
    // writes), so the disconnect lands long before completion.
    let (server, coord, pool, control) =
        http_chaos_stack(2, "9:error@0..", RobustnessConfig::default());
    let addr = server.local_addr();
    let idle_slots = coord.slots_available();
    let mut r = req(3, 96);
    r.window = Some(4);
    r.method = parataa::solver::Method::FixedPoint;
    let body = parataa::serve::wire::request_to_json(&r).expect("encode").to_string();
    let mut conn = parataa::serve::client::SseConn::open(addr, Some("dropper"), &body)
        .expect("stream opens");
    let first = conn.next_event().expect("at least one chunk before the drop");
    assert_eq!(first.event, "chunk");
    // Vanish mid-stream: dropping the connection closes the socket with
    // unread data queued, so the server's next SSE write fails and must
    // cancel the session.
    drop(conn);
    let t0 = Instant::now();
    loop {
        let snap = coord.metrics();
        if snap.cancelled_total == 1 && snap.sessions_in_flight == 0 {
            assert_eq!(snap.failed, 1, "a cancelled session is a failed request");
            assert_eq!(snap.completed, 0);
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "disconnect was never propagated: {snap:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(coord.slots_available(), idle_slots, "cancelled sessions release slots");
    // The freed capacity is immediately serviceable.
    let ok = parataa::serve::client::post_json(addr, "/v1/sample", None, &wire_body(4, 12))
        .expect("service alive after disconnect");
    assert_eq!(ok.status, 200);
    drop(server);
    drop(coord);
    control.cancel();
    drop(pool);
}
