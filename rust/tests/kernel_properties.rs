//! Property sweep for the explicit-SIMD kernel suite
//! (`linalg::kernels`): every reducing kernel must honor the 8-lane
//! reduction-order contract *bitwise*, regardless of which instruction
//! set executed it.
//!
//! Three oracles, in decreasing strictness:
//!
//! 1. **the contract itself** — a from-the-docs reimplementation (lane
//!    `i mod 8`, tail element `j` into lane `j − n8`, fixed pairwise
//!    tree) that the dispatched kernel must match bit-for-bit;
//! 2. **the `*_scalar` fallback** — the dispatched path (AVX where the
//!    machine has it) must agree bitwise, across every length 0..=257 so
//!    all eight remainder classes and several full-lane blocks are hit;
//! 3. **[`proplite::naive_dot`]** — the sequential-accumulator oracle,
//!    matched to f64 relative tolerance (reassociation moves last-ulp
//!    rounding; the contract changes the order on purpose).
//!
//! The batched/tiled forms (`multi_dot8`, and its `DOT_TILE` blocking)
//! additionally must be bitwise equal to their one-slot-at-a-time
//! composition — that equivalence is what lets the Gram refresh batch
//! slots without perturbing the solver's goldens.

use parataa::linalg::kernels::{
    axpy, axpy_scalar, dot8, dot8_scalar, multi_dot8, multi_dot8_scalar, residual_norm_sq,
    residual_norm_sq_scalar, DOT_TILE, LANES,
};
use parataa::util::proplite::{f32_in, forall, naive_dot, size_in};
use parataa::util::rng::Pcg64;

fn vec_of(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| f32_in(rng, -1.5, 1.5)).collect()
}

/// The documented reduction-order contract, reimplemented verbatim from
/// the module docs (not shared with the kernel code, so a kernel bug
/// can't hide in a shared helper): element `i` → lane `i mod 8`, tail
/// element `j ∈ [n8, n)` → lane `j − n8`, lanes closed by the fixed
/// pairwise tree.
fn contract_dot(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let n8 = n - n % LANES;
    let mut lanes = [0.0f64; LANES];
    for i in 0..n8 {
        lanes[i % LANES] += (a[i] as f64) * (b[i] as f64);
    }
    for j in n8..n {
        lanes[j - n8] += (a[j] as f64) * (b[j] as f64);
    }
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
}

/// Every length 0..=257 (all remainder classes, several full blocks):
/// dispatched == scalar == the documented contract, bit for bit; and
/// all three sit within f64 noise of the sequential naive oracle.
#[test]
fn dot8_honors_the_contract_at_every_length() {
    let mut rng = Pcg64::seeded(0xd07);
    for n in 0..=257usize {
        let a = vec_of(&mut rng, n);
        let b = vec_of(&mut rng, n);
        let fast = dot8(&a, &b);
        let slow = dot8_scalar(&a, &b);
        let contract = contract_dot(&a, &b);
        assert_eq!(fast.to_bits(), slow.to_bits(), "dispatch vs scalar, n={n}");
        assert_eq!(fast.to_bits(), contract.to_bits(), "dispatch vs contract, n={n}");
        let oracle = naive_dot(&a, &b);
        assert!(
            (fast - oracle).abs() <= 1e-9 * (1.0 + oracle.abs()),
            "n={n}: dot8 {fast} vs naive {oracle}"
        );
    }
}

/// IEEE multiplication commutes elementwise and the lane assignment
/// depends only on the index, so dot8 is exactly symmetric — the property
/// the b-projection batching relies on to flip argument order freely.
#[test]
fn dot8_is_bitwise_symmetric() {
    forall("dot8 symmetry", 32, |rng, _| {
        let n = size_in(rng, 0, 300);
        let a = vec_of(rng, n);
        let b = vec_of(rng, n);
        if dot8(&a, &b).to_bits() != dot8(&b, &a).to_bits() {
            return Err(format!("n={n}: dot8(a,b) != dot8(b,a)"));
        }
        Ok(())
    });
}

/// The batched kernel must reproduce its per-slot composition bitwise —
/// including lengths straddling the `DOT_TILE` cache blocks, where a
/// broken (non-8-aligned) tiling would move elements between lanes.
#[test]
fn multi_dot8_is_bitwise_per_slot_composition() {
    let mut rng = Pcg64::seeded(0x3017);
    let lengths = [
        0usize,
        1,
        7,
        LANES,
        129,
        DOT_TILE - 1,
        DOT_TILE,
        DOT_TILE + LANES,
        2 * DOT_TILE + 13,
    ];
    for &n in &lengths {
        for k in [1usize, 3, 8] {
            let a = vec_of(&mut rng, n);
            let slots: Vec<Vec<f32>> = (0..k).map(|_| vec_of(&mut rng, n)).collect();
            let refs: Vec<&[f32]> = slots.iter().map(|s| s.as_slice()).collect();
            let mut acc = vec![0.0f64; k * LANES];
            let mut out = vec![0.0f64; k];
            let mut out_scalar = vec![0.0f64; k];
            multi_dot8(&a, &refs, &mut acc, &mut out);
            multi_dot8_scalar(&a, &refs, &mut acc, &mut out_scalar);
            for j in 0..k {
                let per_slot = dot8(&a, &slots[j]);
                assert_eq!(
                    out[j].to_bits(),
                    per_slot.to_bits(),
                    "batched vs per-slot dot8, n={n} k={k} slot={j}"
                );
                assert_eq!(
                    out_scalar[j].to_bits(),
                    per_slot.to_bits(),
                    "scalar batch vs per-slot dot8, n={n} k={k} slot={j}"
                );
            }
        }
    }
}

/// axpy is elementwise (no reduction), so SIMD vs scalar agreement must be
/// exact per element at every length and for awkward alphas.
#[test]
fn axpy_matches_scalar_at_every_length() {
    let mut rng = Pcg64::seeded(0xa999);
    for n in 0..=257usize {
        let base = vec_of(&mut rng, n);
        let x = vec_of(&mut rng, n);
        let alpha = f32_in(&mut rng, -2.0, 2.0);
        let mut fast = base.clone();
        let mut slow = base.clone();
        axpy(&mut fast, &x, alpha);
        axpy_scalar(&mut slow, &x, alpha);
        assert_eq!(fast, slow, "axpy dispatch vs scalar, n={n} alpha={alpha}");
    }
}

/// The fused residual kernel: dispatched == scalar bitwise at every
/// length, and both within f64 noise of the unfused naive loop. The f32
/// inner expression's evaluation order is part of the contract — the AVX
/// path replays `((xp − a·xt) − b·e) − c·ξ` exactly.
#[test]
fn residual_norm_sq_matches_scalar_at_every_length() {
    let mut rng = Pcg64::seeded(0x4e5);
    for n in 0..=257usize {
        let xp = vec_of(&mut rng, n);
        let xt = vec_of(&mut rng, n);
        let e = vec_of(&mut rng, n);
        let xi = vec_of(&mut rng, n);
        let (a, b, c) = (
            f32_in(&mut rng, 0.5, 1.0),
            f32_in(&mut rng, -0.5, 0.5),
            f32_in(&mut rng, -0.2, 0.2),
        );
        let fast = residual_norm_sq(&xp, &xt, &e, &xi, a, b, c);
        let slow = residual_norm_sq_scalar(&xp, &xt, &e, &xi, a, b, c);
        assert_eq!(fast.to_bits(), slow.to_bits(), "dispatch vs scalar, n={n}");
        let naive: f64 = (0..n)
            .map(|i| {
                let r = xp[i] - a * xt[i] - b * e[i] - c * xi[i];
                (r as f64) * (r as f64)
            })
            .sum();
        assert!(
            (fast - naive).abs() <= 1e-9 * (1.0 + naive.abs()),
            "n={n}: fused {fast} vs naive {naive}"
        );
        assert!(fast >= 0.0, "a sum of squares cannot go negative (n={n})");
    }
}

/// Randomized cross-check of the whole suite on one draw: feeding the
/// same data through the batched, per-slot, scalar, and contract paths
/// yields one bit pattern.
#[test]
fn all_dot_paths_agree_on_random_draws() {
    forall("all dot paths agree", 24, |rng, _| {
        let n = size_in(rng, 0, 2 * DOT_TILE + 64);
        let k = size_in(rng, 1, 8);
        let a = vec_of(rng, n);
        let slots: Vec<Vec<f32>> = (0..k).map(|_| vec_of(rng, n)).collect();
        let refs: Vec<&[f32]> = slots.iter().map(|s| s.as_slice()).collect();
        let mut acc = vec![0.0f64; k * LANES];
        let mut out = vec![0.0f64; k];
        multi_dot8(&a, &refs, &mut acc, &mut out);
        for j in 0..k {
            let bits = out[j].to_bits();
            if bits != dot8(&a, &slots[j]).to_bits()
                || bits != dot8_scalar(&a, &slots[j]).to_bits()
                || bits != contract_dot(&a, &slots[j]).to_bits()
            {
                return Err(format!("n={n} k={k} slot={j}: path divergence"));
            }
        }
        Ok(())
    });
}
