//! Golden equivalence: the session-driven `solve()` path must be
//! **bit-identical** to the pre-refactor blocking driver.
//!
//! `reference_solve` below is a frozen, line-for-line copy of the seed
//! `solver::driver::solve_with` loop body (as of the PR that extracted
//! `SolverSession`). It is deliberately NOT shared with library code: it is
//! the oracle the refactor is measured against. If a future change breaks
//! these tests, either the session semantics drifted (a bug) or the solver
//! algorithm itself was intentionally changed — in the latter case update
//! this reference in the same commit and say so.

use parataa::equations::{eval_fk, residual_sq, States};
use parataa::model::gmm::GmmEps;
use parataa::model::Cond;
use parataa::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
use parataa::solver::{
    history::History, update::apply_update, Method, Problem, SolveStrategy, SolverConfig,
    WindowPolicy,
};
use parataa::util::rng::Pcg64;

/// Per-round facts the reference records (mirrors `IterationRecord`).
struct RefRecord {
    iter: usize,
    t1: usize,
    t2: usize,
    nfe: usize,
    residual_sum: f64,
    max_residual_ratio: f64,
    converged_rows: usize,
    row_residuals: Vec<f64>,
}

struct RefResult {
    xs: States,
    iterations: usize,
    total_nfe: usize,
    converged: bool,
    records: Vec<RefRecord>,
}

/// Frozen copy of the seed blocking driver (Algorithm 1).
fn reference_solve(problem: &Problem, cfg: &SolverConfig) -> RefResult {
    let coeffs = problem.coeffs;
    let model = problem.model;
    let t_count = coeffs.steps;
    let d = model.dim();
    let k = cfg.k.clamp(1, t_count);
    let w = cfg.window.clamp(1, t_count);
    let t_init = problem.t_init.unwrap_or(t_count).clamp(1, t_count);

    let mut xs = States::zeros(t_count, d);
    xs.set_row(t_count, problem.xi.row(t_count));
    match (&problem.init, t_init) {
        (Some(init), _) => {
            assert_eq!(init.d, d);
            assert_eq!(init.rows(), t_count + 1);
            xs.data[..t_count * d].copy_from_slice(&init.data[..t_count * d]);
        }
        (None, _) => {
            let mut rng = Pcg64::new(problem.init_seed(), 0x1717_c0de);
            rng.fill_gaussian(&mut xs.data[..t_count * d]);
        }
    }

    let mut eps = States::zeros(t_count, d);
    let mut eps_valid = vec![false; t_count + 1];

    let hist_cols = if cfg.method == Method::FixedPoint { 0 } else { cfg.m.saturating_sub(1) };
    let mut history = History::new(hist_cols, t_count, d);
    let mut prev_x = vec![0.0f32; t_count * d];
    let mut prev_r = vec![0.0f32; t_count * d];
    let mut prev_active: Option<(usize, usize)> = None;

    let mut f_vals = vec![0.0f32; t_count * d];
    let mut r_vals = vec![0.0f32; t_count * d];
    let mut dx_buf = vec![0.0f32; t_count * d];
    let mut df_buf = vec![0.0f32; t_count * d];
    let mut batch_x: Vec<f32> = Vec::new();
    let mut batch_t: Vec<usize> = Vec::new();
    let cond_pool: Vec<Cond> = vec![problem.cond.clone(); t_count + 1];
    let mut batch_out: Vec<f32> = Vec::new();

    let mut last_residual: Vec<Option<f64>> = vec![None; t_count];
    let thresholds: Vec<f64> = (0..t_count).map(|p| coeffs.threshold(p, cfg.tol, d)).collect();

    let mut batch_states: Vec<usize> = Vec::new();
    let mut t2 = t_init - 1;
    let mut t1 = (t2 + 1).saturating_sub(w);
    let mut total_nfe = 0usize;
    let mut records: Vec<RefRecord> = Vec::new();
    let mut converged = false;

    for iter in 1..=cfg.s_max {
        batch_x.clear();
        batch_t.clear();
        batch_states.clear();
        let top_needed = (t2 + 1).min(t_count);
        for j in t1 + 1..=top_needed {
            let active = j <= t2;
            if active || !eps_valid[j] {
                batch_states.push(j);
                batch_x.extend_from_slice(xs.row(j));
                batch_t.push(coeffs.train_t[j]);
            }
        }
        batch_out.resize(batch_states.len() * d, 0.0);
        model.eps_batch(
            &batch_x,
            &batch_t,
            &cond_pool[..batch_states.len()],
            cfg.guidance,
            &mut batch_out,
        );
        total_nfe += batch_states.len();
        for (bi, &j) in batch_states.iter().enumerate() {
            eps.set_row(j, &batch_out[bi * d..(bi + 1) * d]);
            eps_valid[j] = true;
        }

        for p in t1..=t2 {
            last_residual[p] = Some(residual_sq(coeffs, &xs, &eps, &problem.xi, p));
        }
        let mut new_t2: Option<usize> = None;
        for p in (t1..=t2).rev() {
            if last_residual[p].unwrap() > thresholds[p] {
                new_t2 = Some(p);
                break;
            }
        }
        let residual_sum: f64 = last_residual.iter().flatten().sum();
        let max_ratio = (t1..=t2)
            .map(|p| last_residual[p].unwrap() / thresholds[p])
            .fold(0.0f64, f64::max);

        let (nt1, nt2, done) = match new_t2 {
            None if t1 == 0 => (t1, t2, true),
            None => {
                let nt2 = t1 - 1;
                ((nt2 + 1).saturating_sub(w), nt2, false)
            }
            Some(nt2) => ((nt2 + 1).saturating_sub(w), nt2, false),
        };

        let row_residuals: Vec<f64> =
            last_residual.iter().map(|r| r.unwrap_or(f64::NAN)).collect();

        if done {
            converged = true;
            records.push(RefRecord {
                iter,
                t1,
                t2,
                nfe: batch_states.len(),
                residual_sum,
                max_residual_ratio: max_ratio,
                converged_rows: t_count,
                row_residuals,
            });
            break;
        }
        t1 = nt1;
        t2 = nt2;

        let boundary = if cfg.clamp_boundary { t2 + 1 } else { t_count };
        r_vals.fill(0.0);
        for p in t1..=t2 {
            let row = p * d..(p + 1) * d;
            eval_fk(coeffs, &xs, &eps, &problem.xi, k, boundary, p, &mut f_vals[row.clone()]);
            for i in row.clone() {
                r_vals[i] = f_vals[i] - xs.data[i];
            }
        }

        if hist_cols > 0 {
            if let Some((p1, p2)) = prev_active {
                dx_buf.fill(0.0);
                df_buf.fill(0.0);
                let lo = t1.max(p1);
                let hi = t2.min(p2);
                if lo <= hi {
                    for i in lo * d..(hi + 1) * d {
                        dx_buf[i] = xs.data[i] - prev_x[i];
                        df_buf[i] = r_vals[i] - prev_r[i];
                    }
                    history.push(&dx_buf, &df_buf);
                }
            }
            prev_x.copy_from_slice(&xs.data[..t_count * d]);
            prev_r.copy_from_slice(&r_vals);
            prev_active = Some((t1, t2));
        }

        apply_update(
            cfg.method,
            &mut xs.data[..t_count * d],
            &f_vals,
            &r_vals,
            &history,
            t1,
            t2,
            t_count,
            d,
            cfg.lambda,
            cfg.safeguard,
        );

        records.push(RefRecord {
            iter,
            t1,
            t2,
            nfe: batch_states.len(),
            residual_sum,
            max_residual_ratio: max_ratio,
            converged_rows: t_count - (t2 + 1),
            row_residuals,
        });
    }

    let iterations = records.len();
    RefResult { xs, iterations, total_nfe, converged, records }
}

// --- test scaffolding ------------------------------------------------------

const ALL_METHODS: [Method; 4] =
    [Method::FixedPoint, Method::AndersonStd, Method::AndersonUpperTri, Method::Taa];

fn gmm(d: usize, n_comp: usize, seed: u64) -> GmmEps {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let mut rng = Pcg64::seeded(seed);
    let means: Vec<f32> = (0..n_comp * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    GmmEps::new(means, d, 0.25, ns.alpha_bars.clone())
}

fn coeffs(steps: usize, kind: SamplerKind) -> SamplerCoeffs {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    SamplerCoeffs::new(&ns, kind, steps)
}

fn cfg_for(method: Method, steps: usize, safeguard: bool, window: usize) -> SolverConfig {
    SolverConfig {
        k: 4,
        method,
        m: 3,
        lambda: 1e-4,
        safeguard,
        window,
        tol: 1e-4,
        s_max: 8 * steps,
        guidance: 2.0,
        clamp_boundary: true,
        // The golden contract is defined for the static window; the
        // adaptive controller is covered by its own tests.
        window_policy: WindowPolicy::Fixed,
        // Likewise for the single-fidelity path: the multi-fidelity
        // strategies have their own goldens below (compositional for
        // DraftRefine, determinism for Parareal).
        strategy: SolveStrategy::PlainTaa,
        // Single-threaded by default; the parallelism sweep below pins the
        // multi-threaded paths against this same reference.
        parallelism: 1,
    }
}

/// Bit-for-bit comparison of a session-driven solve against the frozen
/// reference: trajectory, rounds, NFE, convergence flag, and every
/// per-round record.
fn assert_golden(problem: &Problem, cfg: &SolverConfig, label: &str) {
    let golden = reference_solve(problem, cfg);
    let actual = parataa::solver::solve(problem, cfg);
    assert_eq!(actual.xs.data, golden.xs.data, "{label}: xs diverged");
    assert_eq!(actual.iterations, golden.iterations, "{label}: iterations");
    assert_eq!(actual.total_nfe, golden.total_nfe, "{label}: total_nfe");
    assert_eq!(actual.converged, golden.converged, "{label}: converged");
    assert_eq!(actual.records.len(), golden.records.len(), "{label}: record count");
    for (a, g) in actual.records.iter().zip(golden.records.iter()) {
        assert_eq!(a.iter, g.iter, "{label}: round index");
        assert_eq!((a.t1, a.t2), (g.t1, g.t2), "{label}: window at round {}", g.iter);
        assert_eq!(a.nfe, g.nfe, "{label}: nfe at round {}", g.iter);
        assert_eq!(a.converged_rows, g.converged_rows, "{label}: front at round {}", g.iter);
        assert_eq!(
            a.residual_sum.to_bits(),
            g.residual_sum.to_bits(),
            "{label}: residual_sum at round {}",
            g.iter
        );
        assert_eq!(
            a.max_residual_ratio.to_bits(),
            g.max_residual_ratio.to_bits(),
            "{label}: max ratio at round {}",
            g.iter
        );
        let ar: Vec<u64> = a.row_residuals.iter().map(|v| v.to_bits()).collect();
        let gr: Vec<u64> = g.row_residuals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ar, gr, "{label}: row residuals at round {}", g.iter);
    }
}

/// All four methods × safeguard on/off, cold start, full window.
#[test]
fn golden_cold_start_all_methods() {
    let steps = 14;
    let sc = coeffs(steps, SamplerKind::Ddim);
    let model = gmm(6, 4, 33);
    for (i, method) in ALL_METHODS.iter().enumerate() {
        let problem = Problem::new(&sc, &model, Cond::Class(i % 4), 100 + i as u64);
        for safeguard in [true, false] {
            let cfg = cfg_for(*method, steps, safeguard, steps);
            assert_golden(
                &problem,
                &cfg,
                &format!("cold {} safeguard={safeguard}", method.label()),
            );
        }
    }
}

/// All four methods × safeguard on/off, warm start (trajectory init with a
/// frozen tail, §4.2) — exercises the `init`/`t_init` admission path.
#[test]
fn golden_warm_start_all_methods() {
    let steps = 14;
    let sc = coeffs(steps, SamplerKind::Ddim);
    let model = gmm(6, 4, 34);
    // Donor trajectory from a converged cold solve.
    let donor_problem = Problem::new(&sc, &model, Cond::Class(0), 7);
    let donor = parataa::solver::solve(&donor_problem, &cfg_for(Method::Taa, steps, true, steps));
    assert!(donor.converged, "donor must converge");
    for (i, method) in ALL_METHODS.iter().enumerate() {
        for safeguard in [true, false] {
            let mut problem = Problem::new(&sc, &model, Cond::Class(1), 7);
            problem.xi = donor_problem.xi.clone();
            problem.init = Some(donor.xs.clone());
            problem.t_init = Some(10);
            let cfg = cfg_for(*method, steps, safeguard, steps);
            assert_golden(
                &problem,
                &cfg,
                &format!("warm {} safeguard={safeguard} ({i})", method.label()),
            );
        }
    }
}

/// DDPM (stochastic sampler, nonzero ξ coupling) and a sliding window —
/// the window-slide/history-clamp interplay is where a state-machine port
/// would most plausibly drift.
#[test]
fn golden_ddpm_and_sliding_window() {
    let steps = 16;
    let model = gmm(5, 3, 35);
    let sc_ddpm = coeffs(steps, SamplerKind::Ddpm);
    for method in [Method::FixedPoint, Method::Taa] {
        let problem = Problem::new(&sc_ddpm, &model, Cond::Class(2), 55);
        assert_golden(
            &problem,
            &cfg_for(method, steps, true, steps),
            &format!("ddpm {}", method.label()),
        );
    }
    let sc_ddim = coeffs(steps, SamplerKind::Ddim);
    for w in [3usize, 6, 11] {
        let problem = Problem::new(&sc_ddim, &model, Cond::Class(1), 56);
        let mut cfg = cfg_for(Method::Taa, steps, true, w);
        cfg.s_max = 30 * steps;
        assert_golden(&problem, &cfg, &format!("window w={w}"));
    }
}

/// The `parallelism` knob must be invisible in the output: every thread
/// count reproduces the frozen single-threaded reference bit-for-bit —
/// trajectory, record stream, and residual bits included. Fixed per-row
/// owners plus solver-thread reductions are what make this hold.
#[test]
fn golden_parallelism_sweep() {
    let steps = 14;
    let sc = coeffs(steps, SamplerKind::Ddim);
    let model = gmm(6, 4, 37);
    let problem = Problem::new(&sc, &model, Cond::Class(1), 101);
    for method in [Method::Taa, Method::AndersonStd, Method::AndersonUpperTri] {
        for threads in [1usize, 2, 4, 8] {
            let mut cfg = cfg_for(method, steps, true, steps);
            cfg.parallelism = threads;
            assert_golden(&problem, &cfg, &format!("{} threads={threads}", method.label()));
        }
    }
    // A sliding window at every thread count — ranged history pushes and
    // clamped active rows are where chunked ownership could most
    // plausibly drift from the sequential path.
    for threads in [2usize, 4, 8] {
        let mut cfg = cfg_for(Method::Taa, steps, true, 5);
        cfg.s_max = 30 * steps;
        cfg.parallelism = threads;
        assert_golden(&problem, &cfg, &format!("windowed threads={threads}"));
    }
}

/// DraftRefine golden: the strategy run must be bit-identical to its
/// composition — a frozen-reference coarse solve on the subsetted grid,
/// `lift_trajectory`, and a frozen-reference fine solve warm-started from
/// the lift (the same three pieces `SolverSession` wires together).
#[test]
fn golden_draft_refine_composes_from_the_reference() {
    use parataa::solver::strategy::lift_trajectory;
    use parataa::solver::DraftRefineConfig;

    let steps = 16;
    let d = 5;
    let sc = coeffs(steps, SamplerKind::Ddim);
    let model = gmm(d, 3, 38);
    let problem = Problem::new(&sc, &model, Cond::Class(1), 91);

    let dr = DraftRefineConfig::default();
    let mut cfg = cfg_for(Method::Taa, steps, true, steps);
    cfg.strategy = SolveStrategy::DraftRefine(dr.clone());
    let actual = parataa::solver::solve(&problem, &cfg);
    assert!(actual.converged, "strategy run must converge");

    // Piece 1: the draft, solved by the frozen reference on the coarsened
    // grid (node-mapped ξ, same seed — the construction SolverSession::new
    // uses).
    let c_steps = dr.resolve_coarse_steps(steps);
    let (coarse_coeffs, idx0) = sc.coarsen(c_steps);
    let mut coarse_problem = Problem::new(&coarse_coeffs, &model, Cond::Class(1), 91);
    let mut cxi = States::zeros(c_steps, d);
    for (c, &r) in idx0.iter().enumerate() {
        cxi.set_row(c, problem.xi.row(r));
    }
    coarse_problem.xi = cxi;
    let mut ccfg = cfg_for(Method::Taa, steps, true, steps);
    ccfg.window = c_steps;
    ccfg.tol = dr.resolve_tol(cfg.tol);
    ccfg.s_max = dr.resolve_rounds(c_steps);
    let coarse = reference_solve(&coarse_problem, &ccfg);

    // Piece 2: lift onto the fine grid; piece 3: the fine refinement,
    // warm-started from the lift.
    let mut lifted = States::zeros(steps, d);
    lift_trajectory(&sc.state_alpha_bars(), &coarse.xs, &idx0, &mut lifted);
    let mut fine_problem = Problem::new(&sc, &model, Cond::Class(1), 91);
    fine_problem.init = Some(lifted);
    let fine = reference_solve(&fine_problem, &cfg_for(Method::Taa, steps, true, steps));
    assert!(fine.converged, "composition must converge");

    assert_eq!(actual.xs.data, fine.xs.data, "draft-refine xs != composition");
    assert_eq!(actual.total_nfe, coarse.total_nfe + fine.total_nfe, "NFE must sum");
    assert_eq!(actual.iterations, coarse.iterations + fine.iterations, "rounds must sum");
    // Draft rounds account the coarse solve's per-round cost on the outer
    // session without moving the fine front.
    for (a, g) in actual.records.iter().take(coarse.iterations).zip(&coarse.records) {
        assert_eq!(a.nfe, g.nfe, "draft round {} nfe", g.iter);
        assert_eq!(a.converged_rows, 0, "draft rounds freeze no fine rows");
        assert_eq!(
            a.residual_sum.to_bits(),
            g.residual_sum.to_bits(),
            "draft round {} residual_sum",
            g.iter
        );
    }
    // The fine phase replays the reference records with the round index
    // offset by the draft length.
    for (a, g) in actual.records.iter().skip(coarse.iterations).zip(&fine.records) {
        assert_eq!(a.iter, g.iter + coarse.iterations, "fine round index");
        assert_eq!((a.t1, a.t2), (g.t1, g.t2), "fine round {} window", g.iter);
        assert_eq!(a.nfe, g.nfe, "fine round {} nfe", g.iter);
        assert_eq!(
            a.residual_sum.to_bits(),
            g.residual_sum.to_bits(),
            "fine round {} residual_sum",
            g.iter
        );
    }
}

/// Parareal golden: run-twice bitwise determinism, coarse sweeps actually
/// interleaving, and the manual session drive bit-identical to the
/// blocking `solve()` wrapper.
#[test]
fn golden_parareal_is_deterministic() {
    use parataa::model::EpsModel;
    use parataa::solver::{PararealConfig, SolverSession};

    let steps = 16;
    let sc = coeffs(steps, SamplerKind::Ddim);
    let model = gmm(5, 3, 39);
    let problem = Problem::new(&sc, &model, Cond::Class(2), 92);
    let mut cfg = cfg_for(Method::Taa, steps, true, steps);
    cfg.strategy = SolveStrategy::Parareal(PararealConfig::default());

    let a = parataa::solver::solve(&problem, &cfg);
    let b = parataa::solver::solve(&problem, &cfg);
    assert!(a.converged, "parareal run must converge");
    assert_eq!(a.xs.data, b.xs.data, "parareal must be run-twice deterministic");
    assert_eq!(a.total_nfe, b.total_nfe, "NFE must be deterministic");
    assert_eq!(a.iterations, b.iterations, "rounds must be deterministic");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!((x.t1, x.t2, x.nfe), (y.t1, y.t2, y.nfe), "round {} facts", x.iter);
        assert_eq!(
            x.residual_sum.to_bits(),
            y.residual_sum.to_bits(),
            "round {} residual_sum",
            x.iter
        );
    }

    // Manual drive of the session state machine == the wrapper, and the
    // coarse sweeps really ran (zero would mean the strategy degraded to
    // plain TAA silently).
    let mut session = SolverSession::new(&problem, &cfg);
    let d = session.dim();
    let mut eps = Vec::new();
    loop {
        let n = match session.pending() {
            None => break,
            Some(batch) => {
                eps.resize(batch.len() * d, 0.0);
                model.eps_batch(batch.x, batch.t, batch.conds, batch.guidance, &mut eps);
                batch.len()
            }
        };
        if session.resume(&eps[..n * d]).done {
            break;
        }
    }
    assert!(session.coarse_rounds() > 0, "parareal must run coarse sweeps");
    let by_session = session.finish();
    assert_eq!(by_session.xs.data, a.xs.data, "session drive != solve()");
    assert_eq!(by_session.total_nfe, a.total_nfe, "session drive NFE != solve()");
}

/// Round-budget exhaustion must truncate identically (records, NFE, and
/// the not-converged flag).
#[test]
fn golden_s_max_truncation() {
    let steps = 12;
    let sc = coeffs(steps, SamplerKind::Ddim);
    let model = gmm(4, 3, 36);
    let problem = Problem::new(&sc, &model, Cond::Class(0), 77);
    let mut cfg = cfg_for(Method::Taa, steps, true, steps);
    cfg.tol = 1e-12; // unreachable: force the s_max exit
    cfg.s_max = 5;
    assert_golden(&problem, &cfg, "s_max truncation");
}
