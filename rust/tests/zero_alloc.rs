//! Steady-state allocation discipline of the TAA numeric core.
//!
//! A counting `#[global_allocator]` wraps the system allocator; after a
//! warmup round has sized every workspace, a window of solver-round work —
//! history pushes, cached suffix-Gram scans, and `apply_update_ws` for all
//! three Anderson variants — must perform **zero** heap allocations. A
//! second window repeats the rounds through the `RowPool` fork-join path
//! (parallelism = 4): the allocator counts every thread, so the window
//! proves the workers are allocation-free too.
//!
//! Tracing is **enabled** (but unsubscribed) for the whole window: the
//! ISSUE-6 recorder must cost at most a few atomic stores into the
//! thread's pre-allocated ring per instrumented call, never a heap
//! allocation. The ring itself is allocated at the thread's first recorded
//! event, which the warmup below triggers before the measured window.
//!
//! One `#[test]` only: the counter is process-global, and concurrent tests
//! in the same binary would pollute the window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use parataa::linalg::{suffix_grams_into, SuffixGrams};
use parataa::solver::history::History;
use parataa::solver::update::{apply_update_par, apply_update_ws};
use parataa::solver::{Method, Workspace};
use parataa::util::rng::Pcg64;
use parataa::util::threadpool::RowPool;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_rounds_allocate_nothing() {
    // The ISSUE-4 regime: W=100 rows, D=256 features, m=8 history columns.
    let (w, d, m) = (100usize, 256usize, 8usize);
    let mut rng = Pcg64::seeded(77);

    // Tracing on, nobody collecting — the hot loop's instrumentation
    // (history pushes) must still allocate nothing in steady state.
    parataa::trace::enable();

    let mut history = History::new(m, w, d);
    let dx = rng.gaussian_vec(w * d);
    let df = rng.gaussian_vec(w * d);
    let f_vals = rng.gaussian_vec(w * d);
    let xs0 = rng.gaussian_vec(w * d);
    let r_vals: Vec<f32> = f_vals.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
    let mut xs = xs0.clone();
    let mut ws = Workspace::new();
    let mut sg = SuffixGrams::new();
    let mut sg_scan = SuffixGrams::new();

    // Fill the ring past capacity (wrap). The from-scratch scan gets its
    // own owned slot buffers (history stays mutable for the per-round
    // pushes below); the Vec of slice refs is built before the window —
    // it is itself an allocation.
    for _ in 0..m + 1 {
        history.push(&dx, &df);
    }
    let slot_bufs: Vec<Vec<f32>> = (0..m).map(|_| rng.gaussian_vec(w * d)).collect();
    let slots: Vec<&[f32]> = slot_bufs.iter().map(|s| s.as_slice()).collect();

    // Warmup: one round of everything sizes ws/sg to capacity.
    let methods = [Method::AndersonStd, Method::AndersonUpperTri, Method::Taa];
    history.suffix_grams_into(&r_vals, 0, &mut sg);
    suffix_grams_into(&mut sg_scan, &slots, &r_vals, w, d, 0);
    for method in methods {
        xs.copy_from_slice(&xs0);
        apply_update_ws(
            method, &mut xs, &f_vals, &r_vals, &history, 0, w - 1, w, d, 1e-4, true, &mut ws,
        );
    }

    // Measured window: 25 full rounds must allocate nothing.
    let before = ALLOCS.load(Relaxed);
    for round in 0..25 {
        history.push(&dx, &df);
        history.suffix_grams_into(&r_vals, round % w, &mut sg);
        suffix_grams_into(&mut sg_scan, &slots, &r_vals, w, d, 0);
        for method in methods {
            xs.copy_from_slice(&xs0);
            apply_update_ws(
                method, &mut xs, &f_vals, &r_vals, &history, 0, w - 1, w, d, 1e-4, true,
                &mut ws,
            );
        }
    }
    let delta = ALLOCS.load(Relaxed) - before;
    assert_eq!(
        delta, 0,
        "steady-state numeric core allocated {delta} times in 25 rounds"
    );

    // The same discipline holds with the intra-round row pool engaged
    // (parallelism = 4): pool spawn and the per-chunk `RowScratch` sizing
    // are one-time session-construction costs, and `RowPool::run` hands
    // out borrowed work (no boxing, no per-round channels). The counting
    // allocator is process-global, so this window also proves the three
    // *worker* threads allocate nothing in steady state.
    let pool = RowPool::new(4);
    history.push_ranged_par(&dx, &df, 0, w, Some(&pool));
    for method in methods {
        xs.copy_from_slice(&xs0);
        apply_update_par(
            method, &mut xs, &f_vals, &r_vals, &history, 0, w - 1, w, d, 1e-4, true,
            &mut ws, Some(&pool),
        );
    }
    let before_par = ALLOCS.load(Relaxed);
    for _ in 0..25 {
        history.push_ranged_par(&dx, &df, 0, w, Some(&pool));
        for method in methods {
            xs.copy_from_slice(&xs0);
            apply_update_par(
                method, &mut xs, &f_vals, &r_vals, &history, 0, w - 1, w, d, 1e-4, true,
                &mut ws, Some(&pool),
            );
        }
    }
    let delta_par = ALLOCS.load(Relaxed) - before_par;
    assert_eq!(
        delta_par, 0,
        "steady-state parallel (threads = 4) rounds allocated {delta_par} times in 25 rounds"
    );

    // The work above must not have been optimized away.
    assert!(xs.iter().all(|v| v.is_finite()));
}
