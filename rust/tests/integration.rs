//! Integration tests across modules, including the cross-language vectors
//! exported by `python/compile/aot.py` and the PJRT artifact path.
//!
//! Tests touching `artifacts/` are skipped (with a notice) when the
//! directory has not been built — `make artifacts` first for full coverage.

use parataa::figures::common::method_config;
use parataa::metrics::match_rmse;
use parataa::model::gmm::GmmEps;
use parataa::model::{Cond, EpsModel};
use parataa::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
use parataa::solver::{self, history::History, update::apply_update, Method, Problem};
use parataa::util::json::{parse, Json};
use parataa::util::proplite::assert_close;

fn artifacts_dir() -> std::path::PathBuf {
    parataa::runtime::default_artifacts_dir()
}

fn load_testvec(name: &str) -> Option<Json> {
    let path = artifacts_dir().join(name);
    let text = std::fs::read_to_string(&path).ok()?;
    Some(parse(&text).expect("test vector parses"))
}

macro_rules! require_artifacts {
    ($name:expr) => {
        match load_testvec($name) {
            Some(v) => v,
            None => {
                eprintln!("SKIP: {} not found (run `make artifacts`)", $name);
                return;
            }
        }
    };
}

// --- cross-language: schedule ------------------------------------------------

#[test]
fn schedule_matches_python() {
    let tv = require_artifacts!("testvec_schedule.json");
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    for (name, kind, steps) in [
        ("ddim10", SamplerKind::Ddim, 10usize),
        ("ddpm10", SamplerKind::Ddpm, 10),
        ("ddim25", SamplerKind::Ddim, 25),
    ] {
        let case = tv.get(name).unwrap();
        let sc = SamplerCoeffs::new(&ns, kind, steps);
        for (field, ours) in [("a", &sc.a), ("b", &sc.b)] {
            let py: Vec<f64> = case
                .get(field)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect();
            for (i, (&a, &b)) in ours.iter().zip(py.iter()).enumerate() {
                assert!((a - b).abs() < 1e-9, "{name}.{field}[{i}]: {a} vs {b}");
            }
        }
        let py_c: Vec<f64> = case
            .get("c")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (i, (&a, &b)) in sc.c.iter().zip(py_c.iter()).enumerate() {
            assert!((a - b).abs() < 1e-9, "{name}.c[{i}]: {a} vs {b}");
        }
        let py_tt: Vec<usize> = case
            .get("train_t")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(sc.train_t, py_tt, "{name}.train_t");
        let py_g2: Vec<f64> = case
            .get("g2")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (i, (&a, &b)) in sc.g2.iter().zip(py_g2.iter()).enumerate() {
            assert!((a - b).abs() < 1e-9, "{name}.g2[{i}]");
        }
    }
}

// --- cross-language: GMM eps -------------------------------------------------

#[test]
fn gmm_eps_matches_python() {
    let tv = require_artifacts!("testvec_gmm.json");
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let (_k, d, means) = tv.get("means").unwrap().as_f32_mat().unwrap();
    let data_std = tv.get("data_std").unwrap().as_f64().unwrap();
    let model = GmmEps::new(means, d, data_std, ns.alpha_bars.clone());
    for case in tv.get("cases").unwrap().as_arr().unwrap() {
        let x = case.get("x").unwrap().as_f32_vec().unwrap();
        let t = case.get("train_t").unwrap().as_usize().unwrap();
        let w = case.get("weights").unwrap().as_f32_vec().unwrap();
        let g = case.get("guidance").unwrap().as_f64().unwrap() as f32;
        let expect = case.get("eps").unwrap().as_f32_vec().unwrap();
        let mut out = vec![0.0f32; d];
        model.eps_batch(&x, &[t], &[Cond::Weights(w)], g, &mut out);
        assert_close(&out, &expect, 1e-4, 1e-3, &format!("gmm eps t={t} g={g}")).unwrap();
    }
}

// --- cross-language: TAA update ----------------------------------------------

#[test]
fn taa_update_matches_python() {
    let tv = require_artifacts!("testvec_taa.json");
    let w = tv.get("w").unwrap().as_usize().unwrap();
    let d = tv.get("d").unwrap().as_usize().unwrap();
    let mc = tv.get("mc").unwrap().as_usize().unwrap();
    let lam = tv.get("lam").unwrap().as_f64().unwrap() as f32;
    let dx = tv.get("dX").unwrap().as_f32_vec().unwrap();
    let df = tv.get("dF").unwrap().as_f32_vec().unwrap();
    let x = tv.get("x").unwrap().as_f32_vec().unwrap();
    let r = tv.get("R").unwrap().as_f32_vec().unwrap();
    let expect_xnew = tv.get("x_new").unwrap().as_f32_vec().unwrap();

    let mut history = History::new(mc, w, d);
    // python layout is [mc, w, d]; our history slots are [w*d] each.
    for h in 0..mc {
        history.push(&dx[h * w * d..(h + 1) * w * d], &df[h * w * d..(h + 1) * w * d]);
    }
    let f_vals: Vec<f32> = x.iter().zip(r.iter()).map(|(a, b)| a + b).collect();
    let mut xs = x.clone();
    apply_update(Method::Taa, &mut xs, &f_vals, &r, &history, 0, w - 1, w, d, lam, false);
    assert_close(&xs, &expect_xnew, 2e-3, 2e-2, "taa x_new").unwrap();
}

// --- PJRT: trained model numerics ---------------------------------------------

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_dit_matches_python() {
    let tv = require_artifacts!("testvec_dit.json");
    if !artifacts_dir().join("eps_batch_1.hlo.txt").exists() {
        eprintln!("SKIP: eps artifacts missing");
        return;
    }
    let actor = parataa::runtime::DeviceActor::spawn(artifacts_dir(), 256).unwrap();
    let handle = actor.handle();
    for case in tv.get("cases").unwrap().as_arr().unwrap() {
        let x = case.get("x").unwrap().as_f32_vec().unwrap();
        let t = case.get("train_t").unwrap().as_f64().unwrap() as i32;
        let y = case.get("y").unwrap().as_f64().unwrap() as i32;
        let g = case.get("guidance").unwrap().as_f64().unwrap() as f32;
        let expect = case.get("eps").unwrap().as_f32_vec().unwrap();
        let out = handle.eps_batch(&x, &[t], &[y], g).unwrap();
        assert_close(&out, &expect, 1e-4, 1e-3, &format!("dit eps t={t} y={y}")).unwrap();
    }
}

// --- PJRT: padding invariance + batching --------------------------------------

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_batch_padding_is_consistent() {
    let tv = require_artifacts!("testvec_dit.json");
    let actor = parataa::runtime::DeviceActor::spawn(artifacts_dir(), 256).unwrap();
    let handle = actor.handle();
    let case = &tv.get("cases").unwrap().as_arr().unwrap()[1];
    let x = case.get("x").unwrap().as_f32_vec().unwrap();
    let t = case.get("train_t").unwrap().as_f64().unwrap() as i32;
    let y = case.get("y").unwrap().as_f64().unwrap() as i32;
    // Same item evaluated alone and replicated 7× (pads to the 10-variant)
    // must agree elementwise.
    let single = handle.eps_batch(&x, &[t], &[y], 2.0).unwrap();
    let mut x7 = Vec::new();
    for _ in 0..7 {
        x7.extend_from_slice(&x);
    }
    let batch = handle.eps_batch(&x7, &[t; 7], &[y; 7], 2.0).unwrap();
    for i in 0..7 {
        assert_close(
            &batch[i * 256..(i + 1) * 256],
            &single,
            1e-5,
            1e-4,
            &format!("padded item {i}"),
        )
        .unwrap();
    }
}

// --- PJRT: end-to-end parallel == sequential on the trained model --------------

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_parataa_matches_sequential() {
    use parataa::figures::common::{ModelChoice, Scenario};
    if !artifacts_dir().join("eps_batch_1.hlo.txt").exists() {
        eprintln!("SKIP: eps artifacts missing");
        return;
    }
    let scenario = Scenario::new(ModelChoice::Dit, SamplerKind::Ddim, 25);
    let coeffs = scenario.coeffs();
    let problem = Problem::new(&coeffs, &*scenario.model, Cond::Class(3), 11);
    let seq = solver::sample_sequential(&problem, scenario.guidance);
    let cfg = method_config(Method::Taa, 25, None, scenario.guidance);
    let par = solver::solve(&problem, &cfg);
    assert!(par.converged, "ParaTAA on PJRT did not converge");
    assert!(par.iterations < 25, "no parallel speedup: {}", par.iterations);
    let rmse = match_rmse(par.xs.row(0), seq.xs.row(0));
    assert!(rmse < 0.02, "parallel/sequential mismatch: {rmse}");
}

// --- PJRT: fused solver_step artifact matches the native update ----------------

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_solver_step_matches_native() {
    if !artifacts_dir().join("solver_step_25.hlo.txt").exists() {
        eprintln!("SKIP: solver_step artifacts missing");
        return;
    }
    use parataa::equations::{build_b_matrix, build_s_matrix, build_xi_comb, eval_fk, States};
    use parataa::runtime::device::{SolverStepInputs, SOLVER_HIST_COLS};
    use parataa::util::rng::Pcg64;

    let steps = 25usize;
    let d = 256usize;
    let k = 6;
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, steps);
    let mut rng = Pcg64::seeded(5);

    let mut xs = States::zeros(steps, d);
    rng.fill_gaussian(&mut xs.data);
    let mut eps = States::zeros(steps, d);
    rng.fill_gaussian(&mut eps.data);
    let mut xi = States::zeros(steps, d);
    rng.fill_gaussian(&mut xi.data);
    let boundary = steps;
    let w = steps;

    let dx: Vec<f32> = (0..SOLVER_HIST_COLS * w * d).map(|_| rng.next_f32() - 0.5).collect();
    let df: Vec<f32> = (0..SOLVER_HIST_COLS * w * d).map(|_| rng.next_f32() - 0.5).collect();

    // Native: F^{(k)}, R, then TAA.
    let mut f_vals = vec![0.0f32; w * d];
    let mut r_vals = vec![0.0f32; w * d];
    for p in 0..w {
        eval_fk(&coeffs, &xs, &eps, &xi, k, boundary, p, &mut f_vals[p * d..(p + 1) * d]);
        for i in p * d..(p + 1) * d {
            r_vals[i] = f_vals[i] - xs.data[i];
        }
    }
    let mut history = History::new(SOLVER_HIST_COLS, w, d);
    for h in 0..SOLVER_HIST_COLS {
        history.push(&dx[h * w * d..(h + 1) * w * d], &df[h * w * d..(h + 1) * w * d]);
    }
    let mut native = xs.data[..w * d].to_vec();
    apply_update(Method::Taa, &mut native, &f_vals, &r_vals, &history, 0, w - 1, w, d, 1e-4, false);

    // PJRT: the fused artifact.
    let actor = parataa::runtime::DeviceActor::spawn(artifacts_dir(), d).unwrap();
    let inputs = SolverStepInputs {
        xs_ext: xs.data.clone(),
        eps_ext: eps.data.clone(),
        x_win: xs.data[..w * d].to_vec(),
        s_mat: build_s_matrix(&coeffs, k, boundary, 0, w),
        b_mat: build_b_matrix(&coeffs, k, boundary, 0, w),
        xi_comb: build_xi_comb(&coeffs, &xi, k, boundary, 0, w),
        s1_mat: build_s_matrix(&coeffs, 1, boundary, 0, w),
        b1_mat: build_b_matrix(&coeffs, 1, boundary, 0, w),
        xi1_comb: build_xi_comb(&coeffs, &xi, 1, boundary, 0, w),
        dx,
        df,
        mask: vec![1.0; w],
        fp_mask: vec![0.0; w],
        lam: 1e-4,
    };
    let out = actor.handle().solver_step(steps, inputs).unwrap();
    assert_close(&out.x_new, &native, 5e-3, 5e-2, "fused vs native x_new").unwrap();
    assert_close(&out.r_vec, &r_vals, 1e-3, 1e-2, "fused vs native R").unwrap();
}

// --- service-level equivalence -------------------------------------------------

#[test]
fn coordinator_end_to_end_gmm() {
    use parataa::coordinator::{
        Batcher, BatcherConfig, Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec,
    };
    use std::sync::Arc;

    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model = Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()));
    let batcher = Batcher::spawn(model.clone(), BatcherConfig::default());
    let eps = Arc::new(batcher.eps_handle(256, "batched"));
    let coord = Coordinator::start(eps, CoordinatorConfig::default());

    let mut handles = Vec::new();
    for i in 0..6u64 {
        let mut req =
            SampleRequest::parataa(Cond::Class(i as usize % 8), i, SamplerSpec::ddim(25));
        req.guidance = 2.0;
        handles.push((i, coord.submit(req)));
    }
    for (i, h) in handles {
        let r = h.wait().unwrap();
        assert!(r.converged, "request {i}");
        // oracle
        let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 25);
        let p = Problem::new(&coeffs, &*model, Cond::Class(i as usize % 8), i);
        let seq = solver::sample_sequential(&p, 2.0);
        let rmse = match_rmse(&r.sample, seq.xs.row(0));
        assert!(rmse < 0.02, "request {i} mismatch {rmse}");
    }
    drop(coord);
}

// --- edge cases across the solver stack -----------------------------------

// --- device pool: service-level equivalence and metrics ---------------------

#[test]
fn pooled_coordinator_matches_single_device_bit_exact() {
    // The same request stream served through a 3-device pool and through the
    // direct single-model path must produce byte-identical samples: sharding
    // and work distribution must never leak into numerics.
    use parataa::coordinator::{Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec};
    use parataa::runtime::{DevicePool, PoolConfig};
    use std::sync::Arc;

    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model = Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()));

    let req = |i: u64| {
        let mut r = SampleRequest::parataa(Cond::Class(i as usize % 8), i, SamplerSpec::ddim(25));
        r.guidance = 2.0;
        r
    };

    let direct = Coordinator::start(model.clone(), CoordinatorConfig::default());
    let baseline: Vec<Vec<f32>> =
        (0..6).map(|i| direct.sample(req(i)).unwrap().sample).collect();
    drop(direct);

    let pool = DevicePool::in_process(model.clone(), 3, PoolConfig::default()).unwrap();
    let pooled = Arc::new(pool.eps_handle("pooled"));
    let coord = Coordinator::start(
        pooled,
        CoordinatorConfig { devices: 3, ..Default::default() },
    );
    coord.attach_pool(pool.stats());
    for (i, expect) in baseline.iter().enumerate() {
        let r = coord.sample(req(i as u64)).unwrap();
        assert!(r.converged);
        assert_eq!(&r.sample, expect, "request {i}: pooled sample diverged");
    }

    let m = coord.metrics();
    assert_eq!(m.completed, 6);
    assert_eq!(m.devices.len(), 3, "metrics must carry the per-device breakdown");
    let items: u64 = m.devices.iter().map(|d| d.items).sum();
    assert!(items > 0, "pool executed no work");
    assert!(m.report().contains("dev2"), "report: {}", m.report());
    drop(coord);
}

#[test]
fn pooled_batcher_coordinator_end_to_end() {
    // Full production stack on the in-process backend: pool -> dynamic
    // batcher -> coordinator, checked against the sequential oracle.
    use parataa::coordinator::{
        Batcher, BatcherConfig, Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec,
    };
    use parataa::runtime::{DevicePool, PoolConfig};
    use std::sync::Arc;

    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model = Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()));
    let pool = DevicePool::in_process(model.clone(), 2, PoolConfig::default()).unwrap();
    let pooled = Arc::new(pool.eps_handle("pooled"));
    let batcher = Batcher::spawn(pooled, BatcherConfig::for_devices(2));
    let eps = Arc::new(batcher.eps_handle(256, "batched"));
    let coord = Coordinator::start(
        eps,
        CoordinatorConfig { devices: 2, ..Default::default() },
    );

    let mut handles = Vec::new();
    for i in 0..6u64 {
        let mut req =
            SampleRequest::parataa(Cond::Class(i as usize % 8), i, SamplerSpec::ddim(25));
        req.guidance = 2.0;
        handles.push((i, coord.submit(req)));
    }
    for (i, h) in handles {
        let r = h.wait().unwrap();
        assert!(r.converged, "request {i}");
        let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 25);
        let p = Problem::new(&coeffs, &*model, Cond::Class(i as usize % 8), i);
        let seq = solver::sample_sequential(&p, 2.0);
        let rmse = match_rmse(&r.sample, seq.xs.row(0));
        assert!(rmse < 0.02, "request {i} mismatch {rmse}");
    }
    drop(coord); // workers, then batcher, then pool
}

#[test]
fn window_one_degenerates_to_sequential_schedule() {
    // w = 1: each round updates a single row; ParaTAA must still converge
    // and match the sequential sample (at ~T rounds).
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model = {
        let mut rng = parataa::util::rng::Pcg64::seeded(3);
        let d = 4;
        let means: Vec<f32> = (0..2 * d).map(|_| rng.next_f32()).collect();
        GmmEps::new(means, d, 0.3, ns.alpha_bars.clone())
    };
    let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 12);
    let problem = Problem::new(&coeffs, &model, Cond::Class(0), 5);
    let seq = solver::sample_sequential(&problem, 1.0);
    let mut cfg = method_config(Method::Taa, 12, None, 1.0);
    cfg.window = 1;
    cfg.s_max = 100;
    let par = solver::solve(&problem, &cfg);
    assert!(par.converged);
    assert!(match_rmse(par.xs.row(0), seq.xs.row(0)) < 1e-2);
}

#[test]
fn k_larger_than_t_is_clamped() {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model = {
        let mut rng = parataa::util::rng::Pcg64::seeded(4);
        let means: Vec<f32> = (0..8).map(|_| rng.next_f32()).collect();
        GmmEps::new(means, 4, 0.3, ns.alpha_bars.clone())
    };
    let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 8);
    let problem = Problem::new(&coeffs, &model, Cond::Class(1), 2);
    let mut cfg = method_config(Method::Taa, 8, Some(10_000), 1.0);
    cfg.s_max = 50;
    let r = solver::solve(&problem, &cfg);
    assert!(r.converged, "oversized k must be clamped, not crash");
}

#[test]
fn t_init_one_freezes_everything_but_the_sample() {
    // T_init = 1: only x_0 is re-solved; all other rows stay frozen.
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model = {
        let mut rng = parataa::util::rng::Pcg64::seeded(6);
        let means: Vec<f32> = (0..12).map(|_| rng.next_f32()).collect();
        GmmEps::new(means, 4, 0.3, ns.alpha_bars.clone())
    };
    let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 10);
    let cfg = method_config(Method::Taa, 10, None, 1.0);
    let p1 = Problem::new(&coeffs, &model, Cond::Class(0), 9);
    let r1 = solver::solve(&p1, &cfg);
    let mut p2 = Problem::new(&coeffs, &model, Cond::Class(2), 9);
    parataa::solver::init::init_from_trajectory(&mut p2, r1.xs.clone(), p1.xi.clone(), 1);
    let r2 = solver::solve(&p2, &cfg);
    assert!(r2.converged);
    for t in 1..=10 {
        assert_eq!(r2.xs.row(t), r1.xs.row(t), "row {t} should be frozen");
    }
}

#[test]
fn gmm_zero_weight_components_are_ignored() {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let mut rng = parataa::util::rng::Pcg64::seeded(8);
    let d = 4;
    let means: Vec<f32> = (0..3 * d).map(|_| rng.next_f32()).collect();
    let model = GmmEps::new(means.clone(), d, 0.2, ns.alpha_bars.clone());
    // Condition with zero weight on components 1,2 must equal a 1-component
    // model built from component 0 alone.
    let single = GmmEps::new(means[..d].to_vec(), d, 0.2, ns.alpha_bars.clone());
    let x: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let mut a = vec![0.0f32; d];
    let mut b = vec![0.0f32; d];
    model.eps_batch(&x, &[300], &[Cond::Weights(vec![1.0, 0.0, 0.0])], 1.0, &mut a);
    single.eps_batch(&x, &[300], &[Cond::Class(0)], 1.0, &mut b);
    assert_close(&a, &b, 1e-6, 1e-5, "zero-weight components").unwrap();
}

#[test]
fn ddpm_parallel_uses_identical_noise_as_sequential() {
    // The stochastic sampler's ξ draws are fixed per problem: parallel and
    // sequential must consume the same stream and produce the same sample.
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let model = {
        let mut rng = parataa::util::rng::Pcg64::seeded(10);
        let means: Vec<f32> = (0..3 * 6).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        GmmEps::new(means, 6, 0.25, ns.alpha_bars.clone())
    };
    let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 20);
    let problem = Problem::new(&coeffs, &model, Cond::Class(1), 77);
    let seq = solver::sample_sequential(&problem, 1.5);
    let mut cfg = method_config(Method::Taa, 20, None, 1.5);
    cfg.tol = 1e-5;
    cfg.s_max = 80;
    let par = solver::solve(&problem, &cfg);
    assert!(par.converged);
    assert!(
        match_rmse(par.xs.row(0), seq.xs.row(0)) < 5e-3,
        "DDPM parallel must reproduce the sequential stochastic sample"
    );
}

#[test]
fn figures_registry_covers_all_experiments() {
    for name in parataa::figures::ALL {
        assert!(
            ["fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig14", "table1", "ablate"]
                .contains(name)
        );
    }
    assert_eq!(parataa::figures::ALL.len(), 10);
}

#[cfg(feature = "pjrt")]
#[test]
fn fused_pjrt_driver_matches_sequential() {
    // The fully-fused device path (2 device calls/round, zero host math on
    // window tensors) must converge to the sequential sample too.
    if !artifacts_dir().join("solver_step_25.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    use parataa::figures::common::{ModelChoice, Scenario};
    use parataa::runtime::pjrt_driver::solve_pjrt;
    let scenario = Scenario::new(ModelChoice::Dit, SamplerKind::Ddim, 25);
    let coeffs = scenario.coeffs();
    let problem = Problem::new(&coeffs, &*scenario.model, Cond::Class(5), 21);
    let seq = solver::sample_sequential(&problem, scenario.guidance);

    let actor = parataa::runtime::DeviceActor::spawn(artifacts_dir(), 256).unwrap();
    let mut cfg = method_config(Method::Taa, 25, None, scenario.guidance);
    cfg.s_max = 60;
    let fused = solve_pjrt(&actor.handle(), &problem, &cfg).unwrap();
    assert!(fused.converged, "fused path did not converge");
    let rmse = match_rmse(fused.xs.row(0), seq.xs.row(0));
    assert!(rmse < 0.02, "fused path mismatch: {rmse}");

    // Native path for comparison: fused may lag a round or two (history
    // staleness, see pjrt_driver.rs) but must stay in the same ballpark.
    let native = solver::solve(&problem, &cfg);
    assert!(
        fused.iterations <= native.iterations + 6,
        "fused {} vs native {} rounds",
        fused.iterations,
        native.iterations
    );
}
