//! End-to-end round trip of the ISSUE-6 tracing subsystem over live
//! streaming coordinator traffic: every admitted session must leave a
//! complete span tree (admission → N rounds → finalize) joined on its
//! trace id, the trace-derived counters must equal the metrics the
//! coordinator reports, and all three exporters — Chrome trace JSON,
//! Prometheus text, convergence telemetry — must round-trip what the
//! run recorded.
//!
//! One `#[test]` only: the span recorder is process-global, and
//! concurrent tests in the same binary would interleave their events
//! into the count-equality assertions below.

use std::sync::Arc;

use parataa::coordinator::{Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec};
use parataa::figures::convergence::{check_monotone_fronts, curves};
use parataa::model::gmm::GmmEps;
use parataa::model::Cond;
use parataa::runtime::{DevicePool, PoolConfig};
use parataa::schedule::{BetaSchedule, NoiseSchedule};
use parataa::trace::telemetry::{parse_jsonl, TelemetryLog};
use parataa::trace::{self, chrome, prom, Layer, Name};
use parataa::util::json::parse;
use parataa::util::rng::Pcg64;

fn gmm_model() -> Arc<GmmEps> {
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let mut rng = Pcg64::seeded(7);
    let d = 8;
    let means: Vec<f32> = (0..8 * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    Arc::new(GmmEps::new(means, d, 0.25, ns.alpha_bars.clone()))
}

#[test]
fn streaming_run_round_trips_through_every_exporter() {
    trace::enable();
    let telemetry = Arc::new(TelemetryLog::new());
    let model = gmm_model();
    // A real device pool behind the coordinator, so the pool layer's
    // dispatch/execute spans are part of the round trip.
    let pool = DevicePool::in_process(model, 2, PoolConfig::default()).unwrap();
    let handle = Arc::new(pool.eps_handle("gmm-pooled"));
    let coord = Coordinator::start(
        handle,
        CoordinatorConfig {
            workers: 2,
            drivers: 2,
            devices: pool.devices(),
            telemetry: Some(telemetry.clone()),
            ..Default::default()
        },
    );
    coord.attach_pool(pool.stats());

    const N: usize = 6;
    const STEPS: usize = 16;
    let handles: Vec<_> = (0..N)
        .map(|i| {
            let mut r =
                SampleRequest::parataa(Cond::Class(1), 700 + i as u64, SamplerSpec::ddim(STEPS));
            r.guidance = 2.0;
            coord.submit_streaming(r)
        })
        .collect();
    let mut resp_rounds: Vec<usize> = Vec::new();
    let mut chunks_seen = 0u64;
    for (i, h) in handles.into_iter().enumerate() {
        let mut rows = 0usize;
        while let Some(c) = h.next_chunk() {
            rows += c.rows.len();
            chunks_seen += 1;
        }
        let resp = h.wait().unwrap();
        assert!(resp.converged, "stream {i} did not converge");
        assert_eq!(rows, STEPS, "stream {i}: chunks tile the trajectory");
        resp_rounds.push(resp.rounds);
    }
    let snapshot = coord.metrics();
    drop(coord); // drivers quiesce before the event log is read
    drop(pool);

    let events = trace::collect();
    let sessions = telemetry.sessions();
    assert_eq!(sessions.len(), N, "one telemetry record per admitted session");

    // --- span-tree completeness, joined on the session trace id ---------
    for s in &sessions {
        let count = |layer: Layer, name: Name| {
            events
                .iter()
                .filter(|e| e.span && e.layer == layer && e.name == name && e.track == s.trace_id)
                .count()
        };
        assert_eq!(count(Layer::Session, Name::Admit), 1, "session {}", s.trace_id);
        assert_eq!(count(Layer::Session, Name::Finalize), 1, "session {}", s.trace_id);
        assert!(!s.rounds.is_empty(), "session {} recorded no rounds", s.trace_id);
        assert_eq!(
            count(Layer::Solver, Name::Round),
            s.rounds.len(),
            "session {}: solver round spans == telemetry rounds",
            s.trace_id
        );
    }
    // The responses' round counts match the telemetry as a multiset
    // (responses do not carry trace ids, so the join is by distribution).
    let mut by_telemetry: Vec<usize> = sessions.iter().map(|s| s.rounds.len()).collect();
    by_telemetry.sort_unstable();
    resp_rounds.sort_unstable();
    assert_eq!(by_telemetry, resp_rounds, "telemetry rounds == response rounds");

    // --- trace-derived counters equal the coordinator's metrics ---------
    let driver_rounds =
        events.iter().filter(|e| e.span && e.name == Name::DriverRound).count() as u64;
    assert_eq!(driver_rounds, snapshot.rounds_driven, "Σ driver_round spans == rounds_driven");
    let chunk_emits = events.iter().filter(|e| !e.span && e.name == Name::ChunkEmit).count() as u64;
    assert_eq!(chunk_emits, snapshot.prefix_chunks_sent);
    assert_eq!(chunks_seen, snapshot.prefix_chunks_sent, "every emitted chunk was delivered");

    // The pool layer recorded work on both devices.
    assert!(events.iter().any(|e| e.span && e.layer == Layer::Pool && e.name == Name::Dispatch));
    for dev in 0..2u64 {
        assert!(
            events.iter().any(|e| e.span && e.name == Name::Execute && e.track == dev),
            "device {dev} executed no shards"
        );
    }

    // --- exporter 1: Chrome trace JSON ----------------------------------
    let rendered = chrome::render(&events).to_string();
    let json = parse(&rendered).expect("chrome trace re-parses");
    let trace_events = json.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(trace_events.len() > events.len(), "events plus metadata records");
    for cat in ["solver", "driver", "pool", "session", "stream"] {
        let n = trace_events
            .iter()
            .filter(|e| e.get("cat").and_then(|c| c.as_str()) == Some(cat))
            .count();
        assert!(n > 0, "no Chrome events for instrumented layer {cat}");
    }

    // --- exporter 2: Prometheus text exposition -------------------------
    let prom_text = prom::render(&snapshot);
    let samples = prom::validate(&prom_text).expect("prometheus exposition validates");
    assert!(samples > 0);
    assert!(prom_text.contains("parataa_requests_completed_total 6"), "{prom_text}");
    assert!(prom_text.contains("parataa_trace_events_total{layer=\"driver\"}"));

    // --- exporter 3: convergence telemetry ------------------------------
    check_monotone_fronts(&sessions).expect("Thm 3.6: fronts are monotone");
    let reparsed = parse_jsonl(&telemetry.to_jsonl()).expect("telemetry JSONL round-trips");
    assert_eq!(reparsed, sessions);
    let table = curves(&sessions);
    assert_eq!(table.rows.len(), sessions.iter().map(|s| s.rounds.len()).sum::<usize>());
}
