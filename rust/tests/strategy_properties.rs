//! Property harness for the multi-fidelity solve strategies
//! (`SolveStrategy::{PlainTaa, DraftRefine, Parareal}`).
//!
//! Each property sweeps randomized solver configurations — steps T,
//! sampler family, window w, Anderson depth m, method, safeguard — from
//! the seeded [`proplite`] generator, so every failure replays
//! deterministically from its reported case index.
//!
//! Contract note (fidelity vs. the issue wording): a floating-point
//! fixed-point iteration stops at solver tolerance, so "final states equal
//! the sequential sampler" cannot be a *bitwise* claim. The contract
//! asserted here is the strongest one the numerics admit:
//!
//! 1. at convergence the sample agrees with the sequential rollout to
//!    solver tolerance (`assert_close` atol 5e-3 / rtol 5e-2, matching
//!    the crate's Remark 5.3 checks), and
//! 2. every strategy is **bitwise run-to-run deterministic**, including
//!    under a manual `pending()`/`resume()` drive of the session.

use parataa::model::gmm::GmmEps;
use parataa::model::{Cond, EpsModel};
use parataa::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
use parataa::solver::{
    self, DraftRefineConfig, Method, PararealConfig, Problem, SolveStrategy, SolverConfig,
    SolverSession,
};
use parataa::util::proplite::{assert_close, forall, size_in};
use parataa::util::rng::Pcg64;

/// One randomized solver setup (owns what `Problem` borrows).
struct Case {
    coeffs: SamplerCoeffs,
    model: GmmEps,
    cfg: SolverConfig,
    seed: u64,
}

impl Case {
    fn problem(&self) -> Problem<'_> {
        Problem::new(&self.coeffs, &self.model, Cond::Class((self.seed % 4) as usize), self.seed)
    }

    fn with_strategy(&self, strategy: SolveStrategy) -> SolverConfig {
        let mut cfg = self.cfg.clone();
        cfg.strategy = strategy;
        cfg
    }
}

/// Draw a random solver setup. The budget is deliberately generous
/// (s_max = 20 T): the properties assert *what* the strategies converge
/// to, not how fast — speed is the bench registry's job.
fn draw_case(rng: &mut Pcg64, case: u64) -> Case {
    let steps = size_in(rng, 12, 20);
    let kind = if rng.below(2) == 0 { SamplerKind::Ddim } else { SamplerKind::Ddpm };
    let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    let coeffs = SamplerCoeffs::new(&ns, kind, steps);
    let d = size_in(rng, 3, 6);
    let n_comp = size_in(rng, 2, 4);
    let mut mrng = Pcg64::new(0x6e0d_e15e, case);
    let means: Vec<f32> = (0..n_comp * d).map(|_| 2.0 * mrng.next_f32() - 1.0).collect();
    let model = GmmEps::new(means, d, 0.25, ns.alpha_bars.clone());

    let mut cfg = SolverConfig::parataa(steps);
    cfg.method = if rng.below(2) == 0 { Method::Taa } else { Method::AndersonUpperTri };
    cfg.m = size_in(rng, 2, 4);
    cfg.safeguard = rng.below(4) != 0; // mostly on, sometimes ablated
    cfg.window = size_in(rng, (steps / 2).max(4), steps);
    cfg.tol = 1e-4;
    cfg.s_max = 20 * steps;
    cfg.guidance = 2.0;
    Case { coeffs, model, cfg, seed: 1000 + case }
}

fn all_strategies() -> [SolveStrategy; 3] {
    [
        SolveStrategy::PlainTaa,
        SolveStrategy::DraftRefine(DraftRefineConfig::default()),
        SolveStrategy::Parareal(PararealConfig::default()),
    ]
}

/// Theorem 3.6 generalized to every strategy: the converged-rows front
/// never retreats across a solve's round records. Coarse rounds (draft
/// rounds, Parareal sweeps) must hold the front, fine rounds may only
/// advance it.
#[test]
fn residual_front_is_monotone_under_every_strategy() {
    forall("monotone front", 10, |rng, case| {
        let c = draw_case(rng, case);
        for strategy in all_strategies() {
            let cfg = c.with_strategy(strategy);
            let r = solver::solve(&c.problem(), &cfg);
            let mut front = 0usize;
            for rec in &r.records {
                if rec.converged_rows < front {
                    return Err(format!(
                        "{}: front retreated {} -> {} at round {}",
                        cfg.strategy.label(),
                        front,
                        rec.converged_rows,
                        rec.iter
                    ));
                }
                front = rec.converged_rows;
            }
            if r.converged && front != c.coeffs.steps {
                return Err(format!(
                    "{}: converged but the last record froze {front}/{} rows",
                    cfg.strategy.label(),
                    c.coeffs.steps
                ));
            }
        }
        Ok(())
    });
}

/// Theorem 2.2 for every strategy: the fixed point is the sequential
/// trajectory, so at convergence the sample row must match the sequential
/// DDIM/DDPM rollout to solver tolerance (see the module docs for why
/// this is a tolerance contract, not a bitwise one).
#[test]
fn strategies_converge_to_the_sequential_sample() {
    forall("sequential fixed point", 10, |rng, case| {
        let c = draw_case(rng, case);
        let seq = solver::sample_sequential(&c.problem(), c.cfg.guidance);
        for strategy in all_strategies() {
            let mut cfg = c.with_strategy(strategy);
            if !cfg.strategy.is_plain() {
                // The multi-fidelity round budgets are calibrated for the
                // safeguarded solver (Theorem 3.6 bounds the draft length
                // and the Parareal fine rounds); the ablated safeguard is
                // still covered by the monotonicity/determinism sweeps.
                cfg.safeguard = true;
            }
            let r = solver::solve(&c.problem(), &cfg);
            if !r.converged {
                return Err(format!(
                    "{}: did not converge within s_max = {}",
                    cfg.strategy.label(),
                    cfg.s_max
                ));
            }
            assert_close(
                r.xs.row(0),
                seq.xs.row(0),
                5e-3,
                5e-2,
                &format!("{}: sample row vs sequential rollout", cfg.strategy.label()),
            )?;
        }
        Ok(())
    });
}

/// Every strategy is bitwise deterministic: run-twice via the blocking
/// wrapper, and a manual `pending()`/`resume()` drive of the session
/// produces the same trajectory, rounds and NFE as `solve()`.
#[test]
fn strategies_are_bitwise_deterministic() {
    forall("bitwise determinism", 8, |rng, case| {
        let c = draw_case(rng, case);
        for strategy in all_strategies() {
            let cfg = c.with_strategy(strategy);
            let a = solver::solve(&c.problem(), &cfg);
            let b = solver::solve(&c.problem(), &cfg);
            if a.xs.data != b.xs.data || a.total_nfe != b.total_nfe {
                return Err(format!("{}: run-twice drift", cfg.strategy.label()));
            }

            let problem = c.problem();
            let mut session = SolverSession::new(&problem, &cfg);
            let d = session.dim();
            let mut eps = Vec::new();
            loop {
                let n = match session.pending() {
                    None => break,
                    Some(batch) => {
                        eps.resize(batch.len() * d, 0.0);
                        c.model.eps_batch(batch.x, batch.t, batch.conds, batch.guidance, &mut eps);
                        batch.len()
                    }
                };
                if session.resume(&eps[..n * d]).done {
                    break;
                }
            }
            let coarse = session.coarse_rounds();
            if cfg.strategy.is_plain() && coarse != 0 {
                return Err(format!("plain ran {coarse} coarse rounds"));
            }
            let by_session = session.finish();
            if by_session.xs.data != a.xs.data
                || by_session.total_nfe != a.total_nfe
                || by_session.iterations != a.iterations
            {
                return Err(format!("{}: session drive != solve()", cfg.strategy.label()));
            }
        }
        Ok(())
    });
}

/// The `parallelism` knob is bitwise inert under every strategy: thread
/// counts 2/4/8 reproduce the single-threaded trajectory, rounds and NFE
/// exactly — including DraftRefine (whose nested coarse session pins
/// parallelism = 1) and Parareal (whose coarse sweeps stay on the solver
/// thread while the fine rounds fan out).
#[test]
fn parallelism_is_bitwise_inert_under_every_strategy() {
    forall("parallelism inert", 6, |rng, case| {
        let c = draw_case(rng, case);
        for strategy in all_strategies() {
            let base_cfg = c.with_strategy(strategy);
            let base = solver::solve(&c.problem(), &base_cfg);
            for threads in [2usize, 4, 8] {
                let mut cfg = base_cfg.clone();
                cfg.parallelism = threads;
                let r = solver::solve(&c.problem(), &cfg);
                if r.xs.data != base.xs.data
                    || r.total_nfe != base.total_nfe
                    || r.iterations != base.iterations
                {
                    return Err(format!(
                        "{}: threads = {threads} drifted from the single-threaded path",
                        cfg.strategy.label()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The draft-and-refine economics (the §4.2 warm-start argument applied
/// in-band): seeding the window from a cheap coarse solve must never cost
/// more ε_θ evaluations than the cold plain solve. Pinned to the Table-1
/// operating point (TAA, safeguard, full window, DDIM) where the paper's
/// warm-start savings are established; steps and seeds still sweep.
#[test]
fn draft_refine_never_needs_more_nfe_than_plain() {
    forall("draft NFE economy", 8, |rng, case| {
        let mut c = draw_case(rng, case);
        c.coeffs = SamplerCoeffs::new(
            &NoiseSchedule::new(BetaSchedule::Linear, 1000),
            SamplerKind::Ddim,
            c.coeffs.steps,
        );
        c.cfg.method = Method::Taa;
        c.cfg.safeguard = true;
        c.cfg.window = c.coeffs.steps;

        let plain = solver::solve(&c.problem(), &c.cfg);
        let draft_cfg = c.with_strategy(SolveStrategy::DraftRefine(DraftRefineConfig::default()));
        let draft = solver::solve(&c.problem(), &draft_cfg);
        if !plain.converged || !draft.converged {
            return Err(format!(
                "non-convergence (plain {}, draft {})",
                plain.converged, draft.converged
            ));
        }
        if draft.total_nfe > plain.total_nfe {
            return Err(format!(
                "draft-refine cost {} NFE vs plain {} (T = {})",
                draft.total_nfe, plain.total_nfe, c.coeffs.steps
            ));
        }
        Ok(())
    });
}
