//! The coordinator server: request queue → worker pool → parallel solves.
//!
//! Wiring (see module docs in `coordinator/mod.rs`):
//!
//! ```text
//!   submit() ──► bounded queue ──► worker pool ──► solver::solve
//!                                   │  ▲               │ one ε job / round
//!                                   │  └─ slot budget  ▼
//!                                   │            dynamic batcher ──► device
//!                                   └─ trajectory cache (warm starts)
//! ```

use super::cache::{CachedTrajectory, TrajectoryCache};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{SampleRequest, SampleResponse};
use super::scheduler::SlotBudget;
use crate::model::EpsModel;
use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs};
use crate::solver::{self, init::init_from_trajectory, Problem};
use crate::util::channel::{bounded, Receiver, Sender};
use crate::util::error::{anyhow, Result};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Coordinator tuning.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads (concurrent solves).
    pub workers: usize,
    /// Total window-row slots in flight (the "device memory" budget).
    pub slot_budget: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Trajectory cache entries.
    pub cache_capacity: usize,
    /// Max condition-weight distance for a warm-start donor.
    pub cache_max_dist: f32,
    /// T_init = ceil(frac · steps) when warm-starting (§4.2).
    pub cache_t_init_frac: f64,
    /// Number of condition components (for densifying `Cond`s).
    pub n_components: usize,
    /// Devices behind the model handle (a [`crate::runtime::DevicePool`]):
    /// the in-flight window-row budget scales as `slot_budget × devices`,
    /// matching the extra device memory a bigger pool brings.
    pub devices: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            slot_budget: 400,
            queue_capacity: 128,
            cache_capacity: 64,
            cache_max_dist: 0.5,
            cache_t_init_frac: 0.7,
            n_components: 8,
            devices: 1,
        }
    }
}

struct Job {
    req: SampleRequest,
    reply: Sender<Result<SampleResponse>>,
    enqueued: Instant,
}

/// Handle to an in-flight request.
pub struct ResponseHandle {
    rx: Receiver<Result<SampleResponse>>,
}

impl ResponseHandle {
    /// Block until the sample is ready.
    pub fn wait(self) -> Result<SampleResponse> {
        self.rx
            .recv()
            .unwrap_or_else(|| Err(anyhow!("coordinator shut down")))
    }
}

/// The sampling service.
pub struct Coordinator {
    tx: Sender<Job>,
    metrics: Arc<Metrics>,
    cache: Arc<TrajectoryCache>,
    budget: Arc<SlotBudget>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the service over a model (direct or batcher-wrapped).
    pub fn start(model: Arc<dyn EpsModel>, cfg: CoordinatorConfig) -> Self {
        let (tx, rx) = bounded::<Job>(cfg.queue_capacity);
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(TrajectoryCache::new(cfg.cache_capacity, cfg.n_components));
        let budget = Arc::new(SlotBudget::new(cfg.slot_budget * cfg.devices.max(1)));
        let schedule = Arc::new(NoiseSchedule::new(BetaSchedule::Linear, 1000));
        let workers = (0..cfg.workers.max(1))
            .map(|i| {
                let rx = rx.clone();
                let model = model.clone();
                let metrics = metrics.clone();
                let cache = cache.clone();
                let budget = budget.clone();
                let schedule = schedule.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("parataa-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            let res =
                                handle_job(&job, &*model, &schedule, &cache, &budget, &cfg);
                            match &res {
                                Ok(r) => metrics.record_success(
                                    r.latency,
                                    r.rounds,
                                    r.nfe,
                                    r.warm_started,
                                ),
                                Err(_) => metrics.record_failure(),
                            }
                            let _ = job.reply.send(res);
                        }
                    })
                    .expect("spawn coordinator worker")
            })
            .collect();
        Coordinator { tx, metrics, cache, budget, workers }
    }

    /// Enqueue a request (blocking if the queue is full — backpressure).
    pub fn submit(&self, req: SampleRequest) -> ResponseHandle {
        let (rtx, rrx) = bounded(1);
        if self.tx.send(Job { req, reply: rtx, enqueued: Instant::now() }).is_err() {
            panic!("coordinator is down");
        }
        ResponseHandle { rx: rrx }
    }

    /// Convenience: submit and wait.
    pub fn sample(&self, req: SampleRequest) -> Result<SampleResponse> {
        self.submit(req).wait()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Record a device pool's per-device counters in this service's
    /// metrics: snapshots/reports then include the per-device breakdown.
    pub fn attach_pool(&self, stats: Arc<crate::runtime::PoolStats>) {
        self.metrics.attach_pool(stats);
    }

    /// Trajectory-cache size (diagnostic).
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Free slots (diagnostic).
    pub fn slots_available(&self) -> usize {
        self.budget.available()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn handle_job(
    job: &Job,
    model: &dyn EpsModel,
    schedule: &NoiseSchedule,
    cache: &TrajectoryCache,
    budget: &SlotBudget,
    cfg: &CoordinatorConfig,
) -> Result<SampleResponse> {
    let req = &job.req;
    let steps = req.sampler.steps;
    let coeffs = SamplerCoeffs::new(schedule, req.sampler.kind, steps);
    let solver_cfg = req.solver_config();
    let scenario = req.sampler.label();

    let mut problem = Problem::new(&coeffs, model, req.cond.clone(), req.seed);
    let mut warm = false;
    if req.use_trajectory_cache {
        if let Some(donor) = cache.lookup(&scenario, req.seed, &req.cond, cfg.cache_max_dist)
        {
            let t_init =
                ((cfg.cache_t_init_frac * steps as f64).ceil() as usize).clamp(1, steps);
            init_from_trajectory(&mut problem, donor.trajectory, donor.xi, t_init);
            warm = true;
        }
    }

    // Hold window-row slots for the duration of the solve.
    let _slots = budget.acquire(solver_cfg.window.min(steps));
    let result = solver::solve(&problem, &solver_cfg);

    if req.use_trajectory_cache && result.converged {
        cache.insert(CachedTrajectory {
            scenario,
            seed: req.seed,
            weights: req.cond.to_weights(cfg.n_components),
            trajectory: result.xs.clone(),
            xi: problem.xi.clone(),
        });
    }

    Ok(SampleResponse {
        sample: result.xs.row(0).to_vec(),
        rounds: result.iterations,
        nfe: result.total_nfe,
        converged: result.converged,
        warm_started: warm,
        latency: job.enqueued.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplerSpec;
    use crate::model::gmm::GmmEps;
    use crate::model::Cond;
    use crate::solver::Method;
    use crate::util::rng::Pcg64;

    fn gmm_model() -> Arc<GmmEps> {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let mut rng = Pcg64::seeded(7);
        let d = 8;
        let means: Vec<f32> = (0..8 * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        Arc::new(GmmEps::new(means, d, 0.25, ns.alpha_bars.clone()))
    }

    fn basic_req(seed: u64) -> SampleRequest {
        let mut r = SampleRequest::parataa(Cond::Class(1), seed, SamplerSpec::ddim(16));
        r.guidance = 2.0;
        r
    }

    #[test]
    fn serves_a_request() {
        let coord = Coordinator::start(gmm_model(), CoordinatorConfig::default());
        let resp = coord.sample(basic_req(1)).unwrap();
        assert!(resp.converged);
        assert!(resp.rounds < 16);
        assert_eq!(resp.sample.len(), 8);
        let m = coord.metrics();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn parallel_result_matches_sequential_through_service() {
        let model = gmm_model();
        let coord = Coordinator::start(model.clone(), CoordinatorConfig::default());
        let mut req = basic_req(5);
        req.method = Method::Taa;
        let resp = coord.sample(req).unwrap();
        // sequential oracle
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, crate::schedule::SamplerKind::Ddim, 16);
        let p = Problem::new(&coeffs, &*model, Cond::Class(1), 5);
        let seq = crate::solver::sample_sequential(&p, 2.0);
        crate::util::proplite::assert_close(&resp.sample, seq.xs.row(0), 5e-3, 5e-2, "service")
            .unwrap();
    }

    #[test]
    fn concurrent_load_all_complete() {
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig { workers: 3, slot_budget: 48, ..Default::default() },
        );
        let handles: Vec<_> = (0..12).map(|i| coord.submit(basic_req(i))).collect();
        for h in handles {
            let r = h.wait().unwrap();
            assert!(r.converged);
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 12);
        assert_eq!(m.failed, 0);
        assert_eq!(coord.slots_available(), 48);
    }

    #[test]
    fn warm_start_reduces_rounds() {
        let coord = Coordinator::start(gmm_model(), CoordinatorConfig::default());
        let mut cold = basic_req(9);
        cold.use_trajectory_cache = true;
        let r1 = coord.sample(cold.clone()).unwrap();
        assert!(!r1.warm_started);
        assert_eq!(coord.cache_len(), 1);
        // Same seed, nearby condition: should warm start and converge faster.
        let mut near = cold.clone();
        near.cond = Cond::Class(1).lerp(&Cond::Class(2), 0.05, 8);
        let r2 = coord.sample(near).unwrap();
        assert!(r2.warm_started);
        assert!(r2.rounds <= r1.rounds, "warm {} vs cold {}", r2.rounds, r1.rounds);
    }

    #[test]
    fn batched_model_through_coordinator() {
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        let model = gmm_model();
        let batcher = Batcher::spawn(model.clone(), BatcherConfig::default());
        let handle = Arc::new(batcher.eps_handle(8, "gmm-batched"));
        let coord = Coordinator::start(handle, CoordinatorConfig::default());
        let handles: Vec<_> = (0..6).map(|i| coord.submit(basic_req(100 + i))).collect();
        for h in handles {
            assert!(h.wait().unwrap().converged);
        }
        drop(coord); // shut down workers before the batcher drops
    }
}
