//! The coordinator server: event-driven round drivers over resumable
//! [`SolverSession`]s.
//!
//! Wiring (see module docs in `coordinator/mod.rs`):
//!
//! ```text
//!   submit() ──► job queue ──► intake (admission: cache lookup, FIFO slot
//!                  │            budget acquire, session construction)
//!                  │                       │
//!                  │                       ▼
//!                  │                  run queue ◄───────────────┐
//!                  │                       │                    │ requeue
//!                  │                       ▼                    │ live
//!                  │              round drivers (fixed pool):   │ sessions
//!                  │              pull ready sessions, merge    │
//!                  │              pending ε batches by guidance─┘
//!                  │              group, ONE pool call / group,
//!                  │              scatter, resume
//!                  └─ trajectory cache (warm starts) ◄─ finalize (reply)
//! ```
//!
//! In-flight sessions are bounded by the **slot budget** (admission blocks
//! in the intake, never in a driver), not by thread count: a single round
//! driver carries hundreds of concurrent solves, advancing each one round
//! at a time. Batch merging happens deterministically at the round boundary
//! — sessions popped this round are grouped by guidance scale (a scalar
//! graph input, so merging is bit-exact) in pop order — replacing the
//! latency-linger heuristic the internal path previously inherited from
//! [`super::batcher`]; the batcher remains as the public `EpsModel`-facing
//! adapter for callers outside the coordinator.
//!
//! Two per-round signals ride on the same scatter loop:
//!
//! - **Streaming prefix delivery** — a request submitted through
//!   [`Coordinator::submit_streaming`] carries a bounded subscription
//!   channel; after each merged round the driver forwards the session's
//!   [`crate::solver::FrontAdvance`] as a [`PrefixChunk`] (frozen rows are
//!   final, so clients receive the converged prefix of the trajectory
//!   while the rest is still solving). The channel is sized so a send can
//!   never block a driver, and the stream closes when the request
//!   finalizes — successfully or not.
//! - **Adaptive window control** — before driving a round with any
//!   adaptive session, each session is told the current device occupancy
//!   (the attached pool's utilization/backlog; 0 without a pool), which
//!   is what [`crate::solver::WindowPolicy::Adaptive`] solves trade
//!   against.

use super::cache::{CachedTrajectory, TrajectoryCache};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{PrefixChunk, SampleRequest, SampleResponse};
use super::scheduler::{OwnedSlotGuard, SlotBudget};
use crate::model::{Cond, EpsModel};
use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs};
use crate::solver::{init::init_from_trajectory, try_sample_sequential, Problem, SolverSession};
use crate::trace::telemetry::{SessionTelemetry, TelemetryLog};
use crate::trace::{self, Layer, Name};
use crate::util::channel::{bounded, Receiver, Sender};
use crate::util::error::{anyhow, Error, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator tuning.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Intake (admission) threads: request parsing, cache lookup, slot
    /// acquisition, session construction. Historically these were
    /// thread-per-solve workers; concurrency is now bounded by
    /// `slot_budget`, so a couple of intakes saturate admission.
    pub workers: usize,
    /// Round-driver threads: each pulls ready sessions from the run queue,
    /// merges their pending ε batches, and submits one pool call per
    /// guidance group per round.
    pub drivers: usize,
    /// Total window-row slots in flight (the "device memory" budget). This
    /// — not `workers` — bounds concurrent sessions.
    pub slot_budget: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Trajectory cache entries.
    pub cache_capacity: usize,
    /// Max condition-weight distance for a warm-start donor.
    pub cache_max_dist: f32,
    /// T_init = ceil(frac · steps) when warm-starting (§4.2).
    pub cache_t_init_frac: f64,
    /// Number of condition components (for densifying `Cond`s).
    pub n_components: usize,
    /// Devices behind the model handle (a [`crate::runtime::DevicePool`]):
    /// the in-flight window-row budget scales as `slot_budget × devices`,
    /// matching the extra device memory a bigger pool brings.
    pub devices: usize,
    /// Convergence telemetry collector: when set, every finalized session
    /// appends its round → (residual norm, front, window, NFE) progression
    /// (see [`crate::trace::telemetry`]). `None` (the default) records
    /// nothing and costs nothing.
    pub telemetry: Option<Arc<TelemetryLog>>,
    /// Fault-tolerance knobs: load-shedding watermark and shed behavior.
    /// The default is fully inert — identical to the historical service.
    pub robustness: RobustnessConfig,
}

/// How the service behaves at the edge of capacity or health: when to shed
/// an incoming request, and what shedding means. Every trigger is opt-in
/// (a watermark, a request deadline) or only reachable under faults (an
/// attached pool with every device quarantined), so the default
/// configuration never changes the historical admission path.
#[derive(Clone, Default)]
pub struct RobustnessConfig {
    /// Slot-budget occupancy fraction in `[0, 1]` at or above which new
    /// requests are shed (degraded or failed per `shed_mode`). `None`
    /// (default) disables watermark shedding. CLI: `--shed-watermark F`.
    pub shed_watermark: Option<f64>,
    /// What to do with a shed request.
    pub shed_mode: ShedMode,
    /// Pool-independent model for degraded sequential rollouts. When the
    /// service degrades *because the pool is unhealthy* (every device
    /// quarantined) or saturated, running the fallback through that same
    /// pool would fail or add load — so where an in-process model exists
    /// (GMM deployments), set it here and degraded requests bypass the
    /// pool entirely. `None` falls back to the serving model via its
    /// fallible path: a pool error then surfaces as a classified failure,
    /// never a panic.
    pub fallback_model: Option<Arc<dyn EpsModel>>,
}

impl std::fmt::Debug for RobustnessConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RobustnessConfig")
            .field("shed_watermark", &self.shed_watermark)
            .field("shed_mode", &self.shed_mode)
            .field("fallback_model", &self.fallback_model.as_ref().map(|m| m.name().to_string()))
            .finish()
    }
}

/// What "shedding" an admitted-but-unservable request means.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedMode {
    /// Graceful degradation (the default): serve a sequential rollout on
    /// the intake thread — slower, but correct (bitwise-equal to
    /// [`crate::solver::sample_sequential`]) and off the saturated
    /// parallel path.
    #[default]
    DegradeSequential,
    /// Reject with an [`crate::util::error::ErrorKind::Shed`] error.
    Fail,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            drivers: 2,
            slot_budget: 400,
            queue_capacity: 128,
            cache_capacity: 64,
            cache_max_dist: 0.5,
            cache_t_init_frac: 0.7,
            n_components: 8,
            devices: 1,
            telemetry: None,
            robustness: RobustnessConfig::default(),
        }
    }
}

struct Job {
    req: SampleRequest,
    reply: Sender<Result<SampleResponse>>,
    /// Converged-prefix subscription (`None` for plain submissions).
    progress: Option<Sender<PrefixChunk>>,
    enqueued: Instant,
    /// Client-disconnect propagation (see [`CancelToken`]).
    cancel: CancelToken,
}

/// Cooperative cancellation flag shared between a request's handle and its
/// in-flight session. Setting it ([`cancel`](Self::cancel)) does not
/// interrupt a round in progress — a merged device call is never torn
/// apart mid-flight — but the intake (at admission) and the round drivers
/// (at every round boundary, the only places a live session is owned)
/// check it and fail the request with an
/// [`ErrorKind::Cancelled`](crate::util::error::ErrorKind::Cancelled)
/// error, releasing its slots. The HTTP front sets it when an SSE client
/// disconnects mid-stream, so abandoned solves stop consuming devices.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation (idempotent; observed at round boundaries).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Session accounting with panic safety. Created at the top of admission;
/// on drop it records the request as failed unless
/// [`defuse`](Self::defuse) ran first (successful finalize), so a session
/// dropped on any abnormal path (an admission panic, a solve panic
/// unwinding a round, a closed run queue) keeps `completed + failed`
/// consistent instead of vanishing from the counters. The in-flight gauge
/// is separate: [`mark_started`](Self::mark_started) fires at slot grant,
/// so the gauge counts only slot-holding sessions (the property the
/// `peak > driver_threads` checks rely on), not admissions still blocked
/// on the budget.
struct SessionGuard {
    metrics: Arc<Metrics>,
    started: bool,
    finalized: bool,
}

impl SessionGuard {
    fn new(metrics: Arc<Metrics>) -> SessionGuard {
        SessionGuard { metrics, started: false, finalized: false }
    }

    /// The session acquired its slots: count it into the in-flight gauge.
    fn mark_started(&mut self) {
        self.metrics.session_started();
        self.started = true;
    }

    /// The request completed normally; drop only clears the gauge.
    fn defuse(&mut self) {
        self.finalized = true;
    }
}

impl Drop for SessionGuard {
    fn drop(&mut self) {
        if !self.finalized {
            self.metrics.record_failure();
        }
        if self.started {
            self.metrics.session_finished();
        }
    }
}

/// One admitted request: a resumable session plus everything needed to
/// finalize it. Owned by exactly one round driver at a time; between
/// rounds it lives on the run queue.
struct ActiveSession {
    session: SolverSession,
    req: SampleRequest,
    reply: Sender<Result<SampleResponse>>,
    enqueued: Instant,
    warm: bool,
    scenario: String,
    /// Converged-prefix subscription; dropping it (any finalize or failure
    /// path) ends the client's stream.
    progress: Option<Sender<PrefixChunk>>,
    /// Prefix chunks already delivered (0 ⇒ the next one records the
    /// latency-to-first-prefix metric).
    chunks_sent: usize,
    /// Absolute deadline (admission time + `req.deadline_ms`), checked by
    /// the round drivers between rounds; `None` = infinitely patient.
    deadline: Option<Instant>,
    /// Client-disconnect flag, checked alongside the deadline.
    cancel: CancelToken,
    /// Window-row slots held for the session's whole lifetime. Declared
    /// before `in_flight` so a plain drop releases budget first, then
    /// clears the gauge the shutdown path waits on.
    slots: OwnedSlotGuard,
    in_flight: SessionGuard,
}

impl ActiveSession {
    /// The request's deadline has already passed.
    fn deadline_expired(&self, now: Instant) -> bool {
        matches!(self.deadline, Some(dl) if now >= dl)
    }

    /// Less than half the request's deadline budget remains. The round
    /// drivers then pin the session's occupancy signal to 0 so the
    /// adaptive window controller grows (never shrinks) the window,
    /// trading device rows for wall-clock rounds.
    fn deadline_urgent(&self) -> bool {
        match (self.deadline, self.req.deadline_ms) {
            (Some(dl), Some(ms)) => {
                dl.saturating_duration_since(Instant::now()) < Duration::from_millis(ms) / 2
            }
            _ => false,
        }
    }
}

/// What admission produced for one job.
enum Admission {
    /// A live session bound for the run queue.
    Run(Box<ActiveSession>),
    /// The request was fully answered on the intake thread (degraded,
    /// shed, or already past its deadline) — nothing reaches the drivers.
    Handled,
}

/// Everything needed to answer a request at admission time.
struct PendingReply {
    reply: Sender<Result<SampleResponse>>,
    progress: Option<Sender<PrefixChunk>>,
    enqueued: Instant,
}

/// Handle to an in-flight request.
pub struct ResponseHandle {
    rx: Receiver<Result<SampleResponse>>,
}

impl ResponseHandle {
    /// Block until the sample is ready.
    pub fn wait(self) -> Result<SampleResponse> {
        self.rx
            .recv()
            .unwrap_or_else(|| Err(anyhow!("coordinator shut down")))
    }
}

/// Handle to an in-flight **streaming** request (from
/// [`Coordinator::submit_streaming`]): converged-prefix chunks arrive on
/// [`next_chunk`](Self::next_chunk) while the solve runs; the final
/// response is read with [`wait`](Self::wait) once the stream ends.
///
/// Typical client loop:
///
/// ```text
/// while let Some(chunk) = handle.next_chunk() { deliver(chunk); }
/// let response = handle.wait()?; // stream closed ⇒ response is imminent
/// ```
pub struct StreamHandle {
    chunks: Receiver<PrefixChunk>,
    response: ResponseHandle,
    cancel: CancelToken,
}

impl StreamHandle {
    /// Cancel the request: the session fails with a classified
    /// [`ErrorKind::Cancelled`](crate::util::error::ErrorKind::Cancelled)
    /// error at the next round boundary (or at admission if it has not
    /// started), releasing its slots. The chunk stream still closes and
    /// [`wait`](Self::wait) still resolves — cancellation never leaves a
    /// hanging handle. The HTTP front calls this when an SSE client
    /// disconnects mid-stream.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clone of the request's [`CancelToken`] (usable after the handle
    /// is consumed by [`wait`](Self::wait)).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Block for the next converged-prefix chunk; `None` once the request
    /// finalized (successfully or not) and no chunks remain.
    pub fn next_chunk(&self) -> Option<PrefixChunk> {
        self.chunks.recv()
    }

    /// Non-blocking poll for an already-delivered chunk.
    pub fn try_chunk(&self) -> Option<PrefixChunk> {
        self.chunks.try_recv()
    }

    /// Block until the final response. Drain the chunk stream first if you
    /// need it — this consumes the handle (undelivered chunks are
    /// dropped).
    pub fn wait(self) -> Result<SampleResponse> {
        self.response.wait()
    }
}

/// The sampling service.
pub struct Coordinator {
    tx: Sender<Job>,
    /// Kept to close the run queue at shutdown (the drivers' exit signal).
    run_tx: Sender<ActiveSession>,
    metrics: Arc<Metrics>,
    cache: Arc<TrajectoryCache>,
    budget: Arc<SlotBudget>,
    intakes: Vec<JoinHandle<()>>,
    drivers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the service over a model (typically a pooled handle; the
    /// round drivers merge ε batches internally, so no batcher is needed
    /// on this path).
    pub fn start(model: Arc<dyn EpsModel>, cfg: CoordinatorConfig) -> Self {
        let (tx, job_rx) = bounded::<Job>(cfg.queue_capacity);
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(TrajectoryCache::new(cfg.cache_capacity, cfg.n_components));
        let budget = Arc::new(SlotBudget::new(cfg.slot_budget * cfg.devices.max(1)));
        let schedule = Arc::new(NoiseSchedule::new(BetaSchedule::Linear, 1000));
        let n_intakes = cfg.workers.max(1);
        let n_drivers = cfg.drivers.max(1);
        metrics.set_drivers(n_drivers);

        // Sized so a requeue can never block: every in-flight session holds
        // at least one budget slot, so sessions ≤ budget.total() < capacity.
        let (run_tx, run_rx) =
            bounded::<ActiveSession>(budget.total() + n_intakes + n_drivers);

        let mut intakes = Vec::with_capacity(n_intakes);
        for i in 0..n_intakes {
            let job_rx = job_rx.clone();
            let run_tx = run_tx.clone();
            let model = model.clone();
            let metrics = metrics.clone();
            let cache = cache.clone();
            let budget = budget.clone();
            let schedule = schedule.clone();
            let cfg = cfg.clone();
            intakes.push(
                std::thread::Builder::new()
                    .name(format!("parataa-intake-{i}"))
                    .spawn(move || {
                        while let Some(job) = job_rx.recv() {
                            // A malformed request must fail itself, not
                            // kill admission: contain panics (mirroring
                            // the driver path) and answer via a clone of
                            // the reply handle. The session guard — made
                            // first thing in admit() — records exactly
                            // one failure for the panicked request.
                            let reply = job.reply.clone();
                            let admitted =
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                    admit(
                                        job, &*model, &schedule, &cache, &budget, &metrics,
                                        &cfg,
                                    )
                                }));
                            let active = match admitted {
                                Ok(Admission::Run(active)) => *active,
                                Ok(Admission::Handled) => continue,
                                Err(_) => {
                                    eprintln!(
                                        "parataa: admission panicked; failing the request"
                                    );
                                    let _ = reply
                                        .send(Err(anyhow!("invalid request: admission failed")));
                                    continue;
                                }
                            };
                            if let Err(back) = run_tx.send(active) {
                                // Drop the session first: its guard
                                // records the failure and frees the slots
                                // before the error becomes observable.
                                let ActiveSession { reply, .. } = back.0;
                                let _ = reply
                                    .send(Err(anyhow!("coordinator run queue closed")));
                            }
                        }
                    })
                    .expect("spawn coordinator intake"),
            );
        }
        let mut drivers = Vec::with_capacity(n_drivers);
        for i in 0..n_drivers {
            let run_rx = run_rx.clone();
            let run_tx = run_tx.clone();
            let model = model.clone();
            let metrics = metrics.clone();
            let cache = cache.clone();
            let cfg = cfg.clone();
            drivers.push(
                std::thread::Builder::new()
                    .name(format!("parataa-driver-{i}"))
                    .spawn(move || run_driver(i, run_rx, run_tx, model, metrics, cache, cfg))
                    .expect("spawn coordinator round driver"),
            );
        }
        Coordinator { tx, run_tx, metrics, cache, budget, intakes, drivers }
    }

    /// Enqueue a request (blocking if the queue is full — backpressure).
    pub fn submit(&self, req: SampleRequest) -> ResponseHandle {
        let (rtx, rrx) = bounded(1);
        let job = Job {
            req,
            reply: rtx,
            progress: None,
            enqueued: Instant::now(),
            cancel: CancelToken::new(),
        };
        if self.tx.send(job).is_err() {
            panic!("coordinator is down");
        }
        ResponseHandle { rx: rrx }
    }

    /// Enqueue a request with a converged-prefix subscription: the round
    /// drivers deliver each advance of the session's residual front as a
    /// [`PrefixChunk`] while the solve is still running, and the chunk
    /// stream closes when the request finalizes. The streamed states are
    /// bit-identical to the final response (frozen rows are never
    /// rewritten), and the channel is sized so delivery can never block a
    /// driver — a slow or abandoned consumer only buffers at most one
    /// chunk per trajectory row.
    pub fn submit_streaming(&self, req: SampleRequest) -> StreamHandle {
        let (rtx, rrx) = bounded(1);
        // ≤ steps chunks can ever be sent (each covers ≥ 1 of the steps
        // rows), so this capacity makes `try_send` infallible in practice.
        let (ptx, prx) = bounded(req.sampler.steps.max(1) + 1);
        let cancel = CancelToken::new();
        let job = Job {
            req,
            reply: rtx,
            progress: Some(ptx),
            enqueued: Instant::now(),
            cancel: cancel.clone(),
        };
        if self.tx.send(job).is_err() {
            panic!("coordinator is down");
        }
        StreamHandle { chunks: prx, response: ResponseHandle { rx: rrx }, cancel }
    }

    /// Convenience: submit and wait.
    pub fn sample(&self, req: SampleRequest) -> Result<SampleResponse> {
        self.submit(req).wait()
    }

    /// Point-in-time metrics snapshot (latency/throughput, merge
    /// occupancy, streaming counters, per-device breakdown).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Record a device pool's per-device counters in this service's
    /// metrics: snapshots/reports then include the per-device breakdown.
    pub fn attach_pool(&self, stats: Arc<crate::runtime::PoolStats>) {
        self.metrics.attach_pool(stats);
    }

    /// Trajectory-cache size (diagnostic).
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Free slots (diagnostic).
    pub fn slots_available(&self) -> usize {
        self.budget.available()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Stop admission: intakes drain whatever is queued, then exit.
        self.tx.close();
        for t in self.intakes.drain(..) {
            let _ = t.join();
        }
        // Admission is over, so the in-flight gauge is now monotone
        // non-increasing; wait for the drivers to finalize the stragglers,
        // then close the run queue — the drivers' (otherwise fully
        // blocking) recv() returns None and they exit. No idle polling
        // anywhere: this 1 ms spin exists only during teardown.
        while self.metrics.sessions_in_flight() > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        self.run_tx.close();
        for t in self.drivers.drain(..) {
            let _ = t.join();
        }
    }
}

/// Admission: enforce the deadline and load-shedding policy, then build
/// the problem (with a §4.2 warm start when the cache has a donor), block
/// FIFO on the slot budget, and construct the session.
fn admit(
    job: Job,
    model: &dyn EpsModel,
    schedule: &NoiseSchedule,
    cache: &TrajectoryCache,
    budget: &Arc<SlotBudget>,
    metrics: &Arc<Metrics>,
    cfg: &CoordinatorConfig,
) -> Admission {
    let Job { req, reply, progress, enqueued, cancel } = job;
    // The admit span's track id is only known once the session exists, so
    // start deferred and complete against its trace id below.
    let admit_span = trace::begin();
    // Guard first: if anything below panics (malformed request), the
    // unwinding guard records exactly one failure.
    let mut in_flight = SessionGuard::new(metrics.clone());
    let deadline = req.deadline_ms.map(|ms| enqueued + Duration::from_millis(ms));

    // Deadline already blown in the queue: reject before doing any work.
    if matches!(deadline, Some(dl) if Instant::now() >= dl) {
        metrics.deadline_miss();
        // The guard records the failure — and the stream closes — before
        // the error becomes observable, mirroring the finalize ordering.
        drop(in_flight);
        drop(progress);
        let _ = reply.send(Err(Error::deadline(format!(
            "deadline of {} ms expired in the queue (waited {:.1} ms)",
            req.deadline_ms.unwrap_or(0),
            enqueued.elapsed().as_secs_f64() * 1e3,
        ))));
        return Admission::Handled;
    }

    // Already abandoned while queued (e.g. the HTTP client disconnected):
    // no point building a session nobody will read.
    if cancel.is_cancelled() {
        metrics.record_cancelled();
        drop(in_flight);
        drop(progress);
        let _ = reply.send(Err(Error::cancelled("request cancelled before admission")));
        return Admission::Handled;
    }

    // Load shedding: at the capacity/health edge, answer on the intake
    // thread instead of queueing work the drivers cannot serve in time.
    if let Some((code, why)) = shed_reason(deadline, budget, metrics, &cfg.robustness) {
        match cfg.robustness.shed_mode {
            ShedMode::DegradeSequential => {
                let out = PendingReply { reply, progress, enqueued };
                return degrade_sequential(
                    &req,
                    out,
                    in_flight,
                    model,
                    schedule,
                    metrics,
                    &cfg.robustness,
                    code,
                );
            }
            ShedMode::Fail => {
                metrics.record_shed();
                drop(in_flight);
                drop(progress);
                let _ = reply.send(Err(Error::shed(format!("request shed: {why}"))));
                return Admission::Handled;
            }
        }
    }

    let steps = req.sampler.steps;
    let coeffs = SamplerCoeffs::new(schedule, req.sampler.kind, steps);
    let solver_cfg = req.solver_config();
    let scenario = req.sampler.label();

    let mut problem = Problem::new(&coeffs, model, req.cond.clone(), req.seed);
    let mut warm = false;
    if req.use_trajectory_cache {
        if let Some(donor) = cache.lookup(&scenario, req.seed, &req.cond, cfg.cache_max_dist)
        {
            let t_init =
                ((cfg.cache_t_init_frac * steps as f64).ceil() as usize).clamp(1, steps);
            init_from_trajectory(&mut problem, donor.trajectory, donor.xi, t_init);
            warm = true;
        }
    }

    // Hold window-row slots for the session's lifetime. Blocking here — in
    // the intake, never in a round driver — is what bounds in-flight
    // sessions by the budget while rounds keep flowing. Adaptive-window
    // sessions reserve their worst-case (max_window) footprint so growth
    // mid-solve can never oversubscribe the budget.
    let slots = SlotBudget::acquire_owned(budget, solver_cfg.max_window_rows().min(steps));
    in_flight.mark_started();
    let session = SolverSession::new(&problem, &solver_cfg);
    // Covers cache lookup + slot wait + construction (admission latency).
    trace::complete(
        admit_span,
        Layer::Session,
        Name::Admit,
        session.trace_id(),
        steps as i64,
        warm as i64,
    );
    Admission::Run(Box::new(ActiveSession {
        session,
        req,
        reply,
        enqueued,
        warm,
        scenario,
        progress,
        chunks_sent: 0,
        deadline,
        cancel,
        slots,
        in_flight,
    }))
}

/// Should this request be shed? Returns a trace reason code (0 = slot
/// watermark, 1 = no healthy devices, 2 = deadline unmeetable) plus a
/// human-readable cause. `None` under normal operation — every trigger
/// requires an opt-in watermark, an attached pool with every device
/// quarantined, or a request deadline.
fn shed_reason(
    deadline: Option<Instant>,
    budget: &SlotBudget,
    metrics: &Metrics,
    rb: &RobustnessConfig,
) -> Option<(i64, String)> {
    if let Some(w) = rb.shed_watermark {
        let total = budget.total().max(1);
        let used = total - budget.available().min(total);
        if used as f64 / total as f64 >= w {
            return Some((0, format!("slot budget at {used}/{total} ≥ watermark {w}")));
        }
    }
    if metrics.pool_healthy_devices() == Some(0) {
        return Some((1, "every pool device is quarantined".to_string()));
    }
    if let Some(dl) = deadline {
        // With latency history, reject-or-degrade a request whose
        // remaining budget is under the observed median: queueing it onto
        // the parallel path would most likely end in a mid-solve miss.
        let snap = metrics.snapshot();
        if snap.completed >= 8 {
            let p50 = Duration::from_secs_f64(snap.latency_ms_p50.max(0.0) / 1e3);
            if dl.saturating_duration_since(Instant::now()) < p50 {
                return Some((
                    2,
                    format!(
                        "deadline unmeetable: remaining budget < p50 latency {:.1} ms",
                        snap.latency_ms_p50
                    ),
                ));
            }
        }
    }
    None
}

/// Graceful degradation: serve the request with a sequential rollout on
/// the intake thread — slower, but correct (bitwise-equal to
/// [`crate::solver::sample_sequential`] on a fresh, un-warm-started
/// problem) and off the
/// saturated or unhealthy parallel path. The rollout runs on
/// [`RobustnessConfig::fallback_model`] when one is configured (bypassing
/// the pool entirely — essential when degradation triggered *because* the
/// pool is unhealthy), else on the serving model's fallible path, where a
/// pool error becomes a classified failure rather than a panic. A
/// streaming subscriber receives the whole trajectory as one chunk before
/// the stream closes.
#[allow(clippy::too_many_arguments)] // admission context + shed policy ARE the signature
fn degrade_sequential(
    req: &SampleRequest,
    out: PendingReply,
    mut guard: SessionGuard,
    model: &dyn EpsModel,
    schedule: &NoiseSchedule,
    metrics: &Metrics,
    rb: &RobustnessConfig,
    reason: i64,
) -> Admission {
    let PendingReply { reply, progress, enqueued } = out;
    let steps = req.sampler.steps;
    let coeffs = SamplerCoeffs::new(schedule, req.sampler.kind, steps);
    let deg_model: &dyn EpsModel = rb.fallback_model.as_deref().unwrap_or(model);
    let problem = Problem::new(&coeffs, deg_model, req.cond.clone(), req.seed);
    let seq = match try_sample_sequential(&problem, req.guidance) {
        Ok(seq) => seq,
        Err(e) => {
            // The fallback itself failed (no pool-independent model and
            // the pool is down): fail the request with the classified
            // error — guard drop records the failure, the stream closes —
            // instead of letting the pooled handle's panic path unwind
            // the intake.
            drop(guard);
            drop(progress);
            let _ = reply.send(Err(e.context("degraded sequential fallback failed")));
            return Admission::Handled;
        }
    };
    trace::instant(Layer::Session, Name::Degrade, req.seed, steps as i64, reason);
    if let Some(tx) = &progress {
        // Every row freezes at once, so the stream contract collapses to a
        // single chunk tiling [0, steps) (round 0, like warm-start rows).
        let d = deg_model.dim();
        let mut states = Vec::with_capacity(steps * d);
        for r in 0..steps {
            states.extend_from_slice(seq.xs.row(r));
        }
        let chunk = PrefixChunk {
            rows: 0..steps,
            states,
            residuals: vec![f64::NAN; steps],
            round: 0,
        };
        if tx.try_send(chunk).is_ok() {
            metrics.record_prefix(steps, Some(enqueued.elapsed()));
        }
    }
    drop(progress);
    let resp = SampleResponse {
        sample: seq.xs.row(0).to_vec(),
        rounds: steps,
        nfe: seq.nfe,
        converged: true,
        warm_started: false,
        degraded: true,
        latency: enqueued.elapsed(),
    };
    // Success accounting settles before the response is observable, like
    // finalize: a degraded request completed, it did not fail.
    metrics.record_success(resp.latency, resp.rounds, resp.nfe, false);
    metrics.record_degraded();
    guard.defuse();
    drop(guard);
    let _ = reply.send(Ok(resp));
    Admission::Handled
}

/// Forward any new converged-prefix advance of `active`'s session to its
/// subscription channel (no-op for non-streaming requests, and purely
/// observational for the solve itself). The channel is sized for one chunk
/// per trajectory row, so `try_send` cannot drop chunks; an abandoned
/// receiver merely buffers them until the request finalizes.
fn emit_progress(active: &mut ActiveSession, metrics: &Metrics) {
    if active.progress.is_none() {
        return;
    }
    let d = active.session.dim();
    if let Some(adv) = active.session.progress() {
        let rows = adv.newly_converged;
        let mut states = Vec::with_capacity(rows.len() * d);
        for p in rows.clone() {
            states.extend_from_slice(active.session.xs().row(p));
        }
        let chunk = PrefixChunk {
            rows: rows.clone(),
            states,
            residuals: adv.residuals,
            round: active.session.iterations(),
        };
        let first = if active.chunks_sent == 0 {
            Some(active.enqueued.elapsed())
        } else {
            None
        };
        // Count only what actually reached the channel, so the streaming
        // metrics never over-report delivery (the capacity bound makes a
        // failed send unreachable in practice, but the accounting should
        // not have to rely on that).
        if let Some(tx) = &active.progress {
            if tx.try_send(chunk).is_ok() {
                active.chunks_sent += 1;
                metrics.record_prefix(rows.len(), first);
                // Same branch as record_prefix, so the trace-derived chunk
                // count always equals `prefix_chunks_sent`.
                trace::instant(
                    Layer::Stream,
                    Name::ChunkEmit,
                    active.session.trace_id(),
                    rows.len() as i64,
                    active.session.iterations() as i64,
                );
            }
        }
    }
}

/// A round-driver thread: pop every ready session, drive them one merged
/// round, requeue the survivors. Blocks in `recv()` while idle — no
/// polling; the Coordinator's Drop closes the run queue (after admission
/// stops and in-flight reaches zero), which is the exit signal.
fn run_driver(
    driver_idx: usize,
    run_rx: Receiver<ActiveSession>,
    // Each driver keeps a sender so it can requeue live sessions; shutdown
    // is therefore an explicit close, not sender disconnection.
    run_tx: Sender<ActiveSession>,
    model: Arc<dyn EpsModel>,
    metrics: Arc<Metrics>,
    cache: Arc<TrajectoryCache>,
    cfg: CoordinatorConfig,
) {
    while let Some(first) = run_rx.recv() {
        let mut round = vec![first];
        round.extend(run_rx.drain_up_to(usize::MAX));
        // drive_round confines solve/backend panics to the poisoned
        // session or guidance group; this outer catch is the backstop for
        // the finalize/requeue path, so a panic there can neither take
        // down the driver nor hang shutdown (dropped sessions' guards
        // release slots and record the failures).
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            drive_round(driver_idx, round, &*model, &cache, &metrics, &run_tx, &cfg)
        }));
        if outcome.is_err() {
            eprintln!("parataa: a round panicked outside the solves; its requests were failed");
        }
    }
}

/// Drive one merged parallel round over `round`'s sessions.
fn drive_round(
    driver_idx: usize,
    mut round: Vec<ActiveSession>,
    model: &dyn EpsModel,
    cache: &TrajectoryCache,
    metrics: &Metrics,
    run_tx: &Sender<ActiveSession>,
    cfg: &CoordinatorConfig,
) {
    // Sessions that arrived already done (e.g. `max_rounds: 0`) finalize
    // without a device call; sessions past their deadline fail here, at
    // the round boundary — the only place a live session is owned.
    let mut i = 0;
    let now = Instant::now();
    while i < round.len() {
        if round[i].session.is_done() {
            finalize(round.swap_remove(i), cache, metrics, cfg);
        } else if round[i].cancel.is_cancelled() {
            metrics.record_cancelled();
            let s = round.swap_remove(i);
            let rounds_run = s.session.iterations();
            // As with deadline expiry below: drop everything but the reply
            // first, so the guard's failure count and the freed slots are
            // settled before the error is observable.
            let ActiveSession { reply, .. } = s;
            let _ = reply.send(Err(Error::cancelled(format!(
                "cancelled by the client after {rounds_run} parallel round(s)"
            ))));
        } else if round[i].deadline_expired(now) {
            metrics.deadline_miss();
            let s = round.swap_remove(i);
            let rounds_run = s.session.iterations();
            // Drop everything but the reply first: the guard records the
            // failure and the slots free before the error is observable.
            let ActiveSession { reply, req, .. } = s;
            let _ = reply.send(Err(Error::deadline(format!(
                "deadline of {} ms expired after {rounds_run} parallel round(s)",
                req.deadline_ms.unwrap_or(0)
            ))));
        } else {
            i += 1;
        }
    }
    if round.is_empty() {
        return;
    }
    // The early-return above skips `record_round` too, so the trace-derived
    // driver_round count stays equal to `MetricsSnapshot::rounds_driven`.
    let round_span = trace::begin();

    // Device occupancy for the adaptive window controllers: the attached
    // pool's mean utilization / backlog. Slot-budget pressure is *not* a
    // substitute signal — adaptive sessions reserve their max_window for
    // their whole lifetime, so shrinking frees no budget rows and a
    // budget-based signal would latch every window at min_window under
    // sustained load. Without a pool the signal stays 0 and adaptive
    // solves size on convergence velocity alone. Guarded so the default
    // all-Fixed workload never pays the per-round pool snapshot.
    if round.iter().any(|s| s.session.is_adaptive()) {
        let occupancy = metrics.device_occupancy().unwrap_or(0.0);
        for s in round.iter_mut() {
            // An urgent deadline pins the signal to 0: the controller then
            // grows (never shrinks) the window, spending device rows to
            // save wall-clock rounds.
            let occ = if s.deadline_urgent() { 0.0 } else { occupancy };
            s.session.set_occupancy(occ);
        }
    }

    let d = model.dim();
    // Deterministic merge: group by guidance bits in pop order (guidance is
    // a scalar graph input, so per-row results are bit-identical to a solo
    // call; there is no linger — whatever is ready now rides this round).
    let mut groups: Vec<(u32, Vec<usize>)> = Vec::new();
    for (i, s) in round.iter().enumerate() {
        let key = s.session.guidance().to_bits();
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(i),
            None => groups.push((key, vec![i])),
        }
    }

    let n_groups = groups.len();
    let mut total_rows = 0usize;
    // A poisoned session carries the classified error it will fail with.
    let mut poisoned: Vec<Option<Error>> = vec![None; round.len()];
    let mut x: Vec<f32> = Vec::new();
    let mut t: Vec<usize> = Vec::new();
    let mut conds: Vec<Cond> = Vec::new();
    let mut lens: Vec<usize> = Vec::new();
    let mut out: Vec<f32> = Vec::new();
    for (gbits, idxs) in &groups {
        let guidance = f32::from_bits(*gbits);
        let merge_span = trace::begin();
        x.clear();
        t.clear();
        conds.clear();
        lens.clear();
        for &i in idxs {
            let b = round[i].session.pending().expect("live session has a pending batch");
            x.extend_from_slice(b.x);
            t.extend_from_slice(b.t);
            conds.extend_from_slice(b.conds);
            lens.push(b.len());
        }
        let rows = t.len();
        total_rows += rows;
        // The gather that builds one guidance group's merged batch.
        trace::complete(
            merge_span,
            Layer::Driver,
            Name::Merge,
            driver_idx as u64,
            idxs.len() as i64,
            rows as i64,
        );
        out.resize(rows * d, 0.0);
        // ONE merged device call per guidance group per round; the pool
        // behind `model` shards it across devices. The fallible entry
        // point surfaces classified device errors (the pool's retry layer
        // has already done what it could); a panicking in-process backend
        // is contained the same way. Either poisons only this guidance
        // group, not the whole round.
        let call = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.try_eps_batch(&x, &t, &conds, guidance, &mut out)
        }));
        let failure = match call {
            Ok(Ok(())) => None,
            Ok(Err(e)) => Some(e.context("parallel round ε batch failed")),
            Err(_) => Some(Error::msg("ε backend panicked during a parallel round")),
        };
        if let Some(e) = failure {
            for &i in idxs {
                poisoned[i] = Some(e.clone());
            }
            continue;
        }
        // Scatter results back: each session advances exactly one round.
        // A panicking update rule poisons only its own session.
        let mut off = 0usize;
        for (&i, &n) in idxs.iter().zip(lens.iter()) {
            let slice = &out[off * d..(off + n) * d];
            off += n;
            let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                round[i].session.resume(slice);
            }));
            if stepped.is_err() {
                poisoned[i] = Some(Error::msg("solve panicked during a parallel round"));
            }
        }
        trace::instant(
            Layer::Driver,
            Name::Scatter,
            driver_idx as u64,
            idxs.len() as i64,
            rows as i64,
        );
    }
    metrics.record_round(round.len(), total_rows, n_groups);
    // Ends exactly at the `record_round` call site (see the early-return
    // note above): Σ driver_round spans ≡ rounds_driven.
    trace::complete(
        round_span,
        Layer::Driver,
        Name::DriverRound,
        driver_idx as u64,
        round.len() as i64,
        total_rows as i64,
    );

    // Forward per-session front advances to streaming subscribers right
    // after the scatter: converged-prefix chunks land one round boundary
    // after the rows freeze, long before the request finalizes.
    for (i, s) in round.iter_mut().enumerate() {
        if poisoned[i].is_none() {
            emit_progress(s, metrics);
        }
    }

    // Poisoned sessions fail with an accurate error (their guards record
    // the failure on drop); finished sessions finalize; live ones rejoin
    // the back of the run queue (round-robin — no session can starve).
    for (i, s) in round.into_iter().enumerate() {
        if let Some(err) = poisoned[i].take() {
            eprintln!("parataa: a solve failed ({}); failing its request", err.kind().label());
            // Drop everything but the reply first, so the failure count,
            // slots and gauge are settled before the caller can observe
            // the error (mirroring finalize's ordering for successes).
            let ActiveSession { reply, .. } = s;
            let _ = reply.send(Err(err));
        } else if s.session.is_done() {
            finalize(s, cache, metrics, cfg);
        } else if let Err(back) = run_tx.send(s) {
            // Unreachable in practice: the queue is sized for every
            // admissible session and only closes once in-flight is zero.
            // The dropped session's guard records the failure (settled,
            // as above, before the reply is visible).
            let ActiveSession { reply, .. } = back.0;
            let _ = reply.send(Err(anyhow!("coordinator run queue closed")));
        }
    }
}

/// Send the response, populate the trajectory cache, release the slots.
fn finalize(
    mut active: ActiveSession,
    cache: &TrajectoryCache,
    metrics: &Metrics,
    cfg: &CoordinatorConfig,
) {
    let finalize_span = trace::begin();
    let trace_id = active.session.trace_id();
    // Deliver any advance the round loop has not reported yet (covers
    // sessions finalized without ever being driven, e.g. `max_rounds: 0`
    // warm starts), then close the stream: subscribers observe "chunks,
    // stream end, response" in that order.
    emit_progress(&mut active, metrics);
    let ActiveSession {
        session,
        req,
        reply,
        enqueued,
        warm,
        scenario,
        progress,
        chunks_sent: _,
        deadline: _,
        cancel: _,
        slots,
        mut in_flight,
    } = active;
    drop(progress);
    let cache_xi = if req.use_trajectory_cache && session.converged() {
        Some(session.xi().clone())
    } else {
        None
    };
    metrics.record_coarse_rounds(session.coarse_rounds());
    let result = session.finish();
    if let Some(log) = &cfg.telemetry {
        log.record(SessionTelemetry::from_records(
            trace_id,
            req.sampler.steps,
            result.converged,
            &result.records,
        ));
    }
    if let Some(xi) = cache_xi {
        cache.insert(CachedTrajectory {
            scenario,
            seed: req.seed,
            weights: req.cond.to_weights(cfg.n_components),
            trajectory: result.xs.clone(),
            xi,
        });
    }
    let resp = SampleResponse {
        sample: result.xs.row(0).to_vec(),
        rounds: result.iterations,
        nfe: result.total_nfe,
        converged: result.converged,
        warm_started: warm,
        degraded: false,
        latency: enqueued.elapsed(),
    };
    // Return budget and clear the in-flight gauge before replying (the
    // historical worker path released its slots before the reply, and a
    // caller that has observed the response must see both already
    // settled). `defuse` first: this finalize is a success, not a failure.
    drop(slots);
    metrics.record_success(resp.latency, resp.rounds, resp.nfe, resp.warm_started);
    in_flight.defuse();
    drop(in_flight);
    trace::complete(
        finalize_span,
        Layer::Session,
        Name::Finalize,
        trace_id,
        resp.rounds as i64,
        resp.converged as i64,
    );
    let _ = reply.send(Ok(resp));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplerSpec;
    use crate::model::gmm::GmmEps;
    use crate::model::Cond;
    use crate::solver::Method;
    use crate::util::rng::Pcg64;

    fn gmm_model() -> Arc<GmmEps> {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let mut rng = Pcg64::seeded(7);
        let d = 8;
        let means: Vec<f32> = (0..8 * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        Arc::new(GmmEps::new(means, d, 0.25, ns.alpha_bars.clone()))
    }

    fn basic_req(seed: u64) -> SampleRequest {
        let mut r = SampleRequest::parataa(Cond::Class(1), seed, SamplerSpec::ddim(16));
        r.guidance = 2.0;
        r
    }

    #[test]
    fn serves_a_request() {
        let coord = Coordinator::start(gmm_model(), CoordinatorConfig::default());
        let resp = coord.sample(basic_req(1)).unwrap();
        assert!(resp.converged);
        assert!(resp.rounds < 16);
        assert_eq!(resp.sample.len(), 8);
        let m = coord.metrics();
        assert_eq!(m.completed, 1);
        assert!(m.rounds_driven >= resp.rounds as u64);
    }

    #[test]
    fn parallel_result_matches_sequential_through_service() {
        let model = gmm_model();
        let coord = Coordinator::start(model.clone(), CoordinatorConfig::default());
        let mut req = basic_req(5);
        req.method = Method::Taa;
        let resp = coord.sample(req).unwrap();
        // sequential oracle
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, crate::schedule::SamplerKind::Ddim, 16);
        let p = Problem::new(&coeffs, &*model, Cond::Class(1), 5);
        let seq = crate::solver::sample_sequential(&p, 2.0);
        crate::util::proplite::assert_close(&resp.sample, seq.xs.row(0), 5e-3, 5e-2, "service")
            .unwrap();
    }

    #[test]
    fn concurrent_load_all_complete() {
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig { workers: 3, slot_budget: 48, ..Default::default() },
        );
        let handles: Vec<_> = (0..12).map(|i| coord.submit(basic_req(i))).collect();
        for h in handles {
            let r = h.wait().unwrap();
            assert!(r.converged);
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 12);
        assert_eq!(m.failed, 0);
        assert_eq!(coord.slots_available(), 48);
    }

    /// Sessions merged into shared rounds must produce exactly the result a
    /// solo blocking solve produces — guidance-grouped merging is bit-exact.
    #[test]
    fn merged_rounds_are_bit_identical_to_solo_solves() {
        let model = gmm_model();
        let coord = Coordinator::start(
            model.clone(),
            CoordinatorConfig { workers: 2, drivers: 2, ..Default::default() },
        );
        let handles: Vec<_> = (0..6).map(|i| coord.submit(basic_req(40 + i))).collect();
        let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, crate::schedule::SamplerKind::Ddim, 16);
        for (i, resp) in responses.iter().enumerate() {
            let req = basic_req(40 + i as u64);
            let p = Problem::new(&coeffs, &*model, req.cond.clone(), req.seed);
            let solo = crate::solver::solve(&p, &req.solver_config());
            assert_eq!(resp.sample, solo.xs.row(0).to_vec(), "request {i}");
            assert_eq!(resp.rounds, solo.iterations, "request {i}");
            assert_eq!(resp.nfe, solo.total_nfe, "request {i}");
        }
    }

    /// Heterogeneous solve strategies co-exist in the same merged rounds:
    /// plain, draft-refine and Parareal sessions share one service, their
    /// ε batches co-batch into a single guidance group per round (coarse
    /// batches carry the same guidance, so the merge path needs nothing
    /// special), and every response is bit-identical to a solo blocking
    /// solve of the same request.
    #[test]
    fn mixed_strategies_cobatch_and_match_solo_solves() {
        use crate::solver::{DraftRefineConfig, PararealConfig, SolveStrategy};
        let model = gmm_model();
        let coord = Coordinator::start(
            model.clone(),
            CoordinatorConfig { workers: 2, drivers: 2, ..Default::default() },
        );
        let strategies = [
            SolveStrategy::PlainTaa,
            SolveStrategy::DraftRefine(DraftRefineConfig::default()),
            SolveStrategy::Parareal(PararealConfig::default()),
        ];
        let reqs: Vec<SampleRequest> = (0..6)
            .map(|i| {
                let mut r = basic_req(40 + i as u64);
                r.strategy = strategies[i % strategies.len()].clone();
                r.max_rounds = Some(400);
                r
            })
            .collect();
        let handles: Vec<_> = reqs.iter().map(|r| coord.submit(r.clone())).collect();
        let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, crate::schedule::SamplerKind::Ddim, 16);
        for (i, (req, resp)) in reqs.iter().zip(&responses).enumerate() {
            assert!(resp.converged, "request {i} ({})", req.strategy.label());
            let p = Problem::new(&coeffs, &*model, req.cond.clone(), req.seed);
            let solo = crate::solver::solve(&p, &req.solver_config());
            assert_eq!(
                resp.sample,
                solo.xs.row(0).to_vec(),
                "request {i} ({})",
                req.strategy.label()
            );
            assert_eq!(resp.rounds, solo.iterations, "request {i}");
            assert_eq!(resp.nfe, solo.total_nfe, "request {i}");
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 6);
        assert_eq!(m.failed, 0);
        // Every request shares the guidance scale, so co-batched rounds
        // still collapse into one device call each.
        assert!(
            (m.merge_groups_mean - 1.0).abs() < 1e-9,
            "same-guidance mixed strategies must form one group per round (got {})",
            m.merge_groups_mean
        );
        assert!(
            m.coarse_rounds_total > 0,
            "draft/parareal sessions must have recorded coarse rounds"
        );
    }

    /// One round driver fairly carries many sessions with heterogeneous
    /// window sizes: nobody starves, everyone converges, and the in-flight
    /// high-water mark exceeds the driver-thread count.
    #[test]
    fn one_driver_carries_many_sessions_fairly() {
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig { workers: 1, drivers: 1, ..Default::default() },
        );
        let windows = [3usize, 16, 5, 9, 12, 4, 7, 16];
        let handles: Vec<_> = windows
            .iter()
            .enumerate()
            .map(|(i, &w)| {
                let mut r = basic_req(60 + i as u64);
                r.window = Some(w);
                r.max_rounds = Some(400); // small windows need many rounds
                coord.submit(r)
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.wait().unwrap();
            assert!(r.converged, "session {i} (window {}) did not converge", windows[i]);
        }
        let m = coord.metrics();
        assert_eq!(m.completed, windows.len() as u64);
        assert_eq!(m.failed, 0);
        assert_eq!(m.driver_threads, 1);
        assert!(
            m.peak_sessions_in_flight > m.driver_threads,
            "peak in-flight {} should exceed the {} driver thread(s)",
            m.peak_sessions_in_flight,
            m.driver_threads
        );
        assert_eq!(m.sessions_in_flight, 0, "everything finalized");
        assert!(m.rounds_driven > 0);
        assert!(m.merge_rows_mean > 0.0);
    }

    /// A malformed request (steps == 0 panics inside admission) must fail
    /// itself — accurately counted — without killing the intake thread.
    #[test]
    fn malformed_request_fails_without_killing_admission() {
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig { workers: 1, ..Default::default() },
        );
        let bad = SampleRequest::parataa(Cond::Class(0), 1, SamplerSpec::ddim(0));
        assert!(coord.sample(bad).is_err(), "steps == 0 must fail, not hang");
        // The same (sole) intake thread must still admit good requests.
        let good = coord.sample(basic_req(2)).unwrap();
        assert!(good.converged);
        let m = coord.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 1);
        assert_eq!(m.sessions_in_flight, 0);
    }

    /// Streaming requests must deliver the converged prefix incrementally
    /// (at least one chunk strictly before the final round), tiling the
    /// trajectory exactly, with states bit-identical to the final
    /// response and to a solo blocking solve.
    #[test]
    fn streaming_delivers_prefix_before_completion_bit_identically() {
        let model = gmm_model();
        let coord = Coordinator::start(model.clone(), CoordinatorConfig::default());
        let req = basic_req(21);
        let handle = coord.submit_streaming(req.clone());
        let mut chunks = Vec::new();
        while let Some(c) = handle.next_chunk() {
            chunks.push(c);
        }
        let resp = handle.wait().unwrap();
        assert!(resp.converged);
        assert!(chunks.len() >= 2, "expected incremental delivery, got {}", chunks.len());
        // Chunks tile [0, 16) from the x_T side down to the sample row.
        let mut expect_end = 16;
        for c in &chunks {
            assert_eq!(c.rows.end, expect_end, "chunks must be contiguous top-down");
            assert!(c.rows.start < c.rows.end);
            assert_eq!(c.states.len(), c.rows.len() * 8);
            assert_eq!(c.residuals.len(), c.rows.len());
            expect_end = c.rows.start;
        }
        assert_eq!(expect_end, 0, "the stream must reach the final sample row");
        assert!(
            chunks.iter().any(|c| c.round < resp.rounds),
            "a prefix chunk must land strictly before solve completion"
        );
        // The streamed sample row is bit-identical to the response and to
        // a solo blocking solve of the same request.
        let last = chunks.last().unwrap();
        assert_eq!(&last.states[..8], &resp.sample[..], "streamed row 0 != response");
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, crate::schedule::SamplerKind::Ddim, 16);
        let p = Problem::new(&coeffs, &*model, req.cond.clone(), req.seed);
        let solo = crate::solver::solve(&p, &req.solver_config());
        assert_eq!(resp.sample, solo.xs.row(0).to_vec());
        let m = coord.metrics();
        assert_eq!(m.prefix_chunks_sent, chunks.len() as u64);
        assert_eq!(m.prefix_rows_streamed, 16);
        assert!(m.first_prefix_ms_p50 > 0.0);
    }

    /// Adaptive-window requests reserve their max_window footprint, serve
    /// to convergence, and return every slot.
    #[test]
    fn adaptive_window_requests_serve_and_settle() {
        use crate::solver::{AdaptiveWindow, WindowPolicy};
        let model = gmm_model();
        let coord = Coordinator::start(
            model.clone(),
            CoordinatorConfig { workers: 2, drivers: 2, slot_budget: 64, ..Default::default() },
        );
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let mut r = basic_req(200 + i);
                r.window_policy = WindowPolicy::Adaptive(AdaptiveWindow::for_steps(16));
                // Start small so the controller actually exercises growth
                // (no pool is attached, so occupancy stays 0 here).
                r.window = Some(4);
                r.max_rounds = Some(400);
                coord.submit(r)
            })
            .collect();
        let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        for (i, r) in responses.iter().enumerate() {
            assert!(r.converged, "adaptive request {i} did not converge");
        }
        // Still the right answer: matches the sequential oracle.
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, crate::schedule::SamplerKind::Ddim, 16);
        let p = Problem::new(&coeffs, &*model, Cond::Class(1), 200);
        let seq = crate::solver::sample_sequential(&p, 2.0);
        crate::util::proplite::assert_close(
            &responses[0].sample,
            seq.xs.row(0),
            5e-3,
            5e-2,
            "adaptive via coordinator",
        )
        .unwrap();
        let m = coord.metrics();
        assert_eq!(m.completed, 6);
        assert_eq!(m.failed, 0);
        assert_eq!(coord.slots_available(), 64, "adaptive sessions must return all slots");
    }

    /// StreamHandle poll/wait semantics, driven directly through the same
    /// channels the coordinator wires up: `try_chunk` is `None` on an
    /// open-but-empty stream, yields buffered chunks without blocking,
    /// turns `None` for good once the stream closes, and `wait` surfaces
    /// an error — instead of hanging — when the reply sender is dropped.
    #[test]
    fn stream_handle_try_chunk_and_wait_semantics() {
        let (ptx, prx) = bounded::<PrefixChunk>(4);
        let (rtx, rrx) = bounded::<Result<SampleResponse>>(1);
        let handle = StreamHandle { chunks: prx, response: ResponseHandle { rx: rrx } };

        // Open stream, nothing delivered yet: polling must not block.
        assert!(handle.try_chunk().is_none(), "empty open stream yields no chunk");

        let chunk = PrefixChunk {
            rows: 12..16,
            states: vec![0.0; 4 * 8],
            residuals: vec![1e-4; 4],
            round: 3,
        };
        assert!(ptx.try_send(chunk).is_ok());
        let got = handle.try_chunk().expect("buffered chunk arrives without blocking");
        assert_eq!(got.rows, 12..16);
        assert_eq!(got.round, 3);

        // Stream closes (any finalize path drops the sender): polls stay
        // None and the blocking accessor must not hang.
        drop(ptx);
        assert!(handle.try_chunk().is_none());
        assert!(handle.next_chunk().is_none(), "closed stream must end next_chunk");

        // A reply sender dropped without a response must fail wait(), not
        // strand the caller.
        drop(rtx);
        assert!(handle.wait().is_err(), "dropped reply sender must error, not hang");
    }

    /// A streaming request whose admission panics (steps == 0) must close
    /// its chunk stream, fail its response, release every slot, and leave
    /// the coordinator serving streaming traffic.
    #[test]
    fn failed_streaming_request_closes_stream_and_releases_slots() {
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig { workers: 1, slot_budget: 32, ..Default::default() },
        );
        let bad = SampleRequest::parataa(Cond::Class(0), 3, SamplerSpec::ddim(0));
        let handle = coord.submit_streaming(bad);
        assert!(handle.next_chunk().is_none(), "failed request must end its stream");
        assert!(handle.wait().is_err(), "failed request must reply with an error");
        // The guard settles the failure before the error is observable.
        let m = coord.metrics();
        assert_eq!(m.failed, 1);
        assert_eq!(m.prefix_chunks_sent, 0);
        assert_eq!(coord.slots_available(), 32, "failed admission must leak no slots");
        // The same (sole) intake thread keeps serving streams.
        let good = coord.submit_streaming(basic_req(3));
        let mut rows = 0;
        while let Some(c) = good.next_chunk() {
            rows += c.rows.len();
        }
        assert_eq!(rows, 16);
        assert!(good.wait().unwrap().converged);
    }

    /// Streaming composes with the adaptive window controller: while the
    /// window grows and shrinks mid-solve, the delivered chunks still tile
    /// the trajectory exactly once, top-down, ending at the sample row.
    #[test]
    fn adaptive_streaming_chunks_tile_despite_window_resizes() {
        use crate::solver::{AdaptiveWindow, WindowPolicy};
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig { workers: 2, drivers: 2, slot_budget: 64, ..Default::default() },
        );
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let mut r = basic_req(300 + i);
                r.window_policy = WindowPolicy::Adaptive(AdaptiveWindow::for_steps(16));
                r.window = Some(4); // start small: the controller resizes mid-run
                r.max_rounds = Some(400);
                coord.submit_streaming(r)
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let mut chunks = Vec::new();
            while let Some(c) = h.next_chunk() {
                chunks.push(c);
            }
            let resp = h.wait().unwrap();
            assert!(resp.converged, "adaptive stream {i} did not converge");
            let mut expect_end = 16;
            for c in &chunks {
                assert_eq!(
                    c.rows.end, expect_end,
                    "stream {i}: chunks must stay contiguous across window resizes"
                );
                assert!(c.rows.start < c.rows.end);
                assert_eq!(c.states.len(), c.rows.len() * 8);
                assert_eq!(c.residuals.len(), c.rows.len());
                expect_end = c.rows.start;
            }
            assert_eq!(expect_end, 0, "stream {i}: tiles must reach the sample row");
            let last = chunks.last().expect("at least one chunk per stream");
            assert_eq!(&last.states[..8], &resp.sample[..], "stream {i}: row 0 mismatch");
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 4);
        assert_eq!(m.failed, 0);
        assert_eq!(m.prefix_rows_streamed, 4 * 16);
        assert_eq!(coord.slots_available(), 64);
    }

    /// A request whose deadline already expired in the queue is rejected
    /// with a classified error and accurate counters, leaking nothing.
    #[test]
    fn expired_deadline_is_rejected_at_admission() {
        use crate::util::error::ErrorKind;
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig { workers: 1, slot_budget: 32, ..Default::default() },
        );
        let mut r = basic_req(1);
        r.deadline_ms = Some(0); // expired on arrival
        let err = coord.sample(r).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::DeadlineExceeded, "{err}");
        let m = coord.metrics();
        assert_eq!(m.failed, 1);
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.completed, 0);
        assert_eq!(coord.slots_available(), 32, "no slots may leak");
        // The same (sole) intake still serves patient requests.
        assert!(coord.sample(basic_req(2)).unwrap().converged);
    }

    /// A generous deadline changes nothing: the request runs the normal
    /// parallel path and completes un-degraded.
    #[test]
    fn generous_deadline_serves_normally() {
        let coord = Coordinator::start(gmm_model(), CoordinatorConfig::default());
        let mut r = basic_req(8);
        r.deadline_ms = Some(60_000);
        let resp = coord.sample(r).unwrap();
        assert!(resp.converged);
        assert!(!resp.degraded);
        let m = coord.metrics();
        assert_eq!(m.deadline_misses, 0);
        assert_eq!(m.degraded_total, 0);
    }

    /// A saturated watermark degrades requests to the sequential fallback:
    /// served on the intake thread, bitwise-equal to the sequential oracle.
    #[test]
    fn watermark_shedding_degrades_bitwise_to_sequential() {
        let model = gmm_model();
        let coord = Coordinator::start(
            model.clone(),
            CoordinatorConfig {
                workers: 1,
                robustness: RobustnessConfig {
                    shed_watermark: Some(0.0), // shed everything
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let resp = coord.sample(basic_req(11)).unwrap();
        assert!(resp.degraded, "watermark 0.0 must shed every request");
        assert!(resp.converged);
        assert_eq!(resp.rounds, 16, "sequential rollout: one round per step");
        assert_eq!(resp.nfe, 16);
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, crate::schedule::SamplerKind::Ddim, 16);
        let p = Problem::new(&coeffs, &*model, Cond::Class(1), 11);
        let seq = crate::solver::sample_sequential(&p, 2.0);
        assert_eq!(resp.sample, seq.xs.row(0).to_vec(), "degraded must match the oracle bitwise");
        let m = coord.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.degraded_total, 1);
        assert_eq!(m.failed, 0);
    }

    /// Fail-mode shedding rejects with a classified `Shed` error instead
    /// of degrading.
    #[test]
    fn fail_mode_shedding_rejects_with_classified_error() {
        use crate::util::error::ErrorKind;
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig {
                workers: 1,
                robustness: RobustnessConfig {
                    shed_watermark: Some(0.0),
                    shed_mode: ShedMode::Fail,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let err = coord.sample(basic_req(4)).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Shed, "{err}");
        let m = coord.metrics();
        assert_eq!(m.failed, 1);
        assert_eq!(m.shed_total, 1);
        assert_eq!(m.completed, 0);
    }

    /// A degraded *streaming* request still honors the stream contract:
    /// one chunk covering the whole trajectory, then stream end, then the
    /// response — with bit-identical states.
    #[test]
    fn degraded_streaming_delivers_one_full_chunk() {
        let coord = Coordinator::start(
            gmm_model(),
            CoordinatorConfig {
                workers: 1,
                robustness: RobustnessConfig {
                    shed_watermark: Some(0.0),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let h = coord.submit_streaming(basic_req(21));
        let chunk = h.next_chunk().expect("degraded stream must deliver the trajectory");
        assert_eq!(chunk.rows, 0..16);
        assert_eq!(chunk.states.len(), 16 * 8);
        assert_eq!(chunk.round, 0, "degraded rows freeze before any parallel round");
        assert!(h.next_chunk().is_none(), "exactly one chunk, then stream end");
        let resp = h.wait().unwrap();
        assert!(resp.degraded);
        assert_eq!(&chunk.states[..8], &resp.sample[..], "streamed row 0 != response");
        let m = coord.metrics();
        assert_eq!(m.prefix_chunks_sent, 1);
        assert_eq!(m.prefix_rows_streamed, 16);
    }

    /// Serving model whose fallible path fails every call — the shape of a
    /// pooled handle over a fully-quarantined pool. The infallible path
    /// panics so any degraded rollout that touches it is caught loudly.
    struct FailingEps;
    impl EpsModel for FailingEps {
        fn dim(&self) -> usize {
            8
        }
        fn eps_batch(
            &self,
            _xs: &[f32],
            _ts: &[usize],
            _conds: &[Cond],
            _g: f32,
            _out: &mut [f32],
        ) {
            panic!("degradation must use the fallible model path");
        }
        fn try_eps_batch(
            &self,
            _xs: &[f32],
            _ts: &[usize],
            _conds: &[Cond],
            _g: f32,
            _out: &mut [f32],
        ) -> Result<()> {
            Err(Error::retryable("every pool device is down"))
        }
        fn name(&self) -> &str {
            "failing"
        }
    }

    /// Review regression: a degraded rollout whose model fails (no
    /// fallback configured, serving model unhealthy) must surface a
    /// classified error from the intake thread — not unwind it through the
    /// infallible panic path — and the service must keep answering.
    #[test]
    fn degrade_failure_is_classified_not_a_panic() {
        use crate::util::error::ErrorKind;
        let coord = Coordinator::start(
            Arc::new(FailingEps),
            CoordinatorConfig {
                workers: 1,
                robustness: RobustnessConfig {
                    shed_watermark: Some(0.0), // degrade every request
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        for seed in 0..2u64 {
            let err = coord.sample(basic_req(seed)).unwrap_err();
            assert_eq!(err.kind(), ErrorKind::Retryable, "{err}");
            assert!(
                err.to_string().contains("degraded sequential fallback failed"),
                "{err}"
            );
        }
        let m = coord.metrics();
        assert_eq!(m.failed, 2, "failed degradations must be counted");
        assert_eq!(m.completed, 0);
    }

    /// Review regression: with a pool-independent fallback model
    /// configured, degradation bypasses the (failing) serving model
    /// entirely and still produces the bitwise sequential oracle.
    #[test]
    fn degrade_uses_fallback_model_when_configured() {
        let fallback = gmm_model();
        let coord = Coordinator::start(
            Arc::new(FailingEps),
            CoordinatorConfig {
                workers: 1,
                robustness: RobustnessConfig {
                    shed_watermark: Some(0.0),
                    fallback_model: Some(fallback.clone()),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let resp = coord.sample(basic_req(11)).unwrap();
        assert!(resp.degraded);
        assert!(resp.converged);
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, crate::schedule::SamplerKind::Ddim, 16);
        let p = Problem::new(&coeffs, &*fallback, Cond::Class(1), 11);
        let seq = crate::solver::sample_sequential(&p, 2.0);
        assert_eq!(
            resp.sample,
            seq.xs.row(0).to_vec(),
            "fallback rollout must match the oracle on the fallback model"
        );
        let m = coord.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.degraded_total, 1);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn warm_start_reduces_rounds() {
        let coord = Coordinator::start(gmm_model(), CoordinatorConfig::default());
        let mut cold = basic_req(9);
        cold.use_trajectory_cache = true;
        let r1 = coord.sample(cold.clone()).unwrap();
        assert!(!r1.warm_started);
        assert_eq!(coord.cache_len(), 1);
        // Same seed, nearby condition: should warm start and converge faster.
        let mut near = cold.clone();
        near.cond = Cond::Class(1).lerp(&Cond::Class(2), 0.05, 8);
        let r2 = coord.sample(near).unwrap();
        assert!(r2.warm_started);
        assert!(r2.rounds <= r1.rounds, "warm {} vs cold {}", r2.rounds, r1.rounds);
    }

    /// Client-disconnect propagation: a cancelled streaming request fails
    /// with a classified `Cancelled` error at a round boundary (never a
    /// hang), its stream closes, its slots return to the budget, and the
    /// cancellation is counted. Cancelling before any round has run is the
    /// deterministic case — the first boundary check always sees the flag.
    #[test]
    fn cancelled_stream_fails_classified_and_releases_slots() {
        use crate::util::error::ErrorKind;
        let coord = Coordinator::start(gmm_model(), CoordinatorConfig::default());
        let idle_slots = coord.slots_available();
        let h = coord.submit_streaming(basic_req(41));
        h.cancel();
        // The stream must terminate (possibly after a chunk or two raced
        // in ahead of the boundary check), then the response resolves.
        while h.next_chunk().is_some() {}
        let err = h.wait().expect_err("a cancelled request must fail");
        assert_eq!(err.kind(), ErrorKind::Cancelled, "{err}");
        let snap = coord.metrics();
        assert_eq!(snap.cancelled_total, 1);
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.failed, 1, "cancellation counts as a failure");
        assert_eq!(coord.slots_available(), idle_slots, "cancelled sessions free slots");
        // The service keeps serving afterwards.
        assert!(coord.sample(basic_req(42)).unwrap().converged);
    }

    #[test]
    fn batched_model_through_coordinator() {
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        let model = gmm_model();
        let batcher = Batcher::spawn(model.clone(), BatcherConfig::default());
        let handle = Arc::new(batcher.eps_handle(8, "gmm-batched"));
        let coord = Coordinator::start(handle, CoordinatorConfig::default());
        let handles: Vec<_> = (0..6).map(|i| coord.submit(basic_req(100 + i))).collect();
        for h in handles {
            assert!(h.wait().unwrap().converged);
        }
        drop(coord); // shut down drivers before the batcher drops
    }
}
