//! Request/response types for the sampling service, including the
//! streaming-prefix event delivered while a solve is still running.

use crate::model::Cond;
use crate::schedule::SamplerKind;
use crate::solver::{Method, SolveStrategy, SolverConfig, WindowPolicy};
use std::time::Duration;

/// Which sequential algorithm (and how many steps) the request wants to
/// reproduce in parallel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplerSpec {
    /// Sequential sampler family (DDIM / DDPM).
    pub kind: SamplerKind,
    /// Steps on the sampler's grid (the trajectory length T).
    pub steps: usize,
}

impl SamplerSpec {
    /// A `steps`-step DDIM (deterministic ODE sampler) spec.
    pub fn ddim(steps: usize) -> Self {
        SamplerSpec { kind: SamplerKind::Ddim, steps }
    }
    /// A `steps`-step DDPM (stochastic SDE sampler) spec.
    pub fn ddpm(steps: usize) -> Self {
        SamplerSpec { kind: SamplerKind::Ddpm, steps }
    }
    /// Scenario key, e.g. `"DDIM-50"` (also the trajectory-cache key).
    pub fn label(&self) -> String {
        format!("{}-{}", self.kind.label(), self.steps)
    }
}

/// One sampling request.
///
/// `PartialEq` compares every field (floats bitwise-by-value), which is
/// what the HTTP wire codec's round-trip property tests pin: a request
/// serialized by [`crate::serve::wire::request_to_json`] and re-parsed
/// must compare equal.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleRequest {
    /// Condition ("class" or "prompt embedding").
    pub cond: Cond,
    /// Noise seed (determines the image; parallel == sequential per seed).
    pub seed: u64,
    /// Sampler family + step count to reproduce in parallel.
    pub sampler: SamplerSpec,
    /// Classifier-free guidance scale (also the cross-request merge key).
    pub guidance: f32,
    /// Solver method (ParaTAA by default).
    pub method: Method,
    /// Order k; `None` = coordinator default for the scenario.
    pub k: Option<usize>,
    /// Anderson history size m.
    pub m: usize,
    /// Sliding window size; `None` = full window.
    pub window: Option<usize>,
    /// Early-stop cap on parallel rounds; `None` = run to the criterion.
    pub max_rounds: Option<usize>,
    /// Consult/populate the trajectory cache (§4.2 warm starts).
    pub use_trajectory_cache: bool,
    /// Sliding-window sizing policy. [`WindowPolicy::Fixed`] (default)
    /// keeps the static §2.2 window; [`WindowPolicy::Adaptive`] lets the
    /// round drivers' occupancy signal grow/shrink w each round. Adaptive
    /// requests reserve their `max_window` bound from the slot budget.
    pub window_policy: WindowPolicy,
    /// Multi-fidelity solve strategy. [`SolveStrategy::PlainTaa`] (default)
    /// runs the single-fidelity paper path; `DraftRefine`/`Parareal`
    /// sessions interleave coarse rounds. Heterogeneous strategies co-batch
    /// freely: coarse ε batches carry the same guidance as fine ones, so
    /// the round drivers' merge path is unchanged.
    pub strategy: SolveStrategy,
    /// Intra-round row-parallelism for this request's solver session (see
    /// [`SolverConfig::parallelism`]). `1` (default) is the exact
    /// historical single-threaded path; any setting is bitwise identical.
    /// CLI: `--threads N`.
    pub parallelism: usize,
    /// End-to-end deadline in milliseconds, measured from admission
    /// (queue wait included). `None` (default) = infinitely patient — the
    /// historical behavior. With a deadline set, admission rejects requests
    /// it cannot serve in time (or degrades them to the sequential
    /// fallback), the round drivers fail expired sessions between rounds
    /// with a `DeadlineExceeded` error, and the adaptive window controller
    /// stops shrinking an urgent session's window. CLI: `--deadline-ms N`.
    pub deadline_ms: Option<u64>,
}

impl SampleRequest {
    /// A ParaTAA request with the paper's defaults.
    pub fn parataa(cond: Cond, seed: u64, sampler: SamplerSpec) -> Self {
        SampleRequest {
            cond,
            seed,
            sampler,
            guidance: 5.0,
            method: Method::Taa,
            k: None,
            m: 3,
            window: None,
            max_rounds: None,
            use_trajectory_cache: false,
            window_policy: WindowPolicy::Fixed,
            strategy: SolveStrategy::PlainTaa,
            parallelism: 1,
            deadline_ms: None,
        }
    }

    /// Materialize the solver configuration for this request.
    pub fn solver_config(&self) -> SolverConfig {
        let steps = self.sampler.steps;
        let mut cfg = SolverConfig::parataa(steps);
        cfg.method = self.method;
        cfg.m = self.m;
        cfg.guidance = self.guidance;
        if let Some(k) = self.k {
            cfg.k = k;
        }
        if self.method == Method::FixedPoint && self.k.is_none() {
            cfg.k = steps; // Shih et al. baseline default
        }
        if let Some(w) = self.window {
            // Clamp like the solver session will, so the coordinator's
            // slot-budget footprint (window rows held per session) agrees
            // with what the solve actually uses. min/max rather than
            // `clamp` — clamp(1, 0) panics on a degenerate steps == 0.
            cfg.window = w.min(steps).max(1);
        }
        if let Some(s) = self.max_rounds {
            cfg.s_max = s;
        } else {
            cfg.s_max = 4 * steps;
        }
        cfg.window_policy = self.window_policy.clone();
        cfg.strategy = self.strategy.clone();
        cfg.parallelism = self.parallelism.max(1);
        cfg
    }
}

/// One increment of a streaming solve's converged prefix, delivered to the
/// request's subscription channel while the rest of the trajectory is
/// still being solved (see [`super::Coordinator::submit_streaming`]).
///
/// The rows are frozen by the monotone residual front (Theorem 3.6
/// safeguard), so the states carried here are bit-identical to what the
/// final [`SampleResponse`] reports; successive chunks of one request tile
/// the trajectory `[0, steps)` from the x_T side (the earliest denoising
/// timesteps) down to the final sample row 0.
#[derive(Debug, Clone)]
pub struct PrefixChunk {
    /// State-row indices `[start, end)` this chunk freezes (the final
    /// chunk of a converged solve ends at `start == 0`).
    pub rows: std::ops::Range<usize>,
    /// Flattened `[rows.len(), d]` row-major states, row `rows.start`
    /// first. Row 0, once delivered, is the final sample.
    pub states: Vec<f32>,
    /// Last measured residuals per row (`NaN` for rows frozen by a §4.2
    /// warm start before any evaluation).
    pub residuals: Vec<f64>,
    /// 1-based parallel round that froze these rows (0 for rows frozen at
    /// admission by a warm start, before any round ran).
    pub round: usize,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct SampleResponse {
    /// The sample x_0 (a 16×16 image for the shipped models).
    pub sample: Vec<f32>,
    /// Parallel rounds used (the paper's "Steps").
    pub rounds: usize,
    /// Total ε_θ evaluations.
    pub nfe: usize,
    /// Whether the stopping criterion was met.
    pub converged: bool,
    /// Whether a cached trajectory seeded this solve.
    pub warm_started: bool,
    /// Whether the request was served by the graceful-degradation path —
    /// a sequential DDIM rollout on the intake thread (bitwise-equal to
    /// [`crate::solver::sample_sequential`]) instead of a parallel solve.
    pub degraded: bool,
    /// End-to-end latency (queue + solve).
    pub latency: Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_labels() {
        assert_eq!(SamplerSpec::ddim(50).label(), "DDIM-50");
        assert_eq!(SamplerSpec::ddpm(100).label(), "DDPM-100");
    }

    #[test]
    fn request_materializes_config() {
        let r = SampleRequest::parataa(Cond::Class(1), 7, SamplerSpec::ddim(50));
        let cfg = r.solver_config();
        assert_eq!(cfg.method, Method::Taa);
        assert_eq!(cfg.window, 50);
        assert_eq!(cfg.s_max, 200);
        let fp = SampleRequest {
            method: Method::FixedPoint,
            ..SampleRequest::parataa(Cond::Class(1), 7, SamplerSpec::ddim(50))
        };
        assert_eq!(fp.solver_config().k, 50, "FP defaults to k = w (PL iteration)");
    }

    #[test]
    fn strategy_threads_through() {
        use crate::solver::{DraftRefineConfig, PararealConfig};
        let mut r = SampleRequest::parataa(Cond::Class(0), 3, SamplerSpec::ddim(32));
        assert!(r.solver_config().strategy.is_plain(), "plain is the default");
        r.strategy = SolveStrategy::DraftRefine(DraftRefineConfig::default());
        assert_eq!(r.solver_config().strategy.label(), "draft_refine");
        r.strategy = SolveStrategy::Parareal(PararealConfig { stride: 5 });
        assert_eq!(
            r.solver_config().strategy,
            SolveStrategy::Parareal(PararealConfig { stride: 5 })
        );
    }

    #[test]
    fn parallelism_threads_through() {
        let mut r = SampleRequest::parataa(Cond::Class(0), 2, SamplerSpec::ddim(16));
        assert_eq!(r.solver_config().parallelism, 1, "sequential by default");
        r.parallelism = 4;
        assert_eq!(r.solver_config().parallelism, 4);
        r.parallelism = 0; // degenerate: clamped to the sequential path
        assert_eq!(r.solver_config().parallelism, 1);
    }

    #[test]
    fn window_policy_threads_through() {
        use crate::solver::AdaptiveWindow;
        let mut r = SampleRequest::parataa(Cond::Class(0), 1, SamplerSpec::ddim(40));
        assert_eq!(r.solver_config().window_policy, WindowPolicy::Fixed);
        assert_eq!(r.solver_config().max_window_rows(), 40);
        let a = AdaptiveWindow::for_steps(40);
        r.window_policy = WindowPolicy::Adaptive(a.clone());
        let cfg = r.solver_config();
        assert_eq!(cfg.window_policy, WindowPolicy::Adaptive(a));
        assert_eq!(cfg.max_window_rows(), 40, "adaptive budgets its max bound");
    }
}
