//! Request/response types for the sampling service.

use crate::model::Cond;
use crate::schedule::SamplerKind;
use crate::solver::{Method, SolverConfig};
use std::time::Duration;

/// Which sequential algorithm (and how many steps) the request wants to
/// reproduce in parallel.
#[derive(Debug, Clone, Copy)]
pub struct SamplerSpec {
    pub kind: SamplerKind,
    pub steps: usize,
}

impl SamplerSpec {
    pub fn ddim(steps: usize) -> Self {
        SamplerSpec { kind: SamplerKind::Ddim, steps }
    }
    pub fn ddpm(steps: usize) -> Self {
        SamplerSpec { kind: SamplerKind::Ddpm, steps }
    }
    pub fn label(&self) -> String {
        format!("{}-{}", self.kind.label(), self.steps)
    }
}

/// One sampling request.
#[derive(Debug, Clone)]
pub struct SampleRequest {
    /// Condition ("class" or "prompt embedding").
    pub cond: Cond,
    /// Noise seed (determines the image; parallel == sequential per seed).
    pub seed: u64,
    pub sampler: SamplerSpec,
    pub guidance: f32,
    /// Solver method (ParaTAA by default).
    pub method: Method,
    /// Order k; `None` = coordinator default for the scenario.
    pub k: Option<usize>,
    /// Anderson history size m.
    pub m: usize,
    /// Sliding window size; `None` = full window.
    pub window: Option<usize>,
    /// Early-stop cap on parallel rounds; `None` = run to the criterion.
    pub max_rounds: Option<usize>,
    /// Consult/populate the trajectory cache (§4.2 warm starts).
    pub use_trajectory_cache: bool,
}

impl SampleRequest {
    /// A ParaTAA request with the paper's defaults.
    pub fn parataa(cond: Cond, seed: u64, sampler: SamplerSpec) -> Self {
        SampleRequest {
            cond,
            seed,
            sampler,
            guidance: 5.0,
            method: Method::Taa,
            k: None,
            m: 3,
            window: None,
            max_rounds: None,
            use_trajectory_cache: false,
        }
    }

    /// Materialize the solver configuration for this request.
    pub fn solver_config(&self) -> SolverConfig {
        let steps = self.sampler.steps;
        let mut cfg = SolverConfig::parataa(steps);
        cfg.method = self.method;
        cfg.m = self.m;
        cfg.guidance = self.guidance;
        if let Some(k) = self.k {
            cfg.k = k;
        }
        if self.method == Method::FixedPoint && self.k.is_none() {
            cfg.k = steps; // Shih et al. baseline default
        }
        if let Some(w) = self.window {
            // Clamp like the solver session will, so the coordinator's
            // slot-budget footprint (window rows held per session) agrees
            // with what the solve actually uses. min/max rather than
            // `clamp` — clamp(1, 0) panics on a degenerate steps == 0.
            cfg.window = w.min(steps).max(1);
        }
        if let Some(s) = self.max_rounds {
            cfg.s_max = s;
        } else {
            cfg.s_max = 4 * steps;
        }
        cfg
    }
}

/// The served result.
#[derive(Debug, Clone)]
pub struct SampleResponse {
    /// The sample x_0 (a 16×16 image for the shipped models).
    pub sample: Vec<f32>,
    /// Parallel rounds used (the paper's "Steps").
    pub rounds: usize,
    /// Total ε_θ evaluations.
    pub nfe: usize,
    /// Whether the stopping criterion was met.
    pub converged: bool,
    /// Whether a cached trajectory seeded this solve.
    pub warm_started: bool,
    /// End-to-end latency (queue + solve).
    pub latency: Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_labels() {
        assert_eq!(SamplerSpec::ddim(50).label(), "DDIM-50");
        assert_eq!(SamplerSpec::ddpm(100).label(), "DDPM-100");
    }

    #[test]
    fn request_materializes_config() {
        let r = SampleRequest::parataa(Cond::Class(1), 7, SamplerSpec::ddim(50));
        let cfg = r.solver_config();
        assert_eq!(cfg.method, Method::Taa);
        assert_eq!(cfg.window, 50);
        assert_eq!(cfg.s_max, 200);
        let fp = SampleRequest {
            method: Method::FixedPoint,
            ..SampleRequest::parataa(Cond::Class(1), 7, SamplerSpec::ddim(50))
        };
        assert_eq!(fp.solver_config().k, 50, "FP defaults to k = w (PL iteration)");
    }
}
