//! Serving coordinator — the L3 system layer (vLLM-router-shaped).
//!
//! ParaTAA turns one sampling request into a *sequence of parallel rounds*,
//! each of which is a batched ε_θ evaluation. A serving deployment has many
//! such requests in flight; this layer provides what the paper's multi-GPU
//! testbed provided implicitly:
//!
//! - [`request`]  — request/response types and handles;
//! - [`batcher`]  — dynamic batching: ε jobs from concurrent solves are
//!   coalesced into single device calls (the cross-request analog of the
//!   paper's within-request window parallelism);
//! - [`scheduler`] — a slot budget bounding total in-flight window rows
//!   (the "GPU memory" the paper's window size w trades against, §5.2);
//! - [`cache`]    — trajectory cache: solved trajectories are kept and
//!   donated as initializations for similar conditions (§4.2 as a serving
//!   feature — the paper's "users adjust prompts" scenario);
//! - [`metrics`]  — latency/throughput/round accounting;
//! - [`server`]   — worker pool tying it together.

pub mod batcher;
pub mod cache;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{BatchedEps, Batcher, BatcherConfig};
pub use cache::TrajectoryCache;
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{SampleRequest, SampleResponse, SamplerSpec};
pub use scheduler::SlotBudget;
pub use server::{Coordinator, CoordinatorConfig};
