//! Serving coordinator — the L3 system layer (vLLM-router-shaped).
//!
//! ParaTAA turns one sampling request into a *sequence of parallel rounds*,
//! each of which is a batched ε_θ evaluation. A serving deployment has many
//! such requests in flight; this layer carries each of them as a resumable
//! [`crate::solver::SolverSession`] and drives all of them, round by round,
//! from a small fixed pool of driver threads:
//!
//! - [`request`]  — request/response types and handles, including the
//!   streaming [`PrefixChunk`] event;
//! - [`server`]   — admission (intake) + the event-driven round drivers:
//!   ready sessions are pulled from a run queue, their pending ε batches
//!   merged deterministically by guidance group into one pool call per
//!   round, results scattered, live sessions requeued — so in-flight
//!   requests are bounded by the slot budget, not by thread count. The
//!   same scatter loop forwards each session's converged-prefix advance
//!   to streaming subscribers ([`Coordinator::submit_streaming`]) and
//!   feeds device occupancy to adaptive-window solves
//!   ([`crate::solver::WindowPolicy::Adaptive`]);
//! - [`scheduler`] — the slot budget bounding total in-flight window rows
//!   (the "GPU memory" the paper's window size w trades against, §5.2);
//! - [`cache`]    — trajectory cache: solved trajectories are kept and
//!   donated as initializations for similar conditions (§4.2 as a serving
//!   feature — the paper's "users adjust prompts" scenario);
//! - [`batcher`]  — the public `EpsModel`-facing coalescing adapter for
//!   callers outside the coordinator (the internal path merges at the
//!   round boundary instead);
//! - [`metrics`]  — latency/throughput/round accounting plus merge
//!   occupancy and sessions-in-flight gauges.

pub mod batcher;
pub mod cache;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{BatchedEps, Batcher, BatcherConfig};
pub use cache::TrajectoryCache;
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{PrefixChunk, SampleRequest, SampleResponse, SamplerSpec};
pub use scheduler::{OwnedSlotGuard, SlotBudget};
pub use server::{
    CancelToken, Coordinator, CoordinatorConfig, ResponseHandle, RobustnessConfig, ShedMode,
    StreamHandle,
};
