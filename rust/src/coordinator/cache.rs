//! Trajectory cache — §4.2 as a serving feature.
//!
//! The paper observes that users iterate on prompts, so solved trajectories
//! for *similar* conditions are plentiful and make excellent warm starts
//! (Fig. 5/13/14). The coordinator keeps an LRU of recent trajectories keyed
//! by (sampler scenario, condition weights, seed) and serves the nearest
//! donor within a similarity threshold.

use crate::equations::States;
use crate::model::Cond;
use std::collections::VecDeque;
use std::sync::Mutex;

/// A cached solve.
#[derive(Clone)]
pub struct CachedTrajectory {
    /// Scenario key, e.g. "DDIM-50" — trajectories are only comparable
    /// within the same sampler/step grid.
    pub scenario: String,
    /// Noise seed of the solve (the donor's ξ must be reused for the warm
    /// start to be meaningful).
    pub seed: u64,
    /// Dense condition weights.
    pub weights: Vec<f32>,
    /// Full trajectory x_0..x_T.
    pub trajectory: States,
    /// The ξ draws of the solve.
    pub xi: States,
}

/// LRU trajectory cache (thread-safe).
///
/// # Example
///
/// Donor selection is scoped by scenario and seed and bounded by an L2
/// similarity threshold on dense condition weights — an exact-threshold
/// donor is accepted, a cross-scenario one never is:
///
/// ```
/// use parataa::coordinator::cache::{CachedTrajectory, TrajectoryCache};
/// use parataa::equations::States;
/// use parataa::model::Cond;
///
/// let cache = TrajectoryCache::new(8, 2);
/// cache.insert(CachedTrajectory {
///     scenario: "DDIM-50".to_string(),
///     seed: 7,
///     weights: Cond::Class(0).to_weights(2), // [1, 0]
///     trajectory: States::zeros(4, 3),
///     xi: States::zeros(4, 3),
/// });
/// // Class(1) is [0, 1]: distance to the donor is exactly √2.
/// let d = std::f32::consts::SQRT_2;
/// assert!(cache.lookup("DDIM-50", 7, &Cond::Class(1), d).is_some(), "d == max_dist counts");
/// assert!(cache.lookup("DDIM-25", 7, &Cond::Class(1), 10.0).is_none(), "scenario must match");
/// assert!(cache.lookup("DDIM-50", 8, &Cond::Class(1), 10.0).is_none(), "seed must match");
/// ```
pub struct TrajectoryCache {
    capacity: usize,
    n_components: usize,
    entries: Mutex<VecDeque<CachedTrajectory>>,
}

impl TrajectoryCache {
    /// A cache holding at most `capacity` trajectories, densifying
    /// conditions to `n_components` weights for similarity lookups.
    pub fn new(capacity: usize, n_components: usize) -> Self {
        TrajectoryCache { capacity, n_components, entries: Mutex::new(VecDeque::new()) }
    }

    /// Cached trajectories currently held.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when no trajectory is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert a solved trajectory (evicting the oldest beyond capacity).
    pub fn insert(&self, entry: CachedTrajectory) {
        let mut e = self.entries.lock().unwrap();
        e.push_back(entry);
        while e.len() > self.capacity {
            e.pop_front();
        }
        crate::trace::instant(
            crate::trace::Layer::Cache,
            crate::trace::Name::CacheInsert,
            0,
            e.len() as i64,
            self.capacity as i64,
        );
    }

    /// Find the closest donor for `cond` in `scenario` with the same seed,
    /// within L2 distance `max_dist` on condition weights. Exact-condition
    /// matches are preferred (distance 0).
    pub fn lookup(
        &self,
        scenario: &str,
        seed: u64,
        cond: &Cond,
        max_dist: f32,
    ) -> Option<CachedTrajectory> {
        let w = cond.to_weights(self.n_components);
        let e = self.entries.lock().unwrap();
        let mut best: Option<(f32, &CachedTrajectory)> = None;
        for entry in e.iter() {
            if entry.scenario != scenario || entry.seed != seed {
                continue;
            }
            let d2: f32 = entry
                .weights
                .iter()
                .zip(w.iter())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let d = d2.sqrt();
            if d <= max_dist && best.map(|(bd, _)| d < bd).unwrap_or(true) {
                best = Some((d, entry));
            }
        }
        let hit = best.is_some();
        crate::trace::instant(
            crate::trace::Layer::Cache,
            crate::trace::Name::CacheLookup,
            0,
            hit as i64,
            e.len() as i64,
        );
        best.map(|(_, e)| e.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(scenario: &str, seed: u64, weights: Vec<f32>) -> CachedTrajectory {
        CachedTrajectory {
            scenario: scenario.to_string(),
            seed,
            weights,
            trajectory: States::zeros(4, 2),
            xi: States::zeros(4, 2),
        }
    }

    #[test]
    fn lookup_prefers_closest() {
        let c = TrajectoryCache::new(8, 4);
        c.insert(entry("DDIM-50", 1, vec![1.0, 0.0, 0.0, 0.0]));
        c.insert(entry("DDIM-50", 1, vec![0.5, 0.5, 0.0, 0.0]));
        let got = c
            .lookup("DDIM-50", 1, &Cond::Weights(vec![0.6, 0.4, 0.0, 0.0]), 1.0)
            .unwrap();
        assert_eq!(got.weights, vec![0.5, 0.5, 0.0, 0.0]);
    }

    #[test]
    fn scenario_and_seed_must_match() {
        let c = TrajectoryCache::new(8, 4);
        c.insert(entry("DDIM-50", 1, vec![1.0, 0.0, 0.0, 0.0]));
        assert!(c.lookup("DDIM-25", 1, &Cond::Class(0), 10.0).is_none());
        assert!(c.lookup("DDIM-50", 2, &Cond::Class(0), 10.0).is_none());
        assert!(c.lookup("DDIM-50", 1, &Cond::Class(0), 10.0).is_some());
    }

    #[test]
    fn distance_threshold_applies() {
        let c = TrajectoryCache::new(8, 2);
        c.insert(entry("DDPM-100", 3, vec![1.0, 0.0]));
        // Class(1) is weights [0,1]: distance sqrt(2) ≈ 1.41
        assert!(c.lookup("DDPM-100", 3, &Cond::Class(1), 1.0).is_none());
        assert!(c.lookup("DDPM-100", 3, &Cond::Class(1), 1.5).is_some());
    }

    /// The similarity threshold is inclusive at the boundary: a donor at
    /// exactly `max_dist` is accepted, a donor infinitesimally beyond it
    /// is not — and no threshold rescues a donor from another scenario.
    #[test]
    fn donor_selection_at_the_threshold_boundary() {
        let c = TrajectoryCache::new(8, 2);
        c.insert(entry("DDIM-50", 7, vec![1.0, 0.0]));
        // Class(1) densifies to [0, 1]: distance is exactly sqrt(2).
        let exact = 2.0f32.sqrt();
        assert!(
            c.lookup("DDIM-50", 7, &Cond::Class(1), exact).is_some(),
            "donor at d == max_dist must be accepted"
        );
        let below = f32::from_bits(exact.to_bits() - 1);
        assert!(
            c.lookup("DDIM-50", 7, &Cond::Class(1), below).is_none(),
            "donor one ulp beyond max_dist must be rejected"
        );
        // A cross-scenario donor is rejected no matter how generous the
        // threshold — trajectories are only comparable on the same
        // sampler/step grid.
        assert!(c.lookup("DDIM-25", 7, &Cond::Class(1), f32::MAX).is_none());
        assert!(c.lookup("DDPM-50", 7, &Cond::Class(1), f32::MAX).is_none());
    }

    #[test]
    fn lru_eviction() {
        let c = TrajectoryCache::new(2, 2);
        c.insert(entry("s", 1, vec![1.0, 0.0]));
        c.insert(entry("s", 2, vec![1.0, 0.0]));
        c.insert(entry("s", 3, vec![1.0, 0.0]));
        assert_eq!(c.len(), 2);
        assert!(c.lookup("s", 1, &Cond::Class(0), 10.0).is_none(), "oldest evicted");
        assert!(c.lookup("s", 3, &Cond::Class(0), 10.0).is_some());
    }
}
