//! Service metrics: counters + latency/round distributions.

use crate::util::stats::percentile;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Aggregated service metrics (interior-mutable, shared by workers).
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

#[derive(Default)]
struct Inner {
    completed: u64,
    failed: u64,
    warm_starts: u64,
    latencies_ms: Vec<f64>,
    rounds: Vec<f64>,
    nfes: Vec<f64>,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub failed: u64,
    pub warm_starts: u64,
    pub uptime: Duration,
    pub throughput_rps: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
    pub mean_rounds: f64,
    pub mean_nfe: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { inner: Mutex::new(Inner::default()), started: Instant::now() }
    }

    pub fn record_success(&self, latency: Duration, rounds: usize, nfe: usize, warm: bool) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        if warm {
            m.warm_starts += 1;
        }
        m.latencies_ms.push(latency.as_secs_f64() * 1e3);
        m.rounds.push(rounds as f64);
        m.nfes.push(nfe as f64);
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let uptime = self.started.elapsed();
        let mean = |v: &[f64]| {
            if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 }
        };
        MetricsSnapshot {
            completed: m.completed,
            failed: m.failed,
            warm_starts: m.warm_starts,
            uptime,
            throughput_rps: m.completed as f64 / uptime.as_secs_f64().max(1e-9),
            latency_ms_p50: percentile(&m.latencies_ms, 0.50),
            latency_ms_p95: percentile(&m.latencies_ms, 0.95),
            latency_ms_p99: percentile(&m.latencies_ms, 0.99),
            mean_rounds: mean(&m.rounds),
            mean_nfe: mean(&m.nfes),
        }
    }
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        format!(
            "completed={} failed={} warm={} | {:.2} req/s | latency ms p50={:.1} p95={:.1} p99={:.1} | rounds μ={:.1} | nfe μ={:.0}",
            self.completed,
            self.failed,
            self.warm_starts,
            self.throughput_rps,
            self.latency_ms_p50,
            self.latency_ms_p95,
            self.latency_ms_p99,
            self.mean_rounds,
            self.mean_nfe,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.record_success(Duration::from_millis(10), 7, 700, false);
        m.record_success(Duration::from_millis(30), 9, 900, true);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.warm_starts, 1);
        assert!((s.mean_rounds - 8.0).abs() < 1e-9);
        assert!(s.latency_ms_p50 >= 10.0 && s.latency_ms_p99 <= 30.5);
        assert!(!s.report().is_empty());
    }

    #[test]
    fn empty_snapshot_is_finite() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_rounds, 0.0);
    }
}
