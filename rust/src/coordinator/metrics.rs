//! Service metrics: counters + latency/round distributions, plus the
//! per-device utilization/queue-depth breakdown of an attached
//! [`crate::runtime::DevicePool`].

use crate::runtime::pool::{DeviceStat, PoolStats};
use crate::util::stats::percentile_sorted;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Aggregated service metrics (interior-mutable, shared by workers).
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
    pool: Mutex<Option<Arc<PoolStats>>>,
}

#[derive(Default)]
struct Inner {
    completed: u64,
    failed: u64,
    warm_starts: u64,
    latencies_ms: Vec<f64>,
    rounds: Vec<f64>,
    nfes: Vec<f64>,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub failed: u64,
    pub warm_starts: u64,
    pub uptime: Duration,
    pub throughput_rps: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
    pub mean_rounds: f64,
    pub mean_nfe: f64,
    /// Per-device pool breakdown (empty unless a pool is attached).
    pub devices: Vec<DeviceStat>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
            pool: Mutex::new(None),
        }
    }

    /// Attach a device pool's counters; snapshots then carry the
    /// per-device utilization/queue-depth breakdown.
    pub fn attach_pool(&self, stats: Arc<PoolStats>) {
        *self.pool.lock().unwrap() = Some(stats);
    }

    pub fn record_success(&self, latency: Duration, rounds: usize, nfe: usize, warm: bool) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        if warm {
            m.warm_starts += 1;
        }
        m.latencies_ms.push(latency.as_secs_f64() * 1e3);
        m.rounds.push(rounds as f64);
        m.nfes.push(nfe as f64);
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let uptime = self.started.elapsed();
        let mean = |v: &[f64]| {
            if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 }
        };
        // One clone+sort serves all three percentiles (percentile() would
        // clone and sort per call, tripling the work under the lock).
        let mut lat = m.latencies_ms.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        MetricsSnapshot {
            completed: m.completed,
            failed: m.failed,
            warm_starts: m.warm_starts,
            uptime,
            throughput_rps: m.completed as f64 / uptime.as_secs_f64().max(1e-9),
            latency_ms_p50: percentile_sorted(&lat, 0.50),
            latency_ms_p95: percentile_sorted(&lat, 0.95),
            latency_ms_p99: percentile_sorted(&lat, 0.99),
            mean_rounds: mean(&m.rounds),
            mean_nfe: mean(&m.nfes),
            devices: self
                .pool
                .lock()
                .unwrap()
                .as_ref()
                .map(|p| p.snapshot())
                .unwrap_or_default(),
        }
    }
}

impl MetricsSnapshot {
    /// The full snapshot as JSON — `parataa serve --json` dumps this, and
    /// the `devices` array is the same shape the bench report embeds
    /// (`docs/bench.md` §devices, via [`DeviceStat::to_json`]).
    /// Percentiles over an empty sample set serialize as `null` (the JSON
    /// writer maps non-finite numbers to `null`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj(vec![
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("warm_starts", Json::Num(self.warm_starts as f64)),
            ("uptime_s", Json::Num(self.uptime.as_secs_f64())),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("latency_ms_p50", Json::Num(self.latency_ms_p50)),
            ("latency_ms_p95", Json::Num(self.latency_ms_p95)),
            ("latency_ms_p99", Json::Num(self.latency_ms_p99)),
            ("mean_rounds", Json::Num(self.mean_rounds)),
            ("mean_nfe", Json::Num(self.mean_nfe)),
            (
                "devices",
                Json::Arr(self.devices.iter().map(|d| d.to_json()).collect()),
            ),
        ])
    }

    /// One-line human-readable summary plus the per-device breakdown.
    pub fn report(&self) -> String {
        let mut out = format!(
            "completed={} failed={} warm={} | {:.2} req/s | latency ms p50={:.1} p95={:.1} p99={:.1} | rounds μ={:.1} | nfe μ={:.0}",
            self.completed,
            self.failed,
            self.warm_starts,
            self.throughput_rps,
            self.latency_ms_p50,
            self.latency_ms_p95,
            self.latency_ms_p99,
            self.mean_rounds,
            self.mean_nfe,
        );
        for s in &self.devices {
            out.push_str(&format!("\n  {s}"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.record_success(Duration::from_millis(10), 7, 700, false);
        m.record_success(Duration::from_millis(30), 9, 900, true);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.warm_starts, 1);
        assert!((s.mean_rounds - 8.0).abs() < 1e-9);
        assert!(s.latency_ms_p50 >= 10.0 && s.latency_ms_p99 <= 30.5);
        assert!(!s.report().is_empty());
    }

    #[test]
    fn empty_snapshot_is_finite() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_rounds, 0.0);
        assert!(s.devices.is_empty());
    }

    #[test]
    fn attached_pool_breakdown_in_report() {
        use crate::model::{Cond, EpsModel};
        use crate::runtime::{DevicePool, PoolConfig};
        use crate::schedule::{BetaSchedule, NoiseSchedule};
        use std::sync::Arc;

        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let model = Arc::new(crate::model::gmm::GmmEps::new(
            vec![0.5; 2 * 4],
            4,
            0.2,
            ns.alpha_bars.clone(),
        ));
        let pool = DevicePool::in_process(model, 2, PoolConfig::default()).unwrap();
        let eps = pool.eps_handle("pooled");
        let mut out = vec![0.0f32; 3 * 4];
        eps.eps_batch(
            &[0.1; 12],
            &[10, 500, 900],
            &[Cond::Class(0), Cond::Class(1), Cond::Uncond],
            1.0,
            &mut out,
        );

        let m = Metrics::new();
        m.attach_pool(pool.stats());
        let s = m.snapshot();
        assert_eq!(s.devices.len(), 2);
        assert_eq!(s.devices.iter().map(|d| d.items).sum::<u64>(), 3);
        assert!(s.report().contains("dev0"), "report: {}", s.report());
        assert!(s.report().contains("dev1"), "report: {}", s.report());
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let m = Metrics::new();
        m.record_success(Duration::from_millis(12), 5, 500, true);
        let j = m.snapshot().to_json();
        assert_eq!(j.get("completed").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("warm_starts").and_then(|v| v.as_f64()), Some(1.0));
        assert!(j.get("latency_ms_p50").and_then(|v| v.as_f64()).unwrap() >= 12.0);
        // Round-trips through the parser (also proves no NaN leaked out).
        let text = j.to_string();
        crate::util::json::parse(&text).expect("snapshot JSON must parse");
    }
}
