//! Service metrics: counters + latency/round distributions, round-driver
//! merge occupancy and sessions-in-flight gauges, streaming-prefix
//! delivery counters, plus the per-device utilization/queue-depth
//! breakdown of an attached [`crate::runtime::DevicePool`] (which also
//! feeds the adaptive window controller's occupancy signal).

use crate::runtime::pool::{DeviceStat, PoolStats};
use crate::util::stats::percentile_sorted;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Aggregated service metrics (interior-mutable, shared by workers).
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
    pool: Mutex<Option<Arc<PoolStats>>>,
    /// Last (timestamp, per-device busy-ns) read, so
    /// [`device_occupancy`](Self::device_occupancy) can report utilization
    /// over the window since the previous call instead of the since-spawn
    /// lifetime average (which would latch high after a past load spike).
    occ_window: Mutex<Option<(Instant, Vec<u64>)>>,
}

#[derive(Default)]
struct Inner {
    completed: u64,
    failed: u64,
    warm_starts: u64,
    latencies_ms: Vec<f64>,
    rounds: Vec<f64>,
    nfes: Vec<f64>,
    /// Round-driver threads configured (0 until a coordinator attaches).
    drivers: u64,
    /// Sessions currently between admission and finalization.
    in_flight: u64,
    /// High-water mark of `in_flight` — the "sustains more sessions than
    /// driver threads" acceptance signal survives snapshot timing.
    peak_in_flight: u64,
    /// Merged round calls driven so far, plus occupancy accumulators.
    rounds_driven: u64,
    merged_sessions: u64,
    merged_rows: u64,
    merged_groups: u64,
    /// Streaming-prefix chunks delivered to subscription channels.
    prefix_chunks: u64,
    /// Converged rows delivered through those chunks.
    prefix_rows: u64,
    /// Per streaming request: ms from enqueue to its first prefix chunk.
    first_prefix_ms: Vec<f64>,
    /// Multi-fidelity coarse rounds (draft rounds + Parareal sweeps)
    /// across finalized sessions.
    coarse_rounds: u64,
    /// Requests served by the graceful-degradation path (sequential
    /// rollout on the intake thread). Degraded requests also count as
    /// `completed`.
    degraded: u64,
    /// Requests failed (at admission or between rounds) because their
    /// deadline expired.
    deadline_misses: u64,
    /// Requests rejected outright by load shedding (no degraded fallback).
    shed: u64,
    /// Requests failed because the client abandoned them (a dropped SSE
    /// connection cancelling the session at a round boundary).
    cancelled: u64,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Requests answered successfully.
    pub completed: u64,
    /// Requests that failed (panics, malformed input, shutdown races).
    pub failed: u64,
    /// Completed requests that warm-started from the trajectory cache.
    pub warm_starts: u64,
    /// Time since the metrics (≈ the coordinator) were created.
    pub uptime: Duration,
    /// Completed requests per second of uptime.
    pub throughput_rps: f64,
    /// Median end-to-end request latency (queue + solve), milliseconds.
    pub latency_ms_p50: f64,
    /// 95th-percentile end-to-end request latency, milliseconds.
    pub latency_ms_p95: f64,
    /// 99th-percentile end-to-end request latency, milliseconds.
    pub latency_ms_p99: f64,
    /// Mean parallel rounds per completed request.
    pub mean_rounds: f64,
    /// Mean ε_θ evaluations per completed request.
    pub mean_nfe: f64,
    /// Round-driver threads carrying the session run queue.
    pub driver_threads: u64,
    /// Sessions in flight at snapshot time.
    pub sessions_in_flight: u64,
    /// High-water mark of concurrent sessions.
    pub peak_sessions_in_flight: u64,
    /// Merged round calls executed by the drivers.
    pub rounds_driven: u64,
    /// Mean sessions merged per round call (the occupancy the refactor
    /// buys: > 1 means cross-request batching is happening).
    pub merge_sessions_mean: f64,
    /// Mean window rows per merged round call.
    pub merge_rows_mean: f64,
    /// Mean guidance groups (device calls) per round.
    pub merge_groups_mean: f64,
    /// Streaming-prefix chunks delivered (0 unless `--stream` requests ran).
    pub prefix_chunks_sent: u64,
    /// Converged rows delivered through prefix chunks.
    pub prefix_rows_streamed: u64,
    /// Median ms from enqueue to a streaming request's first prefix chunk
    /// — the latency-to-first-prefix the streaming layer optimizes.
    pub first_prefix_ms_p50: f64,
    /// 95th-percentile ms to the first prefix chunk.
    pub first_prefix_ms_p95: f64,
    /// Multi-fidelity coarse rounds (draft-phase rounds plus Parareal
    /// sweeps) across finalized sessions — 0 when every request ran the
    /// plain single-fidelity path.
    pub coarse_rounds_total: u64,
    /// Requests served by the graceful-degradation path — a sequential
    /// rollout on the intake thread instead of a parallel solve. These
    /// also count in `completed`.
    pub degraded_total: u64,
    /// Requests failed because their [`deadline`](crate::coordinator::SampleRequest::deadline_ms)
    /// expired (at admission or between parallel rounds).
    pub deadline_misses: u64,
    /// Requests rejected outright by load shedding.
    pub shed_total: u64,
    /// Requests failed because the client abandoned them (client-disconnect
    /// propagation: a dropped SSE stream cancels its session). These also
    /// count in `failed`.
    pub cancelled_total: u64,
    /// Shard re-dispatches performed by the attached device pool
    /// (0 without a pool or with retries disabled).
    pub retries_total: u64,
    /// Quarantine events recorded by the attached pool — devices pulled
    /// from dispatch after repeated consecutive failures.
    pub devices_quarantined: u64,
    /// Per-device pool breakdown (empty unless a pool is attached).
    pub devices: Vec<DeviceStat>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Fresh, empty metrics (uptime starts now).
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
            pool: Mutex::new(None),
            occ_window: Mutex::new(None),
        }
    }

    /// Attach a device pool's counters; snapshots then carry the
    /// per-device utilization/queue-depth breakdown.
    pub fn attach_pool(&self, stats: Arc<PoolStats>) {
        *self.pool.lock().unwrap() = Some(stats);
    }

    /// Record one successfully answered request.
    pub fn record_success(&self, latency: Duration, rounds: usize, nfe: usize, warm: bool) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        if warm {
            m.warm_starts += 1;
        }
        m.latencies_ms.push(latency.as_secs_f64() * 1e3);
        m.rounds.push(rounds as f64);
        m.nfes.push(nfe as f64);
    }

    /// Record one failed request.
    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    /// Record one request served by the graceful-degradation path (call
    /// alongside [`record_success`](Self::record_success) — a degraded
    /// request still completes).
    pub fn record_degraded(&self) {
        self.inner.lock().unwrap().degraded += 1;
    }

    /// Record one request failed because its deadline expired (call
    /// alongside [`record_failure`](Self::record_failure)).
    pub fn deadline_miss(&self) {
        self.inner.lock().unwrap().deadline_misses += 1;
    }

    /// Record one request rejected outright by load shedding (call
    /// alongside [`record_failure`](Self::record_failure)).
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// Record one request cancelled by its client (the failure itself is
    /// recorded by the session guard; this counts the *cause*).
    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    /// Healthy (non-quarantined) devices in the attached pool — the
    /// load-shedding trigger consults this; `None` without a pool.
    pub fn pool_healthy_devices(&self) -> Option<usize> {
        let stats = self.pool.lock().unwrap().as_ref()?.clone();
        Some(stats.healthy_devices())
    }

    /// Record the round-driver pool size (reported in snapshots).
    pub fn set_drivers(&self, drivers: usize) {
        self.inner.lock().unwrap().drivers = drivers as u64;
    }

    /// A session was admitted (between slot grant and finalization).
    pub fn session_started(&self) {
        let mut m = self.inner.lock().unwrap();
        m.in_flight += 1;
        m.peak_in_flight = m.peak_in_flight.max(m.in_flight);
    }

    /// A session was finalized (response sent, slots released).
    pub fn session_finished(&self) {
        let mut m = self.inner.lock().unwrap();
        m.in_flight = m.in_flight.saturating_sub(1);
    }

    /// Sessions currently in flight (the Coordinator's shutdown path waits
    /// for this to reach zero before closing the run queue).
    pub fn sessions_in_flight(&self) -> usize {
        self.inner.lock().unwrap().in_flight as usize
    }

    /// One streaming-prefix chunk of `rows` converged rows was delivered;
    /// `first_latency` is set when it was the request's first chunk
    /// (enqueue → first prefix, the streaming layer's headline latency).
    pub fn record_prefix(&self, rows: usize, first_latency: Option<Duration>) {
        let mut m = self.inner.lock().unwrap();
        m.prefix_chunks += 1;
        m.prefix_rows += rows as u64;
        if let Some(lat) = first_latency {
            m.first_prefix_ms.push(lat.as_secs_f64() * 1e3);
        }
    }

    /// The device-occupancy signal for adaptive window control, in [0, 1]:
    /// the attached pool's utilization over the window since the previous
    /// call (busy-ns deltas — a *current* signal that decays when load
    /// stops, unlike the since-spawn average in [`DeviceStat`], which
    /// would latch high after a past spike), saturating to 1 whenever
    /// shards are queued (a backlog means the pool is at capacity right
    /// now). The first call, with no window yet, reports the lifetime
    /// average. `None` without an attached pool — adaptive sessions then
    /// size on convergence velocity alone (slot-budget pressure is
    /// deliberately *not* a fallback: adaptive sessions hold their
    /// max_window reservation for life, so shrinking frees no budget and
    /// such a signal would latch).
    pub fn device_occupancy(&self) -> Option<f64> {
        let stats = self.pool.lock().unwrap().as_ref()?.clone();
        if stats.queued() > 0 {
            return Some(1.0);
        }
        let busy = stats.busy_ns();
        if busy.is_empty() {
            return None;
        }
        let now = Instant::now();
        let mut win = self.occ_window.lock().unwrap();
        let windowed = match win.take() {
            Some((t0, prev)) if prev.len() == busy.len() && now > t0 => {
                let capacity_ns =
                    now.duration_since(t0).as_nanos() as f64 * busy.len() as f64;
                let busy_delta: u64 = busy
                    .iter()
                    .zip(prev.iter())
                    .map(|(b, p)| b.saturating_sub(*p))
                    .sum();
                Some((busy_delta as f64 / capacity_ns.max(1.0)).min(1.0))
            }
            _ => None,
        };
        *win = Some((now, busy));
        drop(win);
        windowed.or_else(|| {
            let snap = stats.snapshot();
            Some(snap.iter().map(|s| s.utilization).sum::<f64>() / snap.len().max(1) as f64)
        })
    }

    /// Record a finalized session's multi-fidelity coarse-round count
    /// (draft rounds + Parareal sweeps; 0 under the plain strategy).
    pub fn record_coarse_rounds(&self, n: usize) {
        self.inner.lock().unwrap().coarse_rounds += n as u64;
    }

    /// One merged round call: `sessions` sessions contributed `rows` window
    /// rows across `groups` guidance groups (device calls).
    pub fn record_round(&self, sessions: usize, rows: usize, groups: usize) {
        let mut m = self.inner.lock().unwrap();
        m.rounds_driven += 1;
        m.merged_sessions += sessions as u64;
        m.merged_rows += rows as u64;
        m.merged_groups += groups as u64;
    }

    /// Prometheus text exposition of a fresh [`snapshot`](Self::snapshot)
    /// (what `parataa serve --prom-out` writes).
    pub fn to_prometheus(&self) -> String {
        self.snapshot().to_prometheus()
    }

    /// Point-in-time aggregation of everything recorded so far.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let pool = self.pool.lock().unwrap().clone();
        let m = self.inner.lock().unwrap();
        let mut first_prefix = m.first_prefix_ms.clone();
        first_prefix.sort_by(f64::total_cmp);
        let uptime = self.started.elapsed();
        let mean = |v: &[f64]| {
            if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 }
        };
        // One clone+sort serves all three percentiles (percentile() would
        // clone and sort per call, tripling the work under the lock).
        let mut lat = m.latencies_ms.clone();
        lat.sort_by(f64::total_cmp);
        let per_round = |sum: u64| {
            if m.rounds_driven == 0 { 0.0 } else { sum as f64 / m.rounds_driven as f64 }
        };
        MetricsSnapshot {
            completed: m.completed,
            failed: m.failed,
            warm_starts: m.warm_starts,
            uptime,
            throughput_rps: m.completed as f64 / uptime.as_secs_f64().max(1e-9),
            latency_ms_p50: percentile_sorted(&lat, 0.50),
            latency_ms_p95: percentile_sorted(&lat, 0.95),
            latency_ms_p99: percentile_sorted(&lat, 0.99),
            mean_rounds: mean(&m.rounds),
            mean_nfe: mean(&m.nfes),
            driver_threads: m.drivers,
            sessions_in_flight: m.in_flight,
            peak_sessions_in_flight: m.peak_in_flight,
            rounds_driven: m.rounds_driven,
            merge_sessions_mean: per_round(m.merged_sessions),
            merge_rows_mean: per_round(m.merged_rows),
            merge_groups_mean: per_round(m.merged_groups),
            prefix_chunks_sent: m.prefix_chunks,
            prefix_rows_streamed: m.prefix_rows,
            first_prefix_ms_p50: percentile_sorted(&first_prefix, 0.50),
            first_prefix_ms_p95: percentile_sorted(&first_prefix, 0.95),
            coarse_rounds_total: m.coarse_rounds,
            degraded_total: m.degraded,
            deadline_misses: m.deadline_misses,
            shed_total: m.shed,
            cancelled_total: m.cancelled,
            retries_total: pool.as_ref().map(|p| p.retries()).unwrap_or(0),
            devices_quarantined: pool
                .as_ref()
                .map(|p| p.quarantine_events())
                .unwrap_or(0),
            devices: pool.as_ref().map(|p| p.snapshot()).unwrap_or_default(),
        }
    }
}

impl MetricsSnapshot {
    /// The full snapshot as JSON — `parataa serve --json` dumps this, and
    /// the `devices` array is the same shape the bench report embeds
    /// (`docs/bench.md` §devices, via [`DeviceStat::to_json`]).
    /// Percentiles over an empty sample set serialize as `null` (the JSON
    /// writer maps non-finite numbers to `null`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj(vec![
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("warm_starts", Json::Num(self.warm_starts as f64)),
            ("uptime_s", Json::Num(self.uptime.as_secs_f64())),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("latency_ms_p50", Json::Num(self.latency_ms_p50)),
            ("latency_ms_p95", Json::Num(self.latency_ms_p95)),
            ("latency_ms_p99", Json::Num(self.latency_ms_p99)),
            ("mean_rounds", Json::Num(self.mean_rounds)),
            ("mean_nfe", Json::Num(self.mean_nfe)),
            ("driver_threads", Json::Num(self.driver_threads as f64)),
            ("sessions_in_flight", Json::Num(self.sessions_in_flight as f64)),
            (
                "peak_sessions_in_flight",
                Json::Num(self.peak_sessions_in_flight as f64),
            ),
            ("rounds_driven", Json::Num(self.rounds_driven as f64)),
            ("merge_sessions_mean", Json::Num(self.merge_sessions_mean)),
            ("merge_rows_mean", Json::Num(self.merge_rows_mean)),
            ("merge_groups_mean", Json::Num(self.merge_groups_mean)),
            ("prefix_chunks_sent", Json::Num(self.prefix_chunks_sent as f64)),
            (
                "prefix_rows_streamed",
                Json::Num(self.prefix_rows_streamed as f64),
            ),
            ("first_prefix_ms_p50", Json::Num(self.first_prefix_ms_p50)),
            ("first_prefix_ms_p95", Json::Num(self.first_prefix_ms_p95)),
            ("coarse_rounds_total", Json::Num(self.coarse_rounds_total as f64)),
            ("degraded_total", Json::Num(self.degraded_total as f64)),
            ("deadline_misses", Json::Num(self.deadline_misses as f64)),
            ("shed_total", Json::Num(self.shed_total as f64)),
            ("cancelled_total", Json::Num(self.cancelled_total as f64)),
            ("retries_total", Json::Num(self.retries_total as f64)),
            (
                "devices_quarantined",
                Json::Num(self.devices_quarantined as f64),
            ),
            (
                "devices",
                Json::Arr(self.devices.iter().map(|d| d.to_json()).collect()),
            ),
        ])
    }

    /// Prometheus text exposition of this snapshot, plus trace-derived
    /// counters/histograms when the recorder holds events — see
    /// [`crate::trace::prom`] for metric names, units and the validator.
    pub fn to_prometheus(&self) -> String {
        crate::trace::prom::render(self)
    }

    /// One-line human-readable summary plus the per-device breakdown.
    pub fn report(&self) -> String {
        let mut out = format!(
            "completed={} failed={} warm={} | {:.2} req/s | latency ms p50={:.1} p95={:.1} p99={:.1} | rounds μ={:.1} | nfe μ={:.0}",
            self.completed,
            self.failed,
            self.warm_starts,
            self.throughput_rps,
            self.latency_ms_p50,
            self.latency_ms_p95,
            self.latency_ms_p99,
            self.mean_rounds,
            self.mean_nfe,
        );
        if self.rounds_driven > 0 {
            out.push_str(&format!(
                "\n  drivers={} rounds driven={} | merge occupancy μ={:.1} sessions \
                 / {:.0} rows / {:.1} groups | sessions in flight now={} peak={}",
                self.driver_threads,
                self.rounds_driven,
                self.merge_sessions_mean,
                self.merge_rows_mean,
                self.merge_groups_mean,
                self.sessions_in_flight,
                self.peak_sessions_in_flight,
            ));
        }
        if self.prefix_chunks_sent > 0 {
            out.push_str(&format!(
                "\n  streamed: {} prefix chunks / {} rows | first-prefix ms p50={:.1} p95={:.1}",
                self.prefix_chunks_sent,
                self.prefix_rows_streamed,
                self.first_prefix_ms_p50,
                self.first_prefix_ms_p95,
            ));
        }
        if self.degraded_total + self.deadline_misses + self.shed_total + self.cancelled_total
            + self.retries_total
            + self.devices_quarantined
            > 0
        {
            out.push_str(&format!(
                "\n  robustness: degraded={} deadline misses={} shed={} cancelled={} | pool retries={} quarantines={}",
                self.degraded_total,
                self.deadline_misses,
                self.shed_total,
                self.cancelled_total,
                self.retries_total,
                self.devices_quarantined,
            ));
        }
        for s in &self.devices {
            out.push_str(&format!("\n  {s}"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.record_success(Duration::from_millis(10), 7, 700, false);
        m.record_success(Duration::from_millis(30), 9, 900, true);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.warm_starts, 1);
        assert!((s.mean_rounds - 8.0).abs() < 1e-9);
        assert!(s.latency_ms_p50 >= 10.0 && s.latency_ms_p99 <= 30.5);
        assert!(!s.report().is_empty());
    }

    #[test]
    fn session_and_round_gauges_aggregate() {
        let m = Metrics::new();
        m.set_drivers(2);
        m.session_started();
        m.session_started();
        m.session_started();
        m.session_finished();
        m.record_round(3, 75, 1);
        m.record_round(1, 25, 1);
        m.record_coarse_rounds(4);
        m.record_coarse_rounds(0); // plain sessions contribute nothing
        let s = m.snapshot();
        assert_eq!(s.coarse_rounds_total, 4);
        assert_eq!(
            s.to_json().get("coarse_rounds_total").and_then(|v| v.as_f64()),
            Some(4.0)
        );
        assert_eq!(s.driver_threads, 2);
        assert_eq!(s.sessions_in_flight, 2);
        assert_eq!(s.peak_sessions_in_flight, 3);
        assert_eq!(s.rounds_driven, 2);
        assert!((s.merge_sessions_mean - 2.0).abs() < 1e-9);
        assert!((s.merge_rows_mean - 50.0).abs() < 1e-9);
        assert!((s.merge_groups_mean - 1.0).abs() < 1e-9);
        assert!(s.report().contains("merge occupancy"), "report: {}", s.report());
        let j = s.to_json();
        assert_eq!(j.get("peak_sessions_in_flight").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(j.get("rounds_driven").and_then(|v| v.as_f64()), Some(2.0));
    }

    #[test]
    fn prefix_streaming_counters_aggregate() {
        let m = Metrics::new();
        m.record_prefix(5, Some(Duration::from_millis(4)));
        m.record_prefix(3, None);
        m.record_prefix(8, Some(Duration::from_millis(12)));
        let s = m.snapshot();
        assert_eq!(s.prefix_chunks_sent, 3);
        assert_eq!(s.prefix_rows_streamed, 16);
        assert!(s.first_prefix_ms_p50 >= 4.0 && s.first_prefix_ms_p95 <= 12.5);
        assert!(s.report().contains("first-prefix"), "report: {}", s.report());
        let j = s.to_json();
        assert_eq!(j.get("prefix_chunks_sent").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(j.get("prefix_rows_streamed").and_then(|v| v.as_f64()), Some(16.0));
        // No pool attached: no occupancy signal.
        assert!(m.device_occupancy().is_none());
    }

    #[test]
    fn empty_snapshot_is_finite() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_rounds, 0.0);
        assert!(s.devices.is_empty());
    }

    #[test]
    fn attached_pool_breakdown_in_report() {
        use crate::model::{Cond, EpsModel};
        use crate::runtime::{DevicePool, PoolConfig};
        use crate::schedule::{BetaSchedule, NoiseSchedule};
        use std::sync::Arc;

        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let model = Arc::new(crate::model::gmm::GmmEps::new(
            vec![0.5; 2 * 4],
            4,
            0.2,
            ns.alpha_bars.clone(),
        ));
        let pool = DevicePool::in_process(model, 2, PoolConfig::default()).unwrap();
        let eps = pool.eps_handle("pooled");
        let mut out = vec![0.0f32; 3 * 4];
        eps.eps_batch(
            &[0.1; 12],
            &[10, 500, 900],
            &[Cond::Class(0), Cond::Class(1), Cond::Uncond],
            1.0,
            &mut out,
        );

        let m = Metrics::new();
        m.attach_pool(pool.stats());
        let s = m.snapshot();
        assert_eq!(s.devices.len(), 2);
        assert_eq!(s.devices.iter().map(|d| d.items).sum::<u64>(), 3);
        assert!(s.report().contains("dev0"), "report: {}", s.report());
        assert!(s.report().contains("dev1"), "report: {}", s.report());
    }

    #[test]
    fn robustness_counters_aggregate() {
        let m = Metrics::new();
        m.record_success(Duration::from_millis(8), 0, 20, false);
        m.record_degraded();
        m.record_failure();
        m.deadline_miss();
        m.record_failure();
        m.record_shed();
        m.record_failure();
        m.record_cancelled();
        let s = m.snapshot();
        assert_eq!(s.degraded_total, 1);
        assert_eq!(s.deadline_misses, 1);
        assert_eq!(s.shed_total, 1);
        assert_eq!(s.cancelled_total, 1);
        assert_eq!(s.retries_total, 0, "no pool attached");
        assert_eq!(s.devices_quarantined, 0);
        assert!(s.report().contains("robustness:"), "report: {}", s.report());
        let j = s.to_json();
        assert_eq!(j.get("degraded_total").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("deadline_misses").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("shed_total").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("cancelled_total").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("retries_total").and_then(|v| v.as_f64()), Some(0.0));
        assert!(m.pool_healthy_devices().is_none(), "no pool attached");
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let m = Metrics::new();
        m.record_success(Duration::from_millis(12), 5, 500, true);
        let j = m.snapshot().to_json();
        assert_eq!(j.get("completed").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("warm_starts").and_then(|v| v.as_f64()), Some(1.0));
        assert!(j.get("latency_ms_p50").and_then(|v| v.as_f64()).unwrap() >= 12.0);
        // Round-trips through the parser (also proves no NaN leaked out).
        let text = j.to_string();
        crate::util::json::parse(&text).expect("snapshot JSON must parse");
    }
}
