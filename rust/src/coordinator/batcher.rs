//! Dynamic batcher — the public `EpsModel`-facing coalescing adapter.
//!
//! Each in-flight ParaTAA solve emits one ε job per parallel round (its
//! active window). With many callers in flight, executing those jobs one
//! by one wastes device occupancy; the batcher drains the job queue,
//! lingers briefly for stragglers, groups jobs by guidance scale (a scalar
//! graph input), concatenates their items, runs ONE backing `eps_batch`
//! call per group, and scatters the results back.
//!
//! Since the session refactor the *coordinator* no longer sits behind this
//! adapter: its round drivers merge the pending [`crate::solver::EpsBatch`]es
//! of ready sessions deterministically at the round boundary
//! (`coordinator/server.rs`), with no linger. The batcher remains the right
//! tool for callers outside the coordinator — anything holding a plain
//! [`EpsModel`] handle (blocking `solver::solve` loops, figure generators,
//! user threads) that wants cross-caller coalescing without restructuring
//! around sessions.

use crate::model::{Cond, EpsModel};
use crate::util::channel::{bounded, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// One ε job (a whole window from one solve round).
struct EpsJob {
    x: Vec<f32>,
    t: Vec<usize>,
    conds: Vec<Cond>,
    guidance: f32,
    reply: Sender<Vec<f32>>,
}

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum items (window rows) per merged device call **per device**;
    /// the effective merge cap is `max_items × devices`.
    pub max_items: usize,
    /// How long to linger for more jobs once one is pending.
    pub linger: Duration,
    /// Job queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Devices behind the backing model (a [`crate::runtime::DevicePool`]):
    /// merged calls grow to keep every device busy, and the pool then
    /// shards them back out per device.
    pub devices: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_items: 100,
            linger: Duration::from_micros(200),
            queue_capacity: 256,
            devices: 1,
        }
    }
}

impl BatcherConfig {
    /// Defaults scaled for an N-device pool: one full merged device call
    /// (the largest compiled batch variant) per device.
    pub fn for_devices(devices: usize) -> Self {
        BatcherConfig { devices: devices.max(1), ..Default::default() }
    }
}

/// The batcher thread + its submission handle.
pub struct Batcher {
    tx: Sender<EpsJob>,
    join: Option<JoinHandle<()>>,
}

impl Batcher {
    /// Spawn over a backing model (typically a [`crate::runtime::PooledEps`],
    /// `crate::runtime::PjrtEps`, or [`crate::model::gmm::GmmEps`]).
    pub fn spawn(model: Arc<dyn EpsModel>, cfg: BatcherConfig) -> Self {
        let (tx, rx) = bounded::<EpsJob>(cfg.queue_capacity);
        let join = std::thread::Builder::new()
            .name("parataa-batcher".to_string())
            .spawn(move || run_batcher(model, rx, cfg))
            .expect("spawn batcher");
        Batcher { tx, join: Some(join) }
    }

    /// An [`EpsModel`] handle that submits through this batcher.
    pub fn eps_handle(&self, dim: usize, name: &str) -> BatchedEps {
        BatchedEps { tx: self.tx.clone(), dim, name: name.to_string() }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.tx.close();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn run_batcher(model: Arc<dyn EpsModel>, rx: Receiver<EpsJob>, cfg: BatcherConfig) {
    let d = model.dim();
    let merge_cap = cfg.max_items.saturating_mul(cfg.devices.max(1));
    while let Some(first) = rx.recv() {
        // Collect: the first job plus whatever arrives within the linger
        // window, up to one full merged call per device.
        let mut jobs = vec![first];
        let mut items: usize = jobs[0].t.len();
        let deadline = std::time::Instant::now() + cfg.linger;
        while items < merge_cap {
            // `checked_duration_since` (not `deadline - now`): the deadline
            // may already have passed when the drain loop re-checks, and
            // Instant subtraction panics on negative spans.
            let left = deadline.checked_duration_since(std::time::Instant::now());
            let job = match left {
                Some(left) => match rx.recv_timeout(left) {
                    Ok(Some(j)) => j,
                    _ => break,
                },
                None => match rx.try_recv() {
                    Some(j) => j,
                    None => break,
                },
            };
            items += job.t.len();
            jobs.push(job);
        }

        // Group by guidance (bit-exact: it is a scalar input of the graph).
        let mut groups: Vec<(u32, Vec<usize>)> = Vec::new();
        for (i, j) in jobs.iter().enumerate() {
            let key = j.guidance.to_bits();
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, v)) => v.push(i),
                None => groups.push((key, vec![i])),
            }
        }

        for (gbits, idxs) in groups {
            let guidance = f32::from_bits(gbits);
            let total: usize = idxs.iter().map(|&i| jobs[i].t.len()).sum();
            let mut x = Vec::with_capacity(total * d);
            let mut t = Vec::with_capacity(total);
            let mut conds = Vec::with_capacity(total);
            for &i in &idxs {
                x.extend_from_slice(&jobs[i].x);
                t.extend_from_slice(&jobs[i].t);
                conds.extend_from_slice(&jobs[i].conds);
            }
            let mut out = vec![0.0f32; total * d];
            model.eps_batch(&x, &t, &conds, guidance, &mut out);
            // Scatter back.
            let mut off = 0;
            for &i in &idxs {
                let n = jobs[i].t.len();
                let slice = out[off * d..(off + n) * d].to_vec();
                off += n;
                let _ = jobs[i].reply.send(slice);
            }
        }
    }
}

/// `EpsModel` handle submitting through a [`Batcher`]. Clonable, Send+Sync.
#[derive(Clone)]
pub struct BatchedEps {
    tx: Sender<EpsJob>,
    dim: usize,
    name: String,
}

impl EpsModel for BatchedEps {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eps_batch(
        &self,
        xs: &[f32],
        train_ts: &[usize],
        conds: &[Cond],
        guidance: f32,
        out: &mut [f32],
    ) {
        let (rtx, rrx) = bounded(1);
        let job = EpsJob {
            x: xs.to_vec(),
            t: train_ts.to_vec(),
            conds: conds.to_vec(),
            guidance,
            reply: rtx,
        };
        if self.tx.send(job).is_err() {
            panic!("batcher is down");
        }
        let eps = rrx.recv().expect("batcher dropped reply");
        out.copy_from_slice(&eps);
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::GmmEps;
    use crate::schedule::{BetaSchedule, NoiseSchedule};
    use crate::util::rng::Pcg64;

    fn gmm() -> Arc<GmmEps> {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let mut rng = Pcg64::seeded(1);
        let d = 6;
        let means: Vec<f32> = (0..3 * d).map(|_| rng.next_f32()).collect();
        Arc::new(GmmEps::new(means, d, 0.2, ns.alpha_bars.clone()))
    }

    #[test]
    fn batched_matches_direct() {
        let model = gmm();
        let batcher = Batcher::spawn(model.clone(), BatcherConfig::default());
        let handle = batcher.eps_handle(6, "gmm-batched");
        let mut rng = Pcg64::seeded(2);
        let xs: Vec<f32> = (0..4 * 6).map(|_| rng.next_f32()).collect();
        let ts = vec![10usize, 200, 500, 900];
        let conds = vec![Cond::Class(0), Cond::Class(1), Cond::Class(2), Cond::Uncond];
        let mut via_batch = vec![0.0f32; 4 * 6];
        handle.eps_batch(&xs, &ts, &conds, 2.0, &mut via_batch);
        let mut direct = vec![0.0f32; 4 * 6];
        model.eps_batch(&xs, &ts, &conds, 2.0, &mut direct);
        assert_eq!(via_batch, direct);
    }

    #[test]
    fn expired_linger_deadline_does_not_panic() {
        // A zero linger means the deadline has always already passed when
        // the drain loop re-checks; the countdown must saturate, not panic.
        let model = gmm();
        let batcher = Batcher::spawn(
            model.clone(),
            BatcherConfig { linger: Duration::ZERO, ..Default::default() },
        );
        let handle = batcher.eps_handle(6, "gmm-batched");
        let mut rng = Pcg64::seeded(9);
        let xs: Vec<f32> = (0..3 * 6).map(|_| rng.next_f32()).collect();
        let ts = vec![5usize, 400, 800];
        let conds = vec![Cond::Class(0); 3];
        for _ in 0..16 {
            let mut out = vec![0.0f32; 3 * 6];
            handle.eps_batch(&xs, &ts, &conds, 1.5, &mut out);
            let mut direct = vec![0.0f32; 3 * 6];
            model.eps_batch(&xs, &ts, &conds, 1.5, &mut direct);
            assert_eq!(out, direct);
        }
    }

    #[test]
    fn batcher_over_device_pool_matches_direct() {
        use crate::runtime::{DevicePool, PoolConfig};
        let model = gmm();
        let pool = DevicePool::in_process(model.clone(), 2, PoolConfig::default()).unwrap();
        let pooled = Arc::new(pool.eps_handle("pooled"));
        let batcher = Batcher::spawn(pooled, BatcherConfig::for_devices(2));
        let handle = batcher.eps_handle(6, "gmm-pooled-batched");
        let mut rng = Pcg64::seeded(5);
        let n = 11;
        let xs: Vec<f32> = (0..n * 6).map(|_| rng.next_f32()).collect();
        let ts: Vec<usize> = (0..n).map(|i| (i * 83) % 1000).collect();
        let conds: Vec<Cond> = (0..n).map(|i| Cond::Class(i % 3)).collect();
        let mut via_stack = vec![0.0f32; n * 6];
        handle.eps_batch(&xs, &ts, &conds, 2.0, &mut via_stack);
        let mut direct = vec![0.0f32; n * 6];
        model.eps_batch(&xs, &ts, &conds, 2.0, &mut direct);
        assert_eq!(via_stack, direct);
        drop(batcher); // shut the batcher down before the pool drops
    }

    #[test]
    fn concurrent_jobs_all_answered() {
        let model = gmm();
        let batcher = Batcher::spawn(model.clone(), BatcherConfig::default());
        let threads: Vec<_> = (0..8)
            .map(|i| {
                let handle = batcher.eps_handle(6, "gmm-batched");
                let model = model.clone();
                std::thread::spawn(move || {
                    let mut rng = Pcg64::seeded(100 + i);
                    let n = 3;
                    let xs: Vec<f32> = (0..n * 6).map(|_| rng.next_f32()).collect();
                    let ts = vec![50usize * (i as usize + 1); n];
                    let conds = vec![Cond::Class(i as usize % 3); n];
                    // mix of two guidance scales exercises grouping
                    let g = if i % 2 == 0 { 1.0 } else { 3.0 };
                    let mut out = vec![0.0f32; n * 6];
                    handle.eps_batch(&xs, &ts, &conds, g, &mut out);
                    let mut expect = vec![0.0f32; n * 6];
                    model.eps_batch(&xs, &ts, &conds, g, &mut expect);
                    assert_eq!(out, expect);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }
}
