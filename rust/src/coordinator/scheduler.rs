//! Slot budget — admission control over in-flight window rows.
//!
//! One "slot" = one window row = one ε_θ evaluation per round. The budget
//! models the accelerator-memory constraint that makes the paper's window
//! size w a real trade-off (§2.2, §5.2): a request with window w holds w
//! slots for its whole solve. Implemented as a counting semaphore with FIFO
//! fairness (a ticket queue) so large requests cannot be starved by a
//! stream of small ones.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct State {
    available: usize,
    /// FIFO tickets: (ticket id, requested amount).
    queue: VecDeque<(u64, usize)>,
    next_ticket: u64,
}

/// FIFO counting semaphore.
pub struct SlotBudget {
    total: usize,
    state: Mutex<State>,
    cv: Condvar,
}

/// RAII guard returning slots on drop.
pub struct SlotGuard<'a> {
    budget: &'a SlotBudget,
    amount: usize,
}

/// Like [`SlotGuard`], but holds the budget by `Arc`, so it can live inside
/// long-lived session state that migrates between round-driver threads
/// (a borrowed guard would tie the session to one stack frame).
pub struct OwnedSlotGuard {
    budget: Arc<SlotBudget>,
    amount: usize,
}

impl OwnedSlotGuard {
    /// Slots held by this guard.
    pub fn amount(&self) -> usize {
        self.amount
    }
}

impl SlotBudget {
    /// A budget of `total` slots (≥ 1), all initially free.
    pub fn new(total: usize) -> Self {
        assert!(total >= 1);
        SlotBudget {
            total,
            state: Mutex::new(State { available: total, queue: VecDeque::new(), next_ticket: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Total slots in the budget (free + held).
    pub fn total(&self) -> usize {
        self.total
    }

    /// Currently free slots (diagnostic).
    pub fn available(&self) -> usize {
        self.state.lock().unwrap().available
    }

    /// Acquire `amount` slots (clamped to the total so oversized requests
    /// still run — alone). Blocks FIFO until granted.
    pub fn acquire(&self, amount: usize) -> SlotGuard<'_> {
        let amount = self.acquire_raw(amount);
        SlotGuard { budget: self, amount }
    }

    /// [`acquire`](Self::acquire) returning an [`OwnedSlotGuard`] that can
    /// be stored in session state outliving this call frame. An associated
    /// fn (not a method): `&Arc<Self>` receivers are invalid on stable
    /// Rust (E0307), so call as `SlotBudget::acquire_owned(&budget, n)`.
    pub fn acquire_owned(this: &Arc<SlotBudget>, amount: usize) -> OwnedSlotGuard {
        let amount = this.acquire_raw(amount);
        OwnedSlotGuard { budget: this.clone(), amount }
    }

    /// The FIFO wait loop shared by both guard flavors; returns the
    /// (clamped) amount actually granted.
    fn acquire_raw(&self, amount: usize) -> usize {
        let amount = amount.clamp(1, self.total);
        let mut st = self.state.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.queue.push_back((ticket, amount));
        loop {
            let at_head = st.queue.front().map(|&(t, _)| t) == Some(ticket);
            if at_head && st.available >= amount {
                st.queue.pop_front();
                st.available -= amount;
                // Wake the next ticket in case it also fits.
                self.cv.notify_all();
                return amount;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn release(&self, amount: usize) {
        let mut st = self.state.lock().unwrap();
        st.available += amount;
        drop(st);
        self.cv.notify_all();
    }
}

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        self.budget.release(self.amount);
    }
}

impl Drop for OwnedSlotGuard {
    fn drop(&mut self) {
        self.budget.release(self.amount);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn acquire_release_cycle() {
        let b = SlotBudget::new(10);
        {
            let _g = b.acquire(7);
            assert_eq!(b.available(), 3);
        }
        assert_eq!(b.available(), 10);
    }

    #[test]
    fn owned_guard_releases_on_drop_across_threads() {
        let b = Arc::new(SlotBudget::new(8));
        let g = SlotBudget::acquire_owned(&b, 5);
        assert_eq!(g.amount(), 5);
        assert_eq!(b.available(), 3);
        let t = std::thread::spawn(move || drop(g));
        t.join().unwrap();
        assert_eq!(b.available(), 8);
    }

    #[test]
    fn oversized_requests_are_clamped() {
        let b = SlotBudget::new(4);
        let _g = b.acquire(100);
        assert_eq!(b.available(), 0);
    }

    #[test]
    fn blocks_until_released() {
        let b = Arc::new(SlotBudget::new(2));
        let g = b.acquire(2);
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            let _g = b2.acquire(1);
            1u32
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "should be blocked");
        drop(g);
        assert_eq!(t.join().unwrap(), 1);
    }

    #[test]
    fn fifo_prevents_starvation() {
        // A large request queued first must be served before later small
        // ones, even though the small ones would fit immediately.
        let b = Arc::new(SlotBudget::new(4));
        let order = Arc::new(AtomicUsize::new(0));
        let g = b.acquire(3); // occupy most of the budget

        let b_big = b.clone();
        let ord_big = order.clone();
        let big = std::thread::spawn(move || {
            let _g = b_big.acquire(4);
            ord_big.fetch_add(1, Ordering::SeqCst) // records its arrival order
        });
        std::thread::sleep(Duration::from_millis(30));
        let b_small = b.clone();
        let ord_small = order.clone();
        let small = std::thread::spawn(move || {
            let _g = b_small.acquire(1);
            ord_small.fetch_add(1, Ordering::SeqCst)
        });
        std::thread::sleep(Duration::from_millis(30));
        // Small fits (1 free slot) but big was first — neither should have
        // run yet except... big needs all 4, 1 is free; small must wait
        // behind big (FIFO).
        assert!(!big.is_finished() && !small.is_finished());
        drop(g);
        let big_order = big.join().unwrap();
        let small_order = small.join().unwrap();
        assert!(big_order < small_order, "large request must be served first");
    }
}
