//! Denoiser models ε_θ(x_t, t, cond).
//!
//! The solver is generic over [`EpsModel`]: any batched map from a stack of
//! noisy states (plus per-item training timestep and condition) to predicted
//! noise. Two implementations ship:
//!
//! - [`gmm::GmmEps`] — the analytic template-GMM score (exact ε, no network),
//!   used for the SD-analog scenarios, for fast property tests, and as the
//!   ground truth behind the IS/CS quality proxies;
//! - `runtime::PjrtEps` — the trained DiT-tiny loaded from an AOT HLO
//!   artifact and executed on the PJRT CPU client (the production hot path).

pub mod gmm;
pub mod templates;

/// A sampling condition ("class label" for DiT, "prompt embedding" — a
/// weighting over template components — for the SD analog).
#[derive(Debug, Clone, PartialEq)]
pub enum Cond {
    /// Unconditional (the CFG null condition).
    Uncond,
    /// A discrete class id.
    Class(usize),
    /// A continuous embedding: non-negative weights over GMM components
    /// (need not be normalized; the model normalizes).
    Weights(Vec<f32>),
}

impl Cond {
    /// Blend two conditions: (1−α)·self + α·other, the "similar prompt"
    /// construction used by the trajectory-initialization experiments (§5.3).
    pub fn lerp(&self, other: &Cond, alpha: f32, n_components: usize) -> Cond {
        let wa = self.to_weights(n_components);
        let wb = other.to_weights(n_components);
        Cond::Weights(
            wa.iter()
                .zip(wb.iter())
                .map(|(&a, &b)| (1.0 - alpha) * a + alpha * b)
                .collect(),
        )
    }

    /// Densify to component weights (uniform for `Uncond`).
    pub fn to_weights(&self, n_components: usize) -> Vec<f32> {
        match self {
            Cond::Uncond => vec![1.0 / n_components as f32; n_components],
            Cond::Class(c) => {
                let mut w = vec![0.0; n_components];
                w[*c % n_components] = 1.0;
                w
            }
            Cond::Weights(w) => {
                assert_eq!(w.len(), n_components);
                let s: f32 = w.iter().sum();
                if s > 0.0 {
                    w.iter().map(|&x| x / s).collect()
                } else {
                    vec![1.0 / n_components as f32; n_components]
                }
            }
        }
    }
}

/// A batched denoiser. `xs`/`out` are `[n, d]` row-major stacks; item `i`
/// is evaluated at training timestep `train_ts[i]` under `conds[i]` with
/// classifier-free guidance scale `guidance` (1.0 = conditional only).
pub trait EpsModel: Send + Sync {
    /// Feature dimension d.
    fn dim(&self) -> usize;

    /// Batched ε evaluation — **one call = one parallel round** (the unit the
    /// paper counts as a single inference step).
    fn eps_batch(
        &self,
        xs: &[f32],
        train_ts: &[usize],
        conds: &[Cond],
        guidance: f32,
        out: &mut [f32],
    );

    /// Fallible ε evaluation for callers that must survive device failures
    /// (the coordinator's round drivers). The default wraps the infallible
    /// [`EpsModel::eps_batch`]; fallible substrates (the device pool)
    /// override it to propagate classified errors instead of panicking.
    fn try_eps_batch(
        &self,
        xs: &[f32],
        train_ts: &[usize],
        conds: &[Cond],
        guidance: f32,
        out: &mut [f32],
    ) -> crate::util::error::Result<()> {
        self.eps_batch(xs, train_ts, conds, guidance, out);
        Ok(())
    }

    /// Human-readable model name for reports.
    fn name(&self) -> &str;
}
