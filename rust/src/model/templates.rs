//! Synthetic 16×16 template images — the dataset substrate.
//!
//! Eight procedurally-generated grayscale shape classes in [-1, 1] pixel
//! space. The generation rule is integer-exact and mirrored bit-for-bit by
//! `python/compile/dataset.py` (pure threshold logic on integer coordinates,
//! then a fixed scale), so Rust and Python agree on every pixel and the
//! cross-language test vectors can pin the two sides together.
//!
//! The templates serve three roles:
//! 1. class prototypes of the synthetic training set for DiT-tiny,
//! 2. component means of the analytic template-GMM (the SD-analog model),
//! 3. the classifier behind the IS/CS quality proxies.

/// Image side length.
pub const SIDE: usize = 16;
/// Flattened image dimension.
pub const DIM: usize = SIDE * SIDE;
/// Number of shape classes.
pub const N_CLASSES: usize = 8;

/// Foreground / background pixel values.
pub const FG: f32 = 0.8;
pub const BG: f32 = -0.8;

/// Class names, index-aligned with [`template`].
pub const CLASS_NAMES: [&str; N_CLASSES] = [
    "circle", "square", "cross", "hstripes", "vstripes", "diag", "ring", "checker",
];

/// Generate the template image for a class (row-major, length [`DIM`]).
pub fn template(class: usize) -> Vec<f32> {
    let c = class % N_CLASSES;
    let mut img = vec![BG; DIM];
    let s = SIDE as i64;
    for y in 0..s {
        for x in 0..s {
            // Centered integer coordinates scaled by 2 to keep everything
            // integral: cx, cy in {-15, -13, ..., 15}.
            let cx = 2 * x - (s - 1);
            let cy = 2 * y - (s - 1);
            let r2 = cx * cx + cy * cy;
            let on = match c {
                0 => r2 <= 121,                            // circle, radius 5.5px
                1 => cx.abs() <= 9 && cy.abs() <= 9,       // square
                2 => cx.abs() <= 3 || cy.abs() <= 3,       // cross
                3 => (y / 2) % 2 == 0,                     // horizontal stripes
                4 => (x / 2) % 2 == 0,                     // vertical stripes
                5 => (x - y).abs() <= 2 || (x + y - (s - 1)).abs() <= 2, // diagonals
                6 => (49..=169).contains(&r2),             // ring
                7 => ((x / 4) + (y / 4)) % 2 == 0,         // checkerboard
                _ => unreachable!(),
            };
            if on {
                img[(y * s + x) as usize] = FG;
            }
        }
    }
    img
}

/// All templates stacked `[N_CLASSES, DIM]`.
pub fn all_templates() -> Vec<Vec<f32>> {
    (0..N_CLASSES).map(template).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_have_expected_sizes() {
        for c in 0..N_CLASSES {
            let t = template(c);
            assert_eq!(t.len(), DIM);
            let fg = t.iter().filter(|&&p| p == FG).count();
            // every class draws something, but not everything
            assert!(fg > 10, "class {c} too empty ({fg})");
            assert!(fg < DIM - 10, "class {c} too full ({fg})");
            assert!(t.iter().all(|&p| p == FG || p == BG));
        }
    }

    #[test]
    fn classes_are_distinct() {
        let ts = all_templates();
        for i in 0..N_CLASSES {
            for j in i + 1..N_CLASSES {
                let diff: usize = ts[i]
                    .iter()
                    .zip(ts[j].iter())
                    .filter(|(a, b)| a != b)
                    .count();
                assert!(diff > 8, "classes {i} and {j} nearly identical ({diff} px)");
            }
        }
    }

    #[test]
    fn circle_is_symmetric() {
        let t = template(0);
        for y in 0..SIDE {
            for x in 0..SIDE {
                let m = t[y * SIDE + x];
                assert_eq!(m, t[y * SIDE + (SIDE - 1 - x)], "h-mirror at {x},{y}");
                assert_eq!(m, t[(SIDE - 1 - y) * SIDE + x], "v-mirror at {x},{y}");
            }
        }
    }

    #[test]
    fn class_wraps() {
        assert_eq!(template(0), template(N_CLASSES));
    }

    #[test]
    fn checker_period() {
        let t = template(7);
        // 4x4 blocks: (0,0) and (4,4) same parity-sum difference
        assert_eq!(t[0], FG);
        assert_eq!(t[4], BG);
        assert_eq!(t[4 * SIDE + 4], FG);
    }
}
