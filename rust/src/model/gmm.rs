//! Analytic template-GMM diffusion model — the SD-analog substrate.
//!
//! Data distribution: an isotropic Gaussian mixture p₀(x|cond) =
//! Σ_i w_i(cond)·N(μ_i, s²I) whose means μ_i are the shape templates.
//! Under the discrete VP forward process with signal level ᾱ(τ):
//!
//!   p_τ(x|cond) = Σ_i w_i · N(√ᾱ·μ_i, (ᾱ·s² + 1−ᾱ)·I)
//!
//! which yields the *exact* noise predictor in closed form:
//!
//!   ε(x, τ, cond) = −√(1−ᾱ)·∇ log p_τ(x|cond)
//!                 = √(1−ᾱ)/v · (x − Σ_i γ_i(x)·√ᾱ·μ_i),
//!
//! v = ᾱs² + 1−ᾱ and γ = softmax over components of
//! log w_i − ‖x−√ᾱμ_i‖²/(2v). Classifier-free guidance mixes the
//! conditional and marginal predictors exactly as a trained model would.
//!
//! This gives the reproduction a denoiser that is (a) exact, (b) cheap, and
//! (c) has a *known* posterior — which powers the IS- and CLIP-score proxies.
//! Mirrored by `python/compile/gmm.py`; pinned by cross-language vectors.

use super::{Cond, EpsModel};

/// Analytic GMM noise predictor.
#[derive(Debug, Clone)]
pub struct GmmEps {
    /// Component means, row-major `[n_components, d]`.
    pub means: Vec<f32>,
    pub n_components: usize,
    pub d: usize,
    /// Isotropic component std-dev `s` of the data distribution.
    pub data_std: f64,
    /// ᾱ per training timestep (copied from the noise schedule).
    pub alpha_bars: Vec<f64>,
    name: String,
}

impl GmmEps {
    pub fn new(means: Vec<f32>, d: usize, data_std: f64, alpha_bars: Vec<f64>) -> Self {
        assert!(!means.is_empty() && means.len() % d == 0);
        let n_components = means.len() / d;
        GmmEps {
            means,
            n_components,
            d,
            data_std,
            alpha_bars,
            name: "gmm".to_string(),
        }
    }

    /// The SD-analog model: template images as component means.
    pub fn sd_analog(alpha_bars: Vec<f64>) -> Self {
        use super::templates;
        let means: Vec<f32> = templates::all_templates().concat();
        let mut m = Self::new(means, templates::DIM, 0.15, alpha_bars);
        m.name = "sda".to_string();
        m
    }

    #[inline]
    fn mean(&self, i: usize) -> &[f32] {
        &self.means[i * self.d..(i + 1) * self.d]
    }

    /// Component log-posteriors γ_i(x) at noise level ᾱ under `weights`.
    /// Returns (log γ normalized, marginal log-likelihood up to a constant).
    pub fn log_posterior(&self, x: &[f32], abar: f64, weights: &[f32]) -> (Vec<f64>, f64) {
        let v = abar * self.data_std * self.data_std + (1.0 - abar);
        let sqrt_ab = abar.sqrt();
        let mut logits = vec![f64::NEG_INFINITY; self.n_components];
        for i in 0..self.n_components {
            if weights[i] <= 0.0 {
                continue;
            }
            let mu = self.mean(i);
            let mut d2 = 0.0f64;
            for (&xj, &mj) in x.iter().zip(mu.iter()) {
                let r = xj as f64 - sqrt_ab * mj as f64;
                d2 += r * r;
            }
            logits[i] = (weights[i] as f64).ln() - d2 / (2.0 * v);
        }
        // logsumexp-normalize
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + logits.iter().map(|&l| (l - mx).exp()).sum::<f64>().ln();
        let log_post: Vec<f64> = logits.iter().map(|&l| l - lse).collect();
        (log_post, lse)
    }

    /// Exact ε for a single item under dense component weights.
    fn eps_single(&self, x: &[f32], abar: f64, weights: &[f32], out: &mut [f32]) {
        let v = abar * self.data_std * self.data_std + (1.0 - abar);
        let sqrt_ab = abar.sqrt();
        let sqrt_1mab = (1.0 - abar).sqrt();
        let (log_post, _) = self.log_posterior(x, abar, weights);
        // posterior mean of √ᾱ·μ
        let mut mean_mu = vec![0.0f64; self.d];
        for i in 0..self.n_components {
            let g = log_post[i].exp();
            if g < 1e-300 {
                continue;
            }
            let mu = self.mean(i);
            for (mm, &mj) in mean_mu.iter_mut().zip(mu.iter()) {
                *mm += g * sqrt_ab * mj as f64;
            }
        }
        let scale = sqrt_1mab / v;
        for j in 0..self.d {
            out[j] = (scale * (x[j] as f64 - mean_mu[j])) as f32;
        }
    }

    /// Draw a ground-truth sample x₀ ~ p₀(·|cond) (for metric references).
    pub fn sample_data(&self, cond: &Cond, rng: &mut crate::util::rng::Pcg64) -> Vec<f32> {
        let w = cond.to_weights(self.n_components);
        // categorical draw
        let u = rng.next_f64();
        let mut acc = 0.0f64;
        let mut comp = self.n_components - 1;
        for (i, &wi) in w.iter().enumerate() {
            acc += wi as f64;
            if u < acc {
                comp = i;
                break;
            }
        }
        let mu = self.mean(comp).to_vec();
        let mut out = vec![0.0f32; self.d];
        rng.fill_gaussian(&mut out);
        for (o, &m) in out.iter_mut().zip(mu.iter()) {
            *o = m + *o * self.data_std as f32;
        }
        out
    }
}

impl EpsModel for GmmEps {
    fn dim(&self) -> usize {
        self.d
    }

    fn eps_batch(
        &self,
        xs: &[f32],
        train_ts: &[usize],
        conds: &[Cond],
        guidance: f32,
        out: &mut [f32],
    ) {
        let n = train_ts.len();
        assert_eq!(xs.len(), n * self.d);
        assert_eq!(out.len(), n * self.d);
        assert_eq!(conds.len(), n);
        let uniform = vec![1.0 / self.n_components as f32; self.n_components];
        let mut eps_u = vec![0.0f32; self.d];
        for i in 0..n {
            let x = &xs[i * self.d..(i + 1) * self.d];
            let o = &mut out[i * self.d..(i + 1) * self.d];
            let abar = self.alpha_bars[train_ts[i]];
            let w = conds[i].to_weights(self.n_components);
            self.eps_single(x, abar, &w, o);
            if (guidance - 1.0).abs() > 1e-9 && !matches!(conds[i], Cond::Uncond) {
                // ε_cfg = ε_u + g·(ε_c − ε_u)
                self.eps_single(x, abar, &uniform, &mut eps_u);
                for (oj, &uj) in o.iter_mut().zip(eps_u.iter()) {
                    *oj = uj + guidance * (*oj - uj);
                }
            }
        }
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{BetaSchedule, NoiseSchedule};
    use crate::util::proplite::{self, forall, size_in};
    use crate::util::rng::Pcg64;

    fn tiny_gmm(rng: &mut Pcg64, n_comp: usize, d: usize) -> GmmEps {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let means: Vec<f32> = (0..n_comp * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        GmmEps::new(means, d, 0.2, ns.alpha_bars.clone())
    }

    #[test]
    fn single_component_eps_is_exact_gaussian_score() {
        // With one component the ε predictor has the closed form
        // ε = √(1−ᾱ)·(x − √ᾱ·μ)/v, v = ᾱs²+1−ᾱ — check directly.
        forall("gmm_single_component", 24, |rng, _| {
            let d = size_in(rng, 1, 8);
            let m = tiny_gmm(rng, 1, d);
            let tt = size_in(rng, 0, 999);
            let abar = m.alpha_bars[tt];
            let x: Vec<f32> = (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let mut out = vec![0.0f32; d];
            m.eps_batch(&x, &[tt], &[Cond::Class(0)], 1.0, &mut out);
            let v = abar * 0.2 * 0.2 + (1.0 - abar);
            let expect: Vec<f32> = (0..d)
                .map(|j| {
                    ((1.0 - abar).sqrt() * (x[j] as f64 - abar.sqrt() * m.means[j] as f64) / v)
                        as f32
                })
                .collect();
            proplite::assert_close(&out, &expect, 1e-5, 1e-4, "single-comp eps")
        });
    }

    #[test]
    fn eps_at_high_noise_is_nearly_whitening() {
        // As ᾱ→0, p_τ → N(0, I), so ε(x) → x.
        let mut rng = Pcg64::seeded(2);
        let m = tiny_gmm(&mut rng, 4, 6);
        let tt = 999; // ᾱ ≈ 4e-5
        let x: Vec<f32> = (0..6).map(|_| rng.next_f32() - 0.5).collect();
        let mut out = vec![0.0f32; 6];
        m.eps_batch(&x, &[tt], &[Cond::Uncond], 1.0, &mut out);
        proplite::assert_close(&out, &x, 0.05, 0.05, "whitening").unwrap();
    }

    #[test]
    fn guidance_one_equals_conditional() {
        let mut rng = Pcg64::seeded(3);
        let m = tiny_gmm(&mut rng, 3, 4);
        let x: Vec<f32> = vec![0.3, -0.2, 0.5, 0.0];
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        m.eps_batch(&x, &[500], &[Cond::Class(1)], 1.0, &mut a);
        m.eps_batch(&x, &[500], &[Cond::Class(1)], 1.0 + 1e-12, &mut b);
        proplite::assert_close(&a, &b, 1e-5, 1e-5, "g=1").unwrap();
    }

    #[test]
    fn guidance_extrapolates_beyond_conditional() {
        // ε_cfg − ε_u = g·(ε_c − ε_u): check the affine relation at g=5.
        let mut rng = Pcg64::seeded(4);
        let m = tiny_gmm(&mut rng, 3, 4);
        let x = vec![0.1f32, 0.7, -0.3, 0.2];
        let (mut ec, mut eu, mut eg) = (vec![0.0f32; 4], vec![0.0f32; 4], vec![0.0f32; 4]);
        m.eps_batch(&x, &[300], &[Cond::Class(2)], 1.0, &mut ec);
        m.eps_batch(&x, &[300], &[Cond::Uncond], 1.0, &mut eu);
        m.eps_batch(&x, &[300], &[Cond::Class(2)], 5.0, &mut eg);
        let expect: Vec<f32> = (0..4).map(|j| eu[j] + 5.0 * (ec[j] - eu[j])).collect();
        proplite::assert_close(&eg, &expect, 1e-5, 1e-4, "cfg affine").unwrap();
    }

    #[test]
    fn posterior_sums_to_one_and_prefers_own_class() {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let m = GmmEps::sd_analog(ns.alpha_bars.clone());
        let t0 = crate::model::templates::template(3);
        let (lp, _) = m.log_posterior(&t0, 0.999, &vec![1.0 / 8.0; 8]);
        let total: f64 = lp.iter().map(|&l| l.exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        let best = lp
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 3, "template 3 should classify as class 3");
    }

    #[test]
    fn sample_data_concentrates_near_mean() {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let m = GmmEps::sd_analog(ns.alpha_bars);
        let mut rng = Pcg64::seeded(9);
        let s = m.sample_data(&Cond::Class(1), &mut rng);
        let mu = crate::model::templates::template(1);
        let dist2: f64 = s
            .iter()
            .zip(mu.iter())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        // E[dist²] = d·s² = 256·0.0225 = 5.76; allow generous slack.
        assert!(dist2 < 12.0, "sample too far from its component mean: {dist2}");
    }

    #[test]
    fn cond_lerp_blends_weights() {
        let a = Cond::Class(0);
        let b = Cond::Class(1);
        let mid = a.lerp(&b, 0.5, 4);
        assert_eq!(mid.to_weights(4), vec![0.5, 0.5, 0.0, 0.0]);
    }
}
