//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we implement a
//! PCG64-DXSM-style generator (two 64-bit LCG lanes + output permutation)
//! plus the distributions the solver needs: uniform floats and Gaussian
//! variates (Box–Muller with caching).
//!
//! Determinism is load-bearing: the paper's central claim (Remark 5.3) is
//! that parallel sampling reproduces the *same* image as sequential sampling
//! given the same noise vectors ξ_0..ξ_T, so every experiment seeds noise
//! through this generator and both paths must observe identical streams.

/// A small, fast, deterministic PRNG (PCG-XSL-RR 128/64 variant).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | (stream as u128) | 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        // A few warm-up rounds to diffuse low-entropy seeds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output (XSL-RR output permutation).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for practical n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Widening multiply method; bias is negligible (<2^-64 * n) for the
        // experiment sizes used here, and determinism is what matters.
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as u64
    }

    /// Standard normal variate (Box–Muller, both halves used).
    #[inline]
    pub fn next_gaussian(&mut self) -> f64 {
        // Draw until u1 is nonzero to avoid ln(0).
        let mut u1 = self.next_f64();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.next_f64();
        }
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        r * theta.cos()
    }

    /// Fill a slice with standard normal f32 samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        // Generate pairs from Box–Muller to halve the transcendental count.
        let mut i = 0;
        while i + 1 < out.len() {
            let mut u1 = self.next_f64();
            while u1 <= f64::MIN_POSITIVE {
                u1 = self.next_f64();
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            out[i] = (r * theta.cos()) as f32;
            out[i + 1] = (r * theta.sin()) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.next_gaussian() as f32;
        }
    }

    /// Allocate and fill a Gaussian vector.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_gaussian(&mut v);
        v
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "seeds should produce distinct streams");
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(9, 0);
        let mut b = Pcg64::new(9, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seeded(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seeded(4);
        let n = 50_000;
        let mut v = vec![0.0f32; n];
        rng.fill_gaussian(&mut v);
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Pcg64::seeded(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle should move elements");
    }

    #[test]
    fn odd_length_gaussian_fill() {
        let mut rng = Pcg64::seeded(7);
        let mut v = vec![0.0f32; 7];
        rng.fill_gaussian(&mut v);
        assert!(v.iter().any(|&x| x != 0.0));
    }
}
