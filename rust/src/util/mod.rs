//! Infrastructure substrates built from scratch for the offline environment:
//! RNG, channels, threadpool, CLI parsing, JSON, CSV/tables, stats/bench
//! harness, property testing, and image output.

pub mod channel;
pub mod cli;
pub mod error;
pub mod image;
pub mod json;
pub mod proplite;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
