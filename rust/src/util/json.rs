//! Minimal JSON parser + writer.
//!
//! Used to (a) read cross-language test vectors exported by `python/compile/aot.py`
//! and (b) dump coordinator metrics. Supports the full JSON grammar except
//! exotic number forms; numbers are parsed as f64 (adequate: the vectors are
//! f32 tensors and small ints).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Interpret an array of numbers as an f32 vector.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }
    /// Interpret a nested array-of-arrays as a row-major f32 matrix.
    pub fn as_f32_mat(&self) -> Option<(usize, usize, Vec<f32>)> {
        let rows = self.as_arr()?;
        let ncols = rows.first()?.as_arr()?.len();
        let mut out = Vec::with_capacity(rows.len() * ncols);
        for r in rows {
            let r = r.as_arr()?;
            if r.len() != ncols {
                return None;
            }
            for v in r {
                out.push(v.as_f64()? as f32);
            }
        }
        Some((rows.len(), ncols, out))
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Inf literal; degrade to null so the
                    // output always re-parses (readers see `None` via
                    // `as_f64`, which is the honest value here).
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`value.to_string()` comes via `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or("bad escape")? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("short \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                    self.i += 1;
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

/// Serialize with two-space indentation (diff-friendly; used for the
/// repo-root `BENCH_repro.json`). Scalar-only arrays stay on one line.
pub fn to_pretty_string(v: &Json) -> String {
    let mut out = String::new();
    write_pretty(v, 0, &mut out);
    out
}

fn write_pretty(v: &Json, indent: usize, out: &mut String) {
    let pad = |out: &mut String, n: usize| {
        for _ in 0..n {
            out.push_str("  ");
        }
    };
    match v {
        Json::Arr(items) if items.is_empty() => out.push_str("[]"),
        Json::Arr(items)
            if items.iter().all(|i| !matches!(i, Json::Arr(_) | Json::Obj(_))) =>
        {
            // All-scalar array: compact form, written element-wise.
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                item.write(out);
            }
            out.push(']');
        }
        Json::Arr(items) => {
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                pad(out, indent + 1);
                write_pretty(item, indent + 1, out);
                if i + 1 < items.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(out, indent);
            out.push(']');
        }
        Json::Obj(m) if m.is_empty() => out.push_str("{}"),
        Json::Obj(m) => {
            out.push_str("{\n");
            for (i, (k, val)) in m.iter().enumerate() {
                pad(out, indent + 1);
                write_escaped(out, k);
                out.push_str(": ");
                write_pretty(val, indent + 1, out);
                if i + 1 < m.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(out, indent);
            out.push('}');
        }
        scalar => out.push_str(&scalar.to_string()),
    }
}

/// Build a Json object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a Json array of f64s from an f32 slice.
pub fn arr_f32(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"x"],"n":-7}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn f32_vec_and_mat() {
        let v = parse("[1, 2, 3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        let m = parse("[[1,2],[3,4],[5,6]]").unwrap();
        let (r, c, data) = m.as_f32_mat().unwrap();
        assert_eq!((r, c), (3, 2));
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn ragged_mat_rejected() {
        let m = parse("[[1,2],[3]]").unwrap();
        assert!(m.as_f32_mat().is_none());
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let v = obj(vec![("x", Json::Num(f64::NAN))]);
        assert_eq!(parse(&v.to_string()).unwrap().get("x"), Some(&Json::Null));
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let v = parse(r#"{"a":[1,2,3],"b":{"c":"d","e":[{"f":1}]},"g":null}"#).unwrap();
        let pretty = to_pretty_string(&v);
        assert_eq!(parse(&pretty).unwrap(), v, "pretty form must re-parse");
        assert!(pretty.contains("\n"), "expected multi-line output");
        assert!(pretty.contains("\"a\": [1,2,3]"), "scalar arrays stay compact:\n{pretty}");
        assert!(pretty.contains("  \"b\": {"), "objects indent:\n{pretty}");
    }
}
