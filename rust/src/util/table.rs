//! CSV emission and ASCII table rendering for the figure/table harness.
//!
//! Every experiment generator writes a machine-readable CSV under `results/`
//! and prints a human-readable ASCII table mirroring the paper's layout.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::Path;

/// A simple column-oriented table: header + rows of stringified cells.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; must match the header arity.
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Convenience: push a row of display-able values.
    pub fn row(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.push_row(cells.iter().map(|c| c.to_string()).collect());
    }

    /// Render as CSV text (RFC-4180-lite: quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV to a path, creating parent directories.
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    /// Render as an aligned ASCII table.
    pub fn to_ascii(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                let _ = write!(s, " {:<width$} |", cells[i], width = widths[i]);
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "{}", self.title);
        }
        let _ = writeln!(out, "{sep}");
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r));
        }
        let _ = writeln!(out, "{sep}");
        out
    }
}

/// Format an f64 with `digits` significant decimals, trimming noise.
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(vec!["1".into(), "x,y".into()]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(vec!["1".into()]);
    }

    #[test]
    fn ascii_alignment() {
        let mut t = Table::new("demo", &["method", "steps"]);
        t.push_row(vec!["Sequential".into(), "100".into()]);
        t.push_row(vec!["ParaTAA".into(), "7".into()]);
        let a = t.to_ascii();
        assert!(a.contains("| method     | steps |"));
        assert!(a.contains("| ParaTAA    | 7     |"));
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join("parataa_table_test");
        let _ = fs::remove_dir_all(&dir);
        let mut t = Table::new("t", &["x"]);
        t.push_row(vec!["1".into()]);
        let p = dir.join("sub/out.csv");
        t.write_csv(&p).unwrap();
        assert!(p.exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(3.14159, 2), "3.14");
        assert_eq!(fnum(10.0, 1), "10.0");
    }
}
