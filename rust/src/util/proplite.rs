//! `proplite` — a minimal property-based testing helper.
//!
//! The offline environment has no `proptest`, so this module provides the
//! subset we need: run a property over many randomized cases derived from a
//! seeded [`Pcg64`], and on failure report the case index and seed so the
//! exact case can be replayed deterministically.
//!
//! Usage:
//! ```no_run
//! use parataa::util::proplite::forall;
//! forall("sum_commutes", 64, |rng, case| {
//!     let a = rng.next_f32();
//!     let b = rng.next_f32();
//!     if (a + b - (b + a)).abs() > 0.0 {
//!         return Err(format!("case {case}: {a} + {b}"));
//!     }
//!     Ok(())
//! });
//! ```

use super::rng::Pcg64;

/// Fixed base seed; each case gets an independent stream so failures replay
/// in isolation (`Pcg64::new(BASE_SEED, case)`).
pub const BASE_SEED: u64 = 0x5eed_cafe_f00d_0001;

/// Run `prop` over `cases` independently-seeded random cases; panic with a
/// replayable diagnostic on the first failure.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64, u64) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Pcg64::new(BASE_SEED, case);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: Pcg64::new(proplite::BASE_SEED, {case})): {msg}"
            );
        }
    }
}

/// The shared naive dot-product oracle for kernel property tests: one
/// sequential f64 accumulator, no lanes, no tree. Every kernel in
/// `linalg::kernels` is compared against this single reference so the
/// tests can't drift apart on what "correct" means.
pub fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let mut acc = 0.0f64;
    for i in 0..n {
        acc += (a[i] as f64) * (b[i] as f64);
    }
    acc
}

/// Draw a random size in [lo, hi].
pub fn size_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    debug_assert!(hi >= lo);
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Draw a uniform f32 in [lo, hi).
pub fn f32_in(rng: &mut Pcg64, lo: f32, hi: f32) -> f32 {
    lo + rng.next_f32() * (hi - lo)
}

/// Assert two slices are elementwise close; returns a property-style error.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "{what}: mismatch at [{i}]: {x} vs {y} (|Δ|={}, tol={tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall("counter", 17, |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn forall_reports_failure() {
        forall("fails", 8, |_, case| {
            if case == 3 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn size_in_bounds() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..200 {
            let s = size_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&s));
        }
    }

    #[test]
    fn assert_close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 0.0, "eq").is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 0.0, "neq").is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6, 0.0, "len").is_err());
    }
}
