//! Bounded multi-producer / multi-consumer channel.
//!
//! The coordinator needs an MPMC queue with backpressure (block or reject
//! when full) and clean shutdown semantics. The offline environment has no
//! `crossbeam-channel`/`tokio`, so this is a small Mutex+Condvar ring
//! implementation. Throughput requirements are modest: the channel carries
//! *requests* and *window batches*, each of which amortizes an ε_θ device
//! call that costs milliseconds, so a lock-based queue is nowhere near the
//! bottleneck (verified in `benches/bench_coordinator.rs`).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct State<T> {
    items: VecDeque<T>,
    cap: usize,
    closed: bool,
    senders: usize,
}

/// Error returned when sending on a closed channel.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error returned by `try_send`.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// Queue at capacity.
    Full(T),
    /// All receivers dropped / channel closed.
    Closed(T),
}

/// Sending half. Cloneable.
pub struct Sender<T> {
    inner: Arc<Inner<T>>,
}

/// Receiving half. Cloneable (MPMC).
pub struct Receiver<T> {
    inner: Arc<Inner<T>>,
}

/// Create a bounded channel with capacity `cap` (≥1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1, "channel capacity must be >= 1");
    let inner = Arc::new(Inner {
        queue: Mutex::new(State {
            items: VecDeque::with_capacity(cap),
            cap,
            closed: false,
            senders: 1,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender { inner: inner.clone() }, Receiver { inner })
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.queue.lock().unwrap().senders += 1;
        Sender { inner: self.inner.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            // Last sender gone: wake all receivers so they can observe
            // disconnection once the queue drains.
            drop(st);
            self.inner.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver { inner: self.inner.clone() }
    }
}

impl<T> Sender<T> {
    /// Blocking send with backpressure; fails only if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError(item));
            }
            if st.items.len() < st.cap {
                st.items.push_back(item);
                drop(st);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send: `Full` applies backpressure to the caller.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.closed {
            return Err(TrySendError::Closed(item));
        }
        if st.items.len() >= st.cap {
            return Err(TrySendError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel: pending items remain receivable, new sends fail.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    /// Current queue depth (diagnostic; racy by nature).
    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    /// True when the queue is empty (diagnostic; racy by nature).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` once the channel is closed (or all senders
    /// dropped) *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed || st.senders == 0 {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with a timeout. `Ok(None)` means closed+drained; `Err(())`
    /// means timed out with no item.
    pub fn recv_timeout(&self, dur: Duration) -> Result<Option<T>, ()> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Ok(Some(item));
            }
            if st.closed || st.senders == 0 {
                return Ok(None);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (guard, res) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if res.timed_out() && st.items.is_empty() {
                if st.closed || st.senders == 0 {
                    return Ok(None);
                }
                return Err(());
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            drop(st);
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Drain up to `max` immediately-available items (used by the batcher to
    /// coalesce without waiting).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let n = st.items.len().min(max);
        let out: Vec<T> = st.items.drain(..n).collect();
        if !out.is_empty() {
            drop(st);
            self.inner.not_full.notify_all();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn try_send_full() {
        let (tx, _rx) = bounded(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(TrySendError::Full(3)));
    }

    #[test]
    fn close_drains_then_none() {
        let (tx, rx) = bounded(4);
        tx.send("a").unwrap();
        tx.close();
        assert_eq!(tx.try_send("b"), Err(TrySendError::Closed("b")));
        assert_eq!(rx.recv(), Some("a"));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn sender_drop_disconnects() {
        let (tx, rx) = bounded::<u32>(4);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn blocking_backpressure() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || {
            // This blocks until the receiver frees a slot.
            tx.send(1).unwrap();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        t.join().unwrap();
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(16);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..100u32 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<u32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let mut expect: Vec<u32> = (0..4)
            .flat_map(|p| (0..100).map(move |i| p * 1000 + i))
            .collect();
        expect.sort_unstable();
        assert_eq!(all, expect);
    }

    #[test]
    fn recv_timeout_times_out() {
        let (_tx, rx) = bounded::<u8>(1);
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Err(()));
    }

    #[test]
    fn drain_up_to_takes_prefix() {
        let (tx, rx) = bounded(8);
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain_up_to(4), vec![0, 1, 2, 3]);
        assert_eq!(rx.drain_up_to(4), vec![4, 5]);
        assert!(rx.drain_up_to(4).is_empty());
    }
}
