//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `parataa <subcommand> [--flag] [--key value]... [positional]...`
//! Flags may be written `--key value` or `--key=value`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    /// Parse a comma-separated list of usizes, e.g. `--ks 1,2,4,8`.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad list element '{s}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare `--flag` followed by a non-dash token would consume it
        // as a value (`--key value` form), so positionals precede flags.
        let a = parse(&["fig1", "extra", "--steps", "100", "--model=dit", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig1"));
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("model"), Some("dit"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "7", "--tau", "0.5"]);
        assert_eq!(a.usize_or("n", 1), 7);
        assert_eq!(a.usize_or("missing", 3), 3);
        assert!((a.f64_or("tau", 0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["x", "--ks", "1,2,8"]);
        assert_eq!(a.usize_list("ks", &[9]), vec![1, 2, 8]);
        assert_eq!(a.usize_list("ms", &[9]), vec![9]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["x", "--quiet"]);
        assert!(a.has_flag("quiet"));
        assert!(a.get("quiet").is_none());
    }

    #[test]
    fn negative_number_as_value() {
        // `--shift -3`: "-3" doesn't start with --, so it's the value.
        let a = parse(&["x", "--shift", "-3"]);
        assert_eq!(a.get("shift"), Some("-3"));
    }
}
