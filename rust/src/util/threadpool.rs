//! Fixed-size thread pool.
//!
//! Used by the figure/benchmark harness to fan parameter sweeps across cores
//! and by the coordinator for worker loops. `tokio` is unavailable offline;
//! the workloads here are coarse (each job is at least one full solver run or
//! device call), so a plain worker-pool over the bounded channel is ideal.

use super::channel::{bounded, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (≥1). Queue capacity is 4× the worker count, which
    /// provides backpressure for producers that enqueue faster than jobs run.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = bounded::<Job>(n * 4);
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("parataa-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Pool sized to the machine's available parallelism.
    pub fn with_available_parallelism() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n)
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(f))
            .ok();
    }

    /// Map `f` over `items` in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let f = Arc::new(f);
        let (done_tx, done_rx) = bounded::<()>(n.max(1));
        for (i, item) in items.into_iter().enumerate() {
            let results = results.clone();
            let f = f.clone();
            let done_tx = done_tx.clone();
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
                // Release our Arc clones BEFORE signaling completion so the
                // collector's try_unwrap sees a unique reference.
                drop(results);
                drop(f);
                let _ = done_tx.send(());
            });
        }
        drop(done_tx);
        for _ in 0..n {
            done_rx.recv();
        }
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("all workers done"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job completed"))
            .collect()
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            tx.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = count.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(count.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50u64).collect(), |x| x * x);
        assert_eq!(out, (0..50u64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn size_respects_minimum() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }
}
