//! Thread pools: the coarse boxed-job [`ThreadPool`] and the fine-grained
//! fork-join [`RowPool`].
//!
//! [`ThreadPool`] is used by the figure/benchmark harness to fan parameter
//! sweeps across cores and by the coordinator for worker loops. `tokio` is
//! unavailable offline; those workloads are coarse (each job is at least
//! one full solver run or device call), so a plain worker-pool over the
//! bounded channel is ideal.
//!
//! [`RowPool`] exists for the opposite regime: the solver's intra-round
//! row loops, where a "job" is microseconds of work and a boxed-closure
//! channel round trip per row would dominate. One `run()` call fans a
//! borrowed closure across persistent workers with **zero heap
//! allocations** (no boxing — the closure is lifetime-erased for the
//! blocking duration of the call), which the allocation-counting test
//! `tests/zero_alloc.rs` relies on: steady-state solver rounds must stay
//! allocation-free at every `parallelism` setting.

use super::channel::{bounded, Sender};
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (≥1). Queue capacity is 4× the worker count, which
    /// provides backpressure for producers that enqueue faster than jobs run.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = bounded::<Job>(n * 4);
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("parataa-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Pool sized to the machine's available parallelism.
    pub fn with_available_parallelism() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n)
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(f))
            .ok();
    }

    /// Map `f` over `items` in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let f = Arc::new(f);
        let (done_tx, done_rx) = bounded::<()>(n.max(1));
        for (i, item) in items.into_iter().enumerate() {
            let results = results.clone();
            let f = f.clone();
            let done_tx = done_tx.clone();
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
                // Release our Arc clones BEFORE signaling completion so the
                // collector's try_unwrap sees a unique reference.
                drop(results);
                drop(f);
                let _ = done_tx.send(());
            });
        }
        drop(done_tx);
        for _ in 0..n {
            done_rx.recv();
        }
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("all workers done"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job completed"))
            .collect()
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            tx.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// --- fork-join row pool ---------------------------------------------------

/// Lifetime-erased pointer to the current fork-join task. Only ever
/// dereferenced between `run()` publishing it and `run()` returning, and
/// `run()` blocks until every claimed index has completed, so the borrow
/// it was erased from is still live at every dereference.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared by reference across workers) and
// the pointer is only shipped to threads that outlive no borrow — see the
// lifetime argument above.
unsafe impl Send for TaskPtr {}

/// Shared fork-join state, guarded by one mutex.
struct FjState {
    /// The published task, `None` between `run()` calls.
    task: Option<TaskPtr>,
    /// Number of indices in the current run.
    n: usize,
    /// Next unclaimed index.
    next: usize,
    /// Indices finished (claimed AND executed).
    completed: usize,
    /// A task panicked; `run()` re-raises after the join.
    panicked: bool,
    /// Pool is shutting down; workers exit.
    shutdown: bool,
}

struct RowPoolInner {
    state: Mutex<FjState>,
    /// Workers wait here for a published task.
    work_cv: Condvar,
    /// `run()` waits here for stragglers.
    done_cv: Condvar,
}

/// A persistent fork-join pool for the solver's intra-round row loops.
///
/// `run(n, f)` executes `f(0), f(1), …, f(n−1)` across the pool's threads
/// **and the calling thread** (a pool built with `RowPool::new(p)` spawns
/// `p − 1` workers, so total concurrency is `p`), blocking until all
/// indices complete. Indices are claimed dynamically from a shared
/// counter, so uneven rows load-balance; callers must make concurrent
/// `f(i)` calls write to disjoint outputs (see [`SyncSlice`]).
///
/// `run()` performs no heap allocation: the closure is passed by
/// reference and lifetime-erased only for the blocking duration of the
/// call. Panics inside `f` are caught per index, the round is drained,
/// and the panic is re-raised on the calling thread.
pub struct RowPool {
    inner: Arc<RowPoolInner>,
    workers: Vec<JoinHandle<()>>,
}

impl RowPool {
    /// Pool with total concurrency `threads` (≥ 1): `threads − 1` workers
    /// plus the thread that calls [`run`](Self::run).
    pub fn new(threads: usize) -> RowPool {
        let inner = Arc::new(RowPoolInner {
            state: Mutex::new(FjState {
                task: None,
                n: 0,
                next: 0,
                completed: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads.max(1))
            .map(|i| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("parataa-row-{i}"))
                    .spawn(move || row_worker(&inner))
                    .expect("spawn row worker")
            })
            .collect();
        RowPool { inner, workers }
    }

    /// Total concurrency (workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Execute `f(i)` for every `i < n` across the pool, blocking until
    /// all complete. Not reentrant. No-op when `n == 0`.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        // SAFETY: the erased 'static lifetime never outlives `f` — this
        // call publishes the pointer, blocks until `completed == n`, and
        // unpublishes it before returning, so no worker can hold it after
        // the borrow ends.
        let task: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        {
            let mut st = self.inner.state.lock().unwrap();
            debug_assert!(st.task.is_none(), "RowPool::run is not reentrant");
            st.task = Some(TaskPtr(task as *const _));
            st.n = n;
            st.next = 0;
            st.completed = 0;
            st.panicked = false;
            self.inner.work_cv.notify_all();
        }
        // The caller participates: claim and execute until indices run out.
        loop {
            let i = {
                let mut st = self.inner.state.lock().unwrap();
                if st.next >= st.n {
                    break;
                }
                let i = st.next;
                st.next += 1;
                i
            };
            let ok = catch_unwind(AssertUnwindSafe(|| task(i))).is_ok();
            let mut st = self.inner.state.lock().unwrap();
            if !ok {
                st.panicked = true;
            }
            st.completed += 1;
            if st.completed == st.n {
                self.inner.done_cv.notify_all();
            }
        }
        // Wait for workers still executing claimed indices.
        let mut st = self.inner.state.lock().unwrap();
        while st.completed < st.n {
            st = self.inner.done_cv.wait(st).unwrap();
        }
        st.task = None;
        let panicked = st.panicked;
        drop(st);
        if panicked {
            panic!("RowPool task panicked");
        }
    }
}

impl Drop for RowPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl std::fmt::Debug for RowPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RowPool").field("threads", &self.threads()).finish()
    }
}

fn row_worker(inner: &RowPoolInner) {
    let mut st = inner.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        if let Some(TaskPtr(ptr)) = st.task {
            if st.next < st.n {
                let i = st.next;
                st.next += 1;
                drop(st);
                // SAFETY: `run()` keeps the pointee alive until
                // `completed == n`, and this index counts toward that.
                let task: &(dyn Fn(usize) + Sync) = unsafe { &*ptr };
                let ok = catch_unwind(AssertUnwindSafe(|| task(i))).is_ok();
                st = inner.state.lock().unwrap();
                if !ok {
                    st.panicked = true;
                }
                st.completed += 1;
                if st.completed == st.n {
                    inner.done_cv.notify_all();
                }
                continue;
            }
        }
        st = inner.work_cv.wait(st).unwrap();
    }
}

/// Balanced contiguous partition of `rows` items into `chunks` ranges:
/// the half-open row range `[start, end)` of chunk `c`. The first
/// `rows % chunks` chunks get one extra row; empty chunks are legal.
pub fn chunk_range(rows: usize, chunks: usize, c: usize) -> (usize, usize) {
    debug_assert!(c < chunks.max(1));
    let chunks = chunks.max(1);
    let base = rows / chunks;
    let rem = rows % chunks;
    let start = c * base + c.min(rem);
    let end = start + base + usize::from(c < rem);
    (start, end)
}

/// A shared view over a mutable slice for fork-join row loops, where each
/// task writes a *disjoint* sub-range. Rust's aliasing rules can't express
/// "disjoint writes decided at runtime", so the disjointness proof moves
/// to the caller via the unsafe accessor.
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _pd: PhantomData<&'a mut [T]>,
}

// SAFETY: access is only through `slice_mut`, whose contract requires
// concurrent callers to take disjoint ranges; `T: Send` suffices because
// each element is only ever touched by one thread at a time.
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}
unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    /// Wrap a mutable slice for the duration of a fork-join round.
    pub fn new(s: &'a mut [T]) -> SyncSlice<'a, T> {
        SyncSlice { ptr: s.as_mut_ptr(), len: s.len(), _pd: PhantomData }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the underlying slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The mutable sub-slice `[start, start + len)`.
    ///
    /// # Safety
    /// Concurrent callers must request **disjoint** ranges; the range must
    /// lie inside the wrapped slice (debug-asserted).
    #[allow(clippy::mut_from_ref)] // the whole point: caller-proved disjoint writes
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start.checked_add(len).is_some_and(|e| e <= self.len));
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

impl<T> std::fmt::Debug for SyncSlice<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SyncSlice").field("len", &self.len).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = count.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(count.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50u64).collect(), |x| x * x);
        assert_eq!(out, (0..50u64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn size_respects_minimum() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }

    #[test]
    fn row_pool_covers_every_index_exactly_once() {
        for threads in [1usize, 2, 4, 8] {
            let pool = RowPool::new(threads);
            assert_eq!(pool.threads(), threads.max(1));
            let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "index {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn row_pool_is_reusable_across_runs() {
        let pool = RowPool::new(3);
        let total = AtomicUsize::new(0);
        for round in 0..50 {
            pool.run(round % 7, &|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        let expect: usize = (0..50).map(|r| r % 7).sum();
        assert_eq!(total.load(Ordering::SeqCst), expect);
    }

    #[test]
    fn row_pool_zero_rows_is_noop() {
        let pool = RowPool::new(4);
        pool.run(0, &|_| panic!("must not be called"));
    }

    #[test]
    fn row_pool_propagates_task_panic() {
        let pool = RowPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|i| {
                if i == 9 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must surface on the calling thread");
        // The pool must stay usable after a panicked round.
        let count = AtomicUsize::new(0);
        pool.run(8, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn chunk_range_partitions_exactly() {
        for rows in [0usize, 1, 7, 100, 101] {
            for chunks in [1usize, 2, 4, 8] {
                let mut covered = 0;
                let mut prev_end = 0;
                for c in 0..chunks {
                    let (s, e) = chunk_range(rows, chunks, c);
                    assert_eq!(s, prev_end, "chunks must be contiguous");
                    assert!(e >= s && e <= rows);
                    covered += e - s;
                    prev_end = e;
                }
                assert_eq!(covered, rows, "rows={rows} chunks={chunks}");
                assert_eq!(prev_end, rows);
            }
        }
    }

    #[test]
    fn sync_slice_disjoint_writes_land() {
        let mut data = vec![0u32; 64];
        {
            let view = SyncSlice::new(&mut data);
            let pool = RowPool::new(4);
            pool.run(8, &|c| {
                let (s, e) = chunk_range(view.len(), 8, c);
                // SAFETY: chunk_range partitions disjointly.
                let part = unsafe { view.slice_mut(s, e - s) };
                for (k, v) in part.iter_mut().enumerate() {
                    *v = (s + k) as u32;
                }
            });
        }
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }
}
