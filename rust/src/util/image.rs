//! PGM image output for qualitative figures (Fig. 5/13 analogs).
//!
//! Samples live in [-1, 1] pixel space (16×16 grayscale); PGM (P2, ASCII)
//! needs no external codecs and renders everywhere.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// Write a [-1,1]-scaled grayscale image (row-major, h*w values) as ASCII PGM.
pub fn write_pgm<P: AsRef<Path>>(path: P, pixels: &[f32], w: usize, h: usize) -> std::io::Result<()> {
    assert_eq!(pixels.len(), w * h, "pixel count must equal w*h");
    if let Some(parent) = path.as_ref().parent() {
        fs::create_dir_all(parent)?;
    }
    let mut f = fs::File::create(path)?;
    writeln!(f, "P2\n{w} {h}\n255")?;
    for row in pixels.chunks(w) {
        let line: Vec<String> = row
            .iter()
            .map(|&p| {
                let v = ((p.clamp(-1.0, 1.0) + 1.0) * 127.5).round() as u8;
                v.to_string()
            })
            .collect();
        writeln!(f, "{}", line.join(" "))?;
    }
    Ok(())
}

/// Tile a sequence of equally-sized images horizontally into one strip
/// (the paper's "iterations of parallel sampling" rows).
pub fn hstack(images: &[Vec<f32>], w: usize, h: usize, pad: usize) -> (Vec<f32>, usize, usize) {
    let n = images.len();
    assert!(n > 0);
    let out_w = n * w + (n - 1) * pad;
    let mut out = vec![1.0f32; out_w * h]; // white padding
    for (idx, img) in images.iter().enumerate() {
        assert_eq!(img.len(), w * h);
        let x0 = idx * (w + pad);
        for r in 0..h {
            out[r * out_w + x0..r * out_w + x0 + w].copy_from_slice(&img[r * w..(r + 1) * w]);
        }
    }
    (out, out_w, h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgm_roundtrip_header() {
        let dir = std::env::temp_dir().join("parataa_img_test");
        let p = dir.join("t.pgm");
        write_pgm(&p, &vec![0.0; 4], 2, 2).unwrap();
        let text = fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("P2\n2 2\n255\n"));
        // 0.0 maps to mid-gray 128.
        assert!(text.contains("128 128"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn clamping() {
        let dir = std::env::temp_dir().join("parataa_img_test2");
        let p = dir.join("t.pgm");
        write_pgm(&p, &[-5.0, 5.0], 2, 1).unwrap();
        let text = fs::read_to_string(&p).unwrap();
        assert!(text.trim().ends_with("0 255"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn hstack_dims() {
        let a = vec![0.0f32; 4];
        let b = vec![0.5f32; 4];
        let (out, w, h) = hstack(&[a, b], 2, 2, 1);
        assert_eq!((w, h), (5, 2));
        assert_eq!(out.len(), 10);
        // padding column is white
        assert_eq!(out[2], 1.0);
    }
}
