//! Timing and summary statistics: the bench-harness substrate (criterion is
//! unavailable offline) plus latency histograms for the coordinator.

use std::time::{Duration, Instant};

/// Online summary statistics over f64 samples (Welford).
#[derive(Debug, Default, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample buffer (exact, by sorting a copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p.clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
    v[rank]
}

/// Result of a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>10} iters  mean {:>12?}  std {:>10?}  min {:>12?}  max {:>12?}",
            self.name, self.iters, self.mean, self.std, self.min, self.max
        )
    }
}

/// Criterion-lite: warm up, then time `f` for enough iterations to cover
/// `measure` wall-clock, reporting per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    // Warm-up phase (JIT-free in rust, but fills caches and the PJRT pools).
    let start = Instant::now();
    let mut warm_iters = 0u64;
    while start.elapsed() < warmup || warm_iters == 0 {
        f();
        warm_iters += 1;
    }
    // Measurement phase.
    let mut s = Summary::new();
    let phase = Instant::now();
    while phase.elapsed() < measure || s.count() == 0 {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: s.count(),
        mean: Duration::from_secs_f64(s.mean()),
        std: Duration::from_secs_f64(s.std()),
        min: Duration::from_secs_f64(s.min()),
        max: Duration::from_secs_f64(s.max()),
    }
}

/// Quick single-shot timer.
pub fn time_it<R, F: FnOnce() -> R>(f: F) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 0.5), 50.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn bench_runs() {
        let r = bench(
            "noop",
            Duration::from_millis(1),
            Duration::from_millis(5),
            || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(r.iters > 0);
        assert!(r.mean <= r.max);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, d) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
