//! Summary statistics: Welford moments and exact percentiles — the
//! substrate under the coordinator's latency accounting and the
//! `bench::harness` timing loops (criterion is unavailable offline).

/// Online summary statistics over f64 samples (Welford).
#[derive(Debug, Default, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// An empty accumulator.
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in (Welford single-pass update).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples folded in.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Unbiased sample variance (0 with fewer than two samples).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Smallest sample seen (+inf when empty).
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest sample seen (-inf when empty).
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample buffer (exact, by sorting a copy). Callers
/// taking several percentiles of one buffer should sort once and use
/// [`percentile_sorted`].
///
/// Sentinel behaviour (shared with [`percentile_sorted`]): an empty buffer
/// or a non-finite `p` returns `NaN` — "no answer", never a panic. The
/// JSON writer serializes that as `null` and the Prometheus renderer omits
/// the sample, so the sentinel is safe to propagate. NaN *samples* are
/// ordered by IEEE total order (`f64::total_cmp`), i.e. above +inf — they
/// distort nothing below the rank they occupy.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v = samples.to_vec();
    // total_cmp, not partial_cmp().unwrap(): one NaN sample (e.g. a 0/0
    // upstream) must not panic the metrics path.
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile over an already ascending-sorted buffer. Exact (no
/// interpolation): the sample at rank `round(p · (n−1))`, so `n = 1`
/// returns the lone sample for every `p` and `p` outside [0, 1] clamps to
/// the extremes. Empty buffer or non-finite `p` ⇒ `NaN` (see
/// [`percentile`] for why the sentinel, not a panic).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() || !p.is_finite() {
        return f64::NAN;
    }
    let rank = (p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 0.5), 50.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert!(percentile(&[], 0.5).is_nan());
        assert_eq!(percentile_sorted(&v, 0.95), 95.0);
        assert!(percentile_sorted(&[], 0.5).is_nan());
    }

    #[test]
    fn percentile_edge_cases_return_sentinels_not_panics() {
        // n = 0: NaN for every p, both helpers.
        for p in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert!(percentile(&[], p).is_nan());
            assert!(percentile_sorted(&[], p).is_nan());
        }
        // n = 1: the lone sample for every p, including out-of-range p.
        for p in [0.0, 0.5, 0.95, 0.99, 1.0, -3.0, 7.0] {
            assert_eq!(percentile(&[42.0], p), 42.0);
            assert_eq!(percentile_sorted(&[42.0], p), 42.0);
        }
        // p outside [0, 1] clamps to the extremes.
        let v = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&v, -1.0), 1.0);
        assert_eq!(percentile(&v, 2.0), 3.0);
        // Non-finite p: NaN sentinel, not an arbitrary rank.
        assert!(percentile(&v, f64::NAN).is_nan());
        assert!(percentile(&v, f64::INFINITY).is_nan());
        assert!(percentile_sorted(&v, f64::NEG_INFINITY).is_nan());
    }

    #[test]
    fn nan_samples_do_not_panic_and_sort_above_inf() {
        // Regression: partial_cmp().unwrap() panicked here.
        let v = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        // total order: [1, 2, 3, NaN] — rank round(0.5·3) = 2 → 3.0.
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert!(percentile(&v, 1.0).is_nan(), "NaN sorts last under total order");
    }
}
