//! Minimal error handling (`anyhow` is unavailable offline, like the rest of
//! the registry — see `util/channel.rs`): a string-backed [`Error`] plus the
//! `anyhow!` / `ensure!` / `bail!` / [`Context`] surface the crate builds on.
//!
//! Since the fault-tolerance layer, errors also carry an [`ErrorKind`] so
//! retry policy and metrics classify failures structurally instead of
//! string-matching: the device pool marks transient device failures
//! [`ErrorKind::Retryable`], the coordinator marks expired requests
//! [`ErrorKind::DeadlineExceeded`] and load-shed requests [`ErrorKind::Shed`],
//! and everything else stays the historical [`ErrorKind::Terminal`].
//! Context chaining ([`Error::context`]) preserves the kind.

use std::fmt;

/// Failure classification carried by every [`Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ErrorKind {
    /// A transient failure: retrying the same work (possibly elsewhere) may
    /// succeed. The device pool's retry policy only re-dispatches these.
    Retryable,
    /// A permanent failure (the historical default): retrying cannot help.
    #[default]
    Terminal,
    /// The request's deadline expired before (or while) it was served.
    DeadlineExceeded,
    /// The request was rejected by load-shedding admission control.
    Shed,
    /// The client abandoned the request (e.g. a dropped SSE connection):
    /// the coordinator cancelled the session at the next round boundary.
    Cancelled,
}

impl ErrorKind {
    /// Stable lowercase label (used in metrics and log lines).
    pub fn label(self) -> &'static str {
        match self {
            ErrorKind::Retryable => "retryable",
            ErrorKind::Terminal => "terminal",
            ErrorKind::DeadlineExceeded => "deadline_exceeded",
            ErrorKind::Shed => "shed",
            ErrorKind::Cancelled => "cancelled",
        }
    }
}

/// String-backed error with accumulated context prefixes and a failure
/// classification ([`ErrorKind`]).
#[derive(Clone)]
pub struct Error {
    msg: String,
    kind: ErrorKind,
}

impl Error {
    /// Build a [`ErrorKind::Terminal`] error from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), kind: ErrorKind::Terminal }
    }

    /// Build an error with an explicit classification.
    pub fn with_kind<M: fmt::Display>(kind: ErrorKind, msg: M) -> Error {
        Error { msg: msg.to_string(), kind }
    }

    /// A [`ErrorKind::Retryable`] error (transient device failure).
    pub fn retryable<M: fmt::Display>(msg: M) -> Error {
        Error::with_kind(ErrorKind::Retryable, msg)
    }

    /// A [`ErrorKind::DeadlineExceeded`] error.
    pub fn deadline<M: fmt::Display>(msg: M) -> Error {
        Error::with_kind(ErrorKind::DeadlineExceeded, msg)
    }

    /// A [`ErrorKind::Shed`] error (rejected by admission control).
    pub fn shed<M: fmt::Display>(msg: M) -> Error {
        Error::with_kind(ErrorKind::Shed, msg)
    }

    /// A [`ErrorKind::Cancelled`] error (abandoned by the client).
    pub fn cancelled<M: fmt::Display>(msg: M) -> Error {
        Error::with_kind(ErrorKind::Cancelled, msg)
    }

    /// The failure classification.
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Reclassify the error, keeping the message and context chain.
    pub fn into_kind(mut self, kind: ErrorKind) -> Error {
        self.kind = kind;
        self
    }

    /// Prefix additional context, mirroring `anyhow::Error::context`.
    /// The [`ErrorKind`] is preserved through the chain.
    pub fn context<M: fmt::Display>(self, ctx: M) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg), kind: self.kind }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result type (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible result, mirroring `anyhow::Context`.
///
/// The blanket impl over any displayable error necessarily produces a
/// [`ErrorKind::Terminal`] error (a foreign error carries no kind); to chain
/// context on a crate [`Error`] *without* losing its kind, use the inherent
/// [`Error::context`] via `map_err(|e| e.context(...))`.
pub trait Context<T> {
    /// Wrap the error with a `msg:` prefix.
    fn context<M: fmt::Display>(self, msg: M) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<M: fmt::Display>(self, msg: M) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }
}

/// Format an [`Error`] from format-string arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_when(cond: bool) -> Result<u32> {
        ensure!(!cond, "condition was {cond}");
        Ok(7)
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad {}", 42);
        assert_eq!(e.to_string(), "bad 42");
        assert_eq!(e.kind(), ErrorKind::Terminal, "macro errors are terminal");
        assert_eq!(fails_when(false).unwrap(), 7);
        assert_eq!(fails_when(true).unwrap_err().to_string(), "condition was true");
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("formatting header").unwrap_err();
        assert!(e.to_string().starts_with("formatting header: "));
        let e2 = e.context("outer");
        assert!(e2.to_string().starts_with("outer: formatting header"));
    }

    #[test]
    fn io_error_converts() {
        fn io() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        assert!(io().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn kinds_classify_and_survive_context() {
        assert_eq!(Error::msg("x").kind(), ErrorKind::Terminal);
        assert_eq!(Error::retryable("x").kind(), ErrorKind::Retryable);
        assert_eq!(Error::deadline("x").kind(), ErrorKind::DeadlineExceeded);
        assert_eq!(Error::shed("x").kind(), ErrorKind::Shed);
        assert_eq!(Error::cancelled("x").kind(), ErrorKind::Cancelled);

        // Inherent context chaining preserves the kind…
        let e = Error::retryable("device 1 errored").context("shard 3");
        assert_eq!(e.kind(), ErrorKind::Retryable);
        assert_eq!(e.to_string(), "shard 3: device 1 errored");

        // …and reclassification keeps the message chain.
        let t = e.into_kind(ErrorKind::Terminal).context("retries exhausted");
        assert_eq!(t.kind(), ErrorKind::Terminal);
        assert!(t.to_string().starts_with("retries exhausted: shard 3"));
    }

    #[test]
    fn kind_labels_are_stable() {
        assert_eq!(ErrorKind::Retryable.label(), "retryable");
        assert_eq!(ErrorKind::Terminal.label(), "terminal");
        assert_eq!(ErrorKind::DeadlineExceeded.label(), "deadline_exceeded");
        assert_eq!(ErrorKind::Shed.label(), "shed");
        assert_eq!(ErrorKind::Cancelled.label(), "cancelled");
    }

    #[test]
    fn errors_clone() {
        let e = Error::shed("queue full").context("admit");
        let c = e.clone();
        assert_eq!(c.kind(), ErrorKind::Shed);
        assert_eq!(c.to_string(), e.to_string());
    }
}
