//! Minimal error handling (`anyhow` is unavailable offline, like the rest of
//! the registry — see `util/channel.rs`): a string-backed [`Error`] plus the
//! `anyhow!` / `ensure!` / `bail!` / [`Context`] surface the crate builds on.
//!
//! The subset is intentionally tiny — errors here are terminal diagnostics
//! (a missing artifact, a dead actor), not values programs branch on.

use std::fmt;

/// String-backed error with accumulated context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }

    /// Prefix additional context, mirroring `anyhow::Error::context`.
    pub fn context<M: fmt::Display>(self, ctx: M) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result type (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible result, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a `msg:` prefix.
    fn context<M: fmt::Display>(self, msg: M) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<M: fmt::Display>(self, msg: M) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }
}

/// Format an [`Error`] from format-string arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_when(cond: bool) -> Result<u32> {
        ensure!(!cond, "condition was {cond}");
        Ok(7)
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad {}", 42);
        assert_eq!(e.to_string(), "bad 42");
        assert_eq!(fails_when(false).unwrap(), 7);
        assert_eq!(fails_when(true).unwrap_err().to_string(), "condition was true");
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("formatting header").unwrap_err();
        assert!(e.to_string().starts_with("formatting header: "));
        let e2 = e.context("outer");
        assert!(e2.to_string().starts_with("outer: formatting header"));
    }

    #[test]
    fn io_error_converts() {
        fn io() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        assert!(io().unwrap_err().to_string().contains("gone"));
    }
}
