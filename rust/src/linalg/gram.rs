//! Suffix-Gram scan — the core linear algebra of Triangular Anderson
//! Acceleration (Theorem 3.2).
//!
//! For window rows `t = 0..W` (row 0 = earliest timestep in the active
//! window) and Anderson history depth `m`, TAA needs, for every `t`,
//!
//!   G_t = Σ_{j ≥ t} ΔF_jᵀ ΔF_j   ∈ R^{m×m}     (Gram of history residuals)
//!   b_t = Σ_{j ≥ t} ΔF_jᵀ R_j    ∈ R^{m}       (projection of the residual)
//!
//! where ΔF_j stacks the `m` history residual-differences restricted to row
//! `j` (each of dimension D). Because the sums are *suffixes* over j, all W
//! of them are computed in one reverse scan: per-row Grams first (O(W·m²·D)),
//! then a reverse cumulative sum (O(W·m²)). This mirrors the Pallas kernel
//! `python/compile/kernels/taa_update.py`, and the cross-language test
//! vectors pin the two implementations together.

/// Per-row suffix Grams and projections.
pub struct SuffixGrams {
    /// `grams[t]` is the m×m matrix G_t (row-major), length W.
    pub grams: Vec<Vec<f32>>,
    /// `proj[t]` is the m-vector b_t, length W.
    pub proj: Vec<Vec<f32>>,
}

/// Compute suffix Grams.
///
/// Layout: `delta_f[h]` is history slot `h` (h = 0..m), a `[W*D]` row-major
/// window; `residual` is `[W*D]`. Only rows `t0..W` participate (rows below
/// the active window are skipped by callers passing `t0`).
pub fn suffix_grams(
    delta_f: &[&[f32]],
    residual: &[f32],
    w: usize,
    d: usize,
    t0: usize,
) -> SuffixGrams {
    let m = delta_f.len();
    for h in delta_f {
        assert_eq!(h.len(), w * d, "history slot shape");
    }
    assert_eq!(residual.len(), w * d, "residual shape");
    assert!(t0 <= w);

    let mut grams = vec![vec![0.0f32; m * m]; w];
    let mut proj = vec![vec![0.0f32; m]; w];

    // Accumulators carried down the reverse scan, in f64: the suffix sums
    // telescope over up to W=100 rows and the Gram conditioning matters.
    let mut acc_g = vec![0.0f64; m * m];
    let mut acc_b = vec![0.0f64; m];

    for t in (t0..w).rev() {
        let row = t * d..(t + 1) * d;
        // Per-row Gram contribution (symmetric — compute upper, mirror).
        for a in 0..m {
            let fa = &delta_f[a][row.clone()];
            for b in a..m {
                let fb = &delta_f[b][row.clone()];
                let mut s = 0.0f64;
                for (x, y) in fa.iter().zip(fb.iter()) {
                    s += (*x as f64) * (*y as f64);
                }
                acc_g[a * m + b] += s;
                if a != b {
                    acc_g[b * m + a] += s;
                }
            }
            let r = &residual[row.clone()];
            let mut s = 0.0f64;
            for (x, y) in fa.iter().zip(r.iter()) {
                s += (*x as f64) * (*y as f64);
            }
            acc_b[a] += s;
        }
        for (o, &v) in grams[t].iter_mut().zip(acc_g.iter()) {
            *o = v as f32;
        }
        for (o, &v) in proj[t].iter_mut().zip(acc_b.iter()) {
            *o = v as f32;
        }
    }

    SuffixGrams { grams, proj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::{self, forall, size_in};

    /// Naive reference: recompute each suffix sum from scratch.
    fn naive(delta_f: &[&[f32]], residual: &[f32], w: usize, d: usize, t0: usize) -> SuffixGrams {
        let m = delta_f.len();
        let mut grams = vec![vec![0.0f32; m * m]; w];
        let mut proj = vec![vec![0.0f32; m]; w];
        for t in t0..w {
            for a in 0..m {
                for b in 0..m {
                    let mut s = 0.0f64;
                    for j in t..w {
                        for i in 0..d {
                            s += delta_f[a][j * d + i] as f64 * delta_f[b][j * d + i] as f64;
                        }
                    }
                    grams[t][a * m + b] = s as f32;
                }
                let mut s = 0.0f64;
                for j in t..w {
                    for i in 0..d {
                        s += delta_f[a][j * d + i] as f64 * residual[j * d + i] as f64;
                    }
                }
                proj[t][a] = s as f32;
            }
        }
        SuffixGrams { grams, proj }
    }

    #[test]
    fn matches_naive_reference() {
        forall("suffix_gram_naive", 24, |rng, _| {
            let w = size_in(rng, 1, 12);
            let d = size_in(rng, 1, 9);
            let m = size_in(rng, 1, 4);
            let t0 = size_in(rng, 0, w - 1);
            let slots: Vec<Vec<f32>> = (0..m)
                .map(|_| (0..w * d).map(|_| rng.next_f32() - 0.5).collect())
                .collect();
            let refs: Vec<&[f32]> = slots.iter().map(|s| s.as_slice()).collect();
            let res: Vec<f32> = (0..w * d).map(|_| rng.next_f32() - 0.5).collect();
            let fast = suffix_grams(&refs, &res, w, d, t0);
            let slow = naive(&refs, &res, w, d, t0);
            for t in t0..w {
                proplite::assert_close(&fast.grams[t], &slow.grams[t], 1e-4, 1e-4, "gram")?;
                proplite::assert_close(&fast.proj[t], &slow.proj[t], 1e-4, 1e-4, "proj")?;
            }
            Ok(())
        });
    }

    #[test]
    fn suffix_monotone_diagonal() {
        // Gram diagonals are sums of squares, so suffix sums must be
        // non-increasing in t.
        let mut rng = crate::util::rng::Pcg64::seeded(11);
        let (w, d) = (10, 4);
        let slot: Vec<f32> = (0..w * d).map(|_| rng.next_f32()).collect();
        let res = vec![0.0f32; w * d];
        let g = suffix_grams(&[&slot], &res, w, d, 0);
        for t in 1..w {
            assert!(g.grams[t][0] <= g.grams[t - 1][0] + 1e-6);
        }
    }

    #[test]
    fn last_row_is_single_gram() {
        let (w, d) = (3, 2);
        let slot = vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0];
        let res = vec![1.0; w * d];
        let g = suffix_grams(&[&slot], &res, w, d, 0);
        // row 2 suffix = just row 2: [3,4] -> gram 25, proj 7
        assert!((g.grams[2][0] - 25.0).abs() < 1e-6);
        assert!((g.proj[2][0] - 7.0).abs() < 1e-6);
        // row 0 suffix = all rows: 1+4+0+0+9+16 = 30
        assert!((g.grams[0][0] - 30.0).abs() < 1e-6);
    }
}
