//! Suffix-Gram scan — the core linear algebra of Triangular Anderson
//! Acceleration (Theorem 3.2).
//!
//! For window rows `t = 0..W` (row 0 = earliest timestep in the active
//! window) and Anderson history depth `m`, TAA needs, for every `t`,
//!
//!   G_t = Σ_{j ≥ t} ΔF_jᵀ ΔF_j   ∈ R^{m×m}     (Gram of history residuals)
//!   b_t = Σ_{j ≥ t} ΔF_jᵀ R_j    ∈ R^{m}       (projection of the residual)
//!
//! where ΔF_j stacks the `m` history residual-differences restricted to row
//! `j` (each of dimension D). Because the sums are *suffixes* over j, all W
//! of them are computed in one reverse scan: per-row Grams first (O(W·m²·D)),
//! then a reverse cumulative sum (O(W·m²)). This mirrors the Pallas kernel
//! `python/compile/kernels/taa_update.py`, and the cross-language test
//! vectors pin the two implementations together.
//!
//! Storage is one flat `[W, m×m]` / `[W, m]` buffer pair with stride views
//! ([`SuffixGrams::gram`]/[`SuffixGrams::proj`]), and the write-into entry
//! point [`suffix_grams_into`] reuses a caller-owned [`SuffixGrams`] so the
//! per-round scan performs **zero heap allocations** at steady state. The
//! per-row Gram contributions themselves are cached incrementally by
//! `solver::history::History` (one ring push refreshes only the entries
//! involving the overwritten slot); `History::suffix_grams_into` feeds that
//! cache through the same accumulation path, and a bitwise property test
//! pins the two against each other.

use super::kernels::{multi_dot8, LANES};

/// History slots batched per `multi_dot8` call (m ≤ 8 everywhere in
/// practice, so one batch usually covers a whole anchor's group).
const BATCH: usize = 8;

/// Per-row suffix Grams and projections in flat storage.
///
/// `gram(t)` is the row-major m×m matrix G_t, `proj(t)` the m-vector b_t.
/// The struct doubles as the reverse-scan workspace: the f64 suffix
/// accumulators live here so refilling an existing instance allocates
/// nothing once capacity has been reached.
#[derive(Debug, Clone, Default)]
pub struct SuffixGrams {
    w: usize,
    m: usize,
    /// Flat `[w, m*m]` Gram storage.
    grams: Vec<f32>,
    /// Flat `[w, m]` projection storage.
    proj: Vec<f32>,
    /// f64 suffix accumulator for the Gram entries (`m*m`).
    acc_g: Vec<f64>,
    /// f64 suffix accumulator for the projections (`m`).
    acc_b: Vec<f64>,
}

impl SuffixGrams {
    /// An empty workspace; sized lazily by [`reset`](Self::reset).
    pub fn new() -> SuffixGrams {
        SuffixGrams::default()
    }

    /// Re-shape for a `[w, m]` scan and zero all storage and accumulators.
    /// Allocates only when the required capacity grows.
    pub fn reset(&mut self, w: usize, m: usize) {
        self.w = w;
        self.m = m;
        self.grams.clear();
        self.grams.resize(w * m * m, 0.0);
        self.proj.clear();
        self.proj.resize(w * m, 0.0);
        self.acc_g.clear();
        self.acc_g.resize(m * m, 0.0);
        self.acc_b.clear();
        self.acc_b.resize(m, 0.0);
    }

    /// The m×m suffix Gram G_t (row-major view into the flat buffer).
    #[inline]
    pub fn gram(&self, t: usize) -> &[f32] {
        &self.grams[t * self.m * self.m..(t + 1) * self.m * self.m]
    }

    /// The m-vector suffix projection b_t.
    #[inline]
    pub fn proj(&self, t: usize) -> &[f32] {
        &self.proj[t * self.m..(t + 1) * self.m]
    }

    /// Fold one per-row Gram contribution `s = ΔF_aᵀΔF_b` (row-restricted)
    /// into the running suffix accumulator, mirroring across the diagonal.
    #[inline]
    pub fn accumulate_gram(&mut self, a: usize, b: usize, s: f64) {
        self.acc_g[a * self.m + b] += s;
        if a != b {
            self.acc_g[b * self.m + a] += s;
        }
    }

    /// Fold one per-row projection contribution `s = ΔF_aᵀR` into the
    /// running suffix accumulator.
    #[inline]
    pub fn accumulate_proj(&mut self, a: usize, s: f64) {
        self.acc_b[a] += s;
    }

    /// Snapshot the current accumulators as row `t`'s G_t / b_t (the
    /// reverse scan calls this once per row, from `w−1` down to `t0`).
    #[inline]
    pub fn commit_row(&mut self, t: usize) {
        let mm = self.m * self.m;
        for (o, &v) in self.grams[t * mm..(t + 1) * mm].iter_mut().zip(self.acc_g.iter()) {
            *o = v as f32;
        }
        for (o, &v) in
            self.proj[t * self.m..(t + 1) * self.m].iter_mut().zip(self.acc_b.iter())
        {
            *o = v as f32;
        }
    }
}

/// Compute suffix Grams into a reusable workspace (zero allocations once
/// `out` has reached capacity).
///
/// Layout: `delta_f[h]` is history slot `h` (h = 0..m), a `[W*D]` row-major
/// window; `residual` is `[W*D]`. Only rows `t0..W` participate (rows below
/// the active window are skipped by callers passing `t0`); rows `< t0` of
/// `out` are zeroed.
pub fn suffix_grams_into(
    out: &mut SuffixGrams,
    delta_f: &[&[f32]],
    residual: &[f32],
    w: usize,
    d: usize,
    t0: usize,
) {
    let m = delta_f.len();
    for h in delta_f {
        assert_eq!(h.len(), w * d, "history slot shape");
    }
    assert_eq!(residual.len(), w * d, "residual shape");
    assert!(t0 <= w);

    out.reset(w, m);
    // Accumulators carried down the reverse scan, in f64: the suffix sums
    // telescope over up to W=100 rows and the Gram conditioning matters.
    //
    // Per-row contributions are batched: for each anchor slot `a`, one
    // tiled `multi_dot8` pass computes ΔF_aᵀΔF_b for every b ≥ a *and*
    // ΔF_aᵀR — the anchor row streams through L1 once per group instead
    // of once per pair. Bitwise identical to per-pair `dot8` by the
    // kernel reduction-order contract. Symmetric Gram: compute upper,
    // `accumulate_gram` mirrors.
    for t in (t0..w).rev() {
        let row = t * d..(t + 1) * d;
        for a in 0..m {
            let fa = &delta_f[a][row.clone()];
            // Products anchored at `a`: slots a..m, then the residual row.
            let k = m - a + 1;
            let mut j0 = 0;
            while j0 < k {
                let take = (k - j0).min(BATCH);
                let mut slots: [&[f32]; BATCH] = [&[]; BATCH];
                for (i, s) in slots.iter_mut().enumerate().take(take) {
                    let j = j0 + i;
                    *s = if a + j < m {
                        &delta_f[a + j][row.clone()]
                    } else {
                        &residual[row.clone()]
                    };
                }
                let mut acc = [0.0f64; BATCH * LANES];
                let mut vals = [0.0f64; BATCH];
                multi_dot8(fa, &slots[..take], &mut acc, &mut vals);
                for (i, &v) in vals.iter().enumerate().take(take) {
                    let j = j0 + i;
                    if a + j < m {
                        out.accumulate_gram(a, a + j, v);
                    } else {
                        out.accumulate_proj(a, v);
                    }
                }
                j0 += take;
            }
        }
        out.commit_row(t);
    }
}

/// Allocating convenience wrapper over [`suffix_grams_into`].
pub fn suffix_grams(
    delta_f: &[&[f32]],
    residual: &[f32],
    w: usize,
    d: usize,
    t0: usize,
) -> SuffixGrams {
    let mut out = SuffixGrams::new();
    suffix_grams_into(&mut out, delta_f, residual, w, d, t0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::{self, forall, size_in};

    /// Naive reference: recompute each suffix sum from scratch.
    fn naive(
        delta_f: &[&[f32]],
        residual: &[f32],
        w: usize,
        d: usize,
        t0: usize,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let m = delta_f.len();
        let mut grams = vec![vec![0.0f32; m * m]; w];
        let mut proj = vec![vec![0.0f32; m]; w];
        for t in t0..w {
            for a in 0..m {
                for b in 0..m {
                    let mut s = 0.0f64;
                    for j in t..w {
                        for i in 0..d {
                            s += delta_f[a][j * d + i] as f64 * delta_f[b][j * d + i] as f64;
                        }
                    }
                    grams[t][a * m + b] = s as f32;
                }
                let mut s = 0.0f64;
                for j in t..w {
                    for i in 0..d {
                        s += delta_f[a][j * d + i] as f64 * residual[j * d + i] as f64;
                    }
                }
                proj[t][a] = s as f32;
            }
        }
        (grams, proj)
    }

    #[test]
    fn matches_naive_reference() {
        forall("suffix_gram_naive", 24, |rng, _| {
            let w = size_in(rng, 1, 12);
            let d = size_in(rng, 1, 9);
            let m = size_in(rng, 1, 4);
            let t0 = size_in(rng, 0, w - 1);
            let slots: Vec<Vec<f32>> = (0..m)
                .map(|_| (0..w * d).map(|_| rng.next_f32() - 0.5).collect())
                .collect();
            let refs: Vec<&[f32]> = slots.iter().map(|s| s.as_slice()).collect();
            let res: Vec<f32> = (0..w * d).map(|_| rng.next_f32() - 0.5).collect();
            let fast = suffix_grams(&refs, &res, w, d, t0);
            let (slow_g, slow_b) = naive(&refs, &res, w, d, t0);
            for t in t0..w {
                proplite::assert_close(fast.gram(t), &slow_g[t], 1e-4, 1e-4, "gram")?;
                proplite::assert_close(fast.proj(t), &slow_b[t], 1e-4, 1e-4, "proj")?;
            }
            Ok(())
        });
    }

    #[test]
    fn reuse_across_shapes_matches_fresh() {
        // One workspace refilled at several shapes must match a fresh
        // allocation bit-for-bit (stale rows must not leak through).
        let mut rng = crate::util::rng::Pcg64::seeded(14);
        let mut ws = SuffixGrams::new();
        for (w, d, m, t0) in [(9usize, 5usize, 3usize, 0usize), (4, 7, 1, 2), (12, 3, 2, 5)] {
            let slots: Vec<Vec<f32>> = (0..m)
                .map(|_| (0..w * d).map(|_| rng.next_f32() - 0.5).collect())
                .collect();
            let refs: Vec<&[f32]> = slots.iter().map(|s| s.as_slice()).collect();
            let res: Vec<f32> = (0..w * d).map(|_| rng.next_f32() - 0.5).collect();
            suffix_grams_into(&mut ws, &refs, &res, w, d, t0);
            let fresh = suffix_grams(&refs, &res, w, d, t0);
            for t in 0..w {
                assert_eq!(ws.gram(t), fresh.gram(t), "gram row {t} (w={w})");
                assert_eq!(ws.proj(t), fresh.proj(t), "proj row {t} (w={w})");
            }
        }
    }

    #[test]
    fn suffix_monotone_diagonal() {
        // Gram diagonals are sums of squares, so suffix sums must be
        // non-increasing in t.
        let mut rng = crate::util::rng::Pcg64::seeded(11);
        let (w, d) = (10, 4);
        let slot: Vec<f32> = (0..w * d).map(|_| rng.next_f32()).collect();
        let res = vec![0.0f32; w * d];
        let g = suffix_grams(&[&slot], &res, w, d, 0);
        for t in 1..w {
            assert!(g.gram(t)[0] <= g.gram(t - 1)[0] + 1e-6);
        }
    }

    #[test]
    fn last_row_is_single_gram() {
        let (w, d) = (3, 2);
        let slot = vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0];
        let res = vec![1.0; w * d];
        let g = suffix_grams(&[&slot], &res, w, d, 0);
        // row 2 suffix = just row 2: [3,4] -> gram 25, proj 7
        assert!((g.gram(2)[0] - 25.0).abs() < 1e-6);
        assert!((g.proj(2)[0] - 7.0).abs() < 1e-6);
        // row 0 suffix = all rows: 1+4+0+0+9+16 = 30
        assert!((g.gram(0)[0] - 30.0).abs() < 1e-6);
    }
}
