//! Explicit-SIMD inner-loop kernels for the TAA numeric core.
//!
//! The per-round hot path spends its time in four shapes of work, each of
//! which has a kernel here:
//!
//! - [`dot8`] — f32 dot product accumulated in f64 (Gram/projection
//!   entries steer the stopping criterion, so precision matters);
//! - [`multi_dot8`] — one pass of a single `a` row against many history
//!   slots, tiled so `a` streams through L1 once per slot group instead of
//!   once per slot (the Gram-cache refresh and the b_t projection rescan);
//! - [`axpy`] — the dependency-free row update `out += α·x` behind
//!   `mat::add_scaled` and the fused Anderson correction;
//! - [`residual_norm_sq`] — the fused first-order residual norm
//!   `Σ (x_p − a·x_t − b·ε − c·ξ)²` (eq. 11) in one pass, no staging
//!   buffer.
//!
//! # The reduction-order contract
//!
//! Every reducing kernel shares **one** summation order, so any two code
//! paths that compute the same quantity are bitwise identical:
//!
//! 1. element `i` accumulates into f64 lane `i mod 8` (tail elements
//!    included — there is no separate tail accumulator);
//! 2. within a lane, elements are added in increasing index order;
//! 3. the 8 lanes are reduced by the fixed pairwise tree
//!    `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
//!
//! The contract makes the result independent of *how* the elements were
//! fed in (whole-slice, or tile-by-tile with 8-aligned tiles as
//! [`multi_dot8`] does) and of which instruction set ran the loop. The
//! `#[cfg(target_arch = "x86_64")]` AVX paths use two 4×f64 vector
//! accumulators holding exactly the 8 contract lanes and multiply-then-add
//! (never FMA — Rust does not contract float expressions, so a fused
//! multiply-add would *break* bit-equality with the scalar fallback).
//! Every kernel exposes its `*_scalar` fallback publicly and
//! `tests/kernel_properties.rs` sweeps SIMD vs scalar vs a naive oracle
//! across lengths 0..257 for bitwise agreement.
//!
//! Reassociating a sum changes last-ulp rounding versus a single
//! sequential accumulator, but every consumer (session, blocking driver,
//! golden reference) shares these kernels, so the solver's bit-identity
//! tests hold exactly.

/// f64 accumulator lanes per reducing kernel (the contract's modulus).
pub const LANES: usize = 8;

/// Tile length (elements) for [`multi_dot8`]'s cache blocking. A multiple
/// of [`LANES`] so tiling never changes which lane an element lands in;
/// 2048 f32 = 8 KiB keeps the shared `a` tile resident in L1 while the
/// history slots stream past it.
pub const DOT_TILE: usize = 2048;

/// The fixed pairwise reduction tree closing the 8-lane contract.
#[inline]
fn reduce_tree8(acc: &[f64; LANES]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Is the AVX path available at runtime? Cached after the first query so
/// hot loops pay one relaxed load, not a cpuid.
#[cfg(target_arch = "x86_64")]
#[inline]
fn avx_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static AVX: AtomicU8 = AtomicU8::new(0); // 0 = unknown, 1 = yes, 2 = no
    match AVX.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx");
            AVX.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
            yes
        }
    }
}

/// True when the explicit-SIMD kernel paths are active on this machine
/// (x86_64 with AVX); false means every kernel runs its scalar fallback.
/// The `micro_kernels_simd` bench scenario reports this.
pub fn simd_active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        avx_available()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

// --- lane accumulators (the composable core) ------------------------------

/// Scalar reference accumulator: fold `a·b` into `acc` per the lane
/// contract (element `i` → lane `i mod 8`, tail included).
#[inline]
pub(crate) fn dot_accum_scalar(a: &[f32], b: &[f32], acc: &mut [f64; LANES]) {
    let n = a.len();
    debug_assert_eq!(b.len(), n);
    let n8 = n - n % LANES;
    let mut i = 0;
    while i < n8 {
        // Fixed-size subslices let the compiler elide bounds checks and
        // keep the 8 lanes independent.
        let xa = &a[i..i + LANES];
        let xb = &b[i..i + LANES];
        for l in 0..LANES {
            acc[l] += (xa[l] as f64) * (xb[l] as f64);
        }
        i += LANES;
    }
    for j in n8..n {
        acc[j - n8] += (a[j] as f64) * (b[j] as f64);
    }
}

/// AVX accumulator: two 4×f64 vectors hold the 8 contract lanes.
/// Multiply-then-add only — FMA would fuse the rounding step and diverge
/// from [`dot_accum_scalar`] bitwise.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn dot_accum_avx(a: &[f32], b: &[f32], acc: &mut [f64; LANES]) {
    use std::arch::x86_64::*;
    let n = a.len();
    let n8 = n - n % LANES;
    let mut acc_lo = _mm256_loadu_pd(acc.as_ptr());
    let mut acc_hi = _mm256_loadu_pd(acc.as_ptr().add(4));
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut i = 0;
    while i < n8 {
        let va = _mm256_loadu_ps(pa.add(i));
        let vb = _mm256_loadu_ps(pb.add(i));
        let a_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(va));
        let a_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(va));
        let b_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(vb));
        let b_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(vb));
        acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(a_lo, b_lo));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(a_hi, b_hi));
        i += LANES;
    }
    _mm256_storeu_pd(acc.as_mut_ptr(), acc_lo);
    _mm256_storeu_pd(acc.as_mut_ptr().add(4), acc_hi);
    for j in n8..n {
        acc[j - n8] += (*pa.add(j) as f64) * (*pb.add(j) as f64);
    }
}

/// Dispatching accumulator — SIMD when available, scalar otherwise;
/// bitwise identical either way.
#[inline]
pub(crate) fn dot_accum(a: &[f32], b: &[f32], acc: &mut [f64; LANES]) {
    #[cfg(target_arch = "x86_64")]
    if avx_available() {
        // SAFETY: guarded by the runtime AVX check above.
        unsafe { dot_accum_avx(a, b, acc) };
        return;
    }
    dot_accum_scalar(a, b, acc);
}

// --- dot8 -----------------------------------------------------------------

/// Dot product of two f32 slices accumulated in f64 under the 8-lane
/// reduction-order contract (see the module docs). Dispatches to AVX when
/// available; [`dot8_scalar`] is the bitwise-identical fallback.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let mut acc = [0.0f64; LANES];
    dot_accum(&a[..n], &b[..n], &mut acc);
    reduce_tree8(&acc)
}

/// [`dot8`] forced onto the scalar fallback — exposed so property tests
/// (and the `micro_kernels_simd` scenario) can pin SIMD ≡ scalar bitwise.
#[inline]
pub fn dot8_scalar(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let mut acc = [0.0f64; LANES];
    dot_accum_scalar(&a[..n], &b[..n], &mut acc);
    reduce_tree8(&acc)
}

// --- multi_dot8 -----------------------------------------------------------

fn multi_dot8_impl(
    a: &[f32],
    slots: &[&[f32]],
    acc: &mut [f64],
    out: &mut [f64],
    accum: fn(&[f32], &[f32], &mut [f64; LANES]),
) {
    let k = slots.len();
    assert!(acc.len() >= k * LANES, "multi_dot8 needs {} acc lanes", k * LANES);
    assert!(out.len() >= k, "multi_dot8 needs {k} output slots");
    for v in &mut acc[..k * LANES] {
        *v = 0.0;
    }
    let n = a.len();
    let mut t0 = 0;
    while t0 < n {
        let t1 = (t0 + DOT_TILE).min(n);
        let at = &a[t0..t1];
        for (j, s) in slots.iter().enumerate() {
            let lanes: &mut [f64; LANES] =
                (&mut acc[j * LANES..(j + 1) * LANES]).try_into().unwrap();
            accum(at, &s[t0..t1], lanes);
        }
        t0 = t1;
    }
    for j in 0..k {
        let lanes: &[f64; LANES] = (&acc[j * LANES..(j + 1) * LANES]).try_into().unwrap();
        out[j] = reduce_tree8(lanes);
    }
}

/// Batched dot: `out[j] = dot8(a, slots[j])` for every history slot in one
/// tiled pass over `a` ([`DOT_TILE`]-element blocks, so `a`'s tile stays in
/// L1 across the slot group instead of being re-streamed per slot).
///
/// Each slot must be at least `a.len()` long. `acc` is caller-owned lane
/// scratch (`slots.len() * `[`LANES`] f64s) so steady-state callers
/// allocate nothing. Because tiles are 8-aligned, every element lands in
/// the same contract lane as in a whole-slice [`dot8`] — the results are
/// **bitwise identical** to calling [`dot8`] per slot.
#[inline]
pub fn multi_dot8(a: &[f32], slots: &[&[f32]], acc: &mut [f64], out: &mut [f64]) {
    multi_dot8_impl(a, slots, acc, out, dot_accum);
}

/// [`multi_dot8`] forced onto the scalar fallback (property-test oracle).
#[inline]
pub fn multi_dot8_scalar(a: &[f32], slots: &[&[f32]], acc: &mut [f64], out: &mut [f64]) {
    multi_dot8_impl(a, slots, acc, out, dot_accum_scalar);
}

// --- axpy -----------------------------------------------------------------

/// Scalar fallback for [`axpy`]: `out[i] += alpha * x[i]`. Elementwise
/// (no reduction), so SIMD vs scalar agreement is exact per element.
#[inline]
pub fn axpy_scalar(out: &mut [f32], x: &[f32], alpha: f32) {
    let n = out.len().min(x.len());
    for i in 0..n {
        out[i] += alpha * x[i];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn axpy_avx(out: &mut [f32], x: &[f32], alpha: f32) {
    use std::arch::x86_64::*;
    let n = out.len().min(x.len());
    let n8 = n - n % LANES;
    let va = _mm256_set1_ps(alpha);
    let po = out.as_mut_ptr();
    let px = x.as_ptr();
    let mut i = 0;
    while i < n8 {
        let vo = _mm256_loadu_ps(po.add(i));
        let vx = _mm256_loadu_ps(px.add(i));
        // mul then add — no FMA, matching the scalar `out + alpha * x`.
        _mm256_storeu_ps(po.add(i), _mm256_add_ps(vo, _mm256_mul_ps(va, vx)));
        i += LANES;
    }
    for j in n8..n {
        *po.add(j) += alpha * *px.add(j);
    }
}

/// The Anderson-correction axpy `out[i] += alpha * x[i]` over
/// `min(out.len(), x.len())` elements. Dispatches to AVX when available;
/// bitwise identical to [`axpy_scalar`] either way.
#[inline]
pub fn axpy(out: &mut [f32], x: &[f32], alpha: f32) {
    #[cfg(target_arch = "x86_64")]
    if avx_available() {
        // SAFETY: guarded by the runtime AVX check.
        unsafe { axpy_avx(out, x, alpha) };
        return;
    }
    axpy_scalar(out, x, alpha);
}

// --- fused residual norm --------------------------------------------------

/// Scalar fallback for [`residual_norm_sq`] under the same lane contract.
pub fn residual_norm_sq_scalar(xp: &[f32], xt: &[f32], e: &[f32], xi: &[f32], a: f32, b: f32, c: f32) -> f64 {
    let mut acc = [0.0f64; LANES];
    residual_accum_scalar(xp, xt, e, xi, a, b, c, &mut acc);
    reduce_tree8(&acc)
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn residual_accum_scalar(
    xp: &[f32],
    xt: &[f32],
    e: &[f32],
    xi: &[f32],
    a: f32,
    b: f32,
    c: f32,
    acc: &mut [f64; LANES],
) {
    let n = xp.len();
    debug_assert!(xt.len() >= n && e.len() >= n && xi.len() >= n);
    let n8 = n - n % LANES;
    let mut i = 0;
    while i < n8 {
        for l in 0..LANES {
            let r = xp[i + l] - a * xt[i + l] - b * e[i + l] - c * xi[i + l];
            acc[l] += (r as f64) * (r as f64);
        }
        i += LANES;
    }
    for j in n8..n {
        let r = xp[j] - a * xt[j] - b * e[j] - c * xi[j];
        acc[j - n8] += (r as f64) * (r as f64);
    }
}

#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx")]
unsafe fn residual_accum_avx(
    xp: &[f32],
    xt: &[f32],
    e: &[f32],
    xi: &[f32],
    a: f32,
    b: f32,
    c: f32,
    acc: &mut [f64; LANES],
) {
    use std::arch::x86_64::*;
    let n = xp.len();
    let n8 = n - n % LANES;
    let va = _mm256_set1_ps(a);
    let vb = _mm256_set1_ps(b);
    let vc = _mm256_set1_ps(c);
    let mut acc_lo = _mm256_loadu_pd(acc.as_ptr());
    let mut acc_hi = _mm256_loadu_pd(acc.as_ptr().add(4));
    let mut i = 0;
    while i < n8 {
        // r = ((xp − a·xt) − b·e) − c·ξ in f32, exactly the scalar
        // expression's evaluation order, then widen and square-accumulate.
        let mut r = _mm256_sub_ps(
            _mm256_loadu_ps(xp.as_ptr().add(i)),
            _mm256_mul_ps(va, _mm256_loadu_ps(xt.as_ptr().add(i))),
        );
        r = _mm256_sub_ps(r, _mm256_mul_ps(vb, _mm256_loadu_ps(e.as_ptr().add(i))));
        r = _mm256_sub_ps(r, _mm256_mul_ps(vc, _mm256_loadu_ps(xi.as_ptr().add(i))));
        let r_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(r));
        let r_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(r));
        acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(r_lo, r_lo));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(r_hi, r_hi));
        i += LANES;
    }
    _mm256_storeu_pd(acc.as_mut_ptr(), acc_lo);
    _mm256_storeu_pd(acc.as_mut_ptr().add(4), acc_hi);
    for j in n8..n {
        let r = xp[j] - a * xt[j] - b * e[j] - c * xi[j];
        acc[j - n8] += (r as f64) * (r as f64);
    }
}

/// Fused first-order residual norm (eq. 11):
/// `Σ_i (xp[i] − a·xt[i] − b·e[i] − c·xi[i])²` with the residual computed
/// in f32 (matching the historical staging-free loop) and the squares
/// accumulated in f64 under the 8-lane contract. One pass over four
/// streams, no intermediate buffer. Wrong-way scalar expressions here
/// would break bit-equality: the AVX path evaluates the exact scalar
/// operation order per element.
pub fn residual_norm_sq(xp: &[f32], xt: &[f32], e: &[f32], xi: &[f32], a: f32, b: f32, c: f32) -> f64 {
    let mut acc = [0.0f64; LANES];
    #[cfg(target_arch = "x86_64")]
    if avx_available() {
        // SAFETY: guarded by the runtime AVX check.
        unsafe { residual_accum_avx(xp, xt, e, xi, a, b, c, &mut acc) };
        return reduce_tree8(&acc);
    }
    residual_accum_scalar(xp, xt, e, xi, a, b, c, &mut acc);
    reduce_tree8(&acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::{forall, naive_dot, size_in};
    use crate::util::rng::Pcg64;

    #[test]
    fn dot8_matches_naive_all_lengths() {
        // Every remainder class 0..8 plus longer sizes.
        forall("dot8_naive", 40, |rng, _| {
            let n = size_in(rng, 0, 67);
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let fast = dot8(&a, &b);
            let slow = naive_dot(&a, &b);
            if (fast - slow).abs() > 1e-9 * (1.0 + slow.abs()) {
                return Err(format!("n={n}: dot8 {fast} vs naive {slow}"));
            }
            Ok(())
        });
    }

    #[test]
    fn dot8_is_deterministic() {
        let mut rng = Pcg64::seeded(9);
        let a: Vec<f32> = (0..256).map(|_| rng.next_f32()).collect();
        let b: Vec<f32> = (0..256).map(|_| rng.next_f32()).collect();
        assert_eq!(dot8(&a, &b).to_bits(), dot8(&a, &b).to_bits());
    }

    #[test]
    fn dot8_empty_is_zero() {
        assert_eq!(dot8(&[], &[]), 0.0);
        assert_eq!(dot8_scalar(&[], &[]), 0.0);
    }

    #[test]
    fn dispatched_kernels_match_scalar_bitwise() {
        // The full-width sweep lives in tests/kernel_properties.rs; this is
        // the in-module smoke over a few odd lengths.
        let mut rng = Pcg64::seeded(11);
        for n in [1usize, 7, 8, 9, 63, 257] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            assert_eq!(dot8(&a, &b).to_bits(), dot8_scalar(&a, &b).to_bits(), "dot n={n}");
            let mut o1 = a.clone();
            let mut o2 = a.clone();
            axpy(&mut o1, &b, -0.37);
            axpy_scalar(&mut o2, &b, -0.37);
            assert_eq!(o1, o2, "axpy n={n}");
        }
    }

    #[test]
    fn multi_dot8_is_bitwise_per_slot_dot8() {
        // Tiling + batching must not change a single bit vs dot8 per slot,
        // including lengths spanning several DOT_TILE blocks.
        let mut rng = Pcg64::seeded(13);
        for n in [0usize, 5, 8, 100, DOT_TILE - 3, DOT_TILE, 2 * DOT_TILE + 17] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let slots: Vec<Vec<f32>> =
                (0..3).map(|_| (0..n).map(|_| rng.next_f32() - 0.5).collect()).collect();
            let refs: Vec<&[f32]> = slots.iter().map(|s| s.as_slice()).collect();
            let mut acc = vec![0.0f64; refs.len() * LANES];
            let mut out = vec![0.0f64; refs.len()];
            multi_dot8(&a, &refs, &mut acc, &mut out);
            for (j, s) in refs.iter().enumerate() {
                assert_eq!(out[j].to_bits(), dot8(&a, s).to_bits(), "slot {j}, n={n}");
            }
        }
    }

    #[test]
    fn residual_norm_matches_unfused_loop() {
        let mut rng = Pcg64::seeded(15);
        for n in [0usize, 3, 8, 40, 257] {
            let xp: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let xt: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let e: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let xi: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let (a, b, c) = (0.97f32, 0.21f32, 0.04f32);
            let fused = residual_norm_sq(&xp, &xt, &e, &xi, a, b, c);
            let scalar = residual_norm_sq_scalar(&xp, &xt, &e, &xi, a, b, c);
            assert_eq!(fused.to_bits(), scalar.to_bits(), "simd vs scalar, n={n}");
            let naive: f64 = (0..n)
                .map(|i| {
                    let r = xp[i] - a * xt[i] - b * e[i] - c * xi[i];
                    (r as f64) * (r as f64)
                })
                .sum();
            assert!((fused - naive).abs() <= 1e-9 * (1.0 + naive.abs()), "n={n}");
        }
    }
}
