//! Vectorizable inner-loop kernels for the TAA numeric core.
//!
//! The suffix-Gram scan and the Anderson correction loop spend all their
//! time in two shapes of work: f32 dot products accumulated in f64 (the
//! Gram/projection entries steer the stopping criterion, so precision
//! matters) and elementwise row updates. The naive reduction is
//! latency-bound — a single f64 accumulator serializes on the ~4-cycle add
//! latency — so [`dot8`] splits the sum across 8 independent accumulators
//! that the autovectorizer maps onto SIMD lanes, turning the loop
//! throughput-bound. The fused correction
//! `x_p += R_p − Σ_h γ_h·fused_h[p]` needs only the dependency-free axpy
//! already provided by [`super::mat::add_scaled`]
//! (see `solver::history::History::correct_row`).
//!
//! Reassociating the sum changes the last-ulp rounding versus a sequential
//! accumulator; every caller is pinned against a naive reference at
//! tolerance, and the solver's golden tests compare two paths that share
//! these kernels, so bit-identity across the session/driver split is
//! preserved.

/// Dot product of two f32 slices with 8 independent f64 accumulators.
///
/// The 8 partial sums are reduced pairwise at the end, so the result is
/// deterministic for a given length (but differs in the last ulps from a
/// single sequential accumulator).
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let n8 = n - n % 8;
    let mut acc = [0.0f64; 8];
    let mut i = 0;
    while i < n8 {
        // Fixed-size subslices let the compiler elide bounds checks and
        // keep the 8 lanes independent.
        let xa = &a[i..i + 8];
        let xb = &b[i..i + 8];
        for l in 0..8 {
            acc[l] += (xa[l] as f64) * (xb[l] as f64);
        }
        i += 8;
    }
    let mut tail = 0.0f64;
    for j in n8..n {
        tail += (a[j] as f64) * (b[j] as f64);
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::{forall, size_in};
    use crate::util::rng::Pcg64;

    fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b.iter()).map(|(&x, &y)| (x as f64) * (y as f64)).sum()
    }

    #[test]
    fn dot8_matches_naive_all_lengths() {
        // Every remainder class 0..8 plus longer sizes.
        forall("dot8_naive", 40, |rng, _| {
            let n = size_in(rng, 0, 67);
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let fast = dot8(&a, &b);
            let slow = naive_dot(&a, &b);
            if (fast - slow).abs() > 1e-9 * (1.0 + slow.abs()) {
                return Err(format!("n={n}: dot8 {fast} vs naive {slow}"));
            }
            Ok(())
        });
    }

    #[test]
    fn dot8_is_deterministic() {
        let mut rng = Pcg64::seeded(9);
        let a: Vec<f32> = (0..256).map(|_| rng.next_f32()).collect();
        let b: Vec<f32> = (0..256).map(|_| rng.next_f32()).collect();
        assert_eq!(dot8(&a, &b).to_bits(), dot8(&a, &b).to_bits());
    }

    #[test]
    fn dot8_empty_is_zero() {
        assert_eq!(dot8(&[], &[]), 0.0);
    }
}
