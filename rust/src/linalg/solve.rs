//! Small symmetric / general linear solves.
//!
//! The TAA update (Theorem 3.2, Remark 3.3) solves
//! `(Fᵀ_{t:t₂} F_{t:t₂} + λI) γ = b` where the Gram matrix is `m×m` with
//! `m ≤ 8`. Cholesky is the natural factorization (SPD after the λ ridge);
//! LU with partial pivoting is kept as a fallback for the standard-AA path
//! where the post-processed matrix can lose symmetry.

/// Cholesky-factor symmetric positive-definite `A` (n×n, row-major f32)
/// into the caller-owned f64 lower triangle `l` (at least `n*n` long; only
/// the lower triangle including the diagonal is written or later read).
/// Returns `false` if the matrix is not (numerically) SPD — `l` is then
/// partially written and must not be fed to the substitution.
///
/// Factoring in f64: the Gram matrices can be ill-conditioned when Anderson
/// histories become nearly collinear near convergence.
pub fn cholesky_factor_into(a: &[f32], n: usize, l: &mut [f64]) -> bool {
    assert_eq!(a.len(), n * n);
    assert!(l.len() >= n * n, "factor scratch too small");
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j] as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 || !sum.is_finite() {
                    return false;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    true
}

/// Solve `L Lᵀ x = b` given a factor from [`cholesky_factor_into`], writing
/// the solution into `out` (f32). `y` is an `n`-long f64 scratch: the
/// forward substitution fills it and the back substitution runs in place,
/// so the whole solve is allocation-free.
pub fn cholesky_solve_factored(l: &[f64], b: &[f32], n: usize, y: &mut [f64], out: &mut [f32]) {
    assert!(l.len() >= n * n);
    assert_eq!(b.len(), n);
    assert!(y.len() >= n, "substitution scratch too small");
    assert!(out.len() >= n);
    // Forward substitution: L y = b
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    // Back substitution in place: Lᵀ x = y. Row i reads its own forward
    // value before overwriting it and only already-final x[k] for k > i.
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    for i in 0..n {
        out[i] = y[i] as f32;
    }
}

/// Factor + solve `A x = b` into caller-owned scratch (`l`: `n*n` f64,
/// `y`: `n` f64) and output (`out`: `n` f32) — the zero-allocation form of
/// [`cholesky_solve`]. Returns `false` (without touching `out`) when `A` is
/// not numerically SPD.
pub fn cholesky_solve_into(
    a: &[f32],
    b: &[f32],
    n: usize,
    l: &mut [f64],
    y: &mut [f64],
    out: &mut [f32],
) -> bool {
    if !cholesky_factor_into(a, n, l) {
        return false;
    }
    cholesky_solve_factored(l, b, n, y, out);
    true
}

/// Solve `A x = b` for symmetric positive-definite `A` (n×n, row-major)
/// via Cholesky. Returns `None` if the matrix is not (numerically) SPD.
/// Allocating wrapper over [`cholesky_solve_into`].
pub fn cholesky_solve(a: &[f32], b: &[f32], n: usize) -> Option<Vec<f32>> {
    let mut l = vec![0.0f64; n * n];
    let mut y = vec![0.0f64; n];
    let mut out = vec![0.0f32; n];
    cholesky_solve_into(a, b, n, &mut l, &mut y, &mut out).then_some(out)
}

/// Solve `A x = b` for general square `A` via LU with partial pivoting.
/// Returns `None` on (numerical) singularity.
pub fn lu_solve(a: &[f32], b: &[f32], n: usize) -> Option<Vec<f32>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut lu: Vec<f64> = a.iter().map(|&v| v as f64).collect();
    let mut x: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    let mut piv: Vec<usize> = (0..n).collect();
    for col in 0..n {
        // pivot
        let (mut best, mut best_abs) = (col, lu[piv[col] * n + col].abs());
        for r in col + 1..n {
            let v = lu[piv[r] * n + col].abs();
            if v > best_abs {
                best = r;
                best_abs = v;
            }
        }
        if best_abs < 1e-300 || !best_abs.is_finite() {
            return None;
        }
        piv.swap(col, best);
        let prow = piv[col];
        let pval = lu[prow * n + col];
        for r in col + 1..n {
            let row = piv[r];
            let factor = lu[row * n + col] / pval;
            lu[row * n + col] = factor;
            for c in col + 1..n {
                lu[row * n + c] -= factor * lu[prow * n + c];
            }
            x[row] -= factor * x[prow];
        }
    }
    // Back substitution on the permuted upper triangle.
    let mut out = vec![0.0f64; n];
    for i in (0..n).rev() {
        let row = piv[i];
        let mut sum = x[row];
        for c in i + 1..n {
            sum -= lu[row * n + c] * out[c];
        }
        out[i] = sum / lu[row * n + i];
    }
    Some(out.iter().map(|&v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::matvec;
    use crate::util::proplite::{self, forall, size_in};

    #[test]
    fn cholesky_known_system() {
        // A = [[4,2],[2,3]], b = [2, 1] -> x = [0.5, 0]
        let a = [4.0, 2.0, 2.0, 3.0];
        let x = cholesky_solve(&a, &[2.0, 1.0], 2).unwrap();
        assert!((x[0] - 0.5).abs() < 1e-6 && x[1].abs() < 1e-6, "{x:?}");
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_solve(&a, &[1.0, 1.0], 2).is_none());
    }

    #[test]
    fn into_variant_matches_allocating_solve_bitwise() {
        // Reused (stale) scratch must not leak into results.
        let mut rng = crate::util::rng::Pcg64::seeded(31);
        let mut l = vec![f64::NAN; 16];
        let mut y = vec![f64::NAN; 4];
        let mut out = vec![0.0f32; 4];
        for n in 1..=4usize {
            let m: Vec<f32> = (0..n * n).map(|_| rng.next_f32() - 0.5).collect();
            let mut a = vec![0.0f32; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += m[i * n + k] * m[j * n + k];
                    }
                    a[i * n + j] = acc + if i == j { 0.2 } else { 0.0 };
                }
            }
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            assert!(cholesky_solve_into(&a, &b, n, &mut l, &mut y, &mut out));
            let alloc = cholesky_solve(&a, &b, n).unwrap();
            assert_eq!(&out[..n], &alloc[..], "n={n}");
        }
    }

    #[test]
    fn factor_then_many_rhs_matches_full_solves() {
        let a = [4.0, 2.0, 2.0, 3.0];
        let mut l = vec![0.0f64; 4];
        let mut y = vec![0.0f64; 2];
        let mut out = vec![0.0f32; 2];
        assert!(cholesky_factor_into(&a, 2, &mut l));
        for b in [[2.0f32, 1.0], [1.0, -1.0], [0.5, 3.0]] {
            cholesky_solve_factored(&l, &b, 2, &mut y, &mut out);
            let full = cholesky_solve(&a, &b, 2).unwrap();
            assert_eq!(&out[..], &full[..]);
        }
    }

    #[test]
    fn lu_rejects_singular() {
        let a = [1.0, 2.0, 2.0, 4.0];
        assert!(lu_solve(&a, &[1.0, 1.0], 2).is_none());
    }

    #[test]
    fn solvers_agree_on_random_spd() {
        forall("spd_solvers_agree", 48, |rng, _| {
            let n = size_in(rng, 1, 8);
            // A = M Mᵀ + ridge: guaranteed SPD.
            let m: Vec<f32> = (0..n * n).map(|_| rng.next_f32() - 0.5).collect();
            let mut a = vec![0.0f32; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += m[i * n + k] * m[j * n + k];
                    }
                    a[i * n + j] = acc + if i == j { 0.1 } else { 0.0 };
                }
            }
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let xc = cholesky_solve(&a, &b, n).ok_or("chol failed")?;
            let xl = lu_solve(&a, &b, n).ok_or("lu failed")?;
            proplite::assert_close(&xc, &xl, 1e-4, 1e-3, "chol vs lu")?;
            // verify residual A x - b ≈ 0
            let mut ax = vec![0.0f32; n];
            matvec(&a, &xc, &mut ax, n, n);
            proplite::assert_close(&ax, &b, 1e-3, 1e-3, "Ax=b")
        });
    }

    #[test]
    fn lu_solves_nonsymmetric() {
        // A = [[0,1],[2,0]] requires pivoting; x = [b1/2, b0].
        let a = [0.0, 1.0, 2.0, 0.0];
        let x = lu_solve(&a, &[3.0, 8.0], 2).unwrap();
        assert!((x[0] - 4.0).abs() < 1e-6 && (x[1] - 3.0).abs() < 1e-6);
    }
}
