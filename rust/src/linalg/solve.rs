//! Small symmetric / general linear solves.
//!
//! The TAA update (Theorem 3.2, Remark 3.3) solves
//! `(Fᵀ_{t:t₂} F_{t:t₂} + λI) γ = b` where the Gram matrix is `m×m` with
//! `m ≤ 8`. Cholesky is the natural factorization (SPD after the λ ridge);
//! LU with partial pivoting is kept as a fallback for the standard-AA path
//! where the post-processed matrix can lose symmetry.

/// Solve `A x = b` for symmetric positive-definite `A` (n×n, row-major)
/// via Cholesky. Returns `None` if the matrix is not (numerically) SPD.
pub fn cholesky_solve(a: &[f32], b: &[f32], n: usize) -> Option<Vec<f32>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    // Factor in f64 for stability: the Gram matrices can be ill-conditioned
    // when Anderson histories become nearly collinear near convergence.
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j] as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 || !sum.is_finite() {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    // Forward substitution: L y = b
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    // Back substitution: Lᵀ x = y
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    Some(x.iter().map(|&v| v as f32).collect())
}

/// Solve `A x = b` for general square `A` via LU with partial pivoting.
/// Returns `None` on (numerical) singularity.
pub fn lu_solve(a: &[f32], b: &[f32], n: usize) -> Option<Vec<f32>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut lu: Vec<f64> = a.iter().map(|&v| v as f64).collect();
    let mut x: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    let mut piv: Vec<usize> = (0..n).collect();
    for col in 0..n {
        // pivot
        let (mut best, mut best_abs) = (col, lu[piv[col] * n + col].abs());
        for r in col + 1..n {
            let v = lu[piv[r] * n + col].abs();
            if v > best_abs {
                best = r;
                best_abs = v;
            }
        }
        if best_abs < 1e-300 || !best_abs.is_finite() {
            return None;
        }
        piv.swap(col, best);
        let prow = piv[col];
        let pval = lu[prow * n + col];
        for r in col + 1..n {
            let row = piv[r];
            let factor = lu[row * n + col] / pval;
            lu[row * n + col] = factor;
            for c in col + 1..n {
                lu[row * n + c] -= factor * lu[prow * n + c];
            }
            x[row] -= factor * x[prow];
        }
    }
    // Back substitution on the permuted upper triangle.
    let mut out = vec![0.0f64; n];
    for i in (0..n).rev() {
        let row = piv[i];
        let mut sum = x[row];
        for c in i + 1..n {
            sum -= lu[row * n + c] * out[c];
        }
        out[i] = sum / lu[row * n + i];
    }
    Some(out.iter().map(|&v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::matvec;
    use crate::util::proplite::{self, forall, size_in};

    #[test]
    fn cholesky_known_system() {
        // A = [[4,2],[2,3]], b = [2, 1] -> x = [0.5, 0]
        let a = [4.0, 2.0, 2.0, 3.0];
        let x = cholesky_solve(&a, &[2.0, 1.0], 2).unwrap();
        assert!((x[0] - 0.5).abs() < 1e-6 && x[1].abs() < 1e-6, "{x:?}");
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_solve(&a, &[1.0, 1.0], 2).is_none());
    }

    #[test]
    fn lu_rejects_singular() {
        let a = [1.0, 2.0, 2.0, 4.0];
        assert!(lu_solve(&a, &[1.0, 1.0], 2).is_none());
    }

    #[test]
    fn solvers_agree_on_random_spd() {
        forall("spd_solvers_agree", 48, |rng, _| {
            let n = size_in(rng, 1, 8);
            // A = M Mᵀ + ridge: guaranteed SPD.
            let m: Vec<f32> = (0..n * n).map(|_| rng.next_f32() - 0.5).collect();
            let mut a = vec![0.0f32; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += m[i * n + k] * m[j * n + k];
                    }
                    a[i * n + j] = acc + if i == j { 0.1 } else { 0.0 };
                }
            }
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let xc = cholesky_solve(&a, &b, n).ok_or("chol failed")?;
            let xl = lu_solve(&a, &b, n).ok_or("lu failed")?;
            proplite::assert_close(&xc, &xl, 1e-4, 1e-3, "chol vs lu")?;
            // verify residual A x - b ≈ 0
            let mut ax = vec![0.0f32; n];
            matvec(&a, &xc, &mut ax, n, n);
            proplite::assert_close(&ax, &b, 1e-3, 1e-3, "Ax=b")
        });
    }

    #[test]
    fn lu_solves_nonsymmetric() {
        // A = [[0,1],[2,0]] requires pivoting; x = [b1/2, b0].
        let a = [0.0, 1.0, 2.0, 0.0];
        let x = lu_solve(&a, &[3.0, 8.0], 2).unwrap();
        assert!((x[0] - 4.0).abs() < 1e-6 && (x[1] - 3.0).abs() < 1e-6);
    }
}
