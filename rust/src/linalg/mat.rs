//! Dense row-major matrix/vector primitives.

/// `c = a @ b` for row-major `a: [m,k]`, `b: [k,n]`, `c: [m,n]`.
/// ikj loop order keeps the innermost loop contiguous over both `b` and `c`.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue; // banded matrices are mostly zero — skip rows cheaply
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += aik * bj;
            }
        }
    }
}

/// `y = A @ x` for row-major `A: [m,n]`, `x: [n]`.
pub fn matvec(a: &[f32], x: &[f32], y: &mut [f32], m: usize, n: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for i in 0..m {
        y[i] = dot(&a[i * n..(i + 1) * n], x);
    }
}

/// Inner product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // f64 accumulator: the residual norms steer the stopping criterion, so
    // keep accumulation error well below the threshold τ²g²d.
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += (x as f64) * (y as f64);
    }
    acc as f32
}

/// Squared L2 norm with f64 accumulation.
#[inline]
pub fn l2_norm_sq(a: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &x in a {
        acc += (x as f64) * (x as f64);
    }
    acc
}

/// `out += alpha * x` elementwise. Routed through the explicit-SIMD
/// [`kernels::axpy`](super::kernels::axpy); elementwise, so the SIMD and
/// scalar paths agree bitwise per element.
#[inline]
pub fn add_scaled(out: &mut [f32], x: &[f32], alpha: f32) {
    debug_assert_eq!(out.len(), x.len());
    super::kernels::axpy(out, x, alpha);
}

/// `out = a - b` elementwise.
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = x - y;
    }
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::{self, forall, size_in};

    #[test]
    fn matmul_identity() {
        // 3x3 identity times arbitrary matrix.
        let eye = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let b = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let mut c = [0.0; 9];
        matmul(&eye, &b, &mut c, 3, 3, 3);
        assert_eq!(c, b);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular_matches_naive() {
        forall("matmul_naive", 32, |rng, _| {
            let (m, k, n) = (size_in(rng, 1, 8), size_in(rng, 1, 8), size_in(rng, 1, 8));
            let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
            let mut c = vec![0.0; m * n];
            matmul(&a, &b, &mut c, m, k, n);
            // naive ijk reference
            let mut r = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0;
                    for kk in 0..k {
                        acc += a[i * k + kk] * b[kk * n + j];
                    }
                    r[i * n + j] = acc;
                }
            }
            proplite::assert_close(&c, &r, 1e-5, 1e-5, "matmul")
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = [1.0, -1.0, 2.0];
        let mut y = [0.0; 2];
        matvec(&a, &x, &mut y, 2, 3);
        assert_eq!(y, [5.0, 11.0]);
    }

    #[test]
    fn norms_and_axpy() {
        assert_eq!(l2_norm_sq(&[3.0, 4.0]), 25.0);
        let mut o = vec![1.0, 1.0];
        add_scaled(&mut o, &[2.0, -2.0], 0.5);
        assert_eq!(o, vec![2.0, 0.0]);
        let mut d = vec![0.0; 2];
        sub(&[3.0, 1.0], &[1.0, 1.0], &mut d);
        assert_eq!(d, vec![2.0, 0.0]);
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 5.0]), 0.5);
    }
}
