//! Small dense linear algebra for the triangular-system solvers.
//!
//! Everything here operates on row-major `f32` slices. Shapes are tiny by
//! BLAS standards — `W ≤ 100` window rows, `D ≤ 1024` feature columns,
//! `m ≤ 8` Anderson history — so the layout favors cache-friendly flat
//! buffers and the hot inner loops live in [`kernels`], written so the
//! autovectorizer maps them onto SIMD lanes (8 independent accumulators
//! instead of one serial dependency chain).
//!
//! Submodules:
//! - [`mat`]: dense matmul / axpy / norms,
//! - [`solve`]: Cholesky and LU factorizations for the m×m Gram systems,
//!   with `_into` variants that write into caller-owned scratch,
//! - [`gram`]: the suffix-Gram scan at the core of Triangular Anderson
//!   Acceleration (native mirror of the Pallas kernel in
//!   `python/compile/kernels/taa_update.py`), flat storage + write-into API,
//! - [`kernels`]: the explicit-SIMD inner-loop suite — [`kernels::dot8`],
//!   the batched [`kernels::multi_dot8`] (one tiled pass of a row against
//!   several history slots), the correction [`kernels::axpy`], and the
//!   fused [`kernels::residual_norm_sq`] — all sharing one 8-lane
//!   reduction-order contract so SIMD, scalar fallback, and tiled callers
//!   are bitwise identical (see the module docs for the contract).

pub mod gram;
pub mod kernels;
pub mod mat;
pub mod solve;

pub use gram::{suffix_grams, suffix_grams_into, SuffixGrams};
pub use kernels::{axpy, dot8, multi_dot8, residual_norm_sq};
pub use mat::{add_scaled, dot, l2_norm_sq, matmul, matvec, sub};
pub use solve::{
    cholesky_factor_into, cholesky_solve, cholesky_solve_factored, cholesky_solve_into, lu_solve,
};
