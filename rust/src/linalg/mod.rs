//! Small dense linear algebra for the triangular-system solvers.
//!
//! Everything here operates on row-major `f32` slices. Shapes are tiny by
//! BLAS standards — `W ≤ 100` window rows, `D ≤ 1024` feature columns,
//! `m ≤ 8` Anderson history — so clarity and cache-friendly loops beat
//! hand-vectorization; the compiler auto-vectorizes the inner `D` loops.
//!
//! Submodules:
//! - [`mat`]: dense matmul / axpy / norms,
//! - [`solve`]: Cholesky and LU factorizations for the m×m Gram systems,
//! - [`gram`]: the suffix-Gram scan at the core of Triangular Anderson
//!   Acceleration (native mirror of the Pallas kernel in
//!   `python/compile/kernels/taa_update.py`).

pub mod gram;
pub mod mat;
pub mod solve;

pub use gram::{suffix_grams, SuffixGrams};
pub use mat::{add_scaled, dot, l2_norm_sq, matmul, matvec, sub};
pub use solve::{cholesky_solve, lu_solve};
