//! Small dense linear algebra for the triangular-system solvers.
//!
//! Everything here operates on row-major `f32` slices. Shapes are tiny by
//! BLAS standards — `W ≤ 100` window rows, `D ≤ 1024` feature columns,
//! `m ≤ 8` Anderson history — so the layout favors cache-friendly flat
//! buffers and the hot inner loops live in [`kernels`], written so the
//! autovectorizer maps them onto SIMD lanes (8 independent accumulators
//! instead of one serial dependency chain).
//!
//! Submodules:
//! - [`mat`]: dense matmul / axpy / norms,
//! - [`solve`]: Cholesky and LU factorizations for the m×m Gram systems,
//!   with `_into` variants that write into caller-owned scratch,
//! - [`gram`]: the suffix-Gram scan at the core of Triangular Anderson
//!   Acceleration (native mirror of the Pallas kernel in
//!   `python/compile/kernels/taa_update.py`), flat storage + write-into API,
//! - [`kernels`]: the vectorizable 8-accumulator dot product shared by the
//!   Gram scan, the incremental Gram cache, and the projection rescan
//!   (the Anderson correction reuses [`mat::add_scaled`]).

pub mod gram;
pub mod kernels;
pub mod mat;
pub mod solve;

pub use gram::{suffix_grams, suffix_grams_into, SuffixGrams};
pub use kernels::dot8;
pub use mat::{add_scaled, dot, l2_norm_sq, matmul, matvec, sub};
pub use solve::{
    cholesky_factor_into, cholesky_solve, cholesky_solve_factored, cholesky_solve_into, lu_solve,
};
