//! Minimal loopback HTTP client for exercising [`super::http::HttpServer`]
//! from tests, the bench harness, and the CI smoke script — *not* a
//! general-purpose client. One-shot requests send `Connection: close` and
//! read to EOF; [`SseConn`] holds the socket open to consume
//! `text/event-stream` frames one at a time (and to *drop* mid-stream,
//! which is how the disconnect-propagation tests simulate a vanished
//! client).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::util::json::{parse, Json};

/// How long a client read may block before the test is declared hung.
const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// A parsed one-shot response.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    /// Numeric status code (200, 429, ...).
    pub status: u16,
    /// Headers with lowercased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Response body, assumed UTF-8.
    pub body: String,
}

impl HttpResponse {
    /// First header value by lowercase name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json, String> {
        parse(&self.body)
    }
}

fn read_to_eof(stream: &mut TcpStream) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    stream.read_to_end(&mut out).map_err(|e| format!("read: {e}"))?;
    Ok(out)
}

fn parse_response(raw: &[u8]) -> Result<HttpResponse, String> {
    let text = String::from_utf8_lossy(raw);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| format!("no header terminator in response: {text:?}"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("bad status line: {status_line:?}"))?;
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(n, v)| (n.to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    Ok(HttpResponse { status, headers, body: body.to_string() })
}

/// Send one request (with `Connection: close`) and read the full
/// response. `headers` are extra request headers, e.g.
/// `[("X-Parataa-Tenant", "acme")]`.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &str,
) -> Result<HttpResponse, String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: parataa\r\nConnection: close\r\n");
    for (name, value) in headers {
        req.push_str(&format!("{name}: {value}\r\n"));
    }
    if !body.is_empty() || method == "POST" {
        req.push_str(&format!("Content-Length: {}\r\n", body.len()));
    }
    req.push_str("\r\n");
    req.push_str(body);
    stream.write_all(req.as_bytes()).map_err(|e| format!("write: {e}"))?;
    parse_response(&read_to_eof(&mut stream)?)
}

/// `GET path` convenience.
pub fn get(addr: SocketAddr, path: &str) -> Result<HttpResponse, String> {
    request(addr, "GET", path, &[], "")
}

/// `POST path` with a JSON body and optional tenant header.
pub fn post_json(
    addr: SocketAddr,
    path: &str,
    tenant: Option<&str>,
    body: &str,
) -> Result<HttpResponse, String> {
    let mut headers: Vec<(&str, &str)> = vec![("Content-Type", "application/json")];
    if let Some(t) = tenant {
        headers.push(("X-Parataa-Tenant", t));
    }
    request(addr, "POST", path, &headers, body)
}

/// One Server-Sent Event as framed by the serving front: an `event:`
/// name and a single `data:` line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SseEvent {
    /// Event name: `chunk`, `done`, or `error`.
    pub event: String,
    /// The event's JSON payload, verbatim.
    pub data: String,
}

/// An open `POST /v1/sample/stream` connection. Read frames with
/// [`next_event`](Self::next_event); *drop* the connection mid-stream to
/// simulate a client disconnect (the server must then cancel the
/// session).
pub struct SseConn {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl SseConn {
    /// Open a streaming request and consume the response head. Errors if
    /// the server answers anything but `200` + `text/event-stream`.
    pub fn open(
        addr: SocketAddr,
        tenant: Option<&str>,
        body: &str,
    ) -> Result<SseConn, String> {
        let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT));
        let _ = stream.set_nodelay(true);
        let mut req = String::from("POST /v1/sample/stream HTTP/1.1\r\nHost: parataa\r\n");
        if let Some(t) = tenant {
            req.push_str(&format!("X-Parataa-Tenant: {t}\r\n"));
        }
        req.push_str(&format!("Content-Length: {}\r\n\r\n{body}", body.len()));
        stream.write_all(req.as_bytes()).map_err(|e| format!("write: {e}"))?;

        let mut conn = SseConn { stream, buf: Vec::new() };
        let head_end = conn.read_until(b"\r\n\r\n")?;
        let head = String::from_utf8_lossy(&conn.buf[..head_end]).to_string();
        conn.buf.drain(..head_end + 4);
        let status = head.split(' ').nth(1).unwrap_or("");
        if status != "200" {
            return Err(format!("stream refused: {head:?} body {:?}", conn.drain_text()));
        }
        if !head.to_ascii_lowercase().contains("text/event-stream") {
            return Err(format!("not an SSE response: {head:?}"));
        }
        Ok(conn)
    }

    fn read_until(&mut self, needle: &[u8]) -> Result<usize, String> {
        loop {
            if let Some(pos) =
                self.buf.windows(needle.len()).position(|w| w == needle)
            {
                return Ok(pos);
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err("eof".to_string()),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(format!("read: {e}")),
            }
        }
    }

    fn drain_text(&mut self) -> String {
        let mut rest = Vec::new();
        let _ = self.stream.read_to_end(&mut rest);
        self.buf.extend_from_slice(&rest);
        String::from_utf8_lossy(&self.buf).to_string()
    }

    /// Block for the next frame; `None` once the server closes the
    /// stream (after `done`/`error`).
    pub fn next_event(&mut self) -> Option<SseEvent> {
        let frame_end = self.read_until(b"\n\n").ok()?;
        let frame = String::from_utf8_lossy(&self.buf[..frame_end]).to_string();
        self.buf.drain(..frame_end + 2);
        let mut event = String::new();
        let mut data = String::new();
        for line in frame.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                event = v.to_string();
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = v.to_string();
            }
        }
        Some(SseEvent { event, data })
    }

    /// Collect every remaining frame until the server closes the stream.
    pub fn collect(mut self) -> Vec<SseEvent> {
        let mut out = Vec::new();
        while let Some(e) = self.next_event() {
            out.push(e);
        }
        out
    }
}
