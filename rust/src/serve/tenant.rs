//! Multi-tenant admission control: tenant spec grammar, per-tenant token
//! buckets (rate quotas → HTTP 429), weighted fair queueing with priority
//! classes, and per-tenant accounting.
//!
//! Three layers, from pure to blocking:
//!
//! 1. [`TokenBucket`] and [`FairQueue`] are *pure deterministic* data
//!    structures — the clock and the pop order are injected/explicit, so
//!    the fairness and quota properties in `tests/http_fairness.rs` can
//!    drive them over hundreds of randomized schedules without touching a
//!    socket or a sleep.
//! 2. [`FairGate`] wraps a [`FairQueue`] in a `Mutex`/`Condvar` to bound
//!    how many requests are *in service* concurrently; waiters block in
//!    virtual-finish-time order, so a heavy tenant queues behind a light
//!    one instead of monopolizing the coordinator's intake.
//! 3. [`TenantRegistry`] owns the tenant table (parsed from the CLI
//!    `--tenants` spec or auto-populated in open mode), applies the token
//!    bucket at the front door, and keeps per-tenant outcome counters for
//!    `/metrics`.
//!
//! Priority semantics: `interactive` requests may overtake `batch`
//! requests *in the queue* (lower virtual finish times are served first
//! within a class, and the interactive class is preferred across classes),
//! but an admitted request is never preempted — and a waiting batch
//! request is force-served after [`FairQueue::batch_every`] consecutive
//! interactive grants, so batch is delayed, never starved.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::json::{obj, Json};

/// Scheduling class for a tenant's requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive: preferred at the queue head (may overtake batch
    /// queue positions, never running sessions).
    Interactive,
    /// Throughput work: served in fair order, guaranteed a grant at least
    /// every `batch_every` interactive grants.
    Batch,
}

/// One tenant's static configuration, parsed from the `--tenants` spec.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantConfig {
    /// Tenant name, matched case-sensitively against `X-Parataa-Tenant`.
    pub name: String,
    /// Fair-share weight (>= 1): completed-request shares under contention
    /// are proportional to weights.
    pub weight: u32,
    /// Sustained requests-per-second quota; `None` = unlimited.
    pub rps: Option<f64>,
    /// Token-bucket burst size (instantaneous credit), >= 1.
    pub burst: u32,
    /// Scheduling class.
    pub priority: Priority,
}

impl TenantConfig {
    /// An unlimited, weight-1, interactive tenant (open-mode default).
    pub fn open(name: &str) -> TenantConfig {
        TenantConfig {
            name: name.to_string(),
            weight: 1,
            rps: None,
            burst: 1,
            priority: Priority::Interactive,
        }
    }
}

/// Parse the `--tenants` spec grammar:
/// `name:key=val[,key=val...][;name:...]` with keys `weight` (integer
/// >= 1), `rps` (float > 0), `burst` (integer >= 1) and `class`
/// (`interactive` | `batch`). A bare `name` (no `:`) takes all defaults.
///
/// ```
/// use parataa::serve::tenant::{parse_tenant_spec, Priority};
/// let ts = parse_tenant_spec("acme:weight=3,rps=10,burst=5;bulk:class=batch").unwrap();
/// assert_eq!(ts.len(), 2);
/// assert_eq!(ts[0].weight, 3);
/// assert_eq!(ts[1].priority, Priority::Batch);
/// ```
pub fn parse_tenant_spec(spec: &str) -> Result<Vec<TenantConfig>, String> {
    let mut out: Vec<TenantConfig> = Vec::new();
    for part in spec.split(';').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        let (name, kvs) = match part.split_once(':') {
            Some((n, k)) => (n.trim(), k.trim()),
            None => (part, ""),
        };
        if name.is_empty() {
            return Err(format!("tenant entry `{part}` has an empty name"));
        }
        if out.iter().any(|t| t.name == name) {
            return Err(format!("duplicate tenant `{name}`"));
        }
        let mut cfg = TenantConfig::open(name);
        for kv in kvs.split(',').filter(|s| !s.trim().is_empty()) {
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| format!("tenant `{name}`: `{kv}` is not key=value"))?;
            let (key, val) = (key.trim(), val.trim());
            match key {
                "weight" => {
                    let w: u32 = val
                        .parse()
                        .map_err(|_| format!("tenant `{name}`: weight `{val}` is not an integer"))?;
                    if w == 0 {
                        return Err(format!("tenant `{name}`: weight must be >= 1"));
                    }
                    cfg.weight = w;
                }
                "rps" => {
                    let r: f64 = val
                        .parse()
                        .map_err(|_| format!("tenant `{name}`: rps `{val}` is not a number"))?;
                    if !(r > 0.0) || !r.is_finite() {
                        return Err(format!("tenant `{name}`: rps must be a finite positive number"));
                    }
                    cfg.rps = Some(r);
                }
                "burst" => {
                    let b: u32 = val
                        .parse()
                        .map_err(|_| format!("tenant `{name}`: burst `{val}` is not an integer"))?;
                    if b == 0 {
                        return Err(format!("tenant `{name}`: burst must be >= 1"));
                    }
                    cfg.burst = b;
                }
                "class" => {
                    cfg.priority = match val {
                        "interactive" => Priority::Interactive,
                        "batch" => Priority::Batch,
                        other => {
                            return Err(format!(
                                "tenant `{name}`: class `{other}` is not `interactive` or `batch`"
                            ))
                        }
                    };
                }
                other => return Err(format!("tenant `{name}`: unknown key `{other}`")),
            }
        }
        out.push(cfg);
    }
    if out.is_empty() {
        return Err("tenant spec is empty".to_string());
    }
    Ok(out)
}

// --- token bucket ---------------------------------------------------------

/// A deterministic token bucket: `rate` tokens/second refill, capped at
/// `burst`. The clock is injected (`now_ns`, any monotonic nanosecond
/// counter), so quota behaviour is exactly reproducible under test.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last_ns: u64,
}

impl TokenBucket {
    /// A bucket refilling at `rate`/s, starting (and capped) at `burst`.
    pub fn new(rate: f64, burst: u32) -> TokenBucket {
        TokenBucket { rate, burst: burst as f64, tokens: burst as f64, last_ns: 0 }
    }

    /// Take one token at time `now_ns`. On refusal returns the seconds
    /// until a token will be available (the `Retry-After` hint). `now_ns`
    /// must be monotonically non-decreasing across calls; regressions are
    /// clamped (no refill, no panic).
    pub fn try_take(&mut self, now_ns: u64) -> Result<(), f64> {
        let dt = now_ns.saturating_sub(self.last_ns) as f64 / 1e9;
        self.last_ns = self.last_ns.max(now_ns);
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else if self.rate > 0.0 {
            Err((1.0 - self.tokens) / self.rate)
        } else {
            Err(f64::INFINITY)
        }
    }
}

// --- weighted fair queue --------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct Entry {
    vf: f64,
    seq: u64,
    ticket: u64,
    tenant: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    // Reversed so the std max-heap pops the *smallest* virtual finish
    // time first (FIFO by arrival on ties).
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .vf
            .total_cmp(&self.vf)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic weighted fair queue with two priority classes.
///
/// Each pushed ticket gets a virtual finish time
/// `vf = max(global_vtime, tenant_last_vf) + 1/weight`, the classic WFQ
/// recurrence: a weight-3 tenant's finish times advance 3× slower than a
/// weight-1 tenant's, so under sustained contention its grant share is 3×
/// larger. `pop` serves the smallest `vf` in the interactive class,
/// except that after [`Self::batch_every`] consecutive interactive grants
/// with batch work waiting, the next grant is forced from the batch class
/// (anti-starvation bound, pinned by `tests/http_fairness.rs`).
#[derive(Debug)]
pub struct FairQueue {
    interactive: BinaryHeap<Entry>,
    batch: BinaryHeap<Entry>,
    vtime: f64,
    last_vf: Vec<f64>,
    consecutive_interactive: usize,
    batch_every: usize,
    seq: u64,
}

impl FairQueue {
    /// An empty queue whose batch class is force-served after
    /// `batch_every` consecutive interactive grants (0 is clamped to 1).
    pub fn new(batch_every: usize) -> FairQueue {
        FairQueue {
            interactive: BinaryHeap::new(),
            batch: BinaryHeap::new(),
            vtime: 0.0,
            last_vf: Vec::new(),
            consecutive_interactive: 0,
            batch_every: batch_every.max(1),
            seq: 0,
        }
    }

    /// The anti-starvation bound: at most this many consecutive
    /// interactive grants while batch work waits.
    pub fn batch_every(&self) -> usize {
        self.batch_every
    }

    /// Queue `ticket` for `tenant` (a dense index) at `weight`.
    pub fn push(&mut self, ticket: u64, tenant: usize, weight: u32, priority: Priority) {
        if self.last_vf.len() <= tenant {
            self.last_vf.resize(tenant + 1, 0.0);
        }
        let vf = self.vtime.max(self.last_vf[tenant]) + 1.0 / f64::from(weight.max(1));
        self.last_vf[tenant] = vf;
        let e = Entry { vf, seq: self.seq, ticket, tenant };
        self.seq += 1;
        match priority {
            Priority::Interactive => self.interactive.push(e),
            Priority::Batch => self.batch.push(e),
        }
    }

    /// Grant the next ticket, or `None` if the queue is empty. Returns
    /// `(ticket, tenant)`.
    pub fn pop(&mut self) -> Option<(u64, usize)> {
        let force_batch = !self.batch.is_empty()
            && (self.interactive.is_empty()
                || self.consecutive_interactive >= self.batch_every);
        let e = if force_batch {
            self.consecutive_interactive = 0;
            self.batch.pop()?
        } else {
            match self.interactive.pop() {
                Some(e) => {
                    self.consecutive_interactive += 1;
                    e
                }
                None => return None,
            }
        };
        self.vtime = self.vtime.max(e.vf);
        Some((e.ticket, e.tenant))
    }

    /// Total queued tickets across both classes.
    pub fn len(&self) -> usize {
        self.interactive.len() + self.batch.len()
    }

    /// True when no tickets are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// --- blocking gate --------------------------------------------------------

struct GateState {
    queue: FairQueue,
    granted: BTreeSet<u64>,
    in_service: usize,
    capacity: usize,
    next_ticket: u64,
    closed: bool,
}

impl GateState {
    fn grant_ready(&mut self) -> bool {
        let mut any = false;
        while self.in_service < self.capacity {
            match self.queue.pop() {
                Some((ticket, _tenant)) => {
                    self.granted.insert(ticket);
                    self.in_service += 1;
                    any = true;
                }
                None => break,
            }
        }
        any
    }
}

struct GateInner {
    state: Mutex<GateState>,
    cv: Condvar,
}

/// Blocking concurrency gate in weighted-fair order.
///
/// At most `capacity` permits are outstanding; excess callers block in
/// [`FairQueue`] order (not arrival order), so the HTTP accept threads
/// enforce fairness *before* requests reach the coordinator's intake
/// queue. No barging: a freed permit always goes to the queue head.
pub struct FairGate {
    inner: Arc<GateInner>,
}

/// An in-service permit; dropping it frees the slot and wakes the queue.
pub struct FairPermit {
    inner: Arc<GateInner>,
}

impl Drop for FairPermit {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock().unwrap();
        st.in_service -= 1;
        st.grant_ready();
        drop(st);
        self.inner.cv.notify_all();
    }
}

impl FairGate {
    /// A gate admitting `capacity` concurrent requests (0 clamps to 1),
    /// force-serving batch after `batch_every` interactive grants.
    pub fn new(capacity: usize, batch_every: usize) -> FairGate {
        FairGate {
            inner: Arc::new(GateInner {
                state: Mutex::new(GateState {
                    queue: FairQueue::new(batch_every),
                    granted: BTreeSet::new(),
                    in_service: 0,
                    capacity: capacity.max(1),
                    next_ticket: 0,
                    closed: false,
                }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Block until this request reaches the fair-queue head and a slot is
    /// free; `None` once the gate is closed (server shutdown).
    pub fn acquire(&self, tenant: usize, weight: u32, priority: Priority) -> Option<FairPermit> {
        let mut st = self.inner.state.lock().unwrap();
        if st.closed {
            return None;
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.queue.push(ticket, tenant, weight, priority);
        loop {
            if st.grant_ready() {
                self.inner.cv.notify_all();
            }
            if st.granted.remove(&ticket) {
                return Some(FairPermit { inner: Arc::clone(&self.inner) });
            }
            if st.closed {
                return None;
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    /// Close the gate: blocked `acquire`s return `None`; in-service
    /// permits drain normally.
    pub fn close(&self) {
        let mut st = self.inner.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.cv.notify_all();
    }
}

// --- registry -------------------------------------------------------------

/// Per-tenant outcome counters (all monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantCounters {
    /// Requests past the token bucket and into the fair gate.
    pub admitted: u64,
    /// Requests answered 2xx.
    pub completed: u64,
    /// Admitted requests that failed (4xx/5xx after admission).
    pub failed: u64,
    /// Requests refused 429 by the token bucket.
    pub throttled: u64,
}

struct TenantState {
    config: TenantConfig,
    bucket: Option<TokenBucket>,
    counters: TenantCounters,
}

struct RegistryInner {
    tenants: Vec<TenantState>,
    by_name: BTreeMap<String, usize>,
    open: bool,
}

/// Outcome of resolving/admitting a request's tenant at the front door.
#[derive(Debug, Clone, PartialEq)]
pub enum AdmitError {
    /// The named tenant is not in the configured table (HTTP 403).
    UnknownTenant(String),
    /// The tenant is over its rate quota; retry after this many seconds
    /// (HTTP 429 + `Retry-After`).
    OverQuota(f64),
}

/// The tenant table: name → config, token-bucket state, and counters.
///
/// In *open* mode (no `--tenants` spec) any presented tenant name is
/// auto-registered unlimited; in *configured* mode unknown names are
/// refused. A missing `X-Parataa-Tenant` header resolves to `"default"`
/// in both modes (configured mode refuses it unless a `default` tenant is
/// declared).
pub struct TenantRegistry {
    inner: Mutex<RegistryInner>,
}

impl TenantRegistry {
    /// Open-mode registry: tenants auto-register, unlimited quota.
    pub fn open() -> TenantRegistry {
        TenantRegistry {
            inner: Mutex::new(RegistryInner {
                tenants: Vec::new(),
                by_name: BTreeMap::new(),
                open: true,
            }),
        }
    }

    /// Configured-mode registry over a parsed `--tenants` table.
    pub fn configured(configs: Vec<TenantConfig>) -> TenantRegistry {
        let mut inner =
            RegistryInner { tenants: Vec::new(), by_name: BTreeMap::new(), open: false };
        for cfg in configs {
            let idx = inner.tenants.len();
            inner.by_name.insert(cfg.name.clone(), idx);
            let bucket = cfg.rps.map(|r| TokenBucket::new(r, cfg.burst));
            inner.tenants.push(TenantState { config: cfg, bucket, counters: TenantCounters::default() });
        }
        TenantRegistry { inner: Mutex::new(inner) }
    }

    /// Build from an optional spec string: `None`/empty → open mode.
    pub fn from_spec(spec: Option<&str>) -> Result<TenantRegistry, String> {
        match spec {
            None => Ok(TenantRegistry::open()),
            Some(s) if s.trim().is_empty() => Ok(TenantRegistry::open()),
            Some(s) => Ok(TenantRegistry::configured(parse_tenant_spec(s)?)),
        }
    }

    /// Resolve the request's tenant header and charge its token bucket at
    /// `now_ns`. On success returns `(tenant_index, weight, priority)` for
    /// the fair gate and bumps `admitted`.
    pub fn admit(
        &self,
        header: Option<&str>,
        now_ns: u64,
    ) -> Result<(usize, u32, Priority), AdmitError> {
        let name = header.unwrap_or("default");
        let mut inner = self.inner.lock().unwrap();
        let idx = match inner.by_name.get(name) {
            Some(&i) => i,
            None if inner.open => {
                let idx = inner.tenants.len();
                inner.by_name.insert(name.to_string(), idx);
                inner.tenants.push(TenantState {
                    config: TenantConfig::open(name),
                    bucket: None,
                    counters: TenantCounters::default(),
                });
                idx
            }
            None => return Err(AdmitError::UnknownTenant(name.to_string())),
        };
        let t = &mut inner.tenants[idx];
        if let Some(bucket) = t.bucket.as_mut() {
            if let Err(retry_after) = bucket.try_take(now_ns) {
                t.counters.throttled += 1;
                return Err(AdmitError::OverQuota(retry_after));
            }
        }
        t.counters.admitted += 1;
        Ok((idx, t.config.weight, t.config.priority))
    }

    /// Record an admitted request's terminal outcome.
    pub fn record_outcome(&self, tenant: usize, completed: bool) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(t) = inner.tenants.get_mut(tenant) {
            if completed {
                t.counters.completed += 1;
            } else {
                t.counters.failed += 1;
            }
        }
    }

    /// Snapshot `(name, counters)` for every known tenant, in name order.
    pub fn snapshot(&self) -> Vec<(String, TenantCounters)> {
        let inner = self.inner.lock().unwrap();
        inner
            .by_name
            .iter()
            .map(|(name, &i)| (name.clone(), inner.tenants[i].counters))
            .collect()
    }

    /// Append the per-tenant Prometheus text-format lines (labeled
    /// counters, one metric family) for `GET /metrics`.
    pub fn render_prom(&self, out: &mut String) {
        out.push_str("# HELP parataa_tenant_requests_total Per-tenant request outcomes at the HTTP front.\n");
        out.push_str("# TYPE parataa_tenant_requests_total counter\n");
        for (name, c) in self.snapshot() {
            for (outcome, v) in [
                ("admitted", c.admitted),
                ("completed", c.completed),
                ("failed", c.failed),
                ("throttled", c.throttled),
            ] {
                out.push_str(&format!(
                    "parataa_tenant_requests_total{{tenant=\"{name}\",outcome=\"{outcome}\"}} {v}\n"
                ));
            }
        }
    }

    /// Per-tenant counters as JSON (tenant name → outcome counts).
    pub fn to_json(&self) -> Json {
        let mut tenants = BTreeMap::new();
        for (name, c) in self.snapshot() {
            tenants.insert(
                name,
                obj(vec![
                    ("admitted", Json::Num(c.admitted as f64)),
                    ("completed", Json::Num(c.completed as f64)),
                    ("failed", Json::Num(c.failed as f64)),
                    ("throttled", Json::Num(c.throttled as f64)),
                ]),
            );
        }
        Json::Obj(tenants)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_parses_and_rejects() {
        let ts = parse_tenant_spec("a:weight=3,rps=10,burst=5;b:class=batch;c").unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!((ts[0].weight, ts[0].rps, ts[0].burst), (3, Some(10.0), 5));
        assert_eq!(ts[1].priority, Priority::Batch);
        assert_eq!(ts[2], TenantConfig::open("c"));
        for bad in [
            "", "a:weight=0", "a:rps=-1", "a:burst=0", "a:class=fast", "a:oops=1",
            "a;a", "a:weight", ":weight=1",
        ] {
            assert!(parse_tenant_spec(bad).is_err(), "spec `{bad}` should be rejected");
        }
    }

    #[test]
    fn token_bucket_is_deterministic_under_an_injected_clock() {
        let mut b = TokenBucket::new(2.0, 2); // 2 rps, burst 2
        assert!(b.try_take(0).is_ok());
        assert!(b.try_take(0).is_ok());
        let retry = b.try_take(0).unwrap_err();
        assert!((retry - 0.5).abs() < 1e-9, "empty bucket at 2 rps refills in 0.5s, got {retry}");
        // 500ms later exactly one token has accrued.
        assert!(b.try_take(500_000_000).is_ok());
        assert!(b.try_take(500_000_000).is_err());
        // A clock regression neither panics nor refills.
        assert!(b.try_take(100).is_err());
    }

    #[test]
    fn fair_queue_prefers_weight_and_bounds_batch_wait() {
        let mut q = FairQueue::new(2);
        // Tenant 0 (weight 3) and tenant 1 (weight 1), 6 tickets each.
        for i in 0..6 {
            q.push(i, 0, 3, Priority::Interactive);
            q.push(100 + i, 1, 1, Priority::Interactive);
        }
        q.push(500, 2, 1, Priority::Batch);
        let mut grants = Vec::new();
        while let Some((t, _)) = q.pop() {
            grants.push(t);
        }
        // The batch ticket lands within batch_every + 1 grants of the head.
        let batch_pos = grants.iter().position(|&t| t == 500).unwrap();
        assert!(batch_pos <= 2, "batch served by grant {batch_pos}, bound is 2");
        // Of the first 8 grants, the weight-3 tenant holds roughly 3/4 of
        // the interactive ones.
        let heavy = grants.iter().take(8).filter(|&&t| t < 100).count();
        assert!(heavy >= 4, "weight-3 tenant got only {heavy} of the first 8 grants");
    }

    #[test]
    fn fair_gate_caps_concurrency_and_closes() {
        let gate = Arc::new(FairGate::new(1, 4));
        let p = gate.acquire(0, 1, Priority::Interactive).unwrap();
        let g2 = Arc::clone(&gate);
        let waiter = std::thread::spawn(move || g2.acquire(0, 1, Priority::Interactive).is_some());
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(p); // frees the slot → waiter gets the permit
        assert!(waiter.join().unwrap());
        gate.close();
        assert!(gate.acquire(0, 1, Priority::Interactive).is_none());
    }

    #[test]
    fn registry_modes_admit_throttle_and_count() {
        let open = TenantRegistry::open();
        assert!(open.admit(Some("anyone"), 0).is_ok());
        assert!(open.admit(None, 0).is_ok()); // → "default"

        let reg = TenantRegistry::from_spec(Some("a:rps=1,burst=1;b")).unwrap();
        assert!(matches!(
            reg.admit(Some("ghost"), 0),
            Err(AdmitError::UnknownTenant(_))
        ));
        assert!(reg.admit(Some("a"), 0).is_ok());
        assert!(matches!(reg.admit(Some("a"), 0), Err(AdmitError::OverQuota(_))));
        assert!(reg.admit(Some("b"), 0).is_ok(), "tenant b is unaffected by a's quota");
        reg.record_outcome(0, true);
        let snap = reg.snapshot();
        let a = &snap.iter().find(|(n, _)| n == "a").unwrap().1;
        assert_eq!((a.admitted, a.completed, a.throttled), (1, 1, 1));
        let mut prom = String::new();
        reg.render_prom(&mut prom);
        assert!(prom.contains("parataa_tenant_requests_total{tenant=\"a\",outcome=\"throttled\"} 1"));
    }
}
