//! JSON wire codec for the HTTP front: [`SampleRequest`] /
//! [`SampleResponse`] / [`PrefixChunk`] ⇄ [`Json`].
//!
//! The codec is the *only* numeric surface the transport adds, so it must
//! add nothing: a request serialized by [`request_to_json`] and re-parsed
//! by [`request_from_json`] compares equal field-for-field (floats
//! bitwise), and a served sample rendered by [`response_to_json`] decodes
//! to bit-identical `f32`s. Both properties hold because every `f32`
//! crossing the wire is widened to `f64` (exact), printed with Rust's
//! shortest-round-trip float formatting (exact), and narrowed back (exact
//! — the value *is* an `f32`); they are pinned by the round-trip and
//! parity-oracle suites in `tests/http_protocol.rs`. Integral fields ride
//! as JSON numbers and are exact up to 2^53 (an IEEE-double mantissa);
//! seeds above that are rejected at encode time rather than silently
//! rounded.
//!
//! Request schema (all fields except `seed` and `sampler.steps` optional,
//! defaulting to [`SampleRequest::parataa`]'s values — see
//! `docs/serving.md` for the full grammar and curl examples):
//!
//! ```json
//! {
//!   "cond": "uncond" | {"class": 3} | {"weights": [0.1, 0.9]},
//!   "seed": 7,
//!   "sampler": {"kind": "ddim" | "ddpm" | {"eta": 0.3}, "steps": 25},
//!   "guidance": 2.0,
//!   "method": "taa" | "fp" | "aa" | "aa+",
//!   "k": 4, "m": 3, "window": 8, "max_rounds": 64,
//!   "use_trajectory_cache": false,
//!   "window_policy": "fixed" | {"adaptive": {"min_window": 3, ...}},
//!   "strategy": "plain" | {"draft_refine": {...}} | {"parareal": {...}},
//!   "parallelism": 1,
//!   "deadline_ms": 500
//! }
//! ```

use crate::coordinator::{PrefixChunk, SampleRequest, SampleResponse, SamplerSpec};
use crate::model::Cond;
use crate::schedule::SamplerKind;
use crate::solver::{
    AdaptiveWindow, DraftRefineConfig, Method, PararealConfig, SolveStrategy, WindowPolicy,
};
use crate::util::json::{arr_f32, obj, Json};

/// Largest integer exactly representable in a JSON number (2^53).
const MAX_EXACT_INT: u64 = 1 << 53;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn get_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| format!("`{key}` must be a non-negative integer")),
    }
}

fn get_f64(j: &Json, key: &str) -> Result<Option<f64>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v.as_f64().map(Some).ok_or_else(|| format!("`{key}` must be a number")),
    }
}

fn get_bool(j: &Json, key: &str) -> Result<Option<bool>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(format!("`{key}` must be a boolean")),
    }
}

// --- encode ---------------------------------------------------------------

fn cond_to_json(c: &Cond) -> Json {
    match c {
        Cond::Uncond => Json::Str("uncond".to_string()),
        Cond::Class(i) => obj(vec![("class", num(*i as f64))]),
        Cond::Weights(w) => obj(vec![("weights", arr_f32(w))]),
    }
}

fn sampler_to_json(s: &SamplerSpec) -> Json {
    let kind = match s.kind {
        SamplerKind::Ddim => Json::Str("ddim".to_string()),
        SamplerKind::Ddpm => Json::Str("ddpm".to_string()),
        SamplerKind::Eta(e) => obj(vec![("eta", num(e))]),
    };
    obj(vec![("kind", kind), ("steps", num(s.steps as f64))])
}

fn method_label(m: Method) -> &'static str {
    match m {
        Method::FixedPoint => "fp",
        Method::AndersonStd => "aa",
        Method::AndersonUpperTri => "aa+",
        Method::Taa => "taa",
    }
}

fn window_policy_to_json(p: &WindowPolicy) -> Json {
    match p {
        WindowPolicy::Fixed => Json::Str("fixed".to_string()),
        WindowPolicy::Adaptive(a) => obj(vec![(
            "adaptive",
            obj(vec![
                ("min_window", num(a.min_window as f64)),
                ("max_window", num(a.max_window as f64)),
                ("step", num(a.step as f64)),
                ("high_occupancy", num(a.high_occupancy)),
                ("grow_velocity", num(a.grow_velocity)),
            ]),
        )]),
    }
}

fn strategy_to_json(s: &SolveStrategy) -> Json {
    match s {
        SolveStrategy::PlainTaa => Json::Str("plain".to_string()),
        SolveStrategy::DraftRefine(c) => obj(vec![(
            "draft_refine",
            obj(vec![
                ("coarse_steps", num(c.coarse_steps as f64)),
                ("coarse_tol", num(c.coarse_tol)),
                ("max_draft_rounds", num(c.max_draft_rounds as f64)),
            ]),
        )]),
        SolveStrategy::Parareal(c) => {
            obj(vec![("parareal", obj(vec![("stride", num(c.stride as f64))]))])
        }
    }
}

/// Serialize a request to its wire JSON (the exact form
/// [`request_from_json`] re-parses bitwise). Fails only on a seed above
/// 2^53, which a JSON number cannot carry exactly.
pub fn request_to_json(req: &SampleRequest) -> Result<Json, String> {
    if req.seed > MAX_EXACT_INT {
        return Err(format!("seed {} exceeds 2^53 (not exact in JSON)", req.seed));
    }
    let mut pairs: Vec<(&str, Json)> = vec![
        ("cond", cond_to_json(&req.cond)),
        ("seed", num(req.seed as f64)),
        ("sampler", sampler_to_json(&req.sampler)),
        ("guidance", num(req.guidance as f64)),
        ("method", Json::Str(method_label(req.method).to_string())),
        ("m", num(req.m as f64)),
        ("use_trajectory_cache", Json::Bool(req.use_trajectory_cache)),
        ("window_policy", window_policy_to_json(&req.window_policy)),
        ("strategy", strategy_to_json(&req.strategy)),
        ("parallelism", num(req.parallelism as f64)),
    ];
    if let Some(k) = req.k {
        pairs.push(("k", num(k as f64)));
    }
    if let Some(w) = req.window {
        pairs.push(("window", num(w as f64)));
    }
    if let Some(r) = req.max_rounds {
        pairs.push(("max_rounds", num(r as f64)));
    }
    if let Some(ms) = req.deadline_ms {
        if ms > MAX_EXACT_INT {
            return Err(format!("deadline_ms {ms} exceeds 2^53 (not exact in JSON)"));
        }
        pairs.push(("deadline_ms", num(ms as f64)));
    }
    Ok(obj(pairs))
}

// --- decode ---------------------------------------------------------------

fn cond_from_json(j: &Json) -> Result<Cond, String> {
    match j {
        Json::Str(s) if s == "uncond" => Ok(Cond::Uncond),
        Json::Obj(_) => {
            if let Some(c) = j.get("class") {
                return c
                    .as_usize()
                    .map(Cond::Class)
                    .ok_or_else(|| "`cond.class` must be a non-negative integer".to_string());
            }
            if let Some(w) = j.get("weights") {
                let w = w
                    .as_f32_vec()
                    .ok_or_else(|| "`cond.weights` must be an array of numbers".to_string())?;
                if w.is_empty() {
                    return Err("`cond.weights` must be non-empty".to_string());
                }
                if w.iter().any(|x| !x.is_finite()) {
                    return Err("`cond.weights` must be finite".to_string());
                }
                return Ok(Cond::Weights(w));
            }
            Err("`cond` object needs `class` or `weights`".to_string())
        }
        _ => Err("`cond` must be \"uncond\", {\"class\": n} or {\"weights\": [...]}".to_string()),
    }
}

fn sampler_from_json(j: &Json) -> Result<SamplerSpec, String> {
    let steps = get_usize(j, "steps")?.ok_or("`sampler.steps` is required")?;
    if steps == 0 || steps > 10_000 {
        return Err(format!("`sampler.steps` must be in [1, 10000], got {steps}"));
    }
    let kind = match j.get("kind") {
        None | Some(Json::Null) => SamplerKind::Ddim,
        Some(Json::Str(s)) => match s.as_str() {
            "ddim" => SamplerKind::Ddim,
            "ddpm" => SamplerKind::Ddpm,
            other => return Err(format!("unknown sampler kind `{other}`")),
        },
        Some(k) => {
            let eta = get_f64(k, "eta")?
                .ok_or("`sampler.kind` must be \"ddim\", \"ddpm\" or {\"eta\": x}")?;
            if !(0.0..=1.0).contains(&eta) {
                return Err(format!("`sampler.kind.eta` must be in [0, 1], got {eta}"));
            }
            SamplerKind::Eta(eta)
        }
    };
    Ok(SamplerSpec { kind, steps })
}

fn method_from_json(j: &Json) -> Result<Method, String> {
    match j.as_str() {
        Some("fp") => Ok(Method::FixedPoint),
        Some("aa") => Ok(Method::AndersonStd),
        Some("aa+") => Ok(Method::AndersonUpperTri),
        Some("taa") => Ok(Method::Taa),
        _ => Err("`method` must be \"fp\", \"aa\", \"aa+\" or \"taa\"".to_string()),
    }
}

fn window_policy_from_json(j: &Json) -> Result<WindowPolicy, String> {
    match j {
        Json::Str(s) if s == "fixed" => Ok(WindowPolicy::Fixed),
        Json::Obj(_) => {
            let a = j.get("adaptive").ok_or("`window_policy` object needs `adaptive`")?;
            let min_window =
                get_usize(a, "min_window")?.ok_or("`adaptive.min_window` is required")?;
            let max_window =
                get_usize(a, "max_window")?.ok_or("`adaptive.max_window` is required")?;
            if min_window == 0 || max_window < min_window {
                return Err(format!(
                    "adaptive window bounds must satisfy 1 <= min ({min_window}) <= max ({max_window})"
                ));
            }
            Ok(WindowPolicy::Adaptive(AdaptiveWindow {
                min_window,
                max_window,
                step: get_usize(a, "step")?.unwrap_or(1).max(1),
                high_occupancy: get_f64(a, "high_occupancy")?.unwrap_or(0.85),
                grow_velocity: get_f64(a, "grow_velocity")?.unwrap_or(0.25),
            }))
        }
        _ => Err("`window_policy` must be \"fixed\" or {\"adaptive\": {...}}".to_string()),
    }
}

fn strategy_from_json(j: &Json) -> Result<SolveStrategy, String> {
    match j {
        Json::Str(s) if s == "plain" => Ok(SolveStrategy::PlainTaa),
        Json::Obj(_) => {
            if let Some(c) = j.get("draft_refine") {
                return Ok(SolveStrategy::DraftRefine(DraftRefineConfig {
                    coarse_steps: get_usize(c, "coarse_steps")?.unwrap_or(0),
                    coarse_tol: get_f64(c, "coarse_tol")?.unwrap_or(0.0),
                    max_draft_rounds: get_usize(c, "max_draft_rounds")?.unwrap_or(0),
                }));
            }
            if let Some(c) = j.get("parareal") {
                return Ok(SolveStrategy::Parareal(PararealConfig {
                    stride: get_usize(c, "stride")?.unwrap_or(0),
                }));
            }
            Err("`strategy` object needs `draft_refine` or `parareal`".to_string())
        }
        _ => Err(
            "`strategy` must be \"plain\", {\"draft_refine\": {...}} or {\"parareal\": {...}}"
                .to_string(),
        ),
    }
}

/// Parse a wire-JSON request body into a [`SampleRequest`]. Missing
/// optional fields take [`SampleRequest::parataa`]'s defaults; any
/// malformed field yields a human-readable error (→ HTTP 400), never a
/// panic.
pub fn request_from_json(j: &Json) -> Result<SampleRequest, String> {
    if !matches!(j, Json::Obj(_)) {
        return Err("request body must be a JSON object".to_string());
    }
    let seed_f = get_f64(j, "seed")?.ok_or("`seed` is required")?;
    if seed_f < 0.0 || seed_f.fract() != 0.0 || seed_f > MAX_EXACT_INT as f64 {
        return Err(format!("`seed` must be an integer in [0, 2^53], got {seed_f}"));
    }
    let seed = seed_f as u64;
    let sampler =
        sampler_from_json(j.get("sampler").ok_or("`sampler` is required")?)?;
    let cond = match j.get("cond") {
        None | Some(Json::Null) => Cond::Uncond,
        Some(c) => cond_from_json(c)?,
    };
    let mut req = SampleRequest::parataa(cond, seed, sampler);
    if let Some(g) = get_f64(j, "guidance")? {
        if !g.is_finite() {
            return Err("`guidance` must be finite".to_string());
        }
        req.guidance = g as f32;
    }
    if let Some(m) = j.get("method") {
        req.method = method_from_json(m)?;
    }
    if let Some(k) = get_usize(j, "k")? {
        req.k = Some(k);
    }
    if let Some(m) = get_usize(j, "m")? {
        if m == 0 {
            return Err("`m` must be >= 1".to_string());
        }
        req.m = m;
    }
    if let Some(w) = get_usize(j, "window")? {
        req.window = Some(w);
    }
    if let Some(r) = get_usize(j, "max_rounds")? {
        req.max_rounds = Some(r);
    }
    if let Some(b) = get_bool(j, "use_trajectory_cache")? {
        req.use_trajectory_cache = b;
    }
    if let Some(p) = j.get("window_policy") {
        req.window_policy = window_policy_from_json(p)?;
    }
    if let Some(s) = j.get("strategy") {
        req.strategy = strategy_from_json(s)?;
    }
    if let Some(p) = get_usize(j, "parallelism")? {
        if p == 0 || p > 64 {
            return Err(format!("`parallelism` must be in [1, 64], got {p}"));
        }
        req.parallelism = p;
    }
    if let Some(ms) = get_f64(j, "deadline_ms")? {
        if ms < 0.0 || ms.fract() != 0.0 || ms > MAX_EXACT_INT as f64 {
            return Err(format!("`deadline_ms` must be an integer in [0, 2^53], got {ms}"));
        }
        req.deadline_ms = Some(ms as u64);
    }
    Ok(req)
}

// --- responses ------------------------------------------------------------

/// Serialize a served response (the `POST /v1/sample` 200 body and the SSE
/// `done` event payload). The `sample` floats decode bit-identically.
pub fn response_to_json(r: &SampleResponse) -> Json {
    obj(vec![
        ("sample", arr_f32(&r.sample)),
        ("rounds", num(r.rounds as f64)),
        ("nfe", num(r.nfe as f64)),
        ("converged", Json::Bool(r.converged)),
        ("warm_started", Json::Bool(r.warm_started)),
        ("degraded", Json::Bool(r.degraded)),
        ("latency_ms", num(r.latency.as_secs_f64() * 1e3)),
    ])
}

/// Serialize one streaming prefix chunk (the SSE `chunk` event payload).
/// `residuals` may carry `NaN` for warm-started rows; the JSON writer maps
/// non-finite numbers to `null`.
pub fn chunk_to_json(c: &PrefixChunk) -> Json {
    obj(vec![
        ("rows_start", num(c.rows.start as f64)),
        ("rows_end", num(c.rows.end as f64)),
        ("round", num(c.round as f64)),
        ("states", arr_f32(&c.states)),
        (
            "residuals",
            Json::Arr(c.residuals.iter().map(|r| Json::Num(*r)).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn roundtrip(req: &SampleRequest) -> SampleRequest {
        let text = request_to_json(req).expect("encode").to_string();
        request_from_json(&parse(&text).expect("self-encoded JSON parses")).expect("decode")
    }

    #[test]
    fn default_request_roundtrips() {
        let req = SampleRequest::parataa(Cond::Class(3), 7, SamplerSpec::ddim(25));
        assert_eq!(roundtrip(&req), req);
    }

    #[test]
    fn fully_populated_request_roundtrips() {
        let mut req = SampleRequest::parataa(
            Cond::Weights(vec![0.25, 0.5, 0.25]),
            (1 << 53) - 1,
            SamplerSpec { kind: SamplerKind::Eta(0.37), steps: 40 },
        );
        req.guidance = 7.125;
        req.method = Method::AndersonUpperTri;
        req.k = Some(6);
        req.m = 5;
        req.window = Some(10);
        req.max_rounds = Some(99);
        req.use_trajectory_cache = true;
        req.window_policy = WindowPolicy::Adaptive(AdaptiveWindow::for_steps(40));
        req.strategy = SolveStrategy::DraftRefine(DraftRefineConfig {
            coarse_steps: 8,
            coarse_tol: 1e-3,
            max_draft_rounds: 11,
        });
        req.parallelism = 4;
        req.deadline_ms = Some(1500);
        assert_eq!(roundtrip(&req), req);
    }

    #[test]
    fn minimal_body_takes_parataa_defaults() {
        let j = parse(r#"{"seed": 3, "sampler": {"steps": 16}}"#).unwrap();
        let req = request_from_json(&j).unwrap();
        assert_eq!(req, SampleRequest::parataa(Cond::Uncond, 3, SamplerSpec::ddim(16)));
    }

    #[test]
    fn malformed_bodies_are_classified_errors() {
        for (body, needle) in [
            (r#"[1, 2]"#, "object"),
            (r#"{"sampler": {"steps": 16}}"#, "`seed`"),
            (r#"{"seed": 1}"#, "`sampler`"),
            (r#"{"seed": 1, "sampler": {"steps": 0}}"#, "steps"),
            (r#"{"seed": 1.5, "sampler": {"steps": 8}}"#, "`seed`"),
            (r#"{"seed": 1, "sampler": {"steps": 8}, "method": "newton"}"#, "method"),
            (r#"{"seed": 1, "sampler": {"steps": 8}, "cond": {"weights": []}}"#, "weights"),
            (r#"{"seed": 1, "sampler": {"steps": 8}, "parallelism": 0}"#, "parallelism"),
            (r#"{"seed": 1, "sampler": {"steps": 8}, "deadline_ms": -4}"#, "deadline_ms"),
        ] {
            let j = parse(body).expect("test bodies are syntactically valid JSON");
            let err = request_from_json(&j).expect_err(body);
            assert!(err.contains(needle), "error for {body} should mention {needle}: {err}");
        }
    }

    #[test]
    fn response_sample_roundtrips_bitwise() {
        let resp = SampleResponse {
            sample: vec![0.1, -2.5e-8, 3.25, f32::MIN_POSITIVE],
            rounds: 9,
            nfe: 120,
            converged: true,
            warm_started: false,
            degraded: false,
            latency: std::time::Duration::from_millis(12),
        };
        let j = parse(&response_to_json(&resp).to_string()).unwrap();
        let back = j.get("sample").and_then(|s| s.as_f32_vec()).unwrap();
        let bits: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = resp.sample.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, want, "the wire must add zero numeric surface");
        assert_eq!(j.get("rounds").and_then(|v| v.as_usize()), Some(9));
    }

    #[test]
    fn chunk_json_carries_rows_and_nan_residuals_as_null() {
        let c = PrefixChunk {
            rows: 3..5,
            states: vec![1.0; 4],
            residuals: vec![f64::NAN, 0.25],
            round: 2,
        };
        let j = parse(&chunk_to_json(&c).to_string()).unwrap();
        assert_eq!(j.get("rows_start").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(j.get("rows_end").and_then(|v| v.as_usize()), Some(5));
        let res = j.get("residuals").and_then(|r| r.as_arr()).unwrap();
        assert!(matches!(res[0], Json::Null), "NaN residual rides as null");
        assert_eq!(res[1].as_f64(), Some(0.25));
    }
}
