//! Zero-dependency HTTP/1.1 front for the coordinator.
//!
//! A small accept pool (`N` threads sharing one `TcpListener` via
//! `try_clone`) serves connections *inline* — concurrency is bounded by
//! the pool size, and per-request concurrency into the coordinator is
//! bounded again by the [`FairGate`]. The request parser is hand-rolled
//! and hostile-input-safe: header and body sizes are capped, socket reads
//! carry a timeout (slow-loris → 408), and every malformed input maps to
//! a *classified* [`ParseError`] → 4xx — never a panic, never a leaked
//! coordinator slot (admission happens only after a body parses).
//!
//! Routes (full wire reference in `docs/serving.md`):
//!
//! | route                    | behaviour                                    |
//! |--------------------------|----------------------------------------------|
//! | `POST /v1/sample`        | JSON body → [`SampleRequest`] → one JSON response |
//! | `POST /v1/sample/stream` | same body; converged-prefix [`PrefixChunk`]s as SSE |
//! | `GET /metrics`           | Prometheus text (coordinator + per-tenant)   |
//! | `GET /healthz`           | device-health-aware liveness                 |
//!
//! Headers: `X-Parataa-Tenant` selects the tenant (quota + fair-share
//! class); `X-Parataa-Deadline-Ms` overrides the body's `deadline_ms`
//! (PR 9's deadline path — expiry is a 504). Over-quota tenants get 429 +
//! `Retry-After`; coordinator shedding ([`ErrorKind::Shed`]) also maps to
//! 429. A client that disconnects mid-SSE cancels its session
//! ([`StreamHandle::cancel`]) at the next round boundary, freeing its
//! slots for other tenants.

use std::io::{ErrorKind as IoErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::Coordinator;
use crate::serve::tenant::{AdmitError, FairGate, Priority, TenantRegistry};
use crate::serve::wire;
use crate::trace::prom;
use crate::util::error::{Error, ErrorKind};
use crate::util::json::{obj, parse, Json};

/// Tenant-selection header (case-insensitive on the wire).
pub const TENANT_HEADER: &str = "x-parataa-tenant";
/// Deadline-override header: milliseconds from receipt, as an integer.
pub const DEADLINE_HEADER: &str = "x-parataa-deadline-ms";

/// HTTP front configuration.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Accept-pool size = max concurrently served connections.
    pub accept_threads: usize,
    /// Cap on the request line + headers (431 beyond it).
    pub max_header_bytes: usize,
    /// Cap on a request body (413 beyond it, before reading it).
    pub max_body_bytes: usize,
    /// Socket read timeout: a connection idle mid-request this long is a
    /// slow-loris and gets 408.
    pub read_timeout: Duration,
    /// Max requests concurrently *in service* at the coordinator (the
    /// fair gate's capacity); excess queue in weighted-fair order.
    pub gate_capacity: usize,
    /// Anti-starvation bound: a waiting batch request is served after at
    /// most this many consecutive interactive grants.
    pub batch_every: usize,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            accept_threads: 4,
            max_header_bytes: 8 * 1024,
            max_body_bytes: 1024 * 1024,
            read_timeout: Duration::from_millis(2_000),
            gate_capacity: 8,
            batch_every: 4,
        }
    }
}

// --- request parsing ------------------------------------------------------

/// Classified request-parse failures; each maps to one 4xx/5xx status
/// ([`ParseError::status`]) and the table in `docs/robustness.md`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseError {
    /// The connection closed cleanly before a request started (no reply).
    Closed,
    /// Malformed request line (`METHOD SP TARGET SP VERSION`).
    BadRequestLine,
    /// A header line without a `:` separator, or a non-UTF-8 prefix.
    BadHeader,
    /// Request line + headers exceeded the configured cap (431).
    HeadersTooLarge,
    /// `Content-Length` exceeded the configured cap (413).
    BodyTooLarge,
    /// `Content-Length` was present but not a non-negative integer (400).
    BadContentLength,
    /// Not HTTP/1.0 or HTTP/1.1 (505).
    UnsupportedVersion,
    /// `Transfer-Encoding: chunked` — unimplemented by design (501).
    UnsupportedTransferEncoding,
    /// The socket idled past the read timeout mid-request (408).
    Timeout,
    /// Any other socket error mid-request (connection is dropped).
    Io(String),
}

impl ParseError {
    /// The HTTP status + reason this parse failure is answered with.
    /// `Closed` and `Io` get no reply (the peer is gone).
    pub fn status(&self) -> (u16, &'static str) {
        match self {
            ParseError::Closed | ParseError::Io(_) => (0, ""),
            ParseError::BadRequestLine | ParseError::BadHeader | ParseError::BadContentLength => {
                (400, "Bad Request")
            }
            ParseError::HeadersTooLarge => (431, "Request Header Fields Too Large"),
            ParseError::BodyTooLarge => (413, "Content Too Large"),
            ParseError::UnsupportedVersion => (505, "HTTP Version Not Supported"),
            ParseError::UnsupportedTransferEncoding => (501, "Not Implemented"),
            ParseError::Timeout => (408, "Request Timeout"),
        }
    }
}

/// A parsed request: method, target, lowercased headers, raw body.
#[derive(Debug, Clone)]
pub struct Request {
    /// Request method, verbatim (`GET`, `POST`, ...).
    pub method: String,
    /// Request target (path only; no query parsing — none is needed).
    pub path: String,
    /// Headers with lowercased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes (empty without `Content-Length`).
    pub body: Vec<u8>,
}

impl Request {
    /// First header value by lowercase name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// `Connection: close` requested (HTTP/1.1 defaults to keep-alive).
    pub fn wants_close(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Buffered connection reader. The buffer persists *across* requests on
/// one connection, so pipelined requests (several in one TCP segment) are
/// served in order without losing bytes.
struct ConnReader {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl ConnReader {
    fn new(stream: TcpStream) -> ConnReader {
        ConnReader { stream, buf: Vec::new() }
    }

    /// Pull more bytes off the socket; `Closed` on EOF, `Timeout` on an
    /// expired read timeout.
    fn fill(&mut self) -> Result<(), ParseError> {
        let mut chunk = [0u8; 4096];
        match self.stream.read(&mut chunk) {
            Ok(0) => Err(ParseError::Closed),
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                Ok(())
            }
            Err(e) if e.kind() == IoErrorKind::WouldBlock || e.kind() == IoErrorKind::TimedOut => {
                Err(ParseError::Timeout)
            }
            Err(e) => Err(ParseError::Io(e.to_string())),
        }
    }

    /// Read and parse one request, enforcing the caps in `cfg`. The
    /// "clean EOF" case (peer closed between requests) is `Closed` only
    /// if no bytes of the next request had arrived; a mid-request EOF is
    /// `BadRequestLine` (truncated).
    fn read_request(&mut self, cfg: &HttpConfig) -> Result<Request, ParseError> {
        // Accumulate until the blank line ending the header block.
        let head_end = loop {
            if let Some(pos) = find_subslice(&self.buf, b"\r\n\r\n") {
                break pos;
            }
            if self.buf.len() > cfg.max_header_bytes {
                return Err(ParseError::HeadersTooLarge);
            }
            match self.fill() {
                Ok(()) => {}
                Err(ParseError::Closed) if self.buf.is_empty() => return Err(ParseError::Closed),
                Err(ParseError::Closed) => return Err(ParseError::BadRequestLine),
                Err(e) => return Err(e),
            }
        };
        if head_end > cfg.max_header_bytes {
            return Err(ParseError::HeadersTooLarge);
        }
        let head = match std::str::from_utf8(&self.buf[..head_end]) {
            Ok(h) => h.to_string(),
            Err(_) => return Err(ParseError::BadHeader),
        };
        self.buf.drain(..head_end + 4);

        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split(' ');
        let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
        {
            (Some(m), Some(p), Some(v), None) if !m.is_empty() && !p.is_empty() => (m, p, v),
            _ => return Err(ParseError::BadRequestLine),
        };
        if version != "HTTP/1.1" && version != "HTTP/1.0" {
            return Err(ParseError::UnsupportedVersion);
        }
        let mut headers = Vec::new();
        for line in lines {
            let (name, value) = line.split_once(':').ok_or(ParseError::BadHeader)?;
            if name.is_empty() || name.contains(' ') {
                return Err(ParseError::BadHeader);
            }
            headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
        }
        let req_head = Request {
            method: method.to_string(),
            path: path.to_string(),
            headers,
            body: Vec::new(),
        };
        if req_head
            .header("transfer-encoding")
            .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
        {
            return Err(ParseError::UnsupportedTransferEncoding);
        }
        let body_len = match req_head.header("content-length") {
            None => 0usize,
            Some(v) => v.trim().parse::<usize>().map_err(|_| ParseError::BadContentLength)?,
        };
        if body_len > cfg.max_body_bytes {
            return Err(ParseError::BodyTooLarge);
        }
        while self.buf.len() < body_len {
            match self.fill() {
                Ok(()) => {}
                Err(ParseError::Closed) => return Err(ParseError::BadRequestLine),
                Err(e) => return Err(e),
            }
        }
        let body = self.buf.drain(..body_len).collect();
        Ok(Request { body, ..req_head })
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

// --- responses ------------------------------------------------------------

fn error_body(message: &str, kind: Option<&str>) -> String {
    let mut pairs = vec![("error", Json::Str(message.to_string()))];
    if let Some(k) = kind {
        pairs.push(("kind", Json::Str(k.to_string())));
    }
    obj(pairs).to_string()
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &str,
) -> std::io::Result<()> {
    let mut out = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n",
        body.len()
    );
    for (name, value) in extra_headers {
        out.push_str(&format!("{name}: {value}\r\n"));
    }
    out.push_str("\r\n");
    out.push_str(body);
    stream.write_all(out.as_bytes())
}

fn write_json(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    extra: &[(&str, String)],
    body: &str,
) -> std::io::Result<()> {
    write_response(stream, status, reason, "application/json", extra, body)
}

/// Map a classified coordinator error to its HTTP status (the
/// `docs/robustness.md` table): Shed→429, DeadlineExceeded→504,
/// Retryable→503, Cancelled→499 (nginx convention), Terminal→500.
pub fn status_for_error(kind: ErrorKind) -> (u16, &'static str) {
    match kind {
        ErrorKind::Shed => (429, "Too Many Requests"),
        ErrorKind::DeadlineExceeded => (504, "Gateway Timeout"),
        ErrorKind::Retryable => (503, "Service Unavailable"),
        ErrorKind::Cancelled => (499, "Client Closed Request"),
        ErrorKind::Terminal => (500, "Internal Server Error"),
    }
}

// --- server ---------------------------------------------------------------

/// The running HTTP front. Dropping it stops accepting, closes the fair
/// gate (queued requests get `None` → 503), and joins the accept pool;
/// requests already in service drain first.
pub struct HttpServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    gate: Arc<FairGate>,
    threads: Vec<JoinHandle<()>>,
}

struct ServeCtx {
    coord: Arc<Coordinator>,
    tenants: Arc<TenantRegistry>,
    gate: Arc<FairGate>,
    cfg: HttpConfig,
    epoch: Instant,
    stop: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start serving `coord`
    /// under `tenants`' admission policy.
    pub fn start(
        coord: Arc<Coordinator>,
        tenants: Arc<TenantRegistry>,
        addr: &str,
        cfg: HttpConfig,
    ) -> Result<HttpServer, Error> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::msg(format!("bind {addr}: {e}")))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| Error::msg(format!("local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(FairGate::new(cfg.gate_capacity, cfg.batch_every));
        let ctx = Arc::new(ServeCtx {
            coord,
            tenants,
            gate: Arc::clone(&gate),
            cfg: cfg.clone(),
            epoch: Instant::now(),
            stop: Arc::clone(&stop),
        });
        let mut threads = Vec::with_capacity(cfg.accept_threads.max(1));
        for i in 0..cfg.accept_threads.max(1) {
            let listener = listener
                .try_clone()
                .map_err(|e| Error::msg(format!("clone listener: {e}")))?;
            let ctx = Arc::clone(&ctx);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("http-accept-{i}"))
                    .spawn(move || accept_loop(listener, ctx))
                    .map_err(|e| Error::msg(format!("spawn accept thread: {e}")))?,
            );
        }
        Ok(HttpServer { local_addr, stop, gate, threads })
    }

    /// The bound address (resolves `:0` to the kernel-chosen port).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.gate.close();
        // One dummy connection per accept thread unblocks its accept().
        for _ in 0..self.threads.len() {
            let _ = TcpStream::connect(self.local_addr);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn accept_loop(listener: TcpListener, ctx: Arc<ServeCtx>) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if ctx.stop.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
        };
        if ctx.stop.load(Ordering::Acquire) {
            return;
        }
        let _ = stream.set_read_timeout(Some(ctx.cfg.read_timeout));
        let _ = stream.set_nodelay(true);
        serve_connection(stream, &ctx);
    }
}

/// Serve one connection: a keep-alive loop over `read_request`, so
/// pipelined requests on one socket are answered in order. Any parse
/// error is answered (when a reply is possible) and closes the
/// connection, as does SSE, `Connection: close`, or server shutdown.
fn serve_connection(stream: TcpStream, ctx: &ServeCtx) {
    let mut reader = ConnReader::new(stream);
    loop {
        if ctx.stop.load(Ordering::Acquire) {
            return;
        }
        let req = match reader.read_request(&ctx.cfg) {
            Ok(r) => r,
            Err(e) => {
                let (status, reason) = e.status();
                if status != 0 {
                    let _ = write_json(
                        &mut reader.stream,
                        status,
                        reason,
                        &[("Connection", "close".to_string())],
                        &error_body(&format!("{e:?}"), None),
                    );
                }
                return;
            }
        };
        let close_after = req.wants_close();
        match route(&mut reader.stream, &req, ctx) {
            RouteOutcome::KeepAlive => {}
            RouteOutcome::Close => return,
        }
        if close_after {
            return;
        }
    }
}

enum RouteOutcome {
    KeepAlive,
    Close,
}

fn route(stream: &mut TcpStream, req: &Request, ctx: &ServeCtx) -> RouteOutcome {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/sample") => handle_sample(stream, req, ctx, false),
        ("POST", "/v1/sample/stream") => handle_sample(stream, req, ctx, true),
        ("GET", "/metrics") => {
            let mut text = prom::render(&ctx.coord.metrics());
            ctx.tenants.render_prom(&mut text);
            let _ = write_response(
                stream,
                200,
                "OK",
                "text/plain; version=0.0.4; charset=utf-8",
                &[],
                &text,
            );
            RouteOutcome::KeepAlive
        }
        ("GET", "/healthz") => {
            let snap = ctx.coord.metrics();
            let quarantined = snap.devices.iter().filter(|d| d.quarantined).count();
            let healthy = snap.devices.is_empty() || quarantined < snap.devices.len();
            let body = obj(vec![
                ("status", Json::Str(if healthy { "ok" } else { "degraded" }.to_string())),
                ("devices", Json::Num(snap.devices.len() as f64)),
                ("devices_quarantined", Json::Num(quarantined as f64)),
                ("sessions_in_flight", Json::Num(snap.sessions_in_flight as f64)),
            ])
            .to_string();
            let (status, reason) =
                if healthy { (200, "OK") } else { (503, "Service Unavailable") };
            let _ = write_json(stream, status, reason, &[], &body);
            RouteOutcome::KeepAlive
        }
        (_, "/v1/sample") | (_, "/v1/sample/stream") => {
            let _ = write_json(
                stream,
                405,
                "Method Not Allowed",
                &[("Allow", "POST".to_string())],
                &error_body("use POST", None),
            );
            RouteOutcome::KeepAlive
        }
        (_, "/metrics") | (_, "/healthz") => {
            let _ = write_json(
                stream,
                405,
                "Method Not Allowed",
                &[("Allow", "GET".to_string())],
                &error_body("use GET", None),
            );
            RouteOutcome::KeepAlive
        }
        _ => {
            let _ = write_json(stream, 404, "Not Found", &[], &error_body("no such route", None));
            RouteOutcome::KeepAlive
        }
    }
}

/// Admission + solve for both `/v1/sample` and `/v1/sample/stream`.
fn handle_sample(
    stream: &mut TcpStream,
    req: &Request,
    ctx: &ServeCtx,
    streaming: bool,
) -> RouteOutcome {
    // 1. Tenant admission (token bucket) — before any parsing work.
    let now_ns = ctx.epoch.elapsed().as_nanos() as u64;
    let (tenant, weight, priority) = match ctx.tenants.admit(req.header(TENANT_HEADER), now_ns) {
        Ok(t) => t,
        Err(AdmitError::UnknownTenant(name)) => {
            let _ = write_json(
                stream,
                403,
                "Forbidden",
                &[],
                &error_body(&format!("unknown tenant `{name}`"), None),
            );
            return RouteOutcome::KeepAlive;
        }
        Err(AdmitError::OverQuota(retry_after)) => {
            let secs = if retry_after.is_finite() { retry_after.ceil().max(1.0) } else { 3600.0 };
            let _ = write_json(
                stream,
                429,
                "Too Many Requests",
                &[("Retry-After", format!("{}", secs as u64))],
                &error_body("tenant over rate quota", Some("shed")),
            );
            return RouteOutcome::KeepAlive;
        }
    };

    // 2. Body → SampleRequest (400 on any malformed field).
    let fail = |s: &mut TcpStream, msg: &str| {
        let _ = write_json(s, 400, "Bad Request", &[], &error_body(msg, None));
        RouteOutcome::KeepAlive
    };
    let body = match std::str::from_utf8(&req.body) {
        Ok(b) => b,
        Err(_) => return fail(stream, "body is not UTF-8"),
    };
    let json = match parse(body) {
        Ok(j) => j,
        Err(e) => return fail(stream, &format!("invalid JSON: {e}")),
    };
    let mut sample_req = match wire::request_from_json(&json) {
        Ok(r) => r,
        Err(e) => return fail(stream, &e),
    };
    if let Some(v) = req.header(DEADLINE_HEADER) {
        match v.trim().parse::<u64>() {
            Ok(ms) => sample_req.deadline_ms = Some(ms),
            Err(_) => return fail(stream, "x-parataa-deadline-ms must be an integer"),
        }
    }

    // 3. Fair-share gate: block here (not in the coordinator's queue) so
    //    the grant order is weighted-fair across tenants.
    let permit = match ctx.gate.acquire(tenant, weight, priority) {
        Some(p) => p,
        None => {
            ctx.tenants.record_outcome(tenant, false);
            let _ = write_json(
                stream,
                503,
                "Service Unavailable",
                &[("Connection", "close".to_string())],
                &error_body("server shutting down", None),
            );
            return RouteOutcome::Close;
        }
    };

    // 4. Solve, holding the permit for the request's full service time.
    let outcome = if streaming {
        stream_sample(stream, ctx, sample_req, tenant)
    } else {
        let result = ctx.coord.submit(sample_req).wait();
        match result {
            Ok(resp) => {
                ctx.tenants.record_outcome(tenant, true);
                let _ =
                    write_json(stream, 200, "OK", &[], &wire::response_to_json(&resp).to_string());
                RouteOutcome::KeepAlive
            }
            Err(e) => {
                ctx.tenants.record_outcome(tenant, false);
                let (status, reason) = status_for_error(e.kind());
                let mut extra: Vec<(&str, String)> = Vec::new();
                if e.kind() == ErrorKind::Shed {
                    extra.push(("Retry-After", "1".to_string()));
                }
                let _ = write_json(
                    stream,
                    status,
                    reason,
                    &extra,
                    &error_body(&e.to_string(), Some(e.kind().label())),
                );
                RouteOutcome::KeepAlive
            }
        }
    };
    drop(permit);
    outcome
}

/// Serve one streaming request as Server-Sent Events. Framing:
/// `event: chunk` per converged-prefix advance, then exactly one of
/// `event: done` (the full response) or `event: error`. A failed socket
/// write means the client is gone: the session is cancelled, the chunk
/// stream drained, and the terminal result awaited so slot accounting
/// stays exact. SSE responses always close the connection.
fn stream_sample(
    stream: &mut TcpStream,
    ctx: &ServeCtx,
    sample_req: crate::coordinator::SampleRequest,
    tenant: usize,
) -> RouteOutcome {
    let handle = ctx.coord.submit_streaming(sample_req);
    let head = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-store\r\nConnection: close\r\n\r\n";
    let mut client_alive = stream.write_all(head.as_bytes()).is_ok();
    while let Some(chunk) = handle.next_chunk() {
        if client_alive {
            let frame = format!("event: chunk\ndata: {}\n\n", wire::chunk_to_json(&chunk));
            client_alive = stream.write_all(frame.as_bytes()).is_ok();
            if !client_alive {
                // Client disconnect: cancel, then keep draining so the
                // terminal result below reflects the cancellation.
                handle.cancel();
            }
        }
    }
    match handle.wait() {
        Ok(resp) => {
            ctx.tenants.record_outcome(tenant, true);
            if client_alive {
                let frame = format!("event: done\ndata: {}\n\n", wire::response_to_json(&resp));
                let _ = stream.write_all(frame.as_bytes());
            }
        }
        Err(e) => {
            ctx.tenants.record_outcome(tenant, false);
            if client_alive {
                let frame = format!(
                    "event: error\ndata: {}\n\n",
                    error_body(&e.to_string(), Some(e.kind().label()))
                );
                let _ = stream.write_all(frame.as_bytes());
            }
        }
    }
    RouteOutcome::Close
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HttpConfig {
        HttpConfig::default()
    }

    /// Feed a raw byte stream through the parser via a real socket pair.
    fn parse_raw(raw: &[u8]) -> Result<Request, ParseError> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        client.write_all(raw).unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        ConnReader::new(server).read_request(&cfg())
    }

    #[test]
    fn parses_a_post_with_body_and_lowercases_headers() {
        let req = parse_raw(
            b"POST /v1/sample HTTP/1.1\r\nHost: x\r\nX-Parataa-Tenant: acme\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap();
        assert_eq!((req.method.as_str(), req.path.as_str()), ("POST", "/v1/sample"));
        assert_eq!(req.header("x-parataa-tenant"), Some("acme"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn classifies_malformed_inputs() {
        for (raw, want) in [
            (&b"GARBAGE\r\n\r\n"[..], ParseError::BadRequestLine),
            (&b"GET / HTTP/2.0\r\n\r\n"[..], ParseError::UnsupportedVersion),
            (&b"GET / HTTP/1.1\r\nNoColonHere\r\n\r\n"[..], ParseError::BadHeader),
            (
                &b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"[..],
                ParseError::BadContentLength,
            ),
            (
                &b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"[..],
                ParseError::UnsupportedTransferEncoding,
            ),
            (&b"GET / HTTP/1.1\r\nTrunc"[..], ParseError::BadRequestLine),
            (&b""[..], ParseError::Closed),
        ] {
            assert_eq!(parse_raw(raw), Err(want), "raw: {:?}", String::from_utf8_lossy(raw));
        }
    }

    #[test]
    fn parse_errors_map_to_documented_statuses() {
        assert_eq!(ParseError::BadRequestLine.status().0, 400);
        assert_eq!(ParseError::HeadersTooLarge.status().0, 431);
        assert_eq!(ParseError::BodyTooLarge.status().0, 413);
        assert_eq!(ParseError::Timeout.status().0, 408);
        assert_eq!(ParseError::UnsupportedVersion.status().0, 505);
        assert_eq!(ParseError::UnsupportedTransferEncoding.status().0, 501);
        assert_eq!(ParseError::Closed.status().0, 0, "clean EOF gets no reply");
    }

    #[test]
    fn error_kinds_map_to_documented_statuses() {
        assert_eq!(status_for_error(ErrorKind::Shed).0, 429);
        assert_eq!(status_for_error(ErrorKind::DeadlineExceeded).0, 504);
        assert_eq!(status_for_error(ErrorKind::Retryable).0, 503);
        assert_eq!(status_for_error(ErrorKind::Cancelled).0, 499);
        assert_eq!(status_for_error(ErrorKind::Terminal).0, 500);
    }

    #[test]
    fn pipelined_requests_stay_buffered() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        client
            .write_all(b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\n\r\n")
            .unwrap();
        let mut reader = ConnReader::new(server);
        assert_eq!(reader.read_request(&cfg()).unwrap().path, "/healthz");
        assert_eq!(reader.read_request(&cfg()).unwrap().path, "/metrics");
    }
}
