//! HTTP/SSE serving front with multi-tenant admission control — the L4
//! transport layer over the [`crate::coordinator`].
//!
//! The coordinator (L3) turns many concurrent sampling requests into
//! merged per-round device batches; this layer puts a wire protocol in
//! front of it so heterogeneous *tenants* can share one deployment:
//!
//! - [`wire`]   — `SampleRequest`/`SampleResponse`/`PrefixChunk` ⇄ JSON,
//!   bit-exact for every float that crosses it (the transport adds zero
//!   numeric surface — pinned by the parity oracle in
//!   `tests/http_protocol.rs`);
//! - [`tenant`] — admission control: the `--tenants` spec grammar,
//!   per-tenant token buckets (quota → 429 + `Retry-After`), weighted
//!   fair queueing with interactive/batch priority classes, and
//!   per-tenant outcome counters;
//! - [`http`]   — the zero-dependency HTTP/1.1 server: a small accept
//!   pool, a hostile-input-safe hand-rolled parser (classified 4xx,
//!   never a panic), `POST /v1/sample`, `POST /v1/sample/stream`
//!   (converged-prefix chunks as Server-Sent Events), `GET /metrics`
//!   (Prometheus text) and `GET /healthz`, with client-disconnect
//!   propagation into [`crate::coordinator::CancelToken`];
//! - [`client`] — the minimal loopback client the protocol/fairness
//!   tests, bench scenarios, and CI smoke drive the server with.
//!
//! See `docs/serving.md` for the endpoint reference, tenant spec
//! grammar, SSE framing, and curl examples.

pub mod client;
pub mod http;
pub mod tenant;
pub mod wire;

pub use http::{HttpConfig, HttpServer, ParseError, Request};
pub use tenant::{
    parse_tenant_spec, FairGate, FairQueue, Priority, TenantConfig, TenantRegistry, TokenBucket,
};
