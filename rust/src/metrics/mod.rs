//! Quality metrics — the evaluation column of Table 1 / Fig. 3.
//!
//! The paper reports FID + Inception Score (DiT) and CLIP score (SD). Our
//! testbed has no Inception/CLIP networks; DESIGN.md §Substitutions maps
//! each to an analytically-grounded proxy with the same functional form:
//!
//! - **FID-proxy** — Fréchet distance between Gaussian fits of generated
//!   vs reference samples in pixel space (diagonal covariance);
//! - **IS-proxy**  — exp E[KL(p(y|x) ‖ p(y))] with the exact template-GMM
//!   posterior as the classifier;
//! - **CS-proxy**  — mean posterior probability of the *target* condition
//!   (monotone in prompt alignment, like CLIP score);
//! - **match error** — RMSE between parallel and sequential samples for the
//!   same seed (Remark 5.3's "same image" claim, quantified).

use crate::model::gmm::GmmEps;
use crate::model::Cond;

/// Fréchet distance between diagonal-Gaussian fits of two sample sets.
/// `a`, `b` are row-major `[n, d]` stacks.
pub fn fid_proxy(a: &[f32], b: &[f32], d: usize) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    let (mu_a, var_a) = moments(a, d);
    let (mu_b, var_b) = moments(b, d);
    // Fréchet distance for diagonal Gaussians:
    //   |mu_a - mu_b|^2 + Σ_i (var_a + var_b - 2*sqrt(var_a*var_b))
    let mut acc = 0.0;
    for i in 0..d {
        let dm = mu_a[i] - mu_b[i];
        acc += dm * dm;
        acc += var_a[i] + var_b[i] - 2.0 * (var_a[i] * var_b[i]).max(0.0).sqrt();
    }
    acc
}

fn moments(xs: &[f32], d: usize) -> (Vec<f64>, Vec<f64>) {
    let n = xs.len() / d;
    let mut mu = vec![0.0f64; d];
    for row in xs.chunks(d) {
        for (m, &v) in mu.iter_mut().zip(row.iter()) {
            *m += v as f64;
        }
    }
    for m in &mut mu {
        *m /= n as f64;
    }
    let mut var = vec![0.0f64; d];
    for row in xs.chunks(d) {
        for i in 0..d {
            let r = row[i] as f64 - mu[i];
            var[i] += r * r;
        }
    }
    for v in &mut var {
        *v /= (n as f64 - 1.0).max(1.0);
    }
    (mu, var)
}

/// IS-proxy: exp( E_x[ KL( p(y|x) ‖ p(y) ) ] ) using the GMM posterior at
/// ᾱ≈1 (clean images) as the classifier. Higher = sharper + more diverse.
pub fn is_proxy(samples: &[f32], model: &GmmEps) -> f64 {
    let d = model.d;
    let n = samples.len() / d;
    let k = model.n_components;
    let uniform = vec![1.0f32 / k as f32; k];
    // p(y|x) per sample, then the marginal p(y).
    let mut posts: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut marginal = vec![0.0f64; k];
    for row in samples.chunks(d) {
        let (lp, _) = model.log_posterior(row, 0.9999, &uniform);
        let p: Vec<f64> = lp.iter().map(|&l| l.exp()).collect();
        for (m, &v) in marginal.iter_mut().zip(p.iter()) {
            *m += v / n as f64;
        }
        posts.push(p);
    }
    let mut kl_sum = 0.0;
    for p in &posts {
        for i in 0..k {
            if p[i] > 1e-12 && marginal[i] > 1e-12 {
                kl_sum += p[i] * (p[i] / marginal[i]).ln();
            }
        }
    }
    (kl_sum / n as f64).exp()
}

/// CS-proxy: mean posterior probability of the target condition under the
/// GMM classifier, scaled to a CLIP-like 0–30 range (paper's CS ≈ 24).
pub fn cs_proxy(samples: &[f32], conds: &[Cond], model: &GmmEps) -> f64 {
    let d = model.d;
    let n = samples.len() / d;
    assert_eq!(conds.len(), n);
    let k = model.n_components;
    let uniform = vec![1.0f32 / k as f32; k];
    let mut acc = 0.0;
    for (row, cond) in samples.chunks(d).zip(conds.iter()) {
        let (lp, _) = model.log_posterior(row, 0.9999, &uniform);
        let w = cond.to_weights(k);
        let p: f64 = lp
            .iter()
            .zip(w.iter())
            .map(|(&l, &wi)| l.exp() * wi as f64)
            .sum();
        acc += p;
    }
    30.0 * acc / n as f64
}

/// RMSE between two samples (the parallel-vs-sequential match error).
pub fn match_rmse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let acc: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum();
    (acc / a.len() as f64).sqrt()
}

/// PSNR (dB) for [-1, 1]-ranged images — the qualitative-match number.
pub fn psnr(a: &[f32], b: &[f32]) -> f64 {
    let rmse = match_rmse(a, b);
    if rmse <= 1e-12 {
        return f64::INFINITY;
    }
    20.0 * (2.0 / rmse).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::templates;
    use crate::schedule::{BetaSchedule, NoiseSchedule};
    use crate::util::rng::Pcg64;

    fn sd_model() -> GmmEps {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        GmmEps::sd_analog(ns.alpha_bars.clone())
    }

    #[test]
    fn fid_zero_on_identical_sets() {
        let mut rng = Pcg64::seeded(1);
        let xs: Vec<f32> = (0..50 * 4).map(|_| rng.next_f32()).collect();
        assert!(fid_proxy(&xs, &xs, 4) < 1e-9);
    }

    #[test]
    fn fid_grows_with_mean_shift() {
        let mut rng = Pcg64::seeded(2);
        let a: Vec<f32> = (0..200 * 4).map(|_| rng.next_f32()).collect();
        let b_small: Vec<f32> = a.iter().map(|&v| v + 0.1).collect();
        let b_large: Vec<f32> = a.iter().map(|&v| v + 1.0).collect();
        let f_small = fid_proxy(&a, &b_small, 4);
        let f_large = fid_proxy(&a, &b_large, 4);
        assert!(f_small < f_large);
        assert!((f_small - 0.04).abs() < 0.02); // 4 dims * 0.01
    }

    #[test]
    fn is_proxy_ordering() {
        // Samples at distinct templates = diverse + sharp => IS near K.
        // All samples at one template => IS near 1.
        let model = sd_model();
        let mut rng = Pcg64::seeded(3);
        let diverse: Vec<f32> = (0..8)
            .flat_map(|c| {
                let mut t = templates::template(c);
                for v in &mut t {
                    *v += 0.05 * (rng.next_f32() - 0.5);
                }
                t
            })
            .collect();
        let collapsed: Vec<f32> = (0..8).flat_map(|_| templates::template(0)).collect();
        let is_div = is_proxy(&diverse, &model);
        let is_col = is_proxy(&collapsed, &model);
        assert!(is_div > 6.0, "diverse IS {is_div}");
        assert!(is_col < 1.1, "collapsed IS {is_col}");
    }

    #[test]
    fn cs_proxy_prefers_matching_condition() {
        let model = sd_model();
        let samples: Vec<f32> = templates::template(2);
        let right = cs_proxy(&samples, &[Cond::Class(2)], &model);
        let wrong = cs_proxy(&samples, &[Cond::Class(5)], &model);
        assert!(right > 25.0, "right {right}");
        assert!(wrong < 5.0, "wrong {wrong}");
    }

    #[test]
    fn match_metrics() {
        let a = vec![0.0f32, 1.0, -1.0];
        assert_eq!(match_rmse(&a, &a), 0.0);
        assert!(psnr(&a, &a).is_infinite());
        let b = vec![0.1f32, 1.1, -0.9];
        assert!((match_rmse(&a, &b) - 0.1).abs() < 1e-6);
        assert!((psnr(&a, &b) - 26.02).abs() < 0.1);
    }
}
