//! First-order sampler coefficients (eq. 6): DDIM(η) family.
//!
//! Indexing convention (matches the paper): solver states are x_T .. x_0
//! with x_T = ξ_T the initial Gaussian draw and x_0 the sample. State x_t
//! for t ∈ {1..T} lives at training timestep `train_t(t)` = τ_{t-1} of the
//! subsetted grid; x_0 is fully denoised (ᾱ ≡ 1). One solver step is
//!
//!   x_{t-1} = a_t·x_t + b_t·ε_θ(x_t, τ_{t-1}) + c_{t-1}·ξ_{t-1},  t = T..1.
//!
//! DDIM(η) coefficients over ᾱ_hi = ᾱ(τ_{t-1}), ᾱ_lo = ᾱ(τ_{t-2}) (1 for t=1):
//!   a_t = √(ᾱ_lo/ᾱ_hi)
//!   σ_t = η·√((1-ᾱ_lo)/(1-ᾱ_hi))·√(1-ᾱ_hi/ᾱ_lo)
//!   b_t = √(1-ᾱ_lo-σ_t²) − a_t·√(1-ᾱ_hi)
//!   c_{t-1} = σ_t
//!
//! η = 0 recovers the DDIM ODE solver (c ≡ 0); η = 1 the DDPM SDE sampler
//! (footnote 4 of the paper treats DDIM(η=1) as the DDPM sampler).

use super::NoiseSchedule;

/// Which member of the DDIM(η) family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerKind {
    /// Deterministic ODE sampler (η = 0).
    Ddim,
    /// Stochastic DDPM sampler (η = 1).
    Ddpm,
    /// General η ∈ [0, 1].
    Eta(f64),
}

impl SamplerKind {
    pub fn eta(&self) -> f64 {
        match self {
            SamplerKind::Ddim => 0.0,
            SamplerKind::Ddpm => 1.0,
            SamplerKind::Eta(e) => *e,
        }
    }

    pub fn label(&self) -> String {
        match self {
            SamplerKind::Ddim => "DDIM".to_string(),
            SamplerKind::Ddpm => "DDPM".to_string(),
            SamplerKind::Eta(e) => format!("DDIM(eta={e})"),
        }
    }
}

/// All per-step coefficients of the autoregressive procedure (eq. 6) for a
/// `steps`-step run of a sampler over a training schedule.
#[derive(Debug, Clone)]
pub struct SamplerCoeffs {
    pub kind: SamplerKind,
    /// T — number of solver steps.
    pub steps: usize,
    /// a[t], t ∈ 1..=T (index 0 unused, kept for paper-aligned indexing).
    pub a: Vec<f64>,
    /// b[t], t ∈ 1..=T (index 0 unused).
    pub b: Vec<f64>,
    /// c[t], t ∈ 0..T — coefficient of ξ_t in the step producing x_t.
    pub c: Vec<f64>,
    /// Training timestep fed to ε_θ for state x_t, t ∈ 1..=T (index 0 unused).
    pub train_t: Vec<usize>,
    /// g²(τ) at each state's training timestep, t ∈ 0..T used for the
    /// residual-r_t threshold (g² of the step that *produces* x_t).
    pub g2: Vec<f64>,
}

impl SamplerCoeffs {
    /// Build coefficients for `steps` sampling steps over `schedule`.
    pub fn new(schedule: &NoiseSchedule, kind: SamplerKind, steps: usize) -> Self {
        let eta = kind.eta();
        let taus = schedule.subset_timesteps(steps); // ascending, len = steps
        let t_count = steps;
        let mut a = vec![0.0; t_count + 1];
        let mut b = vec![0.0; t_count + 1];
        let mut c = vec![0.0; t_count];
        let mut train_t = vec![0usize; t_count + 1];
        let mut g2 = vec![0.0; t_count];
        for t in 1..=t_count {
            let tau_hi = taus[t - 1];
            let abar_hi = schedule.alpha_bar(tau_hi);
            let abar_lo = if t >= 2 { schedule.alpha_bar(taus[t - 2]) } else { 1.0 };
            let a_t = (abar_lo / abar_hi).sqrt();
            let sigma = if t >= 2 {
                eta * ((1.0 - abar_lo) / (1.0 - abar_hi)).sqrt()
                    * (1.0 - abar_hi / abar_lo).sqrt()
            } else {
                0.0 // final step to the clean sample is deterministic
            };
            let b_t = (1.0 - abar_lo - sigma * sigma).max(0.0).sqrt()
                - a_t * (1.0 - abar_hi).sqrt();
            a[t] = a_t;
            b[t] = b_t;
            c[t - 1] = sigma;
            train_t[t] = tau_hi;
            g2[t - 1] = schedule.g2(tau_hi);
        }
        SamplerCoeffs { kind, steps: t_count, a, b, c, train_t, g2 }
    }

    /// ā_{i,s} = Π_{j=i}^{s} a_j (1 when s < i) — Definition 2.1.
    pub fn abar(&self, i: usize, s: usize) -> f64 {
        if s < i {
            return 1.0;
        }
        debug_assert!(i >= 1 && s <= self.steps);
        let mut p = 1.0;
        for j in i..=s {
            p *= self.a[j];
        }
        p
    }

    /// Residual threshold ε_t = τ²·g²(t)·d for residual r_t (§2.1).
    pub fn threshold(&self, t: usize, tol: f64, d: usize) -> f64 {
        tol * tol * self.g2[t] * d as f64
    }

    /// True if the sampler is deterministic (all c ≡ 0).
    pub fn is_ode(&self) -> bool {
        self.c.iter().all(|&x| x == 0.0)
    }

    /// The schedule ᾱ at every solver state row (length T+1, `[0] = 1`),
    /// recovered from the step coefficients alone: a_t = √(ᾱ_{t-1}/ᾱ_t)
    /// telescopes to ᾱ_t = ᾱ_{t-1} / a_t². This is what lets a coarse
    /// operator be built *from an existing fine grid* without re-deriving
    /// the noise schedule (multi-fidelity strategies,
    /// `solver/strategy.rs`).
    pub fn state_alpha_bars(&self) -> Vec<f64> {
        let mut ab = vec![1.0f64; self.steps + 1];
        for t in 1..=self.steps {
            ab[t] = ab[t - 1] / (self.a[t] * self.a[t]);
        }
        ab
    }

    /// Build a coarse operator over a `coarse_steps`-row subset of this
    /// grid. Returns the coarse coefficients plus the node map `idx0`
    /// (length C+1, strictly increasing, `idx0[0] = 0`, `idx0[C] = T`):
    /// coarse state row c lives at fine state row `idx0[c]`, so coarse ξ
    /// rows, thresholds and the lifted trajectory all index through it.
    ///
    /// Each coarse step bridges two fine states with the same DDIM(η)
    /// formulas the fine grid uses ([`crate::equations::bridge_coeffs`]
    /// over the telescoped [`Self::state_alpha_bars`]), so the coarse
    /// sequential rollout follows the *same* probability-flow path at
    /// lower resolution — the draft a `DraftRefine` solve refines.
    pub fn coarsen(&self, coarse_steps: usize) -> (SamplerCoeffs, Vec<usize>) {
        let t_count = self.steps;
        let c_count = coarse_steps.clamp(1, t_count);
        let mut idx0 = Vec::with_capacity(c_count + 1);
        for c in 0..=c_count {
            idx0.push(c * t_count / c_count);
        }
        let abar = self.state_alpha_bars();
        let eta = self.kind.eta();
        let mut a = vec![0.0; c_count + 1];
        let mut b = vec![0.0; c_count + 1];
        let mut c_vec = vec![0.0; c_count];
        let mut train_t = vec![0usize; c_count + 1];
        let mut g2 = vec![0.0; c_count];
        for c in 1..=c_count {
            let (lo, hi) = (idx0[c - 1], idx0[c]);
            let (a_c, b_c, sigma) = crate::equations::bridge_coeffs(abar[hi], abar[lo], eta);
            a[c] = a_c;
            b[c] = b_c;
            c_vec[c - 1] = sigma;
            // The coarse state *is* the fine state at the node row: same
            // training timestep in, same residual threshold out.
            train_t[c] = self.train_t[hi];
            g2[c - 1] = self.g2[hi - 1];
        }
        (SamplerCoeffs { kind: self.kind, steps: c_count, a, b, c: c_vec, train_t, g2 }, idx0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::BetaSchedule;

    fn sched() -> NoiseSchedule {
        NoiseSchedule::new(BetaSchedule::Linear, 1000)
    }

    #[test]
    fn ddim_is_deterministic() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 100);
        assert!(sc.is_ode());
        assert_eq!(sc.steps, 100);
        assert_eq!(sc.a.len(), 101);
        assert_eq!(sc.c.len(), 100);
    }

    #[test]
    fn ddpm_has_noise_except_last_step() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddpm, 100);
        assert!(!sc.is_ode());
        // c_{t-1} for t=1 (the final denoise) is 0; all earlier are > 0.
        assert_eq!(sc.c[0], 0.0);
        for t in 1..100 {
            assert!(sc.c[t] > 0.0, "c[{t}] should be positive");
        }
    }

    #[test]
    fn signal_preservation_identity() {
        // If ε_θ were exact and x_t = √ᾱ_hi·x0 + √(1-ᾱ_hi)·ε, the DDIM update
        // must produce exactly √ᾱ_lo·x0 + √(1-ᾱ_lo)·ε. On the coefficient
        // level: a_t·√ᾱ_hi = √ᾱ_lo and a_t·√(1-ᾱ_hi) + b_t = √(1-ᾱ_lo).
        let ns = sched();
        let sc = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 50);
        let taus = ns.subset_timesteps(50);
        for t in 1..=50usize {
            let abar_hi = ns.alpha_bar(taus[t - 1]);
            let abar_lo = if t >= 2 { ns.alpha_bar(taus[t - 2]) } else { 1.0 };
            let lhs_sig = sc.a[t] * abar_hi.sqrt();
            assert!((lhs_sig - abar_lo.sqrt()).abs() < 1e-12, "signal at t={t}");
            let lhs_eps = sc.a[t] * (1.0 - abar_hi).sqrt() + sc.b[t];
            assert!((lhs_eps - (1.0 - abar_lo).sqrt()).abs() < 1e-12, "eps at t={t}");
        }
    }

    #[test]
    fn ddpm_variance_preservation() {
        // For η=1: a_t²·(1-ᾱ_hi) + (a_t·√(1-ᾱ_hi)+b_t)² ... simpler identity:
        // total noise variance after the step equals 1-ᾱ_lo:
        // (a√(1-ᾱhi)+b)² + σ² = 1-ᾱ_lo.
        let ns = sched();
        let sc = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 100);
        let taus = ns.subset_timesteps(100);
        for t in 2..=100usize {
            let abar_hi = ns.alpha_bar(taus[t - 1]);
            let abar_lo = ns.alpha_bar(taus[t - 2]);
            let dir = sc.a[t] * (1.0 - abar_hi).sqrt() + sc.b[t];
            let total = dir * dir + sc.c[t - 1] * sc.c[t - 1];
            assert!(
                (total - (1.0 - abar_lo)).abs() < 1e-10,
                "variance at t={t}: {total} vs {}",
                1.0 - abar_lo
            );
        }
    }

    #[test]
    fn abar_products() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 10);
        assert_eq!(sc.abar(5, 4), 1.0); // empty product
        let direct = sc.a[3] * sc.a[4] * sc.a[5];
        assert!((sc.abar(3, 5) - direct).abs() < 1e-15);
        // telescoping: ā_{1,T} = Π all
        let all: f64 = (1..=10).map(|j| sc.a[j]).product();
        assert!((sc.abar(1, 10) - all).abs() < 1e-12);
    }

    #[test]
    fn eta_interpolates() {
        let ns = sched();
        let half = SamplerCoeffs::new(&ns, SamplerKind::Eta(0.5), 50);
        let ddpm = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 50);
        for t in 1..50 {
            assert!((half.c[t] - 0.5 * ddpm.c[t]).abs() < 1e-12);
        }
    }

    #[test]
    fn thresholds_scale_with_d() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 25);
        let e1 = sc.threshold(10, 1e-3, 256);
        let e2 = sc.threshold(10, 1e-3, 512);
        assert!((e2 / e1 - 2.0).abs() < 1e-12);
        assert!(e1 > 0.0);
    }

    #[test]
    fn state_alpha_bars_match_the_schedule() {
        let ns = sched();
        for kind in [SamplerKind::Ddim, SamplerKind::Ddpm, SamplerKind::Eta(0.3)] {
            let sc = SamplerCoeffs::new(&ns, kind, 25);
            let abar = sc.state_alpha_bars();
            let taus = ns.subset_timesteps(25);
            assert_eq!(abar[0], 1.0);
            for t in 1..=25usize {
                let want = ns.alpha_bar(taus[t - 1]);
                assert!(
                    (abar[t] - want).abs() < 1e-10,
                    "{} state {t}: {} vs {want}",
                    kind.label(),
                    abar[t]
                );
            }
        }
    }

    #[test]
    fn coarsen_node_map_tiles_the_grid() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 50);
        for c_steps in [1usize, 3, 10, 12, 50] {
            let (coarse, idx0) = sc.coarsen(c_steps);
            assert_eq!(coarse.steps, c_steps);
            assert_eq!(idx0.len(), c_steps + 1);
            assert_eq!(idx0[0], 0);
            assert_eq!(idx0[c_steps], 50);
            for c in 1..=c_steps {
                assert!(idx0[c] > idx0[c - 1], "node map must be strictly increasing");
                // Node alignment: same training timestep and threshold
                // inputs as the fine state it represents.
                assert_eq!(coarse.train_t[c], sc.train_t[idx0[c]]);
                assert_eq!(coarse.g2[c - 1], sc.g2[idx0[c] - 1]);
            }
        }
        // Oversized requests clamp to the fine grid (identity node map).
        let (full, idx0) = sc.coarsen(500);
        assert_eq!(full.steps, 50);
        assert_eq!(idx0, (0..=50).collect::<Vec<_>>());
    }

    #[test]
    fn coarsen_preserves_signal_and_variance() {
        // The coarse steps must satisfy the same signal-preservation and
        // (for η=1) variance-preservation identities as the fine grid,
        // evaluated on the telescoped per-state ᾱ.
        let ns = sched();
        for kind in [SamplerKind::Ddim, SamplerKind::Ddpm] {
            let sc = SamplerCoeffs::new(&ns, kind, 48);
            let abar = sc.state_alpha_bars();
            let (coarse, idx0) = sc.coarsen(12);
            for c in 1..=12usize {
                let (abar_lo, abar_hi) = (abar[idx0[c - 1]], abar[idx0[c]]);
                let lhs_sig = coarse.a[c] * abar_hi.sqrt();
                assert!((lhs_sig - abar_lo.sqrt()).abs() < 1e-10, "signal at c={c}");
                let dir = coarse.a[c] * (1.0 - abar_hi).sqrt() + coarse.b[c];
                let total = dir * dir + coarse.c[c - 1] * coarse.c[c - 1];
                assert!(
                    (total - (1.0 - abar_lo)).abs() < 1e-9,
                    "{} variance at c={c}: {total} vs {}",
                    kind.label(),
                    1.0 - abar_lo
                );
            }
            // Telescoping: the coarse a-product over a segment equals the
            // fine a-product over the same rows (both are √(ᾱ_lo/ᾱ_hi)).
            let fine_prod: f64 = (idx0[1] + 1..=idx0[3]).map(|j| sc.a[j]).product();
            let coarse_prod = coarse.a[2] * coarse.a[3];
            assert!((fine_prod - coarse_prod).abs() < 1e-10);
            // Final coarse step to the clean sample stays deterministic.
            assert_eq!(coarse.c[0], 0.0);
        }
        // An identity coarsening reproduces the fine coefficients.
        let sc = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 20);
        let (same, _) = sc.coarsen(20);
        for t in 1..=20usize {
            assert!((same.a[t] - sc.a[t]).abs() < 1e-10, "a[{t}]");
            assert!((same.b[t] - sc.b[t]).abs() < 1e-10, "b[{t}]");
            assert!((same.c[t - 1] - sc.c[t - 1]).abs() < 1e-10, "c[{}]", t - 1);
        }
    }

    #[test]
    fn train_t_descends_with_solver_index() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 25);
        // Higher solver index = noisier state = later training timestep.
        for t in 2..=25 {
            assert!(sc.train_t[t] > sc.train_t[t - 1]);
        }
        assert_eq!(sc.train_t[1], 0);
    }
}
