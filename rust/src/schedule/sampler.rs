//! First-order sampler coefficients (eq. 6): DDIM(η) family.
//!
//! Indexing convention (matches the paper): solver states are x_T .. x_0
//! with x_T = ξ_T the initial Gaussian draw and x_0 the sample. State x_t
//! for t ∈ {1..T} lives at training timestep `train_t(t)` = τ_{t-1} of the
//! subsetted grid; x_0 is fully denoised (ᾱ ≡ 1). One solver step is
//!
//!   x_{t-1} = a_t·x_t + b_t·ε_θ(x_t, τ_{t-1}) + c_{t-1}·ξ_{t-1},  t = T..1.
//!
//! DDIM(η) coefficients over ᾱ_hi = ᾱ(τ_{t-1}), ᾱ_lo = ᾱ(τ_{t-2}) (1 for t=1):
//!   a_t = √(ᾱ_lo/ᾱ_hi)
//!   σ_t = η·√((1-ᾱ_lo)/(1-ᾱ_hi))·√(1-ᾱ_hi/ᾱ_lo)
//!   b_t = √(1-ᾱ_lo-σ_t²) − a_t·√(1-ᾱ_hi)
//!   c_{t-1} = σ_t
//!
//! η = 0 recovers the DDIM ODE solver (c ≡ 0); η = 1 the DDPM SDE sampler
//! (footnote 4 of the paper treats DDIM(η=1) as the DDPM sampler).

use super::NoiseSchedule;

/// Which member of the DDIM(η) family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerKind {
    /// Deterministic ODE sampler (η = 0).
    Ddim,
    /// Stochastic DDPM sampler (η = 1).
    Ddpm,
    /// General η ∈ [0, 1].
    Eta(f64),
}

impl SamplerKind {
    pub fn eta(&self) -> f64 {
        match self {
            SamplerKind::Ddim => 0.0,
            SamplerKind::Ddpm => 1.0,
            SamplerKind::Eta(e) => *e,
        }
    }

    pub fn label(&self) -> String {
        match self {
            SamplerKind::Ddim => "DDIM".to_string(),
            SamplerKind::Ddpm => "DDPM".to_string(),
            SamplerKind::Eta(e) => format!("DDIM(eta={e})"),
        }
    }
}

/// All per-step coefficients of the autoregressive procedure (eq. 6) for a
/// `steps`-step run of a sampler over a training schedule.
#[derive(Debug, Clone)]
pub struct SamplerCoeffs {
    pub kind: SamplerKind,
    /// T — number of solver steps.
    pub steps: usize,
    /// a[t], t ∈ 1..=T (index 0 unused, kept for paper-aligned indexing).
    pub a: Vec<f64>,
    /// b[t], t ∈ 1..=T (index 0 unused).
    pub b: Vec<f64>,
    /// c[t], t ∈ 0..T — coefficient of ξ_t in the step producing x_t.
    pub c: Vec<f64>,
    /// Training timestep fed to ε_θ for state x_t, t ∈ 1..=T (index 0 unused).
    pub train_t: Vec<usize>,
    /// g²(τ) at each state's training timestep, t ∈ 0..T used for the
    /// residual-r_t threshold (g² of the step that *produces* x_t).
    pub g2: Vec<f64>,
}

impl SamplerCoeffs {
    /// Build coefficients for `steps` sampling steps over `schedule`.
    pub fn new(schedule: &NoiseSchedule, kind: SamplerKind, steps: usize) -> Self {
        let eta = kind.eta();
        let taus = schedule.subset_timesteps(steps); // ascending, len = steps
        let t_count = steps;
        let mut a = vec![0.0; t_count + 1];
        let mut b = vec![0.0; t_count + 1];
        let mut c = vec![0.0; t_count];
        let mut train_t = vec![0usize; t_count + 1];
        let mut g2 = vec![0.0; t_count];
        for t in 1..=t_count {
            let tau_hi = taus[t - 1];
            let abar_hi = schedule.alpha_bar(tau_hi);
            let abar_lo = if t >= 2 { schedule.alpha_bar(taus[t - 2]) } else { 1.0 };
            let a_t = (abar_lo / abar_hi).sqrt();
            let sigma = if t >= 2 {
                eta * ((1.0 - abar_lo) / (1.0 - abar_hi)).sqrt()
                    * (1.0 - abar_hi / abar_lo).sqrt()
            } else {
                0.0 // final step to the clean sample is deterministic
            };
            let b_t = (1.0 - abar_lo - sigma * sigma).max(0.0).sqrt()
                - a_t * (1.0 - abar_hi).sqrt();
            a[t] = a_t;
            b[t] = b_t;
            c[t - 1] = sigma;
            train_t[t] = tau_hi;
            g2[t - 1] = schedule.g2(tau_hi);
        }
        SamplerCoeffs { kind, steps: t_count, a, b, c, train_t, g2 }
    }

    /// ā_{i,s} = Π_{j=i}^{s} a_j (1 when s < i) — Definition 2.1.
    pub fn abar(&self, i: usize, s: usize) -> f64 {
        if s < i {
            return 1.0;
        }
        debug_assert!(i >= 1 && s <= self.steps);
        let mut p = 1.0;
        for j in i..=s {
            p *= self.a[j];
        }
        p
    }

    /// Residual threshold ε_t = τ²·g²(t)·d for residual r_t (§2.1).
    pub fn threshold(&self, t: usize, tol: f64, d: usize) -> f64 {
        tol * tol * self.g2[t] * d as f64
    }

    /// True if the sampler is deterministic (all c ≡ 0).
    pub fn is_ode(&self) -> bool {
        self.c.iter().all(|&x| x == 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::BetaSchedule;

    fn sched() -> NoiseSchedule {
        NoiseSchedule::new(BetaSchedule::Linear, 1000)
    }

    #[test]
    fn ddim_is_deterministic() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 100);
        assert!(sc.is_ode());
        assert_eq!(sc.steps, 100);
        assert_eq!(sc.a.len(), 101);
        assert_eq!(sc.c.len(), 100);
    }

    #[test]
    fn ddpm_has_noise_except_last_step() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddpm, 100);
        assert!(!sc.is_ode());
        // c_{t-1} for t=1 (the final denoise) is 0; all earlier are > 0.
        assert_eq!(sc.c[0], 0.0);
        for t in 1..100 {
            assert!(sc.c[t] > 0.0, "c[{t}] should be positive");
        }
    }

    #[test]
    fn signal_preservation_identity() {
        // If ε_θ were exact and x_t = √ᾱ_hi·x0 + √(1-ᾱ_hi)·ε, the DDIM update
        // must produce exactly √ᾱ_lo·x0 + √(1-ᾱ_lo)·ε. On the coefficient
        // level: a_t·√ᾱ_hi = √ᾱ_lo and a_t·√(1-ᾱ_hi) + b_t = √(1-ᾱ_lo).
        let ns = sched();
        let sc = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 50);
        let taus = ns.subset_timesteps(50);
        for t in 1..=50usize {
            let abar_hi = ns.alpha_bar(taus[t - 1]);
            let abar_lo = if t >= 2 { ns.alpha_bar(taus[t - 2]) } else { 1.0 };
            let lhs_sig = sc.a[t] * abar_hi.sqrt();
            assert!((lhs_sig - abar_lo.sqrt()).abs() < 1e-12, "signal at t={t}");
            let lhs_eps = sc.a[t] * (1.0 - abar_hi).sqrt() + sc.b[t];
            assert!((lhs_eps - (1.0 - abar_lo).sqrt()).abs() < 1e-12, "eps at t={t}");
        }
    }

    #[test]
    fn ddpm_variance_preservation() {
        // For η=1: a_t²·(1-ᾱ_hi) + (a_t·√(1-ᾱ_hi)+b_t)² ... simpler identity:
        // total noise variance after the step equals 1-ᾱ_lo:
        // (a√(1-ᾱhi)+b)² + σ² = 1-ᾱ_lo.
        let ns = sched();
        let sc = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 100);
        let taus = ns.subset_timesteps(100);
        for t in 2..=100usize {
            let abar_hi = ns.alpha_bar(taus[t - 1]);
            let abar_lo = ns.alpha_bar(taus[t - 2]);
            let dir = sc.a[t] * (1.0 - abar_hi).sqrt() + sc.b[t];
            let total = dir * dir + sc.c[t - 1] * sc.c[t - 1];
            assert!(
                (total - (1.0 - abar_lo)).abs() < 1e-10,
                "variance at t={t}: {total} vs {}",
                1.0 - abar_lo
            );
        }
    }

    #[test]
    fn abar_products() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 10);
        assert_eq!(sc.abar(5, 4), 1.0); // empty product
        let direct = sc.a[3] * sc.a[4] * sc.a[5];
        assert!((sc.abar(3, 5) - direct).abs() < 1e-15);
        // telescoping: ā_{1,T} = Π all
        let all: f64 = (1..=10).map(|j| sc.a[j]).product();
        assert!((sc.abar(1, 10) - all).abs() < 1e-12);
    }

    #[test]
    fn eta_interpolates() {
        let ns = sched();
        let half = SamplerCoeffs::new(&ns, SamplerKind::Eta(0.5), 50);
        let ddpm = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 50);
        for t in 1..50 {
            assert!((half.c[t] - 0.5 * ddpm.c[t]).abs() < 1e-12);
        }
    }

    #[test]
    fn thresholds_scale_with_d() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 25);
        let e1 = sc.threshold(10, 1e-3, 256);
        let e2 = sc.threshold(10, 1e-3, 512);
        assert!((e2 / e1 - 2.0).abs() < 1e-12);
        assert!(e1 > 0.0);
    }

    #[test]
    fn train_t_descends_with_solver_index() {
        let sc = SamplerCoeffs::new(&sched(), SamplerKind::Ddim, 25);
        // Higher solver index = noisier state = later training timestep.
        for t in 2..=25 {
            assert!(sc.train_t[t] > sc.train_t[t - 1]);
        }
        assert_eq!(sc.train_t[1], 0);
    }
}
