//! Diffusion noise schedules and sampler coefficients.
//!
//! Implements the discrete VP (DDPM-style) forward process and the
//! first-order sampler coefficients of eq. (6) in the paper:
//!
//!   x_{t-1} = a_t x_t + b_t ε_θ(x_t, t) + c_{t-1} ξ_{t-1}
//!
//! for the DDIM(η) family (η=0 → DDIM/ODE with c ≡ 0; η=1 → DDPM/SDE),
//! including timestep subsetting (running T ∈ {25,50,100} steps of a
//! 1000-step training schedule) and the cumulative products ā_{i,s} used by
//! the order-k equations (Definition 2.1).
//!
//! Cross-checked against `python/compile/schedule.py` via exported test
//! vectors (`artifacts/testvec_schedule.json`).

pub mod sampler;

pub use sampler::{SamplerCoeffs, SamplerKind};

/// β-schedule families used by common diffusion models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BetaSchedule {
    /// DDPM's linear β ramp (1e-4 → 0.02 over `train_steps`).
    Linear,
    /// Stable-Diffusion's "scaled linear" (linear in √β).
    ScaledLinear,
    /// Nichol & Dhariwal cosine ᾱ schedule.
    Cosine,
}

/// The discrete forward process: β_t, α_t, ᾱ_t for t = 0..train_steps-1.
#[derive(Debug, Clone)]
pub struct NoiseSchedule {
    pub kind: BetaSchedule,
    pub betas: Vec<f64>,
    pub alphas: Vec<f64>,
    pub alpha_bars: Vec<f64>,
}

impl NoiseSchedule {
    pub fn new(kind: BetaSchedule, train_steps: usize) -> Self {
        assert!(train_steps >= 2);
        let n = train_steps as f64;
        let betas: Vec<f64> = match kind {
            BetaSchedule::Linear => {
                let (lo, hi) = (1e-4, 0.02);
                (0..train_steps)
                    .map(|i| lo + (hi - lo) * i as f64 / (n - 1.0))
                    .collect()
            }
            BetaSchedule::ScaledLinear => {
                let (lo, hi) = (0.00085f64.sqrt(), 0.012f64.sqrt());
                (0..train_steps)
                    .map(|i| {
                        let s = lo + (hi - lo) * i as f64 / (n - 1.0);
                        s * s
                    })
                    .collect()
            }
            BetaSchedule::Cosine => {
                let s = 0.008;
                let f = |u: f64| ((u + s) / (1.0 + s) * std::f64::consts::FRAC_PI_2).cos().powi(2);
                (0..train_steps)
                    .map(|i| {
                        let t0 = i as f64 / n;
                        let t1 = (i as f64 + 1.0) / n;
                        (1.0 - f(t1) / f(t0)).clamp(1e-8, 0.999)
                    })
                    .collect()
            }
        };
        let alphas: Vec<f64> = betas.iter().map(|b| 1.0 - b).collect();
        let mut alpha_bars = Vec::with_capacity(train_steps);
        let mut acc = 1.0;
        for &a in &alphas {
            acc *= a;
            alpha_bars.push(acc);
        }
        NoiseSchedule { kind, betas, alphas, alpha_bars }
    }

    /// Number of training timesteps.
    pub fn train_steps(&self) -> usize {
        self.betas.len()
    }

    /// Evenly-spaced subset of `steps` training timesteps, ascending
    /// (the DDIM "leading" spacing: 0, s, 2s, ...).
    pub fn subset_timesteps(&self, steps: usize) -> Vec<usize> {
        assert!(steps >= 1 && steps <= self.train_steps());
        let stride = self.train_steps() / steps;
        (0..steps).map(|i| i * stride).collect()
    }

    /// ᾱ at a training timestep.
    pub fn alpha_bar(&self, t: usize) -> f64 {
        self.alpha_bars[t]
    }

    /// Continuous-time diffusion coefficient g²(t) of the VP-SDE at the
    /// training timestep `t`: g²(t) = β(t)·N (β discretized with dt = 1/N).
    /// Used for the residual thresholds ε_t = τ²·g²(t)·d (§2.1).
    pub fn g2(&self, t: usize) -> f64 {
        self.betas[t] * self.train_steps() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_schedule_monotone() {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        assert_eq!(ns.betas.len(), 1000);
        assert!((ns.betas[0] - 1e-4).abs() < 1e-12);
        assert!((ns.betas[999] - 0.02).abs() < 1e-12);
        for i in 1..1000 {
            assert!(ns.betas[i] > ns.betas[i - 1]);
            assert!(ns.alpha_bars[i] < ns.alpha_bars[i - 1]);
        }
        // ᾱ telescopes: ᾱ_t = Π α_i
        let mut acc = 1.0;
        for i in 0..1000 {
            acc *= ns.alphas[i];
            assert!((ns.alpha_bars[i] - acc).abs() < 1e-15);
        }
    }

    #[test]
    fn cosine_schedule_bounded() {
        let ns = NoiseSchedule::new(BetaSchedule::Cosine, 1000);
        for &b in &ns.betas {
            assert!(b > 0.0 && b <= 0.999);
        }
        // ᾱ decays to near zero by the end.
        assert!(ns.alpha_bars[999] < 1e-3);
    }

    #[test]
    fn scaled_linear_matches_sd_range() {
        let ns = NoiseSchedule::new(BetaSchedule::ScaledLinear, 1000);
        assert!((ns.betas[0] - 0.00085).abs() < 1e-9);
        assert!((ns.betas[999] - 0.012).abs() < 1e-9);
    }

    #[test]
    fn subset_spacing() {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let ts = ns.subset_timesteps(100);
        assert_eq!(ts.len(), 100);
        assert_eq!(ts[0], 0);
        assert_eq!(ts[99], 990);
        for w in ts.windows(2) {
            assert_eq!(w[1] - w[0], 10);
        }
        let ts25 = ns.subset_timesteps(25);
        assert_eq!(ts25[24], 960);
    }

    #[test]
    fn g2_positive_increasing_for_linear() {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        assert!(ns.g2(0) > 0.0);
        assert!(ns.g2(999) > ns.g2(0));
    }
}
