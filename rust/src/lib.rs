//! # ParaTAA — Accelerating Parallel Sampling of Diffusion Models
//!
//! A full-system reproduction of Tang et al., ICML 2024: diffusion sampling
//! reformulated as a triangular nonlinear system solved by (safeguarded,
//! Triangular-Anderson-accelerated) fixed-point iteration, with every window
//! of denoiser evaluations executed in parallel as one batched device call.
//!
//! Architecture (see `DESIGN.md`):
//! - **L3 (this crate)** — solver + serving coordinator + multi-device
//!   execution pool, pure Rust.
//! - **L2** — JAX model (`python/compile/model.py`) AOT-lowered to HLO text.
//! - **L1** — Pallas kernels (`python/compile/kernels/`), lowered into L2.
//!
//! Each solve is a resumable [`solver::SolverSession`] — Algorithm 1 with
//! the parallel-round boundary externalized (`pending()` → ε batch →
//! `resume()`) — and the serving coordinator drives hundreds of sessions
//! from a few round-driver threads, merging their per-round ε batches into
//! single device calls. Because the residual front is monotone, the
//! coordinator can also **stream** each request's converged trajectory
//! prefix to the client while the solve is still running
//! ([`coordinator::Coordinator::submit_streaming`]), and an adaptive
//! window controller ([`solver::WindowPolicy`]) sizes each solve's window
//! from its convergence velocity and the pool's occupancy.
//! `docs/paper_map.md` cross-references the paper's definitions, theorems
//! and sections to the modules implementing them. Execution flows through [`runtime::DevicePool`]:
//! N backend actors (pure-Rust in-process by default; PJRT device actors
//! with `--features pjrt`) behind one [`model::EpsModel`] handle, with
//! per-device queues, batch sharding and work stealing. With the `pjrt`
//! feature the hot path loads `artifacts/*.hlo.txt` through the PJRT CPU
//! client; Python never runs at request time.
//!
//! Performance is tracked by the [`bench`] subsystem: `parataa bench`
//! sweeps a registry of canonical scenarios and writes a versioned
//! `BENCH_repro.json` that later PRs diff against (`--baseline`); see
//! `docs/bench.md` and the README for the workflow. Runtime behaviour is
//! observable through the always-compiled-in [`trace`] subsystem:
//! lock-free per-thread span/event recording across every layer, exported
//! as Perfetto-loadable Chrome trace JSON, Prometheus text, and
//! per-session convergence telemetry (`docs/observability.md`).

// Public-API documentation coverage: tracked as warnings crate-wide, and
// **denied at the source** for the serving layers (`coordinator`,
// `runtime`) below — the same scoped-deny idiom as the clippy::perf gate,
// so any build (not just the CI docs job) fails on a doc gap there.
// Source-level lint attributes take precedence over CLI flags, which is
// why the gate lives here rather than in .github/workflows/ci.yml.
#![warn(missing_docs)]

pub mod bench;
// Serving-layer doc coverage is enforced (see the note above): every pub
// item in coordinator/ and runtime/ must carry a doc comment.
#[deny(missing_docs)]
pub mod coordinator;
pub mod equations;
pub mod figures;
// The numeric core must stay free of clippy's perf lints regardless of CI
// flags: deny them at the source so even a bare `cargo clippy` fails on a
// perf regression in the hot paths (ISSUE-4 lint gate).
#[deny(clippy::perf)]
pub mod linalg;
pub mod metrics;
pub mod model;
#[deny(missing_docs)]
pub mod runtime;
pub mod schedule;
// The HTTP front is a public wire contract (docs/serving.md documents it
// verbatim): hold it to the serving-layer doc bar.
#[deny(missing_docs)]
pub mod serve;
#[deny(clippy::perf)]
pub mod solver;
// The observability layer is a contract later perf work measures against;
// hold it to the same doc bar as the serving layers.
#[deny(missing_docs)]
pub mod trace;
pub mod util;
