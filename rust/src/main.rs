//! ParaTAA CLI — leader entrypoint.
//!
//! Subcommands:
//!   sample        solve one sampling request and write the image
//!   serve         run the coordinator demo under synthetic load
//!   bench         run the perf-scenario registry, write BENCH_repro.json
//!                 (and optionally gate against a --baseline report)
//!   fig1..fig7, fig14, table1
//!                 regenerate a paper figure/table (CSV + ASCII)
//!   all-figures   regenerate everything into results/
//!
//! Common options: --model dit|gmm, --steps N, --samples N, --seed N.
//! `serve` additionally takes --devices N (size of the execution pool),
//! --drivers N (round-driver threads carrying the session run queue),
//! --stream (incremental converged-prefix delivery, bitwise-verified),
//! --adaptive-window (occupancy-driven window sizing), and the robustness
//! knobs --inject-faults SPEC / --deadline-ms N / --shed-watermark F /
//! --shard-timeout-ms N (deterministic chaos, request deadlines, graceful
//! degradation, per-attempt shard deadlines — see docs/robustness.md), and
//! the HTTP front --http ADDR / --tenants SPEC / --http-for-ms N
//! (multi-tenant HTTP/SSE serving — see docs/serving.md).
//! DiT scenarios need the `pjrt` feature plus `make artifacts` (PJRT HLO +
//! trained weights).

use parataa::figures;
use parataa::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "help" | "--help" => help(),
        "sample" => cmd_sample(&args),
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        // Not part of ALL: it replays a recorded --telemetry file rather
        // than running an experiment, so all-figures must not require one.
        "convergence" => run_experiment("convergence", &args),
        "all-figures" => {
            for name in figures::ALL {
                run_experiment(name, &args);
            }
        }
        name if figures::ALL.contains(&name) => run_experiment(name, &args),
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            help();
            std::process::exit(2);
        }
    }
}

fn help() {
    println!(
        "parataa — Accelerating Parallel Sampling of Diffusion Models (ICML 2024)\n\n\
         usage: parataa <subcommand> [--options]\n\n\
         subcommands:\n\
           sample      solve one request    (--model dit|gmm --steps N --seed N\n\
                       --method taa|fp|aa|aa+ --class C --out img.pgm;\n\
                       --threads N: intra-round row-parallelism for the\n\
                       numeric core — bitwise identical at every setting;\n\
                       --trace FILE: Perfetto-loadable Chrome trace of the solve)\n\
           serve       coordinator demo under synthetic load\n\
                       (--requests N --workers N: admission threads; --drivers N:\n\
                       round-driver threads carrying all in-flight sessions and\n\
                       merging their per-round eps batches; --devices N: N-backend\n\
                       execution pool with sharding + work stealing;\n\
                       --threads N: per-session row-parallelism; --stream:\n\
                       deliver each request's converged prefix incrementally and\n\
                       verify the streamed states bitwise against a non-streaming\n\
                       re-run; --adaptive-window: size each solve's window from\n\
                       convergence velocity + pool occupancy;\n\
                       --strategies plain|mixed: 'mixed' cycles the requests\n\
                       through plain / draft-and-refine / Parareal\n\
                       multi-fidelity solve strategies; prints merge\n\
                       occupancy, streaming counters + a per-device utilization\n\
                       breakdown; --json dumps the metrics snapshot;\n\
                       --trace FILE: Chrome trace-event JSON of the whole run,\n\
                       one track per session/driver/device — open in Perfetto;\n\
                       --prom-out FILE: Prometheus text exposition (validated\n\
                       before writing); --telemetry FILE: per-session round ->\n\
                       residual/front/window/NFE progressions as JSON lines,\n\
                       replayable via the convergence subcommand;\n\
                       --inject-faults SPEC: deterministic fault injection\n\
                       behind the device pool, e.g. '1:error@4..' — activates\n\
                       the retry/quarantine path (see docs/robustness.md);\n\
                       --shard-timeout-ms N: per-attempt shard execution\n\
                       deadline activating the pool's retry/quarantine path\n\
                       (defaults to 250 under --inject-faults; raise it for\n\
                       real DiT/PJRT shards);\n\
                       --deadline-ms N: per-request end-to-end deadline,\n\
                       enforced at admission and between rounds;\n\
                       --shed-watermark F: above this slot-occupancy fraction\n\
                       new requests degrade to a bitwise-exact sequential\n\
                       solve instead of queueing;\n\
                       --http ADDR: serve over HTTP/SSE instead of synthetic\n\
                       load (POST /v1/sample, POST /v1/sample/stream,\n\
                       GET /metrics, GET /healthz — see docs/serving.md);\n\
                       --tenants SPEC: per-tenant quotas/weights/classes,\n\
                       e.g. 'acme:weight=3,rps=10;bulk:class=batch';\n\
                       --http-for-ms N: serve N ms then exit with the report;\n\
                       --http-gate N: max requests concurrently in service)\n\
           bench       perf-scenario sweep -> BENCH_repro.json (see docs/bench.md)\n\
                       (--quick: CI smoke subset; --out FILE; --only SUBSTR;\n\
                       --threads N: session parallelism for the hot-loop\n\
                       scenarios;\n\
                       --baseline FILE [--threshold PCT]: print a regression\n\
                       table and exit 3 if any metric is >PCT pct worse)\n\
           fig1        FP residual convergence vs order k\n\
           fig2        FP vs AA vs TAA\n\
           fig3        quality vs rounds across scenarios\n\
           fig4        window-size trade-off\n\
           fig5        qualitative trajectory-init strips (PGM)\n\
           fig6        per-timestep residuals / safeguard / AA+ stress\n\
           fig7        (k, m) grid search\n\
           fig14       trajectory-init CS curves\n\
           table1      the headline table\n\
           convergence residual-decay curves from a recorded --telemetry file\n\
                       (--telemetry FILE [--max-sessions N]; not in all-figures)\n\
           all-figures regenerate everything into results/\n\n\
         common options: --model dit|gmm  --samples N  --seed N  --steps N"
    );
}

fn run_experiment(name: &str, args: &Args) {
    eprintln!("=== {name} ===");
    let t0 = std::time::Instant::now();
    for (csv_name, table) in figures::run(name, args) {
        let path = format!("results/{csv_name}.csv");
        table.write_csv(&path).expect("write csv");
        println!("{}", table.to_ascii());
        println!("wrote {path}");
    }
    eprintln!("=== {name} done in {:?} ===\n", t0.elapsed());
}

fn cmd_sample(args: &Args) {
    use parataa::figures::common::{method_config, ModelChoice, Scenario};
    use parataa::model::Cond;
    use parataa::schedule::SamplerKind;
    use parataa::solver::{self, Method, Problem};

    let model = ModelChoice::parse(&args.get_or("model", "gmm"));
    let steps = args.usize_or("steps", 50);
    let kind = match args.get_or("sampler", "ddim").as_str() {
        "ddim" => SamplerKind::Ddim,
        "ddpm" => SamplerKind::Ddpm,
        other => panic!("unknown sampler '{other}'"),
    };
    let method = match args.get_or("method", "taa").as_str() {
        "taa" => Method::Taa,
        "fp" => Method::FixedPoint,
        "aa" => Method::AndersonStd,
        "aa+" => Method::AndersonUpperTri,
        other => panic!("unknown method '{other}'"),
    };
    let seed = args.u64_or("seed", 0);
    let class = args.usize_or("class", 0);
    let scenario = Scenario::new(model, kind, steps);
    let coeffs = scenario.coeffs();
    let problem = Problem::new(&coeffs, &*scenario.model, Cond::Class(class), seed);
    let mut cfg = method_config(method, steps, args.get("k").map(|v| v.parse().unwrap()), scenario.guidance);
    // Intra-round row-parallelism for the numeric core; bitwise identical
    // at every setting, so --threads is purely a wall-clock knob.
    cfg.parallelism = args.usize_or("threads", 1).max(1);
    let trace_out = args.get("trace").map(str::to_string);
    if trace_out.is_some() {
        parataa::trace::enable();
    }
    let t0 = std::time::Instant::now();
    let result = solver::solve(&problem, &cfg);
    let dt = t0.elapsed();
    if let Some(path) = &trace_out {
        parataa::trace::chrome::write_file(path, &parataa::trace::collect())
            .expect("write trace file");
        println!("wrote {path} (Chrome trace-event JSON — open in ui.perfetto.dev)");
    }
    let seq = solver::sample_sequential(&problem, scenario.guidance);
    let rmse = parataa::metrics::match_rmse(result.xs.row(0), seq.xs.row(0));
    println!(
        "{} {} {}: {} parallel rounds (seq {} steps), nfe {}, converged {}, {dt:?}",
        scenario.label(),
        method.label(),
        seed,
        result.iterations,
        steps,
        result.total_nfe,
        result.converged,
    );
    println!("parallel-vs-sequential RMSE: {rmse:.2e} (Remark 5.3)");
    let out = args.get_or("out", "results/sample.pgm");
    parataa::util::image::write_pgm(&out, result.xs.row(0), 16, 16).expect("write image");
    println!("wrote {out}");
}

/// Build the execution pool for `serve` (plus the scenario's CFG scale):
/// N in-process backends over the analytic model, or N PJRT device actors
/// for `--model dit` (pjrt builds only). Deliberately does NOT go through
/// `figures::common::Scenario`, which would spawn and warm a shared device
/// actor that serve never uses — everything runs through this pool.
///
/// With `--inject-faults` each backend is wrapped in a
/// [`parataa::runtime::FaultyBackend`] applying the scheduled faults for
/// its device index. A `shard_timeout` (from `--shard-timeout-ms`, or the
/// 250 ms chaos default under `--inject-faults`) runs the pool's
/// retry/quarantine path with NaN output validation, so faults surface as
/// retries and quarantines rather than bad samples. Without either flag
/// the configuration is the exact historical default.
///
/// Also returns the pool-independent fallback model for degraded
/// sequential rollouts where one exists (the analytic GMM; PJRT/DiT
/// deployments have no in-process twin, so they degrade through the
/// pooled handle's fallible path instead).
fn build_pool(
    model_choice: parataa::figures::common::ModelChoice,
    devices: usize,
    faults: Option<(&parataa::runtime::FaultSpec, &parataa::runtime::FaultControl)>,
    shard_timeout: Option<std::time::Duration>,
) -> (
    parataa::runtime::DevicePool,
    f32,
    Option<std::sync::Arc<dyn parataa::model::EpsModel>>,
) {
    use parataa::figures::common::ModelChoice;
    use parataa::model::gmm::GmmEps;
    use parataa::runtime::{DevicePool, EpsBackend, FaultyBackend, InProcessBackend, PoolConfig};
    use parataa::schedule::{BetaSchedule, NoiseSchedule};
    use std::sync::Arc;

    let pool_cfg = |warm: Vec<usize>| {
        let mut cfg = PoolConfig { warm, ..Default::default() };
        if let Some(t) = shard_timeout {
            cfg.shard_timeout = Some(t);
            cfg.validate_output = true;
        }
        cfg
    };
    let wrap = |backend: Box<dyn EpsBackend>, device: usize| -> Box<dyn EpsBackend> {
        match faults {
            Some((spec, control)) => {
                Box::new(FaultyBackend::new(backend, device, spec, control.clone()))
            }
            None => backend,
        }
    };

    match model_choice {
        ModelChoice::Gmm => {
            let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
            let model = Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()));
            let fallback: Arc<dyn parataa::model::EpsModel> = model.clone();
            let pool = if faults.is_some() {
                let backends: Vec<Box<dyn EpsBackend>> = (0..devices)
                    .map(|dev| wrap(Box::new(InProcessBackend::new(model.clone())), dev))
                    .collect();
                DevicePool::spawn(backends, pool_cfg(Vec::new()))
            } else {
                DevicePool::in_process(model, devices, pool_cfg(Vec::new()))
            }
            .expect("spawn device pool");
            (pool, 2.0, Some(fallback))
        }
        ModelChoice::Dit => {
            #[cfg(feature = "pjrt")]
            {
                use parataa::runtime::PjrtBackend;
                let mut backends: Vec<Box<dyn EpsBackend>> = Vec::with_capacity(devices);
                for dev in 0..devices {
                    let b =
                        PjrtBackend::spawn(parataa::runtime::default_artifacts_dir(), 256)
                            .expect("artifacts missing — run `make artifacts`");
                    backends.push(wrap(Box::new(b), dev));
                }
                let cfg = pool_cfg(parataa::runtime::EPS_BATCH_SIZES.to_vec());
                (DevicePool::spawn(backends, cfg).expect("spawn device pool"), 5.0, None)
            }
            #[cfg(not(feature = "pjrt"))]
            {
                panic!("serve --model dit needs a `--features pjrt` build (see rust/Cargo.toml)")
            }
        }
    }
}

fn cmd_serve(args: &Args) {
    use parataa::coordinator::{
        Coordinator, CoordinatorConfig, RobustnessConfig, SampleRequest, SamplerSpec,
    };
    if args.get("http").is_some() {
        return cmd_serve_http(args);
    }
    use parataa::figures::common::ModelChoice;
    use parataa::model::Cond;
    use parataa::runtime::{FaultControl, FaultSpec};
    use parataa::solver::{AdaptiveWindow, WindowPolicy};
    use parataa::util::rng::Pcg64;
    use std::sync::Arc;

    let model_choice = ModelChoice::parse(&args.get_or("model", "gmm"));
    let steps = args.usize_or("steps", 50);
    let n_requests = args.usize_or("requests", 32);
    let workers = args.usize_or("workers", 4);
    let drivers = args.usize_or("drivers", 2).max(1);
    let devices = args.usize_or("devices", 1).max(1);
    let stream = args.has_flag("stream");
    let adaptive = args.has_flag("adaptive-window");
    let threads = args.usize_or("threads", 1).max(1);
    let strategies = args.get_or("strategies", "plain");
    let mixed = match strategies.as_str() {
        "plain" => false,
        "mixed" => true,
        other => panic!("unknown --strategies '{other}' (expected plain|mixed)"),
    };

    // Robustness knobs (ISSUE 9) — all default off, leaving the exact
    // historical service when unset.
    let deadline_ms: Option<u64> = args
        .get("deadline-ms")
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --deadline-ms '{v}'")));
    let shed_watermark: Option<f64> = args
        .get("shed-watermark")
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --shed-watermark '{v}'")));
    let faults: Option<FaultSpec> = args.get("inject-faults").map(|spec| {
        FaultSpec::parse(spec)
            .unwrap_or_else(|e| panic!("bad --inject-faults: {e}"))
            .with_seed(args.u64_or("seed", 0))
    });
    // One cancel token shared by every injected hang: cancelled after the
    // run so wedged worker threads release before the pool joins them.
    let fault_control = faults.as_ref().map(|_| FaultControl::new());
    // Per-attempt shard execution deadline (activates the pool's
    // retry/quarantine path). `--inject-faults` defaults it to 250 ms —
    // right for the in-process chaos demo, far too tight for a real
    // DiT/PJRT shard under load, hence the explicit override.
    let shard_timeout_ms: Option<u64> = args
        .get("shard-timeout-ms")
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --shard-timeout-ms '{v}'")));
    let shard_timeout = shard_timeout_ms
        .or(if faults.is_some() { Some(250) } else { None })
        .map(std::time::Duration::from_millis);

    // Observability taps (ISSUE 6): --trace wants span events, and the
    // --prom-out exposition carries trace-derived histograms, so either
    // flag turns the recorder on before any session is admitted.
    let trace_out = args.get("trace").map(str::to_string);
    let prom_out = args.get("prom-out").map(str::to_string);
    let telemetry_out = args.get("telemetry").map(str::to_string);
    if trace_out.is_some() || prom_out.is_some() {
        parataa::trace::enable();
    }
    let telemetry = telemetry_out
        .as_ref()
        .map(|_| Arc::new(parataa::trace::telemetry::TelemetryLog::new()));

    // Stack: backend pool -> coordinator round drivers. The drivers merge
    // the pending ε batches of ready sessions per round (no batcher layer:
    // merging happens deterministically at the round boundary).
    let (pool, guidance, fallback_model) = build_pool(
        model_choice,
        devices,
        faults.as_ref().zip(fault_control.as_ref()),
        shard_timeout,
    );
    let pool_stats = pool.stats();
    let pooled = Arc::new(pool.eps_handle("pooled"));
    let coord = Coordinator::start(
        pooled,
        CoordinatorConfig {
            workers,
            drivers,
            devices,
            telemetry: telemetry.clone(),
            robustness: RobustnessConfig {
                shed_watermark,
                // Degraded rollouts bypass the pool where an in-process
                // model exists — essential when degradation triggers
                // because every pool device is quarantined.
                fallback_model,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    coord.attach_pool(pool_stats);

    eprintln!(
        "serving {n_requests} requests ({} DDIM-{steps}) on {devices} device(s), \
         {drivers} round driver(s){}{}{}{} ...",
        model_choice.label(),
        if stream { ", streaming prefixes" } else { "" },
        if adaptive { ", adaptive windows" } else { "" },
        if mixed { ", mixed strategies" } else { "" },
        if faults.is_some() { ", fault injection ON" } else { "" },
    );
    let mut rng = Pcg64::seeded(args.u64_or("seed", 0));
    let conds: Vec<Cond> =
        (0..n_requests).map(|_| Cond::Class(rng.below(8) as usize)).collect();
    let make_req = |i: usize| {
        let mut req =
            SampleRequest::parataa(conds[i].clone(), i as u64, SamplerSpec::ddim(steps));
        req.guidance = guidance;
        req.deadline_ms = deadline_ms;
        // Intra-round row-parallelism per session (bitwise inert, so the
        // streaming re-run equality check below is unaffected).
        req.parallelism = threads;
        // The streaming demo re-solves every request for the bitwise
        // equality check, so both passes must stay cold (a warm start in
        // one pass only would legitimately change the solve).
        req.use_trajectory_cache = !stream;
        if adaptive {
            req.window_policy = WindowPolicy::Adaptive(AdaptiveWindow::for_steps(steps));
            // Start below the cap so velocity-driven growth has room to
            // act — at the full window the controller could only shrink.
            req.window = Some((steps / 4).max(1));
        }
        if mixed {
            // Cycle the multi-fidelity strategies so every serve round
            // co-batches coarse and fine ε sources (the CI strategy smoke
            // asserts coarse_round spans + zero failures on this path).
            use parataa::solver::{DraftRefineConfig, PararealConfig, SolveStrategy};
            req.strategy = match i % 3 {
                0 => SolveStrategy::PlainTaa,
                1 => SolveStrategy::DraftRefine(DraftRefineConfig::default()),
                _ => SolveStrategy::Parareal(PararealConfig::default()),
            };
        }
        req
    };

    if stream {
        serve_stream_demo(&coord, n_requests, steps, adaptive, &make_req);
    } else {
        let handles: Vec<_> = (0..n_requests).map(|i| coord.submit(make_req(i))).collect();
        for (i, h) in handles.into_iter().enumerate() {
            // Per-request failures (deadline expiry, shedding in Fail mode,
            // exhausted retries) are reported, not fatal: the metrics
            // snapshot below is the run's verdict, and a chaos run is
            // expected to retry/degrade its way through injected faults.
            match h.wait() {
                Ok(r) => {
                    if i < 4 || !r.converged || r.degraded {
                        // Progress goes to stderr so `--json` stdout stays
                        // parseable.
                        eprintln!(
                            "req {i}: rounds={} nfe={} warm={} conv={} degraded={} latency={:?}",
                            r.rounds, r.nfe, r.warm_started, r.converged, r.degraded, r.latency
                        );
                    }
                }
                Err(e) => eprintln!("req {i}: FAILED ({}): {e}", e.kind().label()),
            }
        }
    }
    // The report includes the per-device breakdown (attached pool stats).
    if args.has_flag("json") {
        println!("{}", coord.metrics().to_json());
    } else {
        println!("{}", coord.metrics().report());
    }
    if let Some(path) = &trace_out {
        parataa::trace::chrome::write_file(path, &parataa::trace::collect())
            .expect("write trace file");
        eprintln!("wrote {path} (Chrome trace-event JSON — open in ui.perfetto.dev)");
    }
    if let Some(path) = &prom_out {
        let text = coord.metrics().to_prometheus();
        // Self-check before writing: a rendering bug should fail the run,
        // not the scrape that reads the file later.
        let samples = parataa::trace::prom::validate(&text)
            .expect("generated Prometheus exposition failed validation");
        std::fs::write(path, &text).expect("write Prometheus file");
        eprintln!("wrote {path} ({samples} Prometheus samples)");
    }
    if let (Some(path), Some(log)) = (&telemetry_out, &telemetry) {
        log.write_jsonl(path).expect("write telemetry file");
        eprintln!("wrote {path} ({} session telemetry records)", log.sessions().len());
    }
    drop(coord); // join drivers first ...
    if let Some(control) = &fault_control {
        control.cancel(); // ... then release scripted hangs so the pool's
                          // worker threads return and join on drop.
    }
}

/// `serve --http ADDR`: expose the coordinator over the HTTP/SSE front
/// (`POST /v1/sample`, `POST /v1/sample/stream`, `GET /metrics`,
/// `GET /healthz` — see docs/serving.md) instead of generating synthetic
/// load. `--tenants SPEC` switches admission to configured mode
/// (per-tenant quotas, weights, and priority classes; unknown tenants are
/// refused 403); without it any presented tenant is accepted unlimited.
/// `--http-for-ms N` serves for N ms then shuts down gracefully and
/// prints the metrics report — the CI http-smoke uses this; without it
/// the server runs until the process is killed.
fn cmd_serve_http(args: &Args) {
    use parataa::coordinator::{Coordinator, CoordinatorConfig, RobustnessConfig};
    use parataa::figures::common::ModelChoice;
    use parataa::runtime::{FaultControl, FaultSpec};
    use parataa::serve::{HttpConfig, HttpServer, TenantRegistry};
    use std::sync::Arc;

    let addr = args.get("http").expect("--http ADDR").to_string();
    let model_choice = ModelChoice::parse(&args.get_or("model", "gmm"));
    let devices = args.usize_or("devices", 1).max(1);
    let workers = args.usize_or("workers", 4);
    let drivers = args.usize_or("drivers", 2).max(1);
    let shed_watermark: Option<f64> = args
        .get("shed-watermark")
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --shed-watermark '{v}'")));
    let faults: Option<FaultSpec> = args.get("inject-faults").map(|spec| {
        FaultSpec::parse(spec)
            .unwrap_or_else(|e| panic!("bad --inject-faults: {e}"))
            .with_seed(args.u64_or("seed", 0))
    });
    let fault_control = faults.as_ref().map(|_| FaultControl::new());
    let shard_timeout = args
        .get("shard-timeout-ms")
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --shard-timeout-ms '{v}'")))
        .or(if faults.is_some() { Some(250) } else { None })
        .map(std::time::Duration::from_millis);

    let tenants = Arc::new(
        TenantRegistry::from_spec(args.get("tenants"))
            .unwrap_or_else(|e| panic!("bad --tenants: {e}")),
    );
    let http_cfg = HttpConfig {
        gate_capacity: args.usize_or("http-gate", HttpConfig::default().gate_capacity),
        accept_threads: args
            .usize_or("http-accept", HttpConfig::default().accept_threads)
            .max(1),
        ..Default::default()
    };

    let (pool, _guidance, fallback_model) = build_pool(
        model_choice,
        devices,
        faults.as_ref().zip(fault_control.as_ref()),
        shard_timeout,
    );
    let pool_stats = pool.stats();
    let pooled = Arc::new(pool.eps_handle("pooled"));
    let coord = Arc::new(Coordinator::start(
        pooled,
        CoordinatorConfig {
            workers,
            drivers,
            devices,
            robustness: RobustnessConfig {
                shed_watermark,
                fallback_model,
                ..Default::default()
            },
            ..Default::default()
        },
    ));
    coord.attach_pool(pool_stats);

    let server = HttpServer::start(Arc::clone(&coord), Arc::clone(&tenants), &addr, http_cfg)
        .unwrap_or_else(|e| panic!("http server: {e}"));
    // The bound address resolves ':0'; scripts scrape this line.
    println!("listening http://{}", server.local_addr());
    eprintln!(
        "serving {} over HTTP on {} ({devices} device(s), {drivers} driver(s), tenants: {})",
        model_choice.label(),
        server.local_addr(),
        if args.get("tenants").is_some() { "configured" } else { "open" },
    );

    match args.get("http-for-ms") {
        Some(v) => {
            let ms: u64 = v.parse().unwrap_or_else(|_| panic!("bad --http-for-ms '{v}'"));
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        None => loop {
            // Serve until killed: accept threads carry all the work.
            std::thread::sleep(std::time::Duration::from_secs(3600));
        },
    }
    drop(server); // stop accepting, drain in-service requests, join pool
    if args.has_flag("json") {
        println!("{}", coord.metrics().to_json());
    } else {
        println!("{}", coord.metrics().report());
    }
    drop(coord);
    if let Some(control) = &fault_control {
        control.cancel();
    }
}

/// `serve --stream`: every request goes through the streaming path with a
/// consumer thread draining its prefix chunks, then the whole load is
/// re-run non-streaming and checked **bitwise** against the streamed
/// results. Process-fatal asserts make this the CI stream-smoke oracle:
/// each request must observe ≥ 1 prefix chunk strictly before completion,
/// the chunks must tile the trajectory, and the streamed sample must equal
/// the non-streaming one bit-for-bit (skipped under `--adaptive-window`,
/// where the occupancy-driven window makes runs legitimately non-identical).
fn serve_stream_demo(
    coord: &parataa::coordinator::Coordinator,
    n_requests: usize,
    steps: usize,
    adaptive: bool,
    make_req: &dyn Fn(usize) -> parataa::coordinator::SampleRequest,
) {
    let threads: Vec<_> = (0..n_requests)
        .map(|i| {
            let handle = coord.submit_streaming(make_req(i));
            std::thread::spawn(move || {
                let t0 = std::time::Instant::now();
                let mut chunks = Vec::new();
                let mut first = None;
                while let Some(c) = handle.next_chunk() {
                    if first.is_none() {
                        first = Some(t0.elapsed());
                    }
                    chunks.push(c);
                }
                let resp = handle.wait().expect("streaming request failed");
                (chunks, first, resp)
            })
        })
        .collect();
    let mut streamed = Vec::with_capacity(n_requests);
    for (i, t) in threads.into_iter().enumerate() {
        let (chunks, first, resp) = t.join().expect("stream consumer panicked");
        assert!(resp.converged, "req {i} did not converge");
        assert!(
            chunks.iter().any(|c| c.round < resp.rounds),
            "req {i}: no prefix chunk arrived strictly before completion"
        );
        let mut expect_end = steps;
        for c in &chunks {
            assert_eq!(c.rows.end, expect_end, "req {i}: chunk gap/overlap");
            expect_end = c.rows.start;
        }
        assert_eq!(expect_end, 0, "req {i}: stream never reached the sample row");
        let last = chunks.last().expect("converged stream has chunks");
        assert_eq!(
            &last.states[..resp.sample.len()],
            &resp.sample[..],
            "req {i}: streamed sample row != final response"
        );
        if i < 4 {
            eprintln!(
                "req {i}: {} chunks, first prefix after {:?}, done after {:?} ({} rounds)",
                chunks.len(),
                first.expect("converged stream has a first chunk"),
                resp.latency,
                resp.rounds,
            );
        }
        streamed.push(resp);
    }
    if adaptive {
        eprintln!("stream demo OK (adaptive windows: bitwise re-run check skipped)");
        return;
    }
    // Second pass, non-streaming: identical requests must produce
    // bit-identical samples (streaming is purely observational).
    let handles: Vec<_> = (0..n_requests).map(|i| coord.submit(make_req(i))).collect();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait().expect("verification request failed");
        assert_eq!(
            r.sample, streamed[i].sample,
            "req {i}: streamed and non-streaming samples differ"
        );
    }
    eprintln!("stream demo OK: {n_requests} requests streamed and verified bitwise");
}

/// Do two paths name the same file, regardless of spelling
/// (`./BENCH_repro.json` vs `BENCH_repro.json`)? Falls back to literal
/// comparison when either path cannot be canonicalized (e.g. not yet
/// created).
fn same_file(a: &str, b: &str) -> bool {
    match (std::fs::canonicalize(a), std::fs::canonicalize(b)) {
        (Ok(ca), Ok(cb)) => ca == cb,
        _ => a == b,
    }
}

/// Human label for a report's sweep mode.
fn sweep_kind(quick: bool) -> &'static str {
    if quick {
        "quick"
    } else {
        "full"
    }
}

/// `parataa bench`: sweep the scenario registry, write the JSON report,
/// and optionally gate against a baseline report.
///
/// Exit codes: 0 ok, 1 internal failure (invalid report / unwritable
/// output), 2 usage/input problems (empty --only match; unusable or
/// incomparable baseline), 3 regression(s) detected (the baseline file is
/// left unchanged in that case).
fn cmd_bench(args: &Args) {
    use parataa::bench::{self, BenchOpts};

    let mut opts = if args.has_flag("quick") { BenchOpts::quick() } else { BenchOpts::full() };
    opts.seed = args.u64_or("seed", opts.seed);
    opts.threads = args.usize_or("threads", opts.threads).max(1);
    if let Some(f) = args.get("only") {
        opts.filter = Some(f.to_string());
    }

    // Load the baseline BEFORE running (fail fast on a bad path) and
    // before saving (the default --out equals the conventional baseline
    // path, and the old numbers must be read before being replaced).
    let baseline = args.get("baseline").map(|base_path| {
        match bench::Report::load(base_path) {
            Ok(b) => (base_path.to_string(), b),
            Err(e) => {
                eprintln!("bench: cannot load baseline {base_path}: {e}");
                std::process::exit(2);
            }
        }
    });

    let report = bench::run_all(&opts);
    if report.groups.is_empty() {
        // A misspelled --only (or one naming a scenario the --quick subset
        // excludes) must not masquerade as a successful sweep.
        eprintln!(
            "bench: no scenarios matched (filter {:?}, quick={})",
            opts.filter, opts.quick
        );
        std::process::exit(2);
    }
    println!("{}", report.summary_table().to_ascii());
    if opts.filter.is_none() {
        // A full (or quick) sweep must produce a schema-valid report;
        // filtered sweeps legitimately omit sections.
        if let Err(e) = report.validate() {
            eprintln!("bench: report failed schema validation: {e}");
            std::process::exit(1);
        }
    }
    // Gate BEFORE writing: a failed gate must not replace the baseline
    // file with the regressed numbers (an immediate re-run would then
    // compare the regression against itself and pass).
    let mut gate_failed: Option<(String, usize, f64)> = None;
    if let Some((base_path, baseline)) = &baseline {
        if baseline.schema_version != bench::SCHEMA_VERSION {
            eprintln!(
                "bench: baseline {base_path} has schema v{} (this build reads v{})",
                baseline.schema_version,
                bench::SCHEMA_VERSION
            );
            std::process::exit(2);
        }
        // Reports from different sweep configurations are only loosely
        // comparable: another seed draws different Table-1 conditions
        // (shifting even the deterministic rounds/NFE metrics) and another
        // mode changes phase lengths and seed counts.
        if baseline.meta.seed != report.meta.seed {
            eprintln!(
                "bench: WARNING — baseline seed {} != this sweep's seed {}; \
                 rounds/NFE deltas are not meaningful across seeds",
                baseline.meta.seed, report.meta.seed
            );
        }
        if baseline.meta.quick != report.meta.quick {
            eprintln!(
                "bench: note — comparing a {} sweep against a {} baseline \
                 (common subset only)",
                sweep_kind(report.meta.quick),
                sweep_kind(baseline.meta.quick),
            );
        }
        let threshold = args.f64_or("threshold", 10.0);
        let deltas = bench::compare(baseline, &report, threshold);
        if deltas.is_empty() {
            // No common (group, scenario, metric) at all — almost certainly
            // a wrong/partial baseline file; passing silently would make
            // the gate vacuous.
            eprintln!("bench: baseline {base_path} shares no metrics with this sweep");
            std::process::exit(2);
        }
        println!("{}", bench::regression_table(&deltas, threshold).to_ascii());
        let regressions = bench::regression_count(&deltas);
        if regressions > 0 {
            gate_failed = Some((base_path.clone(), regressions, threshold));
        } else {
            println!(
                "bench: no regressions vs {base_path} ({} metrics compared, threshold {threshold:.0}%)",
                deltas.len()
            );
        }
    }

    let out = args.get_or("out", "BENCH_repro.json");
    if opts.filter.is_some() && args.get("out").is_none() {
        // A filtered sweep is partial and schema-invalid: never let it
        // silently replace the canonical repo-root report (a later
        // --baseline against it would skip everything it lacks). Writing
        // a partial report needs an explicit --out.
        eprintln!("bench: --only sweep is partial; not writing BENCH_repro.json (pass --out to save)");
    } else if gate_failed.as_ref().map(|(bp, _, _)| same_file(bp, &out)).unwrap_or(false) {
        eprintln!("bench: gate failed — keeping baseline {out} unchanged");
    } else {
        // Replacing a report from the other sweep mode loses fidelity
        // (quick uses shorter phases, fewer seeds and a scenario subset);
        // the smoke workflow does exactly this on CI runners, so warn
        // rather than refuse.
        if let Ok(prev) = bench::Report::load(&out) {
            if prev.meta.quick != report.meta.quick {
                eprintln!(
                    "bench: WARNING — replacing a {} report at {out} with a {} one",
                    sweep_kind(prev.meta.quick),
                    sweep_kind(report.meta.quick),
                );
            }
        }
        if let Err(e) = report.save(&out) {
            eprintln!("bench: cannot write {out}: {e}");
            std::process::exit(1);
        }
        println!("wrote {out} (schema v{})", parataa::bench::SCHEMA_VERSION);
    }

    if let Some((base_path, regressions, threshold)) = gate_failed {
        eprintln!("bench: {regressions} metric(s) regressed >{threshold:.0}% vs {base_path}");
        std::process::exit(3);
    }
}
