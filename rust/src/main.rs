//! ParaTAA CLI — leader entrypoint.
//!
//! Subcommands:
//!   sample        solve one sampling request and write the image
//!   serve         run the coordinator demo under synthetic load
//!   fig1..fig7, fig14, table1
//!                 regenerate a paper figure/table (CSV + ASCII)
//!   all-figures   regenerate everything into results/
//!
//! Common options: --model dit|gmm, --steps N, --samples N, --seed N.
//! `serve` additionally takes --devices N (size of the execution pool).
//! DiT scenarios need the `pjrt` feature plus `make artifacts` (PJRT HLO +
//! trained weights).

use parataa::figures;
use parataa::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "help" | "--help" => help(),
        "sample" => cmd_sample(&args),
        "serve" => cmd_serve(&args),
        "all-figures" => {
            for name in figures::ALL {
                run_experiment(name, &args);
            }
        }
        name if figures::ALL.contains(&name) => run_experiment(name, &args),
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            help();
            std::process::exit(2);
        }
    }
}

fn help() {
    println!(
        "parataa — Accelerating Parallel Sampling of Diffusion Models (ICML 2024)\n\n\
         usage: parataa <subcommand> [--options]\n\n\
         subcommands:\n\
           sample      solve one request    (--model dit|gmm --steps N --seed N\n\
                       --method taa|fp|aa|aa+ --class C --out img.pgm)\n\
           serve       coordinator demo under synthetic load\n\
                       (--requests N --workers N --devices N: N-backend execution\n\
                       pool with sharding + work stealing; prints a per-device\n\
                       utilization breakdown)\n\
           fig1        FP residual convergence vs order k\n\
           fig2        FP vs AA vs TAA\n\
           fig3        quality vs rounds across scenarios\n\
           fig4        window-size trade-off\n\
           fig5        qualitative trajectory-init strips (PGM)\n\
           fig6        per-timestep residuals / safeguard / AA+ stress\n\
           fig7        (k, m) grid search\n\
           fig14       trajectory-init CS curves\n\
           table1      the headline table\n\
           all-figures regenerate everything into results/\n\n\
         common options: --model dit|gmm  --samples N  --seed N  --steps N"
    );
}

fn run_experiment(name: &str, args: &Args) {
    eprintln!("=== {name} ===");
    let t0 = std::time::Instant::now();
    for (csv_name, table) in figures::run(name, args) {
        let path = format!("results/{csv_name}.csv");
        table.write_csv(&path).expect("write csv");
        println!("{}", table.to_ascii());
        println!("wrote {path}");
    }
    eprintln!("=== {name} done in {:?} ===\n", t0.elapsed());
}

fn cmd_sample(args: &Args) {
    use parataa::figures::common::{method_config, ModelChoice, Scenario};
    use parataa::model::Cond;
    use parataa::schedule::SamplerKind;
    use parataa::solver::{self, Method, Problem};

    let model = ModelChoice::parse(&args.get_or("model", "gmm"));
    let steps = args.usize_or("steps", 50);
    let kind = match args.get_or("sampler", "ddim").as_str() {
        "ddim" => SamplerKind::Ddim,
        "ddpm" => SamplerKind::Ddpm,
        other => panic!("unknown sampler '{other}'"),
    };
    let method = match args.get_or("method", "taa").as_str() {
        "taa" => Method::Taa,
        "fp" => Method::FixedPoint,
        "aa" => Method::AndersonStd,
        "aa+" => Method::AndersonUpperTri,
        other => panic!("unknown method '{other}'"),
    };
    let seed = args.u64_or("seed", 0);
    let class = args.usize_or("class", 0);
    let scenario = Scenario::new(model, kind, steps);
    let coeffs = scenario.coeffs();
    let problem = Problem::new(&coeffs, &*scenario.model, Cond::Class(class), seed);
    let cfg = method_config(method, steps, args.get("k").map(|v| v.parse().unwrap()), scenario.guidance);
    let t0 = std::time::Instant::now();
    let result = solver::solve(&problem, &cfg);
    let dt = t0.elapsed();
    let seq = solver::sample_sequential(&problem, scenario.guidance);
    let rmse = parataa::metrics::match_rmse(result.xs.row(0), seq.xs.row(0));
    println!(
        "{} {} {}: {} parallel rounds (seq {} steps), nfe {}, converged {}, {dt:?}",
        scenario.label(),
        method.label(),
        seed,
        result.iterations,
        steps,
        result.total_nfe,
        result.converged,
    );
    println!("parallel-vs-sequential RMSE: {rmse:.2e} (Remark 5.3)");
    let out = args.get_or("out", "results/sample.pgm");
    parataa::util::image::write_pgm(&out, result.xs.row(0), 16, 16).expect("write image");
    println!("wrote {out}");
}

/// Build the execution pool for `serve` (plus the scenario's CFG scale):
/// N in-process backends over the analytic model, or N PJRT device actors
/// for `--model dit` (pjrt builds only). Deliberately does NOT go through
/// `figures::common::Scenario`, which would spawn and warm a shared device
/// actor that serve never uses — everything runs through this pool.
fn build_pool(
    model_choice: parataa::figures::common::ModelChoice,
    devices: usize,
) -> (parataa::runtime::DevicePool, f32) {
    use parataa::figures::common::ModelChoice;
    use parataa::model::gmm::GmmEps;
    use parataa::runtime::{DevicePool, PoolConfig};
    use parataa::schedule::{BetaSchedule, NoiseSchedule};
    use std::sync::Arc;

    match model_choice {
        ModelChoice::Gmm => {
            let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
            let model = Arc::new(GmmEps::sd_analog(ns.alpha_bars.clone()));
            let pool = DevicePool::in_process(model, devices, PoolConfig::default())
                .expect("spawn device pool");
            (pool, 2.0)
        }
        ModelChoice::Dit => {
            #[cfg(feature = "pjrt")]
            {
                use parataa::runtime::{EpsBackend, PjrtBackend};
                let mut backends: Vec<Box<dyn EpsBackend>> = Vec::with_capacity(devices);
                for _ in 0..devices {
                    let b =
                        PjrtBackend::spawn(parataa::runtime::default_artifacts_dir(), 256)
                            .expect("artifacts missing — run `make artifacts`");
                    backends.push(Box::new(b));
                }
                let cfg = PoolConfig {
                    warm: parataa::runtime::EPS_BATCH_SIZES.to_vec(),
                    ..Default::default()
                };
                (DevicePool::spawn(backends, cfg).expect("spawn device pool"), 5.0)
            }
            #[cfg(not(feature = "pjrt"))]
            {
                panic!("serve --model dit needs a `--features pjrt` build (see rust/Cargo.toml)")
            }
        }
    }
}

fn cmd_serve(args: &Args) {
    use parataa::coordinator::{
        Batcher, BatcherConfig, Coordinator, CoordinatorConfig, SampleRequest, SamplerSpec,
    };
    use parataa::figures::common::ModelChoice;
    use parataa::model::Cond;
    use parataa::util::rng::Pcg64;
    use std::sync::Arc;

    let model_choice = ModelChoice::parse(&args.get_or("model", "gmm"));
    let steps = args.usize_or("steps", 50);
    let n_requests = args.usize_or("requests", 32);
    let workers = args.usize_or("workers", 4);
    let devices = args.usize_or("devices", 1).max(1);

    // Stack: backend pool -> dynamic batcher -> coordinator worker pool.
    let (pool, guidance) = build_pool(model_choice, devices);
    let pool_stats = pool.stats();
    let dim = pool.dim();
    let pooled = Arc::new(pool.eps_handle("pooled"));
    let batcher = Batcher::spawn(pooled, BatcherConfig::for_devices(devices));
    let eps = Arc::new(batcher.eps_handle(dim, "batched"));
    let coord = Coordinator::start(
        eps,
        CoordinatorConfig { workers, devices, ..Default::default() },
    );
    coord.attach_pool(pool_stats);

    eprintln!(
        "serving {n_requests} requests ({} DDIM-{steps}) on {devices} device(s) ...",
        model_choice.label()
    );
    let mut rng = Pcg64::seeded(args.u64_or("seed", 0));
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            let mut req = SampleRequest::parataa(
                Cond::Class(rng.below(8) as usize),
                i as u64,
                SamplerSpec::ddim(steps),
            );
            req.guidance = guidance;
            req.use_trajectory_cache = true;
            coord.submit(req)
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait().expect("request failed");
        if i < 4 || !r.converged {
            println!(
                "req {i}: rounds={} nfe={} warm={} conv={} latency={:?}",
                r.rounds, r.nfe, r.warm_started, r.converged, r.latency
            );
        }
    }
    // The report includes the per-device breakdown (attached pool stats).
    println!("{}", coord.metrics().report());
    drop(coord);
}
