//! The triangular nonlinear system (Definition 2.1) and its residuals.
//!
//! Unknowns are x_0..x_{T-1} with x_T = ξ_T fixed. The k-th order equation
//! producing row p = t−1 (for t = 1..T) is
//!
//!   x_p = F_p^{(k)} = ā_{t,t_k}·x_{t_k}
//!       + Σ_{j=t}^{t_k} ā_{t,j-1}·b_j·ε_θ(x_j, j)
//!       + Σ_{j=t}^{t_k} ā_{t,j-1}·c_{j-1}·ξ_{j-1},      t_k = min(t+k−1, B)
//!
//! (eq. 9). `B` is the *boundary*: the first frozen state. For the full
//! system B = T (Definition 2.1 verbatim). When the sliding window (§2.2)
//! freezes states ≥ B at tolerance-level accuracy, the window's equations
//! must clamp t_k to B — reaching past the boundary would couple the window
//! to several mutually-inconsistent frozen states, leaving a permanent
//! first-order residual floor that stalls the convergence front. Clamped to
//! the single boundary state, the sub-system's unique solution is exactly
//! the sequential rollout from x_B, so residuals can always reach zero.
//! (This also matches Remark 2.4: the PL iteration of Shih et al. integrates
//! from the window's base state only.)
//!
//! All orders k are equivalent and share the unique solution of the
//! sequential procedure (Theorem 2.2) — property-tested in this module.
//!
//! Two evaluation paths exist:
//! - the direct loop form (this module) used by the native solver, and
//! - dense banded matrices (`build_s_matrix`/`build_b_matrix`) with
//!   identical semantics, which feed the AOT HLO artifact
//!   (`python/compile/kernels/banded_combine.py`) so that the *order k is
//!   runtime data, not a compiled shape*.

use crate::schedule::SamplerCoeffs;

/// Flat storage for the T+1 solver states x_0..x_T, each of dimension `d`.
#[derive(Debug, Clone)]
pub struct States {
    pub d: usize,
    /// Row-major `[(T+1) * d]`; row index = solver state index.
    pub data: Vec<f32>,
}

impl States {
    pub fn zeros(t_count: usize, d: usize) -> Self {
        States { d, data: vec![0.0; (t_count + 1) * d] }
    }

    pub fn rows(&self) -> usize {
        self.data.len() / self.d
    }

    #[inline]
    pub fn row(&self, t: usize) -> &[f32] {
        &self.data[t * self.d..(t + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, t: usize) -> &mut [f32] {
        &mut self.data[t * self.d..(t + 1) * self.d]
    }

    pub fn set_row(&mut self, t: usize, v: &[f32]) {
        self.row_mut(t).copy_from_slice(v);
    }
}

/// Effective upper index t_k = min(t + k − 1, boundary).
#[inline]
pub fn t_k(t: usize, k: usize, boundary: usize) -> usize {
    (t + k - 1).min(boundary)
}

/// Evaluate F_p^{(k)} for producing row `p` with frozen boundary `boundary`,
/// writing into `out`.
///
/// `eps` must hold ε_θ(x_j, ·) at state-row j for every j ∈ [p+1, t_k]
/// (the caller guarantees freshness: active rows recomputed this iteration,
/// the boundary row served from the cache).
#[allow(clippy::too_many_arguments)]
pub fn eval_fk(
    coeffs: &SamplerCoeffs,
    xs: &States,
    eps: &States,
    xi: &States,
    k: usize,
    boundary: usize,
    p: usize,
    out: &mut [f32],
) {
    let t = p + 1;
    let tk = t_k(t, k, boundary);
    let d = xs.d;
    debug_assert!(boundary <= coeffs.steps);
    debug_assert!(t <= boundary, "row {p} at/above the boundary {boundary}");
    debug_assert_eq!(out.len(), d);

    // ā_{t,t_k}·x_{t_k}
    let lead = coeffs.abar(t, tk) as f32;
    let x_tk = xs.row(tk);
    for (o, &v) in out.iter_mut().zip(x_tk.iter()) {
        *o = lead * v;
    }
    // Σ ā_{t,j-1}·b_j·ε_j  +  Σ ā_{t,j-1}·c_{j-1}·ξ_{j-1}
    for j in t..=tk {
        let ab = coeffs.abar(t, j - 1);
        let ce = (ab * coeffs.b[j]) as f32;
        let e = eps.row(j);
        let cx = (ab * coeffs.c[j - 1]) as f32;
        if cx != 0.0 {
            let xr = xi.row(j - 1);
            for i in 0..d {
                out[i] += ce * e[i] + cx * xr[i];
            }
        } else {
            for (o, &v) in out.iter_mut().zip(e.iter()) {
                *o += ce * v;
            }
        }
    }
}

/// DDIM(η) bridge coefficients `(a, b, σ)` for one step from a noisier
/// state at signal level `abar_hi` down to a cleaner state at `abar_lo` —
/// the `SamplerCoeffs::new` formulas applied to an *arbitrary* pair of
/// schedule points instead of adjacent grid entries:
///
///   a = √(ᾱ_lo/ᾱ_hi),
///   σ = η·√((1−ᾱ_lo)/(1−ᾱ_hi))·√(1−ᾱ_hi/ᾱ_lo)   (0 when ᾱ_lo = 1),
///   b = √(max(0, 1−ᾱ_lo−σ²)) − a·√(1−ᾱ_hi).
///
/// This is the coarse-operator primitive of the multi-fidelity strategies
/// (`solver/strategy.rs`): `SamplerCoeffs::coarsen` bridges subsetted
/// nodes of an existing fine grid, and the Parareal sweep bridges window
/// rows directly. The `ᾱ_lo = 1` target (the clean sample) is exactly
/// deterministic, matching the fine grid's final-step convention.
pub fn bridge_coeffs(abar_hi: f64, abar_lo: f64, eta: f64) -> (f64, f64, f64) {
    debug_assert!(
        abar_hi > 0.0 && abar_lo >= abar_hi && abar_lo <= 1.0,
        "bridge requires 0 < ᾱ_hi ≤ ᾱ_lo ≤ 1 (got hi={abar_hi}, lo={abar_lo})"
    );
    let a = (abar_lo / abar_hi).sqrt();
    let sigma = if abar_lo < 1.0 {
        eta * ((1.0 - abar_lo) / (1.0 - abar_hi)).sqrt() * (1.0 - abar_hi / abar_lo).sqrt()
    } else {
        0.0
    };
    let b = (1.0 - abar_lo - sigma * sigma).max(0.0).sqrt() - a * (1.0 - abar_hi).sqrt();
    (a, b, sigma)
}

/// First-order residual r_p = ‖x_p − a_{p+1}x_{p+1} − b_{p+1}ε_{p+1} −
/// c_p ξ_p‖² (eq. 11) — the universal stopping criterion for every order k.
/// Routed through the fused [`crate::linalg::residual_norm_sq`] kernel:
/// one SIMD pass over the four streams, residual in f32, squares
/// accumulated in f64 under the shared reduction-order contract.
pub fn residual_sq(
    coeffs: &SamplerCoeffs,
    xs: &States,
    eps: &States,
    xi: &States,
    p: usize,
) -> f64 {
    let t = p + 1;
    let a = coeffs.a[t] as f32;
    let b = coeffs.b[t] as f32;
    let c = coeffs.c[p] as f32;
    crate::linalg::residual_norm_sq(xs.row(p), xs.row(t), eps.row(t), xi.row(p), a, b, c)
}

/// Combined noise vectors ξ̄_p = Σ_j ā_{t,j-1}·c_{j-1}·ξ_{j-1} for rows
/// `p0..p0+w` — one of the three inputs of the AOT `solver_step` artifact.
pub fn build_xi_comb(
    coeffs: &SamplerCoeffs,
    xi: &States,
    k: usize,
    boundary: usize,
    p0: usize,
    w: usize,
) -> Vec<f32> {
    let d = xi.d;
    let mut data = vec![0.0f32; w * d];
    for r in 0..w {
        let p = p0 + r;
        let t = p + 1;
        let tk = t_k(t, k, boundary);
        let row = &mut data[r * d..(r + 1) * d];
        for j in t..=tk {
            let coeff = (coeffs.abar(t, j - 1) * coeffs.c[j - 1]) as f32;
            if coeff != 0.0 {
                let xi_row = xi.row(j - 1);
                for (o, &v) in row.iter_mut().zip(xi_row.iter()) {
                    *o += coeff * v;
                }
            }
        }
    }
    data
}

/// Dense selector matrix S ∈ R^{W × (T+1)}: row p has ā_{t,t_k} at column
/// t_k. Multiplying the full state stack reproduces the x_{t_k} term.
/// Used to feed the HLO `banded_combine` artifact (order k as data).
pub fn build_s_matrix(
    coeffs: &SamplerCoeffs,
    k: usize,
    boundary: usize,
    p0: usize,
    w: usize,
) -> Vec<f32> {
    let t_count = coeffs.steps;
    let cols = t_count + 1;
    let mut s = vec![0.0f32; w * cols];
    for r in 0..w {
        let p = p0 + r;
        let t = p + 1;
        let tk = t_k(t, k, boundary);
        s[r * cols + tk] = coeffs.abar(t, tk) as f32;
    }
    s
}

/// Dense banded matrix B ∈ R^{W × (T+1)}: row p has ā_{t,j-1}·b_j at column
/// j for j ∈ [t, t_k]. Multiplying the eps stack reproduces the ε sum.
pub fn build_b_matrix(
    coeffs: &SamplerCoeffs,
    k: usize,
    boundary: usize,
    p0: usize,
    w: usize,
) -> Vec<f32> {
    let t_count = coeffs.steps;
    let cols = t_count + 1;
    let mut bm = vec![0.0f32; w * cols];
    for r in 0..w {
        let p = p0 + r;
        let t = p + 1;
        let tk = t_k(t, k, boundary);
        for j in t..=tk {
            bm[r * cols + j] = (coeffs.abar(t, j - 1) * coeffs.b[j]) as f32;
        }
    }
    bm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerKind};
    use crate::util::proplite::{self, forall, size_in};
    use crate::util::rng::Pcg64;

    fn setup(steps: usize, kind: SamplerKind) -> SamplerCoeffs {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        SamplerCoeffs::new(&ns, kind, steps)
    }

    fn random_states(rng: &mut Pcg64, rows: usize, d: usize) -> States {
        let mut s = States::zeros(rows - 1, d);
        rng.fill_gaussian(&mut s.data);
        s
    }

    /// Sequential rollout with a fixed eps table (treated as the true ε_θ).
    fn rollout(coeffs: &SamplerCoeffs, eps: &States, xi: &States, d: usize) -> States {
        let steps = coeffs.steps;
        let mut xs = States::zeros(steps, d);
        xs.set_row(steps, xi.row(steps));
        for t in (1..=steps).rev() {
            let row: Vec<f32> = (0..d)
                .map(|i| {
                    coeffs.a[t] as f32 * xs.row(t)[i]
                        + coeffs.b[t] as f32 * eps.row(t)[i]
                        + coeffs.c[t - 1] as f32 * xi.row(t - 1)[i]
                })
                .collect();
            xs.set_row(t - 1, &row);
        }
        xs
    }

    #[test]
    fn first_order_fk_is_sequential_step() {
        // k=1: F_p^{(1)} must equal a_{p+1}x_{p+1} + b_{p+1}ε_{p+1} + c_pξ_p.
        forall("fk1_sequential", 16, |rng, _| {
            let steps = size_in(rng, 2, 12);
            let d = size_in(rng, 1, 6);
            let coeffs = setup(steps, SamplerKind::Ddpm);
            let xs = random_states(rng, steps + 1, d);
            let eps = random_states(rng, steps + 1, d);
            let xi = random_states(rng, steps + 1, d);
            for p in 0..steps {
                let mut out = vec![0.0f32; d];
                eval_fk(&coeffs, &xs, &eps, &xi, 1, steps, p, &mut out);
                let t = p + 1;
                let expect: Vec<f32> = (0..d)
                    .map(|i| {
                        coeffs.a[t] as f32 * xs.row(t)[i]
                            + coeffs.b[t] as f32 * eps.row(t)[i]
                            + coeffs.c[p] as f32 * xi.row(p)[i]
                    })
                    .collect();
                proplite::assert_close(&out, &expect, 1e-5, 1e-4, "F^(1)")?;
            }
            Ok(())
        });
    }

    #[test]
    fn orders_agree_on_exact_solution() {
        // Theorem 2.2: on the exact sequential solution every F_p^{(k)} must
        // reproduce x_p for every order k.
        forall("orders_equivalent", 16, |rng, _| {
            let steps = size_in(rng, 3, 10);
            let d = size_in(rng, 1, 5);
            let coeffs = setup(steps, SamplerKind::Ddpm);
            let eps = random_states(rng, steps + 1, d);
            let xi = random_states(rng, steps + 1, d);
            let xs = rollout(&coeffs, &eps, &xi, d);
            for k in 1..=steps {
                for p in 0..steps {
                    let mut out = vec![0.0f32; d];
                    eval_fk(&coeffs, &xs, &eps, &xi, k, steps, p, &mut out);
                    proplite::assert_close(
                        out.as_slice(),
                        xs.row(p),
                        2e-4,
                        2e-3,
                        &format!("k={k} p={p}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn clamped_boundary_subsystem_solves_exactly() {
        // With an arbitrary (inconsistent) frozen boundary state x_B, the
        // clamped order-k sub-system below B must be solved exactly by the
        // sequential rollout from x_B — the property that keeps the sliding
        // window from stalling.
        forall("boundary_clamp", 12, |rng, _| {
            let steps = size_in(rng, 4, 10);
            let d = size_in(rng, 1, 4);
            let b = size_in(rng, 2, steps); // boundary state index
            let k = size_in(rng, 1, steps);
            let coeffs = setup(steps, SamplerKind::Ddpm);
            let eps = random_states(rng, steps + 1, d);
            let xi = random_states(rng, steps + 1, d);
            // xs: arbitrary garbage above b is fine — clamp must not read it.
            let mut xs = random_states(rng, steps + 1, d);
            // Sequential rollout below the boundary only.
            for t in (1..=b).rev() {
                let row: Vec<f32> = (0..d)
                    .map(|i| {
                        coeffs.a[t] as f32 * xs.row(t)[i]
                            + coeffs.b[t] as f32 * eps.row(t)[i]
                            + coeffs.c[t - 1] as f32 * xi.row(t - 1)[i]
                    })
                    .collect();
                xs.set_row(t - 1, &row);
            }
            for p in 0..b {
                let mut out = vec![0.0f32; d];
                eval_fk(&coeffs, &xs, &eps, &xi, k, b, p, &mut out);
                proplite::assert_close(
                    out.as_slice(),
                    xs.row(p),
                    2e-4,
                    2e-3,
                    &format!("boundary={b} k={k} p={p}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn residual_zero_on_solution() {
        let mut rng = Pcg64::seeded(3);
        let steps = 8;
        let d = 4;
        let coeffs = setup(steps, SamplerKind::Ddim);
        let eps = random_states(&mut rng, steps + 1, d);
        let xi = random_states(&mut rng, steps + 1, d);
        let mut xs = rollout(&coeffs, &eps, &xi, d);
        for p in 0..steps {
            assert!(residual_sq(&coeffs, &xs, &eps, &xi, p) < 1e-10);
        }
        // Perturb one row -> its residual becomes positive.
        xs.row_mut(2)[0] += 0.5;
        assert!(residual_sq(&coeffs, &xs, &eps, &xi, 2) > 0.01);
    }

    #[test]
    fn matrix_path_matches_direct() {
        // S·x_stack + B·eps_stack + ξ̄ == eval_fk for every row, order, and
        // boundary — the contract the HLO artifact path relies on.
        forall("banded_matches_direct", 12, |rng, _| {
            let steps = size_in(rng, 3, 9);
            let d = size_in(rng, 1, 4);
            let k = size_in(rng, 1, steps);
            let b = size_in(rng, 2, steps);
            let coeffs = setup(steps, SamplerKind::Ddpm);
            let xs = random_states(rng, steps + 1, d);
            let eps = random_states(rng, steps + 1, d);
            let xi = random_states(rng, steps + 1, d);
            let w = b; // window covers all rows below the boundary
            let s_mat = build_s_matrix(&coeffs, k, b, 0, w);
            let b_mat = build_b_matrix(&coeffs, k, b, 0, w);
            let xi_comb = build_xi_comb(&coeffs, &xi, k, b, 0, w);
            let cols = steps + 1;
            let mut sx = vec![0.0f32; w * d];
            matmul(&s_mat, &xs.data, &mut sx, w, cols, d);
            let mut be = vec![0.0f32; w * d];
            matmul(&b_mat, &eps.data, &mut be, w, cols, d);
            for p in 0..w {
                let via_mat: Vec<f32> = (0..d)
                    .map(|i| sx[p * d + i] + be[p * d + i] + xi_comb[p * d + i])
                    .collect();
                let mut direct = vec![0.0f32; d];
                eval_fk(&coeffs, &xs, &eps, &xi, k, b, p, &mut direct);
                proplite::assert_close(&via_mat, &direct, 1e-4, 1e-3, &format!("row {p}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn bridge_recovers_the_grid_coefficients() {
        // Bridging adjacent per-state ᾱ values must reproduce the grid's
        // own (a, b, c) — SamplerCoeffs::new and bridge_coeffs are the
        // same formulas on different inputs.
        forall("bridge_vs_grid", 12, |rng, _| {
            let steps = size_in(rng, 2, 24);
            let eta = rng.next_f32() as f64;
            let coeffs = setup(steps, SamplerKind::Eta(eta));
            let abar = coeffs.state_alpha_bars();
            for t in 1..=steps {
                let (a, b, sigma) = bridge_coeffs(abar[t], abar[t - 1], eta);
                if (a - coeffs.a[t]).abs() > 1e-9 {
                    return Err(format!("a[{t}]: {a} vs {}", coeffs.a[t]));
                }
                if (b - coeffs.b[t]).abs() > 1e-9 {
                    return Err(format!("b[{t}]: {b} vs {}", coeffs.b[t]));
                }
                if (sigma - coeffs.c[t - 1]).abs() > 1e-9 {
                    return Err(format!("c[{}]: {sigma} vs {}", t - 1, coeffs.c[t - 1]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bridge_composes_across_skipped_nodes() {
        // A single bridge over [lo, hi] and the two-hop path through any
        // midpoint transport the signal identically: a and the total noise
        // magnitude (b+a·√(1−ᾱ_hi) combined with σ in quadrature) depend
        // only on the endpoints.
        let coeffs = setup(16, SamplerKind::Ddpm);
        let abar = coeffs.state_alpha_bars();
        let (lo, mid, hi) = (2usize, 7usize, 13usize);
        let (a_direct, b_direct, s_direct) = bridge_coeffs(abar[hi], abar[lo], 1.0);
        let (a1, _, _) = bridge_coeffs(abar[hi], abar[mid], 1.0);
        let (a2, _, _) = bridge_coeffs(abar[mid], abar[lo], 1.0);
        assert!((a_direct - a1 * a2).abs() < 1e-12, "a composes multiplicatively");
        // Endpoint-only variance identity (the same one the grid
        // satisfies): (b + a·√(1−ᾱ_hi))² + σ² = 1 − ᾱ_lo.
        let dir = b_direct + a_direct * (1.0 - abar[hi]).sqrt();
        assert!((dir * dir + s_direct * s_direct - (1.0 - abar[lo])).abs() < 1e-10);
    }

    #[test]
    fn ddim_xicomb_is_zero() {
        let mut rng = Pcg64::seeded(4);
        let coeffs = setup(10, SamplerKind::Ddim);
        let xi = random_states(&mut rng, 11, 3);
        let xic = build_xi_comb(&coeffs, &xi, 4, 10, 0, 10);
        assert!(xic.iter().all(|&v| v == 0.0), "ODE sampler has no noise term");
    }
}
