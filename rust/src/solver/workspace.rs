//! Reusable scratch for the zero-allocation update path.
//!
//! [`apply_update_ws`](super::update::apply_update_ws) needs, per round:
//! the suffix-Gram storage, a ridged m×m copy, per-row and global γ
//! vectors, and the f64 Cholesky factor + substitution scratch. Allocating
//! those per row (as the historical update did) dominated the CPU profile
//! of small-D solves; a [`Workspace`] owns them all, is resized only when
//! the history depth grows, and lives on the [`super::SolverSession`] so
//! steady-state rounds perform **zero** heap allocations inside the update
//! (asserted by `tests/zero_alloc.rs` with a counting global allocator).
//!
//! With `parallelism > 1` the per-row loop fans across a
//! [`RowPool`](crate::util::threadpool::RowPool) in contiguous chunks, and
//! each chunk needs its own ridge/γ/Cholesky scratch ([`RowScratch`]) so
//! concurrent rows never share a mutable buffer. The chunk scratch is
//! sized once at session setup ([`Workspace::ensure_rows`] reuses
//! capacity), keeping steady-state rounds allocation-free at every thread
//! count — and since the scratch only carries *intermediate* values, which
//! buffer a row used never shows in the output: results stay bitwise
//! identical to the sequential path.
//!
//! The workspace holds plain `Vec`s, so it is `Send` and migrates between
//! round-driver threads with its session.

use crate::linalg::gram::SuffixGrams;

/// Per-chunk scratch for the parallel per-row update loop: everything a
/// row's γ solve mutates, duplicated per chunk so chunks never contend.
#[derive(Debug, Default)]
pub struct RowScratch {
    /// Ridged m×m Gram copy (Remark 3.3).
    pub(crate) ridged: Vec<f32>,
    /// Per-row γ_p solution vector (m).
    pub(crate) gamma: Vec<f32>,
    /// f64 Cholesky factor scratch (m×m lower triangle).
    pub(crate) chol: Vec<f64>,
    /// f64 substitution scratch (m).
    pub(crate) y: Vec<f64>,
}

impl RowScratch {
    /// Size every buffer for history depth `m`; allocation-free once
    /// capacity has been reached.
    pub(crate) fn ensure(&mut self, m: usize) {
        self.ridged.clear();
        self.ridged.resize(m * m, 0.0);
        self.gamma.clear();
        self.gamma.resize(m, 0.0);
        self.chol.clear();
        self.chol.resize(m * m, 0.0);
        self.y.clear();
        self.y.resize(m, 0.0);
    }
}

/// Owned scratch buffers for one solver session's update path.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Flat suffix-Gram storage + f64 scan accumulators.
    pub(crate) sg: SuffixGrams,
    /// Ridged m×m Gram copy (Remark 3.3) the Cholesky factors from.
    pub(crate) ridged: Vec<f32>,
    /// Per-row γ_p solution vector (m).
    pub(crate) gamma: Vec<f32>,
    /// Global γ for standard AA (m), solved once per round.
    pub(crate) global_gamma: Vec<f32>,
    /// f64 Cholesky factor scratch (m×m lower triangle).
    pub(crate) chol: Vec<f64>,
    /// f64 substitution scratch (m).
    pub(crate) y: Vec<f64>,
    /// Per-chunk scratch for the parallel row loop (empty until
    /// [`ensure_rows`](Self::ensure_rows) is called with `chunks > 0`).
    pub(crate) row_scratch: Vec<RowScratch>,
}

impl Workspace {
    /// An empty workspace; buffers are sized on first use.
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Size every buffer for history depth `m`. Allocates only when `m`
    /// outgrows the current capacity; shrinking reuses the allocation.
    pub(crate) fn ensure(&mut self, m: usize) {
        self.ridged.clear();
        self.ridged.resize(m * m, 0.0);
        self.gamma.clear();
        self.gamma.resize(m, 0.0);
        self.global_gamma.clear();
        self.global_gamma.resize(m, 0.0);
        self.chol.clear();
        self.chol.resize(m * m, 0.0);
        self.y.clear();
        self.y.resize(m, 0.0);
    }

    /// Size `chunks` per-chunk [`RowScratch`] sets for history depth `m`.
    /// The `Vec` of scratch sets grows only the first time a chunk count
    /// is seen (session setup); per-round calls at steady state just
    /// re-zero within existing capacity — no heap traffic.
    pub(crate) fn ensure_rows(&mut self, chunks: usize, m: usize) {
        if self.row_scratch.len() < chunks {
            self.row_scratch.resize_with(chunks, RowScratch::default);
        }
        for rs in &mut self.row_scratch[..chunks] {
            rs.ensure(m);
        }
    }
}
