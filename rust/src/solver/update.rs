//! Parallel update rules: FP (eq. 10), standard AA (eq. 12–13), AA+
//! (Remark 3.4), and Triangular AA (Theorem 3.2) + safeguard (Theorem 3.6).
//!
//! All Anderson variants share one identity: with history matrices
//! X = [ΔX^{i-m_i}..ΔX^{i-1}], F = [ΔF^{i-m_i}..ΔF^{i-1}] the update
//! x^{i+1} = x − G R with G from eq. (13) expands to
//!
//!   x^{i+1}_p = x_p + R_p − (ΔX_p + ΔF_p)·γ_p
//!
//! where γ_p ∈ R^{m_i} is a per-row coefficient vector. The variants differ
//! *only* in how γ is computed:
//!
//! | method | Gram               | projection        | γ |
//! |--------|--------------------|-------------------|---|
//! | AA     | full-window FᵀF    | full-window FᵀR   | one global γ (eq. 13) |
//! | AA+    | full-window FᵀF    | suffix Fᵀ_{p:}R_{p:} | per-row γ_p = M·b_p (upper-tri extraction of G) |
//! | TAA    | suffix Fᵀ_{p:}F_{p:} | suffix Fᵀ_{p:}R_{p:} | per-row γ_p = (G_p+λI)⁻¹·b_p (Thm 3.2) |
//!
//! which is exactly why TAA restricts information flow to later timesteps:
//! row p's correction involves only rows ≥ p of the history.
//!
//! An empty history makes every variant degenerate to the FP step
//! x^{i+1} = x + R = F(x) — also the safeguarded row's update.
//!
//! # Allocation discipline
//!
//! [`apply_update_ws`] is the production path: suffix Grams come from the
//! [`History`]'s incremental per-row cache, the Gram/γ/Cholesky scratch
//! lives in a caller-owned [`Workspace`], and the correction loop reads the
//! history's fused `ΔX+ΔF` slots — **zero heap allocations per call** at
//! steady state. AA+ additionally factors its shared full-window Gram once
//! per round instead of refactoring the same matrix for every row (AA
//! always solved once per round; its per-row cost was a γ clone, now a
//! shared borrow). [`apply_update`] is the allocating convenience wrapper
//! (tests, one-shot callers).

use super::history::History;
use super::workspace::{RowScratch, Workspace};
use super::Method;
use crate::linalg::gram::SuffixGrams;
use crate::linalg::{cholesky_factor_into, cholesky_solve_factored, cholesky_solve_into};
use crate::util::threadpool::{chunk_range, RowPool, SyncSlice};

/// Apply one parallel update over active rows `[t1, t2]` (inclusive),
/// reusing `ws` for every intermediate — no heap allocation once `ws` has
/// reached capacity.
///
/// * `xs_rows` — mutable view of the unknown states `[T*d]` (rows 0..T−1);
/// * `f_vals` — F_p^{(k)} for active rows (`[T*d]`, other rows ignored);
/// * `r_vals` — residuals R_p = F_p − x_p (`[T*d]`, **zero outside the
///   active window** — the suffix Grams rely on it);
/// * `history` — Anderson difference pairs (may be empty), spanning the
///   same `[T, d]` state range;
/// * `lambda` — Gram ridge (Remark 3.3);
/// * `safeguard` — force the top unconverged row `t2` to a plain FP step
///   (Theorem 3.6; rows above t2 are converged, i.e. R ≈ 0, so t2 is the
///   row the theorem's condition bites on).
#[allow(clippy::too_many_arguments)]
pub fn apply_update_ws(
    method: Method,
    xs_rows: &mut [f32],
    f_vals: &[f32],
    r_vals: &[f32],
    history: &History,
    t1: usize,
    t2: usize,
    t_rows: usize,
    d: usize,
    lambda: f32,
    safeguard: bool,
    ws: &mut Workspace,
) {
    apply_update_par(
        method, xs_rows, f_vals, r_vals, history, t1, t2, t_rows, d, lambda, safeguard, ws, None,
    );
}

/// [`apply_update_ws`] with the per-row loop fanned across `pool`.
///
/// Rows are independent given the shared round inputs (the triangular
/// structure serializes *rounds*, not rows): each row reads the shared
/// suffix Grams / history and writes only its own `x_p` slice, using its
/// chunk's private [`RowScratch`] for the γ solve. Scratch carries only
/// intermediates, so which chunk ran a row never shows in the output —
/// results are **bitwise identical** at every thread count. The
/// round-level work (standard-AA global γ, AA+ shared factor, the suffix
/// scan itself) stays sequential on the calling thread.
#[allow(clippy::too_many_arguments)]
pub fn apply_update_par(
    method: Method,
    xs_rows: &mut [f32],
    f_vals: &[f32],
    r_vals: &[f32],
    history: &History,
    t1: usize,
    t2: usize,
    t_rows: usize,
    d: usize,
    lambda: f32,
    safeguard: bool,
    ws: &mut Workspace,
    pool: Option<&RowPool>,
) {
    debug_assert_eq!(xs_rows.len(), t_rows * d);
    debug_assert!(t1 <= t2 && t2 < t_rows);

    let m = history.len();
    if method == Method::FixedPoint || m == 0 {
        // x ← F(x)
        for p in t1..=t2 {
            xs_rows[p * d..(p + 1) * d].copy_from_slice(&f_vals[p * d..(p + 1) * d]);
        }
        return;
    }
    debug_assert_eq!(history.rows(), t_rows);
    debug_assert_eq!(history.dim(), d);

    let chunks = pool.map_or(1, |p| p.threads()).max(1);
    ws.ensure(m);
    ws.ensure_rows(chunks, m);
    let Workspace { sg, ridged, global_gamma, chol, y, row_scratch, .. } = ws;

    // Suffix Grams over the full row range (cached G, rescanned b); rows
    // above t2 hold zeros, so G_{t1} is also the full-window Gram used by
    // AA/AA+.
    history.suffix_grams_into(r_vals, t1, sg);

    // Round-level work: the global γ (AA) or the shared full-window Gram
    // factor (AA+) — both were historically recomputed per row.
    let mut have_global = false;
    let mut shared_factor = false;
    match method {
        Method::AndersonStd => {
            ridge_into(sg.gram(t1), ridged, m, lambda);
            have_global = cholesky_solve_into(ridged, sg.proj(t1), m, chol, y, global_gamma);
        }
        Method::AndersonUpperTri => {
            ridge_into(sg.gram(t1), ridged, m, lambda);
            shared_factor = cholesky_factor_into(ridged, m, chol);
        }
        _ => {}
    }

    let sg: &SuffixGrams = sg;
    let global_gamma: &[f32] = global_gamma;
    let shared_chol: &[f64] = chol;

    match pool {
        Some(pool) if chunks > 1 => {
            let nrows = t2 - t1 + 1;
            let xs_view = SyncSlice::new(xs_rows);
            let scratch_view = SyncSlice::new(&mut row_scratch[..chunks]);
            pool.run(chunks, &|c| {
                // SAFETY: chunk c exclusively owns scratch set c and the
                // disjoint row range chunk_range hands it.
                let scratch = unsafe { &mut scratch_view.slice_mut(c, 1)[0] };
                let (s, e) = chunk_range(nrows, chunks, c);
                for p in (t1 + s)..(t1 + e) {
                    let row = p * d..(p + 1) * d;
                    let x_row = unsafe { xs_view.slice_mut(p * d, d) };
                    update_row(
                        method,
                        p,
                        safeguard && p == t2,
                        &f_vals[row.clone()],
                        &r_vals[row],
                        x_row,
                        history,
                        sg,
                        m,
                        lambda,
                        have_global,
                        global_gamma,
                        shared_factor,
                        shared_chol,
                        scratch,
                    );
                }
            });
        }
        _ => {
            let scratch = &mut row_scratch[0];
            for p in t1..=t2 {
                let row = p * d..(p + 1) * d;
                update_row(
                    method,
                    p,
                    safeguard && p == t2,
                    &f_vals[row.clone()],
                    &r_vals[row.clone()],
                    &mut xs_rows[row],
                    history,
                    sg,
                    m,
                    lambda,
                    have_global,
                    global_gamma,
                    shared_factor,
                    shared_chol,
                    scratch,
                );
            }
        }
    }
}

/// One row's update: compute γ_p per the method, then apply the fused
/// correction (or the FP copy when γ is unavailable or safeguarded).
/// Mutates only `x_row` and `scratch` — the parallel loop's independence
/// argument rests on exactly that.
#[allow(clippy::too_many_arguments)]
fn update_row(
    method: Method,
    p: usize,
    fp_only: bool,
    f_row: &[f32],
    r_row: &[f32],
    x_row: &mut [f32],
    history: &History,
    sg: &SuffixGrams,
    m: usize,
    lambda: f32,
    have_global: bool,
    global_gamma: &[f32],
    shared_factor: bool,
    shared_chol: &[f64],
    scratch: &mut RowScratch,
) {
    // Safeguarded row: plain FP (γ = 0). Theorem 3.6's condition is
    // imposed on the top unconverged row, whose suffix residuals
    // R_{p+1:} are all (numerically) zero.
    let g: Option<&[f32]> = if fp_only {
        None
    } else {
        match method {
            Method::FixedPoint => None, // handled by the caller's early path
            Method::AndersonStd => have_global.then_some(global_gamma),
            Method::AndersonUpperTri => {
                // M = (full-window Gram + λI)⁻¹ applied to the *suffix*
                // projection b_p — the upper-triangular part of eq. (13).
                if shared_factor {
                    cholesky_solve_factored(
                        shared_chol,
                        sg.proj(p),
                        m,
                        &mut scratch.y,
                        &mut scratch.gamma,
                    );
                    Some(scratch.gamma.as_slice())
                } else {
                    None
                }
            }
            Method::Taa => {
                ridge_into(sg.gram(p), &mut scratch.ridged, m, lambda);
                if cholesky_solve_into(
                    &scratch.ridged,
                    sg.proj(p),
                    m,
                    &mut scratch.chol,
                    &mut scratch.y,
                    &mut scratch.gamma,
                ) {
                    Some(scratch.gamma.as_slice())
                } else {
                    None
                }
            }
        }
    };

    match g {
        None => {
            x_row.copy_from_slice(f_row);
        }
        Some(g) => {
            // x_p ← x_p + R_p − Σ_h γ_h·fused_h[p]
            history.correct_row(p, g, r_row, x_row);
        }
    }
}

/// Allocating convenience wrapper over [`apply_update_ws`] — numerically
/// identical (same kernels, same accumulation order), it just pays for a
/// fresh [`Workspace`] per call.
#[allow(clippy::too_many_arguments)]
pub fn apply_update(
    method: Method,
    xs_rows: &mut [f32],
    f_vals: &[f32],
    r_vals: &[f32],
    history: &History,
    t1: usize,
    t2: usize,
    t_rows: usize,
    d: usize,
    lambda: f32,
    safeguard: bool,
) {
    let mut ws = Workspace::new();
    apply_update_ws(
        method, xs_rows, f_vals, r_vals, history, t1, t2, t_rows, d, lambda, safeguard, &mut ws,
    );
}

/// Copy `g` into `out` and add the scale-aware ridge λ·(1 + tr(G)/m) to the
/// diagonal — keeps conditioning stable across the wildly varying residual
/// magnitudes of early vs late iterations.
fn ridge_into(g: &[f32], out: &mut [f32], m: usize, lambda: f32) {
    out.copy_from_slice(g);
    let tr: f32 = (0..m).map(|i| g[i * m + i]).sum();
    let scale = lambda * (1.0 + tr / m as f32);
    for i in 0..m {
        out[i * m + i] += scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::{self, forall, size_in};

    fn mk_history(rows: usize, d: usize, slots: &[(Vec<f32>, Vec<f32>)]) -> History {
        let mut h = History::new(slots.len().max(1), rows, d);
        for (dx, df) in slots {
            h.push(dx, df);
        }
        h
    }

    #[test]
    fn fp_copies_f() {
        let (t_rows, d) = (4, 2);
        let mut xs = vec![0.0f32; t_rows * d];
        let f: Vec<f32> = (0..t_rows * d).map(|i| i as f32).collect();
        let r = vec![0.0f32; t_rows * d];
        let h = History::new(0, t_rows, d);
        apply_update(Method::FixedPoint, &mut xs, &f, &r, &h, 1, 2, t_rows, d, 0.0, false);
        // rows 1..=2 updated, rows 0 and 3 untouched
        assert_eq!(&xs[2..6], &f[2..6]);
        assert_eq!(&xs[0..2], &[0.0, 0.0]);
        assert_eq!(&xs[6..8], &[0.0, 0.0]);
    }

    #[test]
    fn empty_history_degenerates_to_fp() {
        let (t_rows, d) = (3, 2);
        let mut xs_a = vec![1.0f32; t_rows * d];
        let mut xs_b = vec![1.0f32; t_rows * d];
        let f: Vec<f32> = (0..t_rows * d).map(|i| (i as f32).sin()).collect();
        let r: Vec<f32> = f.iter().zip(xs_a.iter()).map(|(a, b)| a - b).collect();
        let h = History::new(3, t_rows, d); // empty
        apply_update(Method::Taa, &mut xs_a, &f, &r, &h, 0, 2, t_rows, d, 1e-4, true);
        apply_update(Method::FixedPoint, &mut xs_b, &f, &r, &h, 0, 2, t_rows, d, 0.0, false);
        assert_eq!(xs_a, xs_b);
    }

    #[test]
    fn ws_reuse_matches_fresh_workspace_bitwise() {
        // One workspace driven across methods and shapes must be
        // indistinguishable from a fresh allocation per call.
        let mut rng = crate::util::rng::Pcg64::seeded(19);
        let mut ws = Workspace::new();
        for (t_rows, d, n_slots) in [(6usize, 3usize, 2usize), (4, 5, 1), (8, 2, 3)] {
            let slots: Vec<(Vec<f32>, Vec<f32>)> = (0..n_slots)
                .map(|_| {
                    (
                        (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect(),
                        (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect(),
                    )
                })
                .collect();
            let h = mk_history(t_rows, d, &slots);
            let xs0: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
            let f: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
            let r: Vec<f32> = f.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
            for method in [Method::AndersonStd, Method::AndersonUpperTri, Method::Taa] {
                let mut reused = xs0.clone();
                apply_update_ws(
                    method, &mut reused, &f, &r, &h, 0, t_rows - 1, t_rows, d, 1e-4, true,
                    &mut ws,
                );
                let mut fresh = xs0.clone();
                apply_update(
                    method, &mut fresh, &f, &r, &h, 0, t_rows - 1, t_rows, d, 1e-4, true,
                );
                assert_eq!(reused, fresh, "{} t_rows={t_rows}", method.label());
            }
        }
    }

    #[test]
    fn parallel_update_is_bitwise_identical_to_sequential() {
        // The fanned per-row loop must not differ from the sequential path
        // by a single bit, for every method and several thread counts.
        let mut rng = crate::util::rng::Pcg64::seeded(29);
        let (t_rows, d, n_slots) = (23usize, 17usize, 3usize);
        let slots: Vec<(Vec<f32>, Vec<f32>)> = (0..n_slots)
            .map(|_| {
                (
                    (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect(),
                    (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect(),
                )
            })
            .collect();
        let h = mk_history(t_rows, d, &slots);
        let xs0: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let f: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let r: Vec<f32> = f.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
        for method in [Method::AndersonStd, Method::AndersonUpperTri, Method::Taa] {
            let mut seq = xs0.clone();
            let mut ws_seq = Workspace::new();
            apply_update_ws(
                method, &mut seq, &f, &r, &h, 0, t_rows - 1, t_rows, d, 1e-4, true, &mut ws_seq,
            );
            for threads in [2usize, 4, 8] {
                let pool = RowPool::new(threads);
                let mut par = xs0.clone();
                let mut ws_par = Workspace::new();
                apply_update_par(
                    method,
                    &mut par,
                    &f,
                    &r,
                    &h,
                    0,
                    t_rows - 1,
                    t_rows,
                    d,
                    1e-4,
                    true,
                    &mut ws_par,
                    Some(&pool),
                );
                assert_eq!(seq, par, "{} drift at {threads} threads", method.label());
            }
        }
    }

    #[test]
    fn safeguard_forces_fp_on_top_row() {
        let (t_rows, d) = (3, 2);
        let mut rng = crate::util::rng::Pcg64::seeded(8);
        let f: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let xs0: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let r: Vec<f32> = f.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
        let slots = vec![(
            (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect::<Vec<f32>>(),
            (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect::<Vec<f32>>(),
        )];
        let h = mk_history(t_rows, d, &slots);
        let mut with_sg = xs0.clone();
        apply_update(Method::Taa, &mut with_sg, &f, &r, &h, 0, 2, t_rows, d, 1e-4, true);
        // Top row (2) must equal the FP step = F row 2.
        assert_eq!(&with_sg[4..6], &f[4..6]);
        // Lower rows get Anderson corrections (differ from plain FP).
        let mut no_sg = xs0.clone();
        apply_update(Method::Taa, &mut no_sg, &f, &r, &h, 0, 2, t_rows, d, 1e-4, false);
        assert_ne!(&no_sg[4..6], &with_sg[4..6]);
        assert_eq!(&no_sg[0..4], &with_sg[0..4], "safeguard only touches the top row");
    }

    #[test]
    fn taa_row_update_depends_only_on_suffix() {
        // Corrupting history below row p must not change row p's TAA update
        // (the triangularity property motivating the method).
        forall("taa_suffix_locality", 16, |rng, _| {
            let t_rows = size_in(rng, 3, 8);
            let d = size_in(rng, 1, 4);
            let p_check = t_rows - 1; // top row, no safeguard
            let xs0: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
            let f: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
            let r: Vec<f32> = f.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
            let dx: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect();
            let df: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect();
            let h1 = mk_history(t_rows, d, &[(dx.clone(), df.clone())]);
            // Corrupt all rows BELOW p_check in the history.
            let mut dx2 = dx.clone();
            let mut df2 = df.clone();
            for v in &mut dx2[..p_check * d] {
                *v += 10.0 * rng.next_f32();
            }
            for v in &mut df2[..p_check * d] {
                *v += 10.0 * rng.next_f32();
            }
            let h2 = mk_history(t_rows, d, &[(dx2, df2)]);
            let mut out1 = xs0.clone();
            let mut out2 = xs0.clone();
            apply_update(Method::Taa, &mut out1, &f, &r, &h1, 0, t_rows - 1, t_rows, d, 1e-4, false);
            apply_update(Method::Taa, &mut out2, &f, &r, &h2, 0, t_rows - 1, t_rows, d, 1e-4, false);
            proplite::assert_close(
                &out1[p_check * d..],
                &out2[p_check * d..],
                1e-5,
                1e-4,
                "top row invariant to prefix corruption",
            )
        });
    }

    #[test]
    fn std_aa_is_dense_prefix_corruption_changes_top_row() {
        // Contrast with the TAA test: standard AA lets earlier rows leak
        // into later rows (the instability the paper identifies in §3.1).
        let mut rng = crate::util::rng::Pcg64::seeded(21);
        let (t_rows, d) = (4, 2);
        let xs0: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let f: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let r: Vec<f32> = f.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
        let dx: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect();
        let df: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut df2 = df.clone();
        for v in &mut df2[..d] {
            *v += 5.0;
        }
        let h1 = mk_history(t_rows, d, &[(dx.clone(), df)]);
        let h2 = mk_history(t_rows, d, &[(dx, df2)]);
        let mut o1 = xs0.clone();
        let mut o2 = xs0.clone();
        apply_update(Method::AndersonStd, &mut o1, &f, &r, &h1, 0, 3, t_rows, d, 1e-4, false);
        apply_update(Method::AndersonStd, &mut o2, &f, &r, &h2, 0, 3, t_rows, d, 1e-4, false);
        let top_diff: f32 = o1[6..8]
            .iter()
            .zip(o2[6..8].iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(top_diff > 1e-6, "AA top row should see prefix corruption");
    }

    #[test]
    fn anderson_exact_on_linear_problem() {
        // For an affine map F(x) = Wx + v (W scalar diag here), AA with one
        // history column solves a 1-parameter secant problem. With a single
        // unknown row and exact arithmetic, the update must land on the
        // fixed point of the scalar recursion x ← 0.5x + 1 (x* = 2).
        let (t_rows, d) = (1, 1);
        let fmap = |x: f32| 0.5 * x + 1.0;
        let x0 = 0.0f32;
        let x1 = fmap(x0); // FP step: 1.0
        // history: Δx = x1-x0 = 1, ΔR: R(x)=F(x)-x = 1-0.5x; R0=1, R1=0.5, ΔR=-0.5
        let h = mk_history(t_rows, d, &[(vec![x1 - x0], vec![-0.5])]);
        let f1 = vec![fmap(x1)]; // 1.5
        let r1 = vec![fmap(x1) - x1]; // 0.5
        let mut xs = vec![x1];
        apply_update(Method::Taa, &mut xs, &f1, &r1, &h, 0, 0, t_rows, d, 0.0, false);
        assert!((xs[0] - 2.0).abs() < 1e-5, "AA should hit x*=2, got {}", xs[0]);
    }
}
