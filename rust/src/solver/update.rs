//! Parallel update rules: FP (eq. 10), standard AA (eq. 12–13), AA+
//! (Remark 3.4), and Triangular AA (Theorem 3.2) + safeguard (Theorem 3.6).
//!
//! All Anderson variants share one identity: with history matrices
//! X = [ΔX^{i-m_i}..ΔX^{i-1}], F = [ΔF^{i-m_i}..ΔF^{i-1}] the update
//! x^{i+1} = x − G R with G from eq. (13) expands to
//!
//!   x^{i+1}_p = x_p + R_p − (ΔX_p + ΔF_p)·γ_p
//!
//! where γ_p ∈ R^{m_i} is a per-row coefficient vector. The variants differ
//! *only* in how γ is computed:
//!
//! | method | Gram               | projection        | γ |
//! |--------|--------------------|-------------------|---|
//! | AA     | full-window FᵀF    | full-window FᵀR   | one global γ (eq. 13) |
//! | AA+    | full-window FᵀF    | suffix Fᵀ_{p:}R_{p:} | per-row γ_p = M·b_p (upper-tri extraction of G) |
//! | TAA    | suffix Fᵀ_{p:}F_{p:} | suffix Fᵀ_{p:}R_{p:} | per-row γ_p = (G_p+λI)⁻¹·b_p (Thm 3.2) |
//!
//! which is exactly why TAA restricts information flow to later timesteps:
//! row p's correction involves only rows ≥ p of the history.
//!
//! An empty history makes every variant degenerate to the FP step
//! x^{i+1} = x + R = F(x) — also the safeguarded row's update.

use super::history::History;
use super::Method;
use crate::linalg::{cholesky_solve, suffix_grams};

/// Apply one parallel update over active rows `[t1, t2]` (inclusive).
///
/// * `xs_rows` — mutable view of the unknown states `[T*d]` (rows 0..T−1);
/// * `f_vals` — F_p^{(k)} for active rows (`[T*d]`, other rows ignored);
/// * `r_vals` — residuals R_p = F_p − x_p (`[T*d]`, **zero outside the
///   active window** — the suffix Grams rely on it);
/// * `history` — Anderson difference pairs (may be empty);
/// * `lambda` — Gram ridge (Remark 3.3);
/// * `safeguard` — force the top unconverged row `t2` to a plain FP step
///   (Theorem 3.6; rows above t2 are converged, i.e. R ≈ 0, so t2 is the
///   row the theorem's condition bites on).
#[allow(clippy::too_many_arguments)]
pub fn apply_update(
    method: Method,
    xs_rows: &mut [f32],
    f_vals: &[f32],
    r_vals: &[f32],
    history: &History,
    t1: usize,
    t2: usize,
    t_rows: usize,
    d: usize,
    lambda: f32,
    safeguard: bool,
) {
    debug_assert_eq!(xs_rows.len(), t_rows * d);
    debug_assert!(t1 <= t2 && t2 < t_rows);

    let m = history.len();
    if method == Method::FixedPoint || m == 0 {
        // x ← F(x)
        for p in t1..=t2 {
            xs_rows[p * d..(p + 1) * d].copy_from_slice(&f_vals[p * d..(p + 1) * d]);
        }
        return;
    }

    let dx = history.dx_slots();
    let df = history.df_slots();

    // Suffix Grams over the full row range; rows above t2 hold zeros, so
    // G_{t1} is also the full-window Gram used by AA/AA+.
    let sg = suffix_grams(&df, r_vals, t_rows, d, t1);

    // Ridge the diagonal.
    let ridge = |g: &[f32]| -> Vec<f32> {
        let mut a = g.to_vec();
        // Scale-aware ridge: λ·(1 + tr(G)/m) keeps conditioning stable
        // across the wildly varying residual magnitudes of early vs late
        // iterations.
        let tr: f32 = (0..m).map(|i| g[i * m + i]).sum();
        let scale = lambda * (1.0 + tr / m as f32);
        for i in 0..m {
            a[i * m + i] += scale;
        }
        a
    };

    // Global γ (AA) or the shared Gram factor (AA+).
    let global_gamma: Option<Vec<f32>> = match method {
        Method::AndersonStd => cholesky_solve(&ridge(&sg.grams[t1]), &sg.proj[t1], m),
        _ => None,
    };

    for p in t1..=t2 {
        let row = p * d..(p + 1) * d;
        // Safeguarded row: plain FP (γ = 0). Theorem 3.6's condition is
        // imposed on the top unconverged row, whose suffix residuals
        // R_{p+1:} are all (numerically) zero.
        let fp_only = safeguard && p == t2;

        let gamma: Option<Vec<f32>> = if fp_only {
            None
        } else {
            match method {
                Method::FixedPoint => None,
                Method::AndersonStd => global_gamma.clone(),
                Method::AndersonUpperTri => {
                    // M = (full-window Gram + λI)⁻¹ applied to the *suffix*
                    // projection b_p — the upper-triangular part of eq. (13).
                    cholesky_solve(&ridge(&sg.grams[t1]), &sg.proj[p], m)
                }
                Method::Taa => cholesky_solve(&ridge(&sg.grams[p]), &sg.proj[p], m),
            }
        };

        match gamma {
            None => {
                xs_rows[row.clone()].copy_from_slice(&f_vals[row]);
            }
            Some(g) => {
                // x_p ← x_p + R_p − Σ_h γ_h·(ΔX_h[p] + ΔF_h[p])
                let (xr, rr) = (row.clone(), row.clone());
                for i in 0..d {
                    let idx = p * d + i;
                    let mut corr = 0.0f32;
                    for h in 0..m {
                        corr += g[h] * (dx[h][idx] + df[h][idx]);
                    }
                    let _ = (&xr, &rr);
                    xs_rows[idx] += r_vals[idx] - corr;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::{self, forall, size_in};

    fn mk_history(rows: usize, d: usize, slots: &[(Vec<f32>, Vec<f32>)]) -> History {
        let mut h = History::new(slots.len().max(1), rows, d);
        for (dx, df) in slots {
            h.push(dx, df);
        }
        h
    }

    #[test]
    fn fp_copies_f() {
        let (t_rows, d) = (4, 2);
        let mut xs = vec![0.0f32; t_rows * d];
        let f: Vec<f32> = (0..t_rows * d).map(|i| i as f32).collect();
        let r = vec![0.0f32; t_rows * d];
        let h = History::new(0, t_rows, d);
        apply_update(Method::FixedPoint, &mut xs, &f, &r, &h, 1, 2, t_rows, d, 0.0, false);
        // rows 1..=2 updated, rows 0 and 3 untouched
        assert_eq!(&xs[2..6], &f[2..6]);
        assert_eq!(&xs[0..2], &[0.0, 0.0]);
        assert_eq!(&xs[6..8], &[0.0, 0.0]);
    }

    #[test]
    fn empty_history_degenerates_to_fp() {
        let (t_rows, d) = (3, 2);
        let mut xs_a = vec![1.0f32; t_rows * d];
        let mut xs_b = vec![1.0f32; t_rows * d];
        let f: Vec<f32> = (0..t_rows * d).map(|i| (i as f32).sin()).collect();
        let r: Vec<f32> = f.iter().zip(xs_a.iter()).map(|(a, b)| a - b).collect();
        let h = History::new(3, t_rows, d); // empty
        apply_update(Method::Taa, &mut xs_a, &f, &r, &h, 0, 2, t_rows, d, 1e-4, true);
        apply_update(Method::FixedPoint, &mut xs_b, &f, &r, &h, 0, 2, t_rows, d, 0.0, false);
        assert_eq!(xs_a, xs_b);
    }

    #[test]
    fn safeguard_forces_fp_on_top_row() {
        let (t_rows, d) = (3, 2);
        let mut rng = crate::util::rng::Pcg64::seeded(8);
        let f: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let xs0: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let r: Vec<f32> = f.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
        let slots = vec![(
            (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect::<Vec<f32>>(),
            (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect::<Vec<f32>>(),
        )];
        let h = mk_history(t_rows, d, &slots);
        let mut with_sg = xs0.clone();
        apply_update(Method::Taa, &mut with_sg, &f, &r, &h, 0, 2, t_rows, d, 1e-4, true);
        // Top row (2) must equal the FP step = F row 2.
        assert_eq!(&with_sg[4..6], &f[4..6]);
        // Lower rows get Anderson corrections (differ from plain FP).
        let mut no_sg = xs0.clone();
        apply_update(Method::Taa, &mut no_sg, &f, &r, &h, 0, 2, t_rows, d, 1e-4, false);
        assert_ne!(&no_sg[4..6], &with_sg[4..6]);
        assert_eq!(&no_sg[0..4], &with_sg[0..4], "safeguard only touches the top row");
    }

    #[test]
    fn taa_row_update_depends_only_on_suffix() {
        // Corrupting history below row p must not change row p's TAA update
        // (the triangularity property motivating the method).
        forall("taa_suffix_locality", 16, |rng, _| {
            let t_rows = size_in(rng, 3, 8);
            let d = size_in(rng, 1, 4);
            let p_check = t_rows - 1; // top row, no safeguard
            let xs0: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
            let f: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
            let r: Vec<f32> = f.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
            let dx: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect();
            let df: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect();
            let h1 = mk_history(t_rows, d, &[(dx.clone(), df.clone())]);
            // Corrupt all rows BELOW p_check in the history.
            let mut dx2 = dx.clone();
            let mut df2 = df.clone();
            for v in &mut dx2[..p_check * d] {
                *v += 10.0 * rng.next_f32();
            }
            for v in &mut df2[..p_check * d] {
                *v += 10.0 * rng.next_f32();
            }
            let h2 = mk_history(t_rows, d, &[(dx2, df2)]);
            let mut out1 = xs0.clone();
            let mut out2 = xs0.clone();
            apply_update(Method::Taa, &mut out1, &f, &r, &h1, 0, t_rows - 1, t_rows, d, 1e-4, false);
            apply_update(Method::Taa, &mut out2, &f, &r, &h2, 0, t_rows - 1, t_rows, d, 1e-4, false);
            proplite::assert_close(
                &out1[p_check * d..],
                &out2[p_check * d..],
                1e-5,
                1e-4,
                "top row invariant to prefix corruption",
            )
        });
    }

    #[test]
    fn std_aa_is_dense_prefix_corruption_changes_top_row() {
        // Contrast with the TAA test: standard AA lets earlier rows leak
        // into later rows (the instability the paper identifies in §3.1).
        let mut rng = crate::util::rng::Pcg64::seeded(21);
        let (t_rows, d) = (4, 2);
        let xs0: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let f: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32()).collect();
        let r: Vec<f32> = f.iter().zip(xs0.iter()).map(|(a, b)| a - b).collect();
        let dx: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect();
        let df: Vec<f32> = (0..t_rows * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut df2 = df.clone();
        for v in &mut df2[..d] {
            *v += 5.0;
        }
        let h1 = mk_history(t_rows, d, &[(dx.clone(), df)]);
        let h2 = mk_history(t_rows, d, &[(dx, df2)]);
        let mut o1 = xs0.clone();
        let mut o2 = xs0.clone();
        apply_update(Method::AndersonStd, &mut o1, &f, &r, &h1, 0, 3, t_rows, d, 1e-4, false);
        apply_update(Method::AndersonStd, &mut o2, &f, &r, &h2, 0, 3, t_rows, d, 1e-4, false);
        let top_diff: f32 = o1[6..8]
            .iter()
            .zip(o2[6..8].iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(top_diff > 1e-6, "AA top row should see prefix corruption");
    }

    #[test]
    fn anderson_exact_on_linear_problem() {
        // For an affine map F(x) = Wx + v (W scalar diag here), AA with one
        // history column solves a 1-parameter secant problem. With a single
        // unknown row and exact arithmetic, the update must land on the
        // fixed point of the scalar recursion x ← 0.5x + 1 (x* = 2).
        let (t_rows, d) = (1, 1);
        let fmap = |x: f32| 0.5 * x + 1.0;
        let x0 = 0.0f32;
        let x1 = fmap(x0); // FP step: 1.0
        // history: Δx = x1-x0 = 1, ΔR: R(x)=F(x)-x = 1-0.5x; R0=1, R1=0.5, ΔR=-0.5
        let h = mk_history(t_rows, d, &[(vec![x1 - x0], vec![-0.5])]);
        let f1 = vec![fmap(x1)]; // 1.5
        let r1 = vec![fmap(x1) - x1]; // 0.5
        let mut xs = vec![x1];
        apply_update(Method::Taa, &mut xs, &f1, &r1, &h, 0, 0, t_rows, d, 0.0, false);
        assert!((xs[0] - 2.0).abs() < 1e-5, "AA should hit x*=2, got {}", xs[0]);
    }
}
