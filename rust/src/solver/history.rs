//! Anderson history ring buffers.
//!
//! Stores the last `cap` difference pairs (ΔX^j, ΔF^j) over the *full* state
//! range `[T, d]` (not just the active window): the sliding window moves
//! between iterations and full-range storage keeps row alignment trivial.
//! Rows that were inactive (frozen or outside the window) when a slot was
//! recorded hold zeros, which contribute nothing to the suffix Grams — the
//! λ-ridge (Remark 3.3) absorbs the resulting rank deficiency.

/// Ring buffer of history difference pairs.
pub struct History {
    /// Capacity = number of difference columns (paper's m − 1).
    cap: usize,
    rows: usize,
    d: usize,
    /// Slots in insertion order; `dx[s]` and `df[s]` are `[rows*d]`.
    dx: Vec<Vec<f32>>,
    df: Vec<Vec<f32>>,
    /// Next slot to overwrite.
    next: usize,
    /// Number of valid slots (≤ cap).
    len: usize,
}

impl History {
    pub fn new(cap: usize, rows: usize, d: usize) -> Self {
        History {
            cap,
            rows,
            d,
            dx: (0..cap).map(|_| vec![0.0; rows * d]).collect(),
            df: (0..cap).map(|_| vec![0.0; rows * d]).collect(),
            next: 0,
            len: 0,
        }
    }

    /// Number of valid difference columns m_i.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Record a new difference pair. `dx`/`df` are full `[rows*d]` buffers;
    /// the caller zeroes rows without valid previous values.
    pub fn push(&mut self, dx: &[f32], df: &[f32]) {
        if self.cap == 0 {
            return;
        }
        debug_assert_eq!(dx.len(), self.rows * self.d);
        debug_assert_eq!(df.len(), self.rows * self.d);
        self.dx[self.next].copy_from_slice(dx);
        self.df[self.next].copy_from_slice(df);
        self.next = (self.next + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    /// Valid ΔX slots (arbitrary but consistent order w.r.t. [`df_slots`]).
    pub fn dx_slots(&self) -> Vec<&[f32]> {
        (0..self.len).map(|i| self.dx[i].as_slice()).collect()
    }

    /// Valid ΔF slots, index-aligned with [`dx_slots`].
    pub fn df_slots(&self) -> Vec<&[f32]> {
        (0..self.len).map(|i| self.df[i].as_slice()).collect()
    }

    /// Drop all history (used when the window jumps discontinuously).
    pub fn clear(&mut self) {
        self.len = 0;
        self.next = 0;
        for s in &mut self.dx {
            s.fill(0.0);
        }
        for s in &mut self.df {
            s.fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest() {
        let mut h = History::new(2, 1, 2);
        h.push(&[1.0, 1.0], &[10.0, 10.0]);
        h.push(&[2.0, 2.0], &[20.0, 20.0]);
        assert_eq!(h.len(), 2);
        h.push(&[3.0, 3.0], &[30.0, 30.0]);
        assert_eq!(h.len(), 2);
        // Slot 0 was overwritten by the third push.
        let slots = h.dx_slots();
        let mut firsts: Vec<f32> = slots.iter().map(|s| s[0]).collect();
        firsts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(firsts, vec![2.0, 3.0]);
    }

    #[test]
    fn dx_df_alignment_survives_wrap() {
        let mut h = History::new(2, 1, 1);
        h.push(&[1.0], &[-1.0]);
        h.push(&[2.0], &[-2.0]);
        h.push(&[3.0], &[-3.0]);
        let dx = h.dx_slots();
        let df = h.df_slots();
        for i in 0..h.len() {
            assert_eq!(dx[i][0], -df[i][0], "slot {i} misaligned");
        }
    }

    #[test]
    fn zero_capacity_is_noop() {
        let mut h = History::new(0, 2, 2);
        h.push(&[0.0; 4], &[0.0; 4]);
        assert!(h.is_empty());
        assert!(h.dx_slots().is_empty());
    }

    #[test]
    fn clear_resets() {
        let mut h = History::new(3, 1, 1);
        h.push(&[1.0], &[1.0]);
        h.clear();
        assert_eq!(h.len(), 0);
    }
}
