//! Anderson history ring buffers + the incrementally-maintained Gram cache.
//!
//! Stores the last `cap` difference pairs (ΔX^j, ΔF^j) over the *full* state
//! range `[T, d]` (not just the active window): the sliding window moves
//! between iterations and full-range storage keeps row alignment trivial.
//! Rows that were inactive (frozen or outside the window) when a slot was
//! recorded hold zeros, which contribute nothing to the suffix Grams — the
//! λ-ridge (Remark 3.3) absorbs the resulting rank deficiency.
//!
//! # Layout and the incremental Gram cache
//!
//! All slots live in flat `[cap, rows*d]` buffers. Each push materializes
//! the **fused** slot `ΔX + ΔF`, which is the only thing the correction
//! loop `x_p += R_p − Σ_h γ_h·(ΔX_h[p]+ΔF_h[p])` ever reads — one stream
//! per slot instead of two. ΔX itself is **not retained**: nothing
//! downstream needs it once the fused slot exists, and dropping it saves a
//! third of the slot memory and one `rows*d` copy per push (ΔF must stay:
//! the Gram cache and the per-round b_t projection rescans read it).
//!
//! The expensive part of the suffix-Gram scan (`linalg::gram`) is the
//! per-row pairwise products `g_t[a,b] = ΔF_a[t]·ΔF_b[t]` — O(W·m²·D) when
//! recomputed from scratch every round. But a ring push replaces exactly
//! one slot, so only the `m` pairs involving the overwritten slot change:
//! this module caches `g_t[a,b]` (f64, `[rows, cap, cap]`) and refreshes
//! the affected entries at push time, O(W·m·D). [`History::suffix_grams_into`]
//! then reduces the cache in O(W·m²) and rescans only the residual
//! projection b_t (which changes every round, O(W·m·D)). [`History::clear`]
//! — the window-jump path — drops the cache wholesale.
//!
//! The cached per-row products are computed by the same kernel contract
//! the from-scratch scan uses ([`multi_dot8`] is bitwise-identical to
//! per-pair `dot8`), so the cached and rescanned suffix Grams are
//! **bit-identical** (pinned by a property test below).
//!
//! # Tiling and row-parallelism
//!
//! The refresh is structured **row-outer**: for each window row `t`, one
//! tiled [`multi_dot8`] pass streams the new slot's row against every live
//! slot's row, so the `[rows*d]`-strided buffers are walked row-at-a-time
//! instead of slot-at-a-time and the new row stays in L1 across the slot
//! group. Because each `t` writes only its own `cap×cap` block of the
//! cache, rows are independent — [`History::push_ranged_par`] fans them
//! across an optional [`RowPool`]. Chunking never changes any value (each
//! entry is computed by exactly one row's pass), so results are bitwise
//! identical at every thread count.

use crate::linalg::gram::SuffixGrams;
use crate::linalg::kernels::{multi_dot8, LANES};
use crate::linalg::mat::add_scaled;
use crate::util::threadpool::{chunk_range, RowPool, SyncSlice};

/// Slots batched per `multi_dot8` call in the cache refresh and the
/// projection rescan (cap ≤ 8 in practice, so one batch usually suffices).
const GRAM_BATCH: usize = 8;

/// Ring buffer of history difference pairs with a per-row Gram cache.
pub struct History {
    /// Capacity = number of difference columns (paper's m − 1).
    cap: usize,
    rows: usize,
    d: usize,
    /// ΔF slot storage, flat `[cap, rows*d]`; slot `s` starts at `s*rows*d`.
    df: Vec<f32>,
    /// Fused `dx + df` per slot, materialized at push time (ΔX is not
    /// stored separately — see the module docs).
    fused: Vec<f32>,
    /// Active row range `[lo, hi)` per slot: rows outside are all-zero.
    lo: Vec<usize>,
    hi: Vec<usize>,
    /// Per-row pairwise Gram contributions, `[rows, cap, cap]` f64:
    /// `row_gram[t*cap*cap + a*cap + b] = ΔF_a[t]·ΔF_b[t]` (symmetric).
    row_gram: Vec<f64>,
    /// Next slot to overwrite.
    next: usize,
    /// Number of valid slots (≤ cap).
    len: usize,
}

impl History {
    /// A ring for `cap` difference columns over `[rows, d]` states.
    pub fn new(cap: usize, rows: usize, d: usize) -> Self {
        History {
            cap,
            rows,
            d,
            df: vec![0.0; cap * rows * d],
            fused: vec![0.0; cap * rows * d],
            lo: vec![0; cap],
            hi: vec![0; cap],
            row_gram: vec![0.0; rows * cap * cap],
            next: 0,
            len: 0,
        }
    }

    /// Number of valid difference columns m_i.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no difference pairs have been recorded.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Ring capacity (maximum difference columns).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// State rows T each slot spans.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Feature dimension d of each state row.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Record a new difference pair. `dx`/`df` are full `[rows*d]` buffers;
    /// the caller zeroes rows without valid previous values.
    pub fn push(&mut self, dx: &[f32], df: &[f32]) {
        let rows = self.rows;
        self.push_ranged(dx, df, 0, rows);
    }

    /// Record a new difference pair whose nonzero rows all lie in
    /// `[lo, hi)` (rows outside MUST be zero in both buffers — this is what
    /// lets the Gram cache and the correction loop skip them). `push` is
    /// the full-range special case; the two are numerically identical.
    pub fn push_ranged(&mut self, dx: &[f32], df: &[f32], lo: usize, hi: usize) {
        self.push_ranged_par(dx, df, lo, hi, None);
    }

    /// [`push_ranged`](Self::push_ranged) with the Gram-cache refresh
    /// fanned across `pool` (row-partitioned; bitwise identical to the
    /// sequential path at every thread count — see the module docs).
    pub fn push_ranged_par(
        &mut self,
        dx: &[f32],
        df: &[f32],
        lo: usize,
        hi: usize,
        pool: Option<&RowPool>,
    ) {
        if self.cap == 0 {
            return;
        }
        // Ring-wrap evicting the oldest column is the Anderson "restart"
        // signal: `a` is the nonzero row count pushed, `b` is 1 when this
        // push overwrote a live column (len already at capacity).
        crate::trace::instant(
            crate::trace::Layer::Solver,
            crate::trace::Name::HistoryPush,
            0,
            (hi - lo) as i64,
            (self.len == self.cap) as i64,
        );
        let n = self.rows * self.d;
        debug_assert_eq!(dx.len(), n);
        debug_assert_eq!(df.len(), n);
        debug_assert!(lo <= hi && hi <= self.rows);
        #[cfg(debug_assertions)]
        for (name, buf) in [("dx", dx), ("df", df)] {
            for (i, &v) in buf.iter().enumerate() {
                let row = i / self.d.max(1);
                debug_assert!(
                    v == 0.0 || (row >= lo && row < hi),
                    "{name} row {row} nonzero outside [{lo}, {hi})"
                );
            }
        }

        let s = self.next;
        self.df[s * n..(s + 1) * n].copy_from_slice(df);
        for (o, (&a, &b)) in
            self.fused[s * n..(s + 1) * n].iter_mut().zip(dx.iter().zip(df.iter()))
        {
            *o = a + b;
        }
        self.lo[s] = lo;
        self.hi[s] = hi;
        self.next = (self.next + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);

        // Refresh the cache entries involving slot s (only those changed).
        // Row-outer: each window row owns its cap×cap block, computed by
        // one tiled multi_dot8 pass of the new slot's row against every
        // live in-range slot's row. Rows are independent, so they fan
        // across the pool; every entry is produced by exactly one row's
        // pass, making the result bitwise chunking-invariant.
        let cap = self.cap;
        let d = self.d;
        let len = self.len;
        let rows = self.rows;
        let df_buf = &self.df;
        let slot_lo = &self.lo;
        let slot_hi = &self.hi;
        let rg_view = SyncSlice::new(&mut self.row_gram);

        let refresh_row = |t: usize| {
            // SAFETY: row t's cap×cap block is touched by no other row.
            let rgt = unsafe { rg_view.slice_mut(t * cap * cap, cap * cap) };
            // Drop the previous occupant's contributions...
            for h in 0..len {
                rgt[s * cap + h] = 0.0;
                rgt[h * cap + s] = 0.0;
            }
            // ...then fill where both the new slot and a live slot can be
            // nonzero on this row.
            if t < lo || t >= hi {
                return;
            }
            let fs = &df_buf[s * n + t * d..s * n + (t + 1) * d];
            let mut hs = [0usize; GRAM_BATCH];
            let mut slots: [&[f32]; GRAM_BATCH] = [&[]; GRAM_BATCH];
            let mut cnt = 0;
            for h in 0..len {
                if t < slot_lo[h] || t >= slot_hi[h] {
                    continue;
                }
                hs[cnt] = h;
                slots[cnt] = &df_buf[h * n + t * d..h * n + (t + 1) * d];
                cnt += 1;
                if cnt == GRAM_BATCH {
                    fill_gram_row(fs, &hs[..cnt], &slots[..cnt], rgt, s, cap);
                    cnt = 0;
                }
            }
            if cnt > 0 {
                fill_gram_row(fs, &hs[..cnt], &slots[..cnt], rgt, s, cap);
            }
        };

        match pool {
            Some(pool) if rows > 1 => {
                let chunks = pool.threads();
                pool.run(chunks, &|c| {
                    let (c0, c1) = chunk_range(rows, chunks, c);
                    for t in c0..c1 {
                        refresh_row(t);
                    }
                });
            }
            _ => {
                for t in 0..rows {
                    refresh_row(t);
                }
            }
        }
    }

    /// ΔF slot `h` (`h < len()`), a `[rows*d]` view.
    #[inline]
    pub fn df_slot(&self, h: usize) -> &[f32] {
        let n = self.rows * self.d;
        &self.df[h * n..(h + 1) * n]
    }

    /// Fused `ΔX + ΔF` slot `h`, index-aligned with
    /// [`df_slot`](Self::df_slot) — what the correction loop reads.
    #[inline]
    pub fn fused_slot(&self, h: usize) -> &[f32] {
        let n = self.rows * self.d;
        &self.fused[h * n..(h + 1) * n]
    }

    /// Valid ΔF slots (arbitrary but consistent order w.r.t.
    /// [`fused_slot`](Self::fused_slot)).
    pub fn df_slots(&self) -> Vec<&[f32]> {
        (0..self.len).map(|i| self.df_slot(i)).collect()
    }

    /// Suffix Grams over all `len()` slots via the incremental per-row
    /// cache: G_t comes from the cached pairwise products (O(W·m²) here,
    /// maintained in O(W·m·D) at push time), b_t is rescanned against the
    /// fresh `residual` (O(W·m·D)). Bit-identical to
    /// [`crate::linalg::suffix_grams_into`] over [`df_slots`](Self::df_slots).
    pub fn suffix_grams_into(&self, residual: &[f32], t0: usize, out: &mut SuffixGrams) {
        let (w, d, m) = (self.rows, self.d, self.len);
        assert_eq!(residual.len(), w * d, "residual shape");
        assert!(t0 <= w);
        out.reset(w, m);
        let cc = self.cap * self.cap;
        let n = w * d;
        for t in (t0..w).rev() {
            let base = t * cc;
            for a in 0..m {
                for b in a..m {
                    out.accumulate_gram(a, b, self.row_gram[base + a * self.cap + b]);
                }
            }
            // Projection rescan, batched: one tiled multi_dot8 pass of the
            // residual row against every in-range slot row (the dot is
            // bitwise symmetric — per-lane products commute and the
            // reduction order is fixed by the kernel contract). Rows
            // outside a slot's active range hold zeros and are skipped
            // entirely (contributes exactly +0.0).
            let r_row = &residual[t * d..(t + 1) * d];
            let mut idx = [0usize; GRAM_BATCH];
            let mut slots: [&[f32]; GRAM_BATCH] = [&[]; GRAM_BATCH];
            let mut cnt = 0;
            for a in 0..m {
                if t < self.lo[a] || t >= self.hi[a] {
                    continue;
                }
                idx[cnt] = a;
                slots[cnt] = &self.df[a * n + t * d..a * n + (t + 1) * d];
                cnt += 1;
                if cnt == GRAM_BATCH {
                    accumulate_proj_batch(r_row, &idx[..cnt], &slots[..cnt], out);
                    cnt = 0;
                }
            }
            if cnt > 0 {
                accumulate_proj_batch(r_row, &idx[..cnt], &slots[..cnt], out);
            }
            out.commit_row(t);
        }
    }

    /// The fused Anderson correction for one window row:
    /// `x_row = (x_row + r_row) − Σ_h gamma[h]·fused_h[p]`, skipping slots
    /// whose active range excludes `p` (their fused row is all-zero).
    pub fn correct_row(&self, p: usize, gamma: &[f32], r_row: &[f32], x_row: &mut [f32]) {
        debug_assert!(gamma.len() <= self.len);
        debug_assert_eq!(r_row.len(), self.d);
        debug_assert_eq!(x_row.len(), self.d);
        add_scaled(x_row, r_row, 1.0);
        let n = self.rows * self.d;
        for (h, &g) in gamma.iter().enumerate() {
            if p < self.lo[h] || p >= self.hi[h] {
                continue;
            }
            let fh = &self.fused[h * n + p * self.d..h * n + (p + 1) * self.d];
            add_scaled(x_row, fh, -g);
        }
    }

    /// Drop all history (used when the window jumps discontinuously).
    /// Invalidates the Gram cache wholesale.
    pub fn clear(&mut self) {
        self.len = 0;
        self.next = 0;
        self.df.fill(0.0);
        self.fused.fill(0.0);
        self.row_gram.fill(0.0);
        self.lo.fill(0);
        self.hi.fill(0);
    }
}

/// One batched Gram fill: `rgt[s,h] = rgt[h,s] = fs·slots[i]` for each
/// batched slot `h = hs[i]`, bitwise identical to per-pair `dot8`.
fn fill_gram_row(fs: &[f32], hs: &[usize], slots: &[&[f32]], rgt: &mut [f64], s: usize, cap: usize) {
    let mut acc = [0.0f64; GRAM_BATCH * LANES];
    let mut vals = [0.0f64; GRAM_BATCH];
    multi_dot8(fs, slots, &mut acc, &mut vals);
    for (&h, &v) in hs.iter().zip(vals.iter()) {
        rgt[s * cap + h] = v;
        rgt[h * cap + s] = v;
    }
}

/// One batched projection fill: `b[a] += r_row·slots[i]` for each batched
/// slot `a = idx[i]`.
fn accumulate_proj_batch(r_row: &[f32], idx: &[usize], slots: &[&[f32]], out: &mut SuffixGrams) {
    let mut acc = [0.0f64; GRAM_BATCH * LANES];
    let mut vals = [0.0f64; GRAM_BATCH];
    multi_dot8(r_row, slots, &mut acc, &mut vals);
    for (&a, &v) in idx.iter().zip(vals.iter()) {
        out.accumulate_proj(a, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram::suffix_grams_into;
    use crate::util::proplite::{forall, size_in};
    use crate::util::rng::Pcg64;

    #[test]
    fn ring_overwrites_oldest() {
        let mut h = History::new(2, 1, 2);
        h.push(&[1.0, 1.0], &[10.0, 10.0]);
        h.push(&[2.0, 2.0], &[20.0, 20.0]);
        assert_eq!(h.len(), 2);
        h.push(&[3.0, 3.0], &[30.0, 30.0]);
        assert_eq!(h.len(), 2);
        // Slot 0 was overwritten by the third push.
        let slots = h.df_slots();
        let mut firsts: Vec<f32> = slots.iter().map(|s| s[0]).collect();
        firsts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(firsts, vec![20.0, 30.0]);
    }

    #[test]
    fn fused_df_alignment_survives_wrap() {
        // dx = k, df = -2k ⇒ fused = -k: each surviving slot must keep its
        // fused row paired with its own df row across the ring wrap.
        let mut h = History::new(2, 1, 1);
        h.push(&[1.0], &[-2.0]);
        h.push(&[2.0], &[-4.0]);
        h.push(&[3.0], &[-6.0]);
        for i in 0..h.len() {
            assert_eq!(
                h.fused_slot(i)[0],
                0.5 * h.df_slot(i)[0],
                "slot {i} misaligned"
            );
        }
    }

    #[test]
    fn zero_capacity_is_noop() {
        let mut h = History::new(0, 2, 2);
        h.push(&[0.0; 4], &[0.0; 4]);
        assert!(h.is_empty());
        assert!(h.df_slots().is_empty());
    }

    #[test]
    fn clear_resets() {
        let mut h = History::new(3, 1, 1);
        h.push(&[1.0], &[1.0]);
        h.clear();
        assert_eq!(h.len(), 0);
    }

    #[test]
    fn fused_slot_is_dx_plus_df() {
        let mut h = History::new(2, 2, 2);
        h.push(&[1.0, 2.0, 3.0, 4.0], &[0.5, -0.5, 0.25, -0.25]);
        assert_eq!(h.fused_slot(0), &[1.5, 1.5, 3.25, 3.75]);
    }

    /// Randomized ranged pushes into a History, then push the same buffers
    /// into a fresh instance via full-range `push`: ranged and full pushes
    /// must be indistinguishable through the cached suffix-Gram API.
    fn random_history(rng: &mut Pcg64, cap: usize, w: usize, d: usize) -> (History, History) {
        let mut ranged = History::new(cap, w, d);
        let mut full = History::new(cap, w, d);
        let pushes = size_in(rng, 1, 2 * cap.max(1) + 1);
        for _ in 0..pushes {
            let lo = size_in(rng, 0, w - 1);
            let hi = size_in(rng, lo, w - 1) + 1;
            let mut dx = vec![0.0f32; w * d];
            let mut df = vec![0.0f32; w * d];
            for i in lo * d..hi * d {
                dx[i] = rng.next_f32() - 0.5;
                df[i] = rng.next_f32() - 0.5;
            }
            ranged.push_ranged(&dx, &df, lo, hi);
            full.push(&dx, &df);
        }
        (ranged, full)
    }

    #[test]
    fn cached_suffix_grams_match_rescan_bitwise() {
        forall("gram_cache_vs_rescan", 24, |rng, _| {
            let w = size_in(rng, 1, 12);
            let d = size_in(rng, 1, 9);
            let cap = size_in(rng, 1, 4);
            let (ranged, full) = random_history(rng, cap, w, d);
            let res: Vec<f32> = (0..w * d).map(|_| rng.next_f32() - 0.5).collect();
            let t0 = size_in(rng, 0, w - 1);

            let mut cached = SuffixGrams::new();
            ranged.suffix_grams_into(&res, t0, &mut cached);
            let mut cached_full = SuffixGrams::new();
            full.suffix_grams_into(&res, t0, &mut cached_full);
            let slots = full.df_slots();
            let mut rescan = SuffixGrams::new();
            suffix_grams_into(&mut rescan, &slots, &res, w, d, t0);

            for t in t0..w {
                if cached.gram(t) != rescan.gram(t) || cached.proj(t) != rescan.proj(t) {
                    return Err(format!("ranged cache != rescan at row {t}"));
                }
                if cached_full.gram(t) != rescan.gram(t)
                    || cached_full.proj(t) != rescan.proj(t)
                {
                    return Err(format!("full-range cache != rescan at row {t}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cache_survives_clear_and_rebuild() {
        let mut rng = Pcg64::seeded(23);
        let (w, d, cap) = (6usize, 3usize, 2usize);
        let mut h = History::new(cap, w, d);
        h.push(&rng.gaussian_vec(w * d), &rng.gaussian_vec(w * d));
        h.clear();
        let dx = rng.gaussian_vec(w * d);
        let df = rng.gaussian_vec(w * d);
        h.push(&dx, &df);
        let res = rng.gaussian_vec(w * d);
        let mut cached = SuffixGrams::new();
        h.suffix_grams_into(&res, 0, &mut cached);
        let mut rescan = SuffixGrams::new();
        suffix_grams_into(&mut rescan, &[&df], &res, w, d, 0);
        for t in 0..w {
            assert_eq!(cached.gram(t), rescan.gram(t), "stale cache after clear, row {t}");
            assert_eq!(cached.proj(t), rescan.proj(t), "stale proj after clear, row {t}");
        }
    }

    #[test]
    fn realistic_size_cache_parity_with_wrap_and_slides() {
        // The ISSUE-4 regime: W=100, m=8, D=256, sliding ranges, ring wrap,
        // several t0 fronts. Cached and rescanned suffix Grams must agree
        // bitwise.
        let (w, d, cap) = (100usize, 256usize, 8usize);
        let mut rng = Pcg64::seeded(41);
        let mut h = History::new(cap, w, d);
        for i in 0..cap + 2 {
            // A window sliding downward, as the solver's front advances.
            let hi = w - 4 * i.min(10);
            let lo = hi.saturating_sub(40);
            let mut dx = vec![0.0f32; w * d];
            let mut df = vec![0.0f32; w * d];
            for j in lo * d..hi * d {
                dx[j] = rng.next_f32() - 0.5;
                df[j] = rng.next_f32() - 0.5;
            }
            h.push_ranged(&dx, &df, lo, hi);
        }
        let res = rng.gaussian_vec(w * d);
        let slots = h.df_slots();
        for t0 in [0usize, 41, 99] {
            let mut cached = SuffixGrams::new();
            h.suffix_grams_into(&res, t0, &mut cached);
            let mut rescan = SuffixGrams::new();
            suffix_grams_into(&mut rescan, &slots, &res, w, d, t0);
            for t in t0..w {
                assert_eq!(cached.gram(t), rescan.gram(t), "gram row {t} (t0={t0})");
                assert_eq!(cached.proj(t), rescan.proj(t), "proj row {t} (t0={t0})");
            }
        }
    }

    #[test]
    fn parallel_push_is_bitwise_identical_to_sequential() {
        // Same pushes through push_ranged (sequential) and push_ranged_par
        // at several thread counts: the Gram cache must not differ by a
        // single bit (chunking invariance).
        use crate::util::threadpool::RowPool;
        let (w, d, cap) = (37usize, 129usize, 5usize);
        for threads in [2usize, 4, 8] {
            let pool = RowPool::new(threads);
            let mut rng = Pcg64::seeded(31);
            let mut seq = History::new(cap, w, d);
            let mut par = History::new(cap, w, d);
            for i in 0..cap + 3 {
                let hi = w - 2 * i.min(8);
                let lo = hi.saturating_sub(20);
                let mut dx = vec![0.0f32; w * d];
                let mut df = vec![0.0f32; w * d];
                for j in lo * d..hi * d {
                    dx[j] = rng.next_f32() - 0.5;
                    df[j] = rng.next_f32() - 0.5;
                }
                seq.push_ranged(&dx, &df, lo, hi);
                par.push_ranged_par(&dx, &df, lo, hi, Some(&pool));
            }
            assert_eq!(seq.row_gram, par.row_gram, "gram cache drift at {threads} threads");
            let res = rng.gaussian_vec(w * d);
            let mut a = SuffixGrams::new();
            let mut b = SuffixGrams::new();
            seq.suffix_grams_into(&res, 0, &mut a);
            par.suffix_grams_into(&res, 0, &mut b);
            for t in 0..w {
                assert_eq!(a.gram(t), b.gram(t), "gram row {t} at {threads} threads");
                assert_eq!(a.proj(t), b.proj(t), "proj row {t} at {threads} threads");
            }
        }
    }

    #[test]
    fn correct_row_matches_naive() {
        // No ring wrap (cap pushes), so slot order == push order and the
        // naive reference can recompute ΔX+ΔF from the original buffers —
        // independent of the stored fused slots.
        let mut rng = Pcg64::seeded(17);
        let (w, d, cap) = (5usize, 4usize, 3usize);
        let mut h = History::new(cap, w, d);
        let mut pushed: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for _ in 0..cap {
            let dx = rng.gaussian_vec(w * d);
            let df = rng.gaussian_vec(w * d);
            h.push(&dx, &df);
            pushed.push((dx, df));
        }
        let gamma: Vec<f32> = (0..h.len()).map(|_| rng.next_f32() - 0.5).collect();
        for p in 0..w {
            let x0 = rng.gaussian_vec(d);
            let r = rng.gaussian_vec(d);
            let mut fast = x0.clone();
            h.correct_row(p, &gamma, &r, &mut fast);
            // Naive: x + r − Σ_h γ_h (ΔX_h[p] + ΔF_h[p]), same accumulation
            // order as correct_row (add r, then subtract slot by slot).
            let mut slow = x0.clone();
            for i in 0..d {
                slow[i] += r[i];
            }
            for (hh, &g) in gamma.iter().enumerate() {
                let (dx, df) = &pushed[hh];
                for i in 0..d {
                    slow[i] -= g * (dx[p * d + i] + df[p * d + i]);
                }
            }
            crate::util::proplite::assert_close(&fast, &slow, 1e-6, 1e-5, "correct_row")
                .unwrap();
        }
    }
}
