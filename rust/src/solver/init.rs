//! Trajectory initialization (§4.2) and the SDEdit-style splice.
//!
//! If a similar problem (e.g. a nearby prompt) has already been solved, its
//! trajectory is a far better starting point than Gaussian noise: the two
//! nonlinear systems are close, so the old solution nearly solves the new
//! one. Optionally the later (noisier) portion of the trajectory is frozen
//! (`t_init`), which anchors the new sample near the old image and yields
//! the paper's smooth source→target interpolations (Fig. 5/13/15).

use super::Problem;
use crate::equations::States;

/// Configure `problem` to start from `trajectory` (a full x_0..x_T stack
/// from a previous solve), freezing rows ≥ `t_init`.
///
/// The ξ draws of `problem` are **replaced** by `xi`: re-using the source
/// problem's noise is what makes the two systems differ only through the
/// condition, giving the interpolation its smoothness.
pub fn init_from_trajectory(
    problem: &mut Problem,
    trajectory: States,
    xi: States,
    t_init: usize,
) {
    assert_eq!(trajectory.d, problem.model.dim());
    let t_count = problem.coeffs.steps;
    assert_eq!(trajectory.rows(), t_count + 1);
    assert_eq!(xi.rows(), t_count + 1);
    assert!(t_init >= 1 && t_init <= t_count, "t_init out of range");
    problem.xi = xi;
    problem.init = Some(trajectory);
    problem.t_init = Some(t_init);
}

/// Distance between two conditions' trajectories at the sample row — used
/// by the coordinator's trajectory cache to pick the closest donor.
pub fn trajectory_distance(a: &States, b: &States) -> f64 {
    assert_eq!(a.d, b.d);
    assert_eq!(a.rows(), b.rows());
    let mut acc = 0.0f64;
    for (x, y) in a.row(0).iter().zip(b.row(0).iter()) {
        let r = (*x - *y) as f64;
        acc += r * r;
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::GmmEps;
    use crate::model::{Cond, EpsModel};
    use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
    use crate::solver::{solve, SolverConfig};
    use crate::util::rng::Pcg64;

    fn setup() -> (SamplerCoeffs, GmmEps) {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 24);
        let mut rng = Pcg64::seeded(50);
        let d = 6;
        let means: Vec<f32> = (0..4 * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        let model = GmmEps::new(means, d, 0.25, ns.alpha_bars.clone());
        (coeffs, model)
    }

    #[test]
    fn warm_init_converges_faster_than_cold() {
        let (coeffs, model) = setup();
        let cfg = SolverConfig { guidance: 2.0, ..SolverConfig::parataa(24) };

        // Solve for "prompt" P1 = pure class 0.
        let p1 = Problem::new(&coeffs, &model, Cond::Class(0), 123);
        let r1 = solve(&p1, &cfg);
        assert!(r1.converged);

        // P2 = a nearby prompt (90% class 0, 10% class 1).
        let near = Cond::Class(0).lerp(&Cond::Class(1), 0.1, 4);
        let cold = {
            let p2 = Problem::new(&coeffs, &model, near.clone(), 123);
            solve(&p2, &cfg)
        };
        let warm = {
            let mut p2 = Problem::new(&coeffs, &model, near, 123);
            let xi = p1.xi.clone();
            init_from_trajectory(&mut p2, r1.xs.clone(), xi, 24);
            solve(&p2, &cfg)
        };
        assert!(cold.converged && warm.converged);
        assert!(
            warm.iterations <= cold.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
    }

    #[test]
    fn frozen_tail_is_preserved() {
        let (coeffs, model) = setup();
        let cfg = SolverConfig { guidance: 1.0, tol: 1e-4, ..SolverConfig::parataa(24) };
        let p1 = Problem::new(&coeffs, &model, Cond::Class(1), 9);
        let r1 = solve(&p1, &cfg);
        let t_init = 16;
        let mut p2 = Problem::new(&coeffs, &model, Cond::Class(2), 9);
        let xi = p1.xi.clone();
        init_from_trajectory(&mut p2, r1.xs.clone(), xi, t_init);
        let r2 = solve(&p2, &cfg);
        // Rows ≥ t_init must be bit-identical to the donor trajectory.
        for t in t_init..=24 {
            assert_eq!(r2.xs.row(t), r1.xs.row(t), "frozen row {t} moved");
        }
        // ...and the sample row must still satisfy the new condition's
        // system below T_init: just check it changed from the donor.
        assert_ne!(r2.xs.row(0), r1.xs.row(0));
    }

    #[test]
    fn splice_matches_sequential_from_frozen_state() {
        // Freezing rows ≥ t_init and solving the rest must equal running the
        // *sequential* sampler for the new condition starting from the
        // donor's x_{t_init}.
        let (coeffs, model) = setup();
        let cfg = SolverConfig { guidance: 1.0, tol: 1e-6, ..SolverConfig::parataa(24) };
        let p1 = Problem::new(&coeffs, &model, Cond::Class(0), 31);
        let r1 = solve(&p1, &cfg);
        let t_init = 12;
        let mut p2 = Problem::new(&coeffs, &model, Cond::Class(3), 31);
        init_from_trajectory(&mut p2, r1.xs.clone(), p1.xi.clone(), t_init);
        let par = solve(&p2, &cfg);
        assert!(par.converged);

        // Sequential reference: descend from the frozen x_{t_init}.
        let d = model.dim();
        let mut xs = r1.xs.clone();
        let mut eps = vec![0.0f32; d];
        for t in (1..=t_init).rev() {
            model.eps_batch(
                xs.row(t),
                &[coeffs.train_t[t]],
                &[Cond::Class(3)],
                1.0,
                &mut eps,
            );
            let row: Vec<f32> = (0..d)
                .map(|i| {
                    coeffs.a[t] as f32 * xs.row(t)[i]
                        + coeffs.b[t] as f32 * eps[i]
                        + coeffs.c[t - 1] as f32 * p2.xi.row(t - 1)[i]
                })
                .collect();
            xs.set_row(t - 1, &row);
        }
        crate::util::proplite::assert_close(par.xs.row(0), xs.row(0), 1e-3, 1e-2, "splice")
            .unwrap();
    }

    #[test]
    fn trajectory_distance_basics() {
        let mut a = States::zeros(3, 2);
        let b = States::zeros(3, 2);
        assert_eq!(trajectory_distance(&a, &b), 0.0);
        a.row_mut(0)[0] = 3.0;
        a.row_mut(0)[1] = 4.0;
        assert!((trajectory_distance(&a, &b) - 5.0).abs() < 1e-9);
    }
}
