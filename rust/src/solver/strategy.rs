//! Multi-fidelity solve strategies (ROADMAP item 2).
//!
//! The session substrate (one `pending()`/`resume()` pair per parallel
//! round, see [`super::session`]) was built to host iteration schemes that
//! mix *fidelities* — not just the paper's single-grid TAA rounds. This
//! module adds the strategy layer choosing how a solve schedules fidelity:
//!
//! - [`SolveStrategy::PlainTaa`] — the default: single-fidelity rounds on
//!   the full step grid, byte-for-byte the historical path (golden-tested
//!   in `tests/golden_session.rs`);
//! - [`SolveStrategy::DraftRefine`] — DRiffusion-style draft-and-refine: a
//!   cheap coarse solve (C ≪ T steps on a subsetted grid) runs first, its
//!   trajectory is lifted onto the fine grid ([`lift_trajectory`]) and
//!   seeds the window exactly like a §4.2 warm start, then fine TAA rounds
//!   refine it;
//! - [`SolveStrategy::Parareal`] — Self-Refining-style Parareal: coarse
//!   sweeps (a strided sequential pass over the active window using bridge
//!   coefficients, [`crate::equations::bridge_coeffs`]) alternate with the
//!   standard fine parallel-correction rounds.
//!
//! Both multi-fidelity schemes preserve the Theorem 3.6 invariants: the
//! coarse phases never write the safeguarded row t2 or any frozen row
//! above it, so the residual front stays monotone and the fixed point is
//! unchanged (Theorem 2.2 — every strategy converges to the sequential
//! trajectory). Property-tested in `tests/strategy_properties.rs`.
//!
//! The coarse operator is constructed *from the fine problem*, not from
//! the schedule: [`crate::schedule::SamplerCoeffs::coarsen`] subsets the
//! existing step grid (recovering per-state ᾱ by telescoping the `a`
//! coefficients), so a coarse step bridges two fine states with the same
//! DDIM(η) formulas the fine grid uses.

use crate::equations::States;

/// How a [`SolverSession`](super::SolverSession) schedules fidelity across
/// its parallel rounds.
///
/// # Example
///
/// Draft-and-refine lands on the same fixed point as plain TAA
/// (Theorem 2.2) while seeding the window from a cheap coarse pass:
///
/// ```
/// use parataa::model::{gmm::GmmEps, Cond};
/// use parataa::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
/// use parataa::solver::{self, DraftRefineConfig, Problem, SolveStrategy, SolverConfig};
///
/// let schedule = NoiseSchedule::new(BetaSchedule::Linear, 1000);
/// let model = GmmEps::sd_analog(schedule.alpha_bars.clone());
/// let coeffs = SamplerCoeffs::new(&schedule, SamplerKind::Ddim, 16);
/// let problem = Problem::new(&coeffs, &model, Cond::Class(0), 3);
///
/// let mut cfg = SolverConfig::parataa(16);
/// cfg.guidance = 2.0;
/// cfg.s_max = 64;
/// assert_eq!(cfg.strategy, SolveStrategy::PlainTaa); // the default
/// let plain = solver::solve(&problem, &cfg);
///
/// cfg.strategy = SolveStrategy::DraftRefine(DraftRefineConfig::default());
/// let draft = solver::solve(&problem, &cfg);
///
/// assert!(plain.converged && draft.converged);
/// // Same fixed point: the sample rows agree to solver tolerance.
/// for (a, b) in draft.xs.row(0).iter().zip(plain.xs.row(0)) {
///     assert!((a - b).abs() < 5e-2);
/// }
/// ```
#[derive(Debug, Clone, PartialEq, Default)]
pub enum SolveStrategy {
    /// Single-fidelity TAA rounds on the full grid — byte-for-byte the
    /// historical solver path.
    #[default]
    PlainTaa,
    /// Draft-and-refine: solve a coarse subset of the grid first, lift the
    /// result onto the fine grid as the window initialization, then run
    /// standard fine rounds.
    DraftRefine(DraftRefineConfig),
    /// Alternating coarse sweep + fine parallel correction over the active
    /// window (Self-Refining Parareal scheme).
    Parareal(PararealConfig),
}

impl SolveStrategy {
    /// Short display label used by benches, metrics and the CLI.
    pub fn label(&self) -> &'static str {
        match self {
            SolveStrategy::PlainTaa => "plain",
            SolveStrategy::DraftRefine(_) => "draft_refine",
            SolveStrategy::Parareal(_) => "parareal",
        }
    }

    /// True for the default single-fidelity path.
    pub fn is_plain(&self) -> bool {
        matches!(self, SolveStrategy::PlainTaa)
    }
}

/// Parameters of the [`SolveStrategy::DraftRefine`] draft phase. All
/// fields accept a zero sentinel meaning "derive from the fine problem",
/// so `Default` (all zeros) is the fully-automatic configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DraftRefineConfig {
    /// Coarse grid size C (number of coarse solver steps over the same
    /// schedule span). `0` ⇒ auto: `max(2, T / 4)`, clamped to `[1, T]`.
    pub coarse_steps: usize,
    /// Stopping tolerance of the draft solve. `0.0` ⇒ inherit the fine
    /// tolerance (`SolverConfig::tol`).
    pub coarse_tol: f64,
    /// Round budget of the draft solve. `0` ⇒ auto: `C + 1`, the
    /// Theorem 3.6 worst case for the coarse system.
    pub max_draft_rounds: usize,
}

impl DraftRefineConfig {
    /// Resolve the coarse grid size against a fine grid of `steps` rows.
    pub fn resolve_coarse_steps(&self, steps: usize) -> usize {
        let c = if self.coarse_steps == 0 { (steps / 4).max(2) } else { self.coarse_steps };
        c.clamp(1, steps)
    }

    /// Resolve the draft tolerance against the fine tolerance.
    pub fn resolve_tol(&self, fine_tol: f64) -> f64 {
        if self.coarse_tol > 0.0 {
            self.coarse_tol
        } else {
            fine_tol
        }
    }

    /// Resolve the draft round budget for a coarse grid of `coarse_steps`.
    pub fn resolve_rounds(&self, coarse_steps: usize) -> usize {
        if self.max_draft_rounds == 0 {
            coarse_steps + 1
        } else {
            self.max_draft_rounds
        }
    }
}

/// Parameters of the [`SolveStrategy::Parareal`] coarse sweeps. The zero
/// `Default` derives the stride from the window.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PararealConfig {
    /// Node stride of the coarse sweep: every `stride`-th window row is a
    /// Parareal node (the rows the sequential bridge pass writes). `0` ⇒
    /// auto: `max(2, w / 4)` against the configured window; any explicit
    /// value is clamped to ≥ 2 so the sweep can never touch the
    /// safeguarded row t2 (its first written node sits at `t2 + 1 −
    /// stride ≤ t2 − 1`).
    pub stride: usize,
}

impl PararealConfig {
    /// Resolve the node stride against a window of `window` rows.
    pub fn resolve_stride(&self, window: usize) -> usize {
        if self.stride == 0 {
            (window / 4).max(2)
        } else {
            self.stride.max(2)
        }
    }
}

/// Re-noise the two-point signal model between fine rows `lo` and `hi`
/// into every intermediate row strictly below `row_cap`.
///
/// Under the signal model x_r = √ᾱ_r·x0 + √(1−ᾱ_r)·ε, the states at the
/// segment ends determine the pair (x0, ε) uniquely (the 2×2 system has
/// determinant √ᾱ_lo·√(1−ᾱ_hi) − √ᾱ_hi·√(1−ᾱ_lo) > 0 whenever ᾱ_lo >
/// ᾱ_hi); each intermediate row is that pair re-noised to its own ᾱ.
/// Rows ≥ `row_cap` are left untouched — the Parareal sweep passes
/// `row_cap = t2` so the safeguarded row (and everything frozen above it)
/// is never rewritten, preserving the Theorem 3.6 front monotonicity.
pub fn interpolate_segment(
    fine_abar: &[f64],
    lo: usize,
    hi: usize,
    x_lo: &[f32],
    x_hi: &[f32],
    row_cap: usize,
    out: &mut States,
) {
    debug_assert!(lo < hi && hi < fine_abar.len());
    let d = out.d;
    debug_assert!(x_lo.len() == d && x_hi.len() == d);
    let (s_lo, n_lo) = (fine_abar[lo].sqrt(), (1.0 - fine_abar[lo]).max(0.0).sqrt());
    let (s_hi, n_hi) = (fine_abar[hi].sqrt(), (1.0 - fine_abar[hi]).max(0.0).sqrt());
    let det = s_lo * n_hi - s_hi * n_lo;
    for r in lo + 1..hi.min(row_cap) {
        let (s_r, n_r) = (fine_abar[r].sqrt(), (1.0 - fine_abar[r]).max(0.0).sqrt());
        let dst = out.row_mut(r);
        for i in 0..d {
            let (xl, xh) = (x_lo[i] as f64, x_hi[i] as f64);
            dst[i] = if det.abs() > 1e-9 {
                let x0 = (n_hi * xl - n_lo * xh) / det;
                let e = (s_lo * xh - s_hi * xl) / det;
                (s_r * x0 + n_r * e) as f32
            } else {
                // Degenerate segment (ᾱ barely moves): hold the cleaner
                // node's value.
                x_lo[i]
            };
        }
    }
}

/// Lift a solved coarse trajectory onto the fine state grid — the
/// draft-and-refine hand-off into the §4.2 warm-start path.
///
/// `fine_abar` is the fine grid's per-state ᾱ (length T+1, from
/// [`crate::schedule::SamplerCoeffs::state_alpha_bars`]); `idx0` maps
/// coarse state row c to its fine row (length C+1, from
/// [`crate::schedule::SamplerCoeffs::coarsen`]). Node rows transfer
/// bitwise; intermediate rows come from [`interpolate_segment`]. The fixed
/// row T (= ξ_T on both grids) is never written.
pub fn lift_trajectory(fine_abar: &[f64], coarse: &States, idx0: &[usize], out: &mut States) {
    let d = out.d;
    assert_eq!(coarse.d, d, "coarse/fine dimension mismatch");
    assert_eq!(coarse.rows(), idx0.len(), "one coarse row per node");
    let t_fine = fine_abar.len() - 1;
    assert_eq!(out.rows(), t_fine + 1, "fine trajectory length mismatch");
    for (c, &r) in idx0.iter().enumerate() {
        if r < t_fine {
            out.set_row(r, coarse.row(c));
        }
    }
    for c in 0..idx0.len() - 1 {
        let (lo, hi) = (idx0[c], idx0[c + 1]);
        if hi - lo >= 2 {
            interpolate_segment(fine_abar, lo, hi, coarse.row(c), coarse.row(c + 1), t_fine, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};

    #[test]
    fn config_resolution_defaults_and_clamps() {
        let dr = DraftRefineConfig::default();
        assert_eq!(dr.resolve_coarse_steps(50), 12);
        assert_eq!(dr.resolve_coarse_steps(4), 2); // floor of max(2, _)
        assert_eq!(dr.resolve_coarse_steps(2), 2);
        let explicit = DraftRefineConfig { coarse_steps: 99, ..Default::default() };
        assert_eq!(explicit.resolve_coarse_steps(8), 8); // clamped to T
        assert_eq!(dr.resolve_tol(1e-3), 1e-3);
        let loose = DraftRefineConfig { coarse_tol: 5e-3, ..Default::default() };
        assert_eq!(loose.resolve_tol(1e-3), 5e-3);
        assert_eq!(dr.resolve_rounds(12), 13);
        let capped = DraftRefineConfig { max_draft_rounds: 4, ..Default::default() };
        assert_eq!(capped.resolve_rounds(12), 4);

        let pr = PararealConfig::default();
        assert_eq!(pr.resolve_stride(16), 4);
        assert_eq!(pr.resolve_stride(4), 2);
        // Explicit strides below 2 would let the sweep write the
        // safeguarded row; they are clamped up.
        assert_eq!(PararealConfig { stride: 1 }.resolve_stride(16), 2);

        assert_eq!(SolveStrategy::default(), SolveStrategy::PlainTaa);
        assert!(SolveStrategy::PlainTaa.is_plain());
        assert_eq!(SolveStrategy::DraftRefine(dr).label(), "draft_refine");
        assert_eq!(SolveStrategy::Parareal(pr).label(), "parareal");
    }

    #[test]
    fn lift_is_exact_on_the_signal_model() {
        // If the coarse trajectory follows x = √ᾱ·x0 + √(1−ᾱ)·ε exactly,
        // the lift must reproduce the same model on every fine row.
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let fine = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 12);
        let (coarse, idx0) = fine.coarsen(4);
        assert_eq!(coarse.steps, 4);
        let abar = fine.state_alpha_bars();
        let d = 3;
        let x0 = [0.7f32, -0.3, 1.1];
        let e = [-0.2f32, 0.9, 0.4];
        let mut cs = States::zeros(coarse.steps, d);
        for (c, &r) in idx0.iter().enumerate() {
            let (s, n) = (abar[r].sqrt() as f32, (1.0 - abar[r]).sqrt() as f32);
            let row: Vec<f32> = (0..d).map(|i| s * x0[i] + n * e[i]).collect();
            cs.set_row(c, &row);
        }
        let sentinel = 77.0f32;
        let mut out = States { d, data: vec![sentinel; (fine.steps + 1) * d] };
        lift_trajectory(&abar, &cs, &idx0, &mut out);
        for r in 0..fine.steps {
            let (s, n) = (abar[r].sqrt(), (1.0 - abar[r]).sqrt());
            for i in 0..d {
                let want = (s * x0[i] as f64 + n * e[i] as f64) as f32;
                assert!(
                    (out.row(r)[i] - want).abs() < 1e-4,
                    "row {r} dim {i}: {} vs {want}",
                    out.row(r)[i]
                );
            }
        }
        // Node rows transfer bitwise; the fixed row T is never written.
        for (c, &r) in idx0.iter().enumerate() {
            if r < fine.steps {
                assert_eq!(out.row(r), cs.row(c), "node row {r} must be bitwise");
            }
        }
        assert!(out.row(fine.steps).iter().all(|&v| v == sentinel), "row T untouched");
    }

    #[test]
    fn interpolate_segment_respects_the_row_cap() {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let fine = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 10);
        let abar = fine.state_alpha_bars();
        let d = 2;
        let sentinel = -9.0f32;
        let mut out = States { d, data: vec![sentinel; 11 * d] };
        let x_lo = [1.0f32, 0.0];
        let x_hi = [0.0f32, 1.0];
        // Segment (2, 8) capped at row 5: rows 3,4 written; 5,6,7 untouched.
        interpolate_segment(&abar, 2, 8, &x_lo, &x_hi, 5, &mut out);
        for r in 3..5 {
            assert!(out.row(r).iter().all(|&v| v != sentinel), "row {r} written");
        }
        for r in 5..8 {
            assert!(out.row(r).iter().all(|&v| v == sentinel), "row {r} capped out");
        }
    }
}
