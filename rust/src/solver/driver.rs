//! Algorithm 1 — the blocking ParaTAA driver entry points.
//!
//! One iteration = one *parallel round*: a single batched ε_θ call over the
//! active window followed by the chosen update rule. The number of rounds is
//! the paper's "Steps" metric (Table 1); it is hardware-independent, unlike
//! wall-clock, and is what the reproduction pins against the paper.
//!
//! Since the session refactor all round mechanics (window sliding,
//! residual/convergence front, safeguard, Anderson history) live in
//! [`super::session::SolverSession`]; [`solve`]/[`solve_with`] are thin
//! wrappers that evaluate each pending ε batch on the problem's own model
//! and feed it back. Their output is **bit-identical** to the historical
//! blocking loop (golden-tested against a frozen copy of it in
//! `tests/golden_session.rs`).

use super::session::SolverSession;
use super::{Problem, SolverConfig};
use crate::equations::States;

/// Per-iteration diagnostics.
#[derive(Debug, Clone)]
pub struct IterationRecord {
    /// 1-based parallel round index.
    pub iter: usize,
    /// Active window at this round (producing rows, inclusive).
    pub t1: usize,
    pub t2: usize,
    /// ε_θ evaluations in this round (window + one-off frozen fills).
    pub nfe: usize,
    /// Σ over rows with known residuals of r_p (the Fig. 1/2 y-axis).
    pub residual_sum: f64,
    /// max over active rows of r_p / ε_p (≤ 1 ⇒ all active rows converged).
    pub max_residual_ratio: f64,
    /// Rows converged so far (T − front).
    pub converged_rows: usize,
    /// Per-row residuals r_p (NaN where never evaluated) — Fig. 6a data.
    pub row_residuals: Vec<f64>,
}

/// Result of a parallel solve.
pub struct SolveResult {
    /// Final trajectory x_0..x_T.
    pub xs: States,
    /// Parallel rounds used (the paper's "Steps").
    pub iterations: usize,
    /// Total ε_θ evaluations (the compute-cost axis).
    pub total_nfe: usize,
    /// Whether the stopping criterion was met for every row.
    pub converged: bool,
    /// Per-iteration history.
    pub records: Vec<IterationRecord>,
}

/// Solve with the default (no-op) observer.
pub fn solve(problem: &Problem, cfg: &SolverConfig) -> SolveResult {
    solve_with(problem, cfg, |_, _| false)
}

/// Solve, invoking `observer(record, xs)` after every round. Returning
/// `true` stops early (the §4.1 "user accepts the image" trick).
pub fn solve_with<F>(problem: &Problem, cfg: &SolverConfig, mut observer: F) -> SolveResult
where
    F: FnMut(&IterationRecord, &States) -> bool,
{
    let mut session = SolverSession::new(problem, cfg);
    let d = session.dim();
    let mut eps_out: Vec<f32> = Vec::new();
    loop {
        // Evaluate the pending ε batch on the problem's model — exactly the
        // values the historical in-loop call passed (same window rows, same
        // per-item conditions, same guidance), so the solve is bit-identical.
        let n = match session.pending() {
            None => break,
            Some(batch) => {
                eps_out.resize(batch.len() * d, 0.0);
                problem.model.eps_batch(
                    batch.x,
                    batch.t,
                    batch.conds,
                    batch.guidance,
                    &mut eps_out,
                );
                batch.len()
            }
        };
        let outcome = session.resume(&eps_out[..n * d]);
        let stop = observer(&outcome.record, session.xs());
        if outcome.done || stop {
            break;
        }
    }
    session.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::GmmEps;
    use crate::model::Cond;
    use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
    use crate::solver::sequential::sample_sequential;
    use crate::solver::{Method, SolveStrategy, WindowPolicy};
    use crate::util::proplite::{self, forall, size_in};
    use crate::util::rng::Pcg64;

    fn gmm(d: usize, n_comp: usize, seed: u64) -> GmmEps {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let mut rng = Pcg64::seeded(seed);
        let means: Vec<f32> = (0..n_comp * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        GmmEps::new(means, d, 0.25, ns.alpha_bars.clone())
    }

    fn coeffs(steps: usize, kind: SamplerKind) -> SamplerCoeffs {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        SamplerCoeffs::new(&ns, kind, steps)
    }

    /// Parallel ≡ sequential (Theorem 2.2 / Remark 5.3) for every method.
    #[test]
    fn parallel_matches_sequential_all_methods() {
        forall("parallel_eq_sequential", 6, |rng, case| {
            let steps = size_in(rng, 6, 16);
            let d = size_in(rng, 2, 6);
            let kind = if case % 2 == 0 { SamplerKind::Ddim } else { SamplerKind::Ddpm };
            let sc = coeffs(steps, kind);
            let model = gmm(d, 3, 100 + case);
            let problem = Problem::new(&sc, &model, Cond::Class(1), 7 + case);
            let seq = sample_sequential(&problem, 2.0);
            for method in [Method::FixedPoint, Method::AndersonStd, Method::AndersonUpperTri, Method::Taa] {
                let cfg = SolverConfig {
                    k: size_in(rng, 1, steps),
                    method,
                    m: 3,
                    lambda: 1e-4,
                    safeguard: true,
                    window: steps,
                    tol: 1e-5, // tight: near-exact match expected
                    s_max: 4 * steps,
                    guidance: 2.0,
                    clamp_boundary: true,
                    window_policy: WindowPolicy::Fixed,
                    strategy: SolveStrategy::PlainTaa,
                    parallelism: 1,
                };
                let par = solve(&problem, &cfg);
                if !par.converged {
                    return Err(format!("{} did not converge", method.label()));
                }
                proplite::assert_close(
                    par.xs.row(0),
                    seq.xs.row(0),
                    5e-3,
                    5e-2,
                    &format!("{} vs sequential (k={})", method.label(), cfg.k),
                )?;
            }
            Ok(())
        });
    }

    /// Theorem 3.6 / Song et al. Prop. 1: safeguarded methods converge in
    /// at most T parallel rounds (with full window).
    #[test]
    fn worst_case_t_rounds_with_safeguard() {
        forall("safeguard_T_rounds", 6, |rng, case| {
            let steps = size_in(rng, 4, 12);
            let d = size_in(rng, 2, 4);
            let sc = coeffs(steps, SamplerKind::Ddpm);
            let model = gmm(d, 2, 200 + case);
            let problem = Problem::new(&sc, &model, Cond::Class(0), case);
            for method in [Method::FixedPoint, Method::Taa] {
                let cfg = SolverConfig {
                    k: size_in(rng, 1, steps),
                    method,
                    m: 3,
                    lambda: 1e-4,
                    safeguard: true,
                    window: steps,
                    tol: 1e-4,
                    s_max: steps + 1, // T rounds + the final check round
                    guidance: 1.0,
                    clamp_boundary: true,
                    window_policy: WindowPolicy::Fixed,
                    strategy: SolveStrategy::PlainTaa,
                    parallelism: 1,
                };
                let r = solve(&problem, &cfg);
                if !r.converged {
                    return Err(format!(
                        "{} (k={}) exceeded T+1={} rounds",
                        method.label(),
                        cfg.k,
                        steps + 1
                    ));
                }
            }
            Ok(())
        });
    }

    /// The convergence front only advances (rows never un-freeze).
    #[test]
    fn front_is_monotone() {
        let sc = coeffs(20, SamplerKind::Ddim);
        let model = gmm(4, 3, 5);
        let problem = Problem::new(&sc, &model, Cond::Class(2), 3);
        let cfg = SolverConfig::parataa(20);
        let mut last = 0usize;
        let r = solve_with(&problem, &cfg, |rec, _| {
            assert!(rec.converged_rows >= last, "front went backwards");
            last = rec.converged_rows;
            false
        });
        assert!(r.converged);
    }

    /// TAA converges in (weakly) fewer rounds than plain FP on the same
    /// problem — the paper's headline ordering (Fig. 2).
    #[test]
    fn taa_not_slower_than_fp() {
        let steps = 24;
        let sc = coeffs(steps, SamplerKind::Ddim);
        let model = gmm(6, 4, 11);
        let problem = Problem::new(&sc, &model, Cond::Class(1), 9);
        let k = 6;
        let fp = solve(&problem, &SolverConfig {
            k,
            method: Method::FixedPoint,
            m: 1,
            lambda: 0.0,
            safeguard: false,
            window: steps,
            tol: 1e-3,
            s_max: 3 * steps,
            guidance: 2.0,
            clamp_boundary: true,
            window_policy: WindowPolicy::Fixed,
            strategy: SolveStrategy::PlainTaa,
            parallelism: 1,
        });
        let taa = solve(&problem, &SolverConfig {
            k,
            method: Method::Taa,
            m: 3,
            lambda: 1e-4,
            safeguard: true,
            window: steps,
            tol: 1e-3,
            s_max: 3 * steps,
            guidance: 2.0,
            clamp_boundary: true,
            window_policy: WindowPolicy::Fixed,
            strategy: SolveStrategy::PlainTaa,
            parallelism: 1,
        });
        assert!(fp.converged && taa.converged);
        assert!(
            taa.iterations <= fp.iterations,
            "TAA {} rounds vs FP {} rounds",
            taa.iterations,
            fp.iterations
        );
    }

    /// Sliding windows (w < T) still converge to the sequential solution.
    #[test]
    fn sliding_window_correct() {
        forall("sliding_window", 4, |rng, case| {
            let steps = 16;
            let d = 4;
            let sc = coeffs(steps, SamplerKind::Ddim);
            let model = gmm(d, 3, 300 + case);
            let problem = Problem::new(&sc, &model, Cond::Class(0), 40 + case);
            let seq = sample_sequential(&problem, 1.0);
            let w = size_in(rng, 2, 8);
            let cfg = SolverConfig {
                k: 4,
                method: Method::Taa,
                m: 3,
                lambda: 1e-4,
                safeguard: true,
                window: w,
                tol: 1e-5,
                s_max: 20 * steps,
                guidance: 1.0,
                clamp_boundary: true,
                window_policy: WindowPolicy::Fixed,
                strategy: SolveStrategy::PlainTaa,
                parallelism: 1,
            };
            let par = solve(&problem, &cfg);
            if !par.converged {
                return Err(format!("w={w} did not converge"));
            }
            proplite::assert_close(par.xs.row(0), seq.xs.row(0), 5e-3, 5e-2, "windowed")
        });
    }

    /// Early-stop observer halts the solve.
    #[test]
    fn observer_can_stop() {
        let sc = coeffs(30, SamplerKind::Ddim);
        let model = gmm(4, 2, 8);
        let problem = Problem::new(&sc, &model, Cond::Class(0), 1);
        let cfg = SolverConfig::parataa(30);
        let r = solve_with(&problem, &cfg, |rec, _| rec.iter >= 3);
        assert_eq!(r.iterations, 3);
        assert!(!r.converged);
    }

    /// Trajectory init (§4.2): starting from the solved trajectory of the
    /// *same* problem converges immediately (1 round).
    #[test]
    fn init_from_own_solution_converges_immediately() {
        let sc = coeffs(20, SamplerKind::Ddim);
        let model = gmm(5, 3, 6);
        let mut problem = Problem::new(&sc, &model, Cond::Class(1), 77);
        let cfg = SolverConfig { tol: 1e-4, ..SolverConfig::parataa(20) };
        let first = solve(&problem, &cfg);
        assert!(first.converged);
        problem.init = Some(first.xs.clone());
        let again = solve(&problem, &cfg);
        assert!(again.converged);
        assert_eq!(again.iterations, 1, "warm restart should converge in one round");
    }

    /// NFE accounting: full-window FP does ≈ (w+. . .) evals per round.
    #[test]
    fn nfe_accounting() {
        let steps = 10;
        let sc = coeffs(steps, SamplerKind::Ddim);
        let model = gmm(3, 2, 2);
        let problem = Problem::new(&sc, &model, Cond::Class(0), 5);
        let cfg = SolverConfig::fp_baseline(steps);
        let r = solve(&problem, &cfg);
        assert!(r.converged);
        assert_eq!(
            r.total_nfe,
            r.records.iter().map(|rec| rec.nfe).sum::<usize>()
        );
        // First round evaluates the full window [t1+1, T].
        assert_eq!(r.records[0].nfe, steps);
    }
}
