//! Algorithm 1 — the ParaTAA driver.
//!
//! One iteration = one *parallel round*: a single batched ε_θ call over the
//! active window followed by the chosen update rule. The number of rounds is
//! the paper's "Steps" metric (Table 1); it is hardware-independent, unlike
//! wall-clock, and is what the reproduction pins against the paper.
//!
//! Window/stopping mechanics follow §2.1–2.2: first-order residuals with
//! thresholds ε_t = τ²g²(t)d decide the convergence *front* (states freeze
//! strictly from the top down — the triangular structure guarantees states
//! above the front can no longer change), and the active window [t1, t2]
//! slides down as the front advances.

use super::history::History;
use super::update::apply_update;
use super::{Method, Problem, SolverConfig};
use crate::equations::{eval_fk, residual_sq, States};
use crate::model::Cond;

/// Per-iteration diagnostics.
#[derive(Debug, Clone)]
pub struct IterationRecord {
    /// 1-based parallel round index.
    pub iter: usize,
    /// Active window at this round (producing rows, inclusive).
    pub t1: usize,
    pub t2: usize,
    /// ε_θ evaluations in this round (window + one-off frozen fills).
    pub nfe: usize,
    /// Σ over rows with known residuals of r_p (the Fig. 1/2 y-axis).
    pub residual_sum: f64,
    /// max over active rows of r_p / ε_p (≤ 1 ⇒ all active rows converged).
    pub max_residual_ratio: f64,
    /// Rows converged so far (T − front).
    pub converged_rows: usize,
    /// Per-row residuals r_p (NaN where never evaluated) — Fig. 6a data.
    pub row_residuals: Vec<f64>,
}

/// Result of a parallel solve.
pub struct SolveResult {
    /// Final trajectory x_0..x_T.
    pub xs: States,
    /// Parallel rounds used (the paper's "Steps").
    pub iterations: usize,
    /// Total ε_θ evaluations (the compute-cost axis).
    pub total_nfe: usize,
    /// Whether the stopping criterion was met for every row.
    pub converged: bool,
    /// Per-iteration history.
    pub records: Vec<IterationRecord>,
}

/// Solve with the default (no-op) observer.
pub fn solve(problem: &Problem, cfg: &SolverConfig) -> SolveResult {
    solve_with(problem, cfg, |_, _| false)
}

/// Solve, invoking `observer(record, xs)` after every round. Returning
/// `true` stops early (the §4.1 "user accepts the image" trick).
pub fn solve_with<F>(problem: &Problem, cfg: &SolverConfig, mut observer: F) -> SolveResult
where
    F: FnMut(&IterationRecord, &States) -> bool,
{
    let coeffs = problem.coeffs;
    let model = problem.model;
    let t_count = coeffs.steps;
    let d = model.dim();
    let k = cfg.k.clamp(1, t_count);
    let w = cfg.window.clamp(1, t_count);
    let t_init = problem.t_init.unwrap_or(t_count).clamp(1, t_count);

    // --- State ------------------------------------------------------------
    let mut xs = States::zeros(t_count, d);
    xs.set_row(t_count, problem.xi.row(t_count));
    match (&problem.init, t_init) {
        (Some(init), _) => {
            assert_eq!(init.d, d, "init trajectory dimension mismatch");
            assert_eq!(init.rows(), t_count + 1, "init trajectory length mismatch");
            xs.data[..t_count * d].copy_from_slice(&init.data[..t_count * d]);
        }
        (None, _) => {
            // Standard-Gaussian initialization of all unknowns (§5.1).
            let mut rng = crate::util::rng::Pcg64::new(problem.init_seed(), 0x1717_c0de);
            rng.fill_gaussian(&mut xs.data[..t_count * d]);
        }
    }

    let mut eps = States::zeros(t_count, d);
    let mut eps_valid = vec![false; t_count + 1];

    // Anderson history: paper's m counts the iterate window, so m−1
    // difference columns (m = 1 ⇒ plain FP; Appendix C).
    let hist_cols = if cfg.method == Method::FixedPoint { 0 } else { cfg.m.saturating_sub(1) };
    let mut history = History::new(hist_cols, t_count, d);
    let mut prev_x = vec![0.0f32; t_count * d];
    let mut prev_r = vec![0.0f32; t_count * d];
    let mut prev_active: Option<(usize, usize)> = None;

    // Reusable per-round buffers (no allocation in the hot loop).
    let mut f_vals = vec![0.0f32; t_count * d];
    let mut r_vals = vec![0.0f32; t_count * d];
    let mut dx_buf = vec![0.0f32; t_count * d];
    let mut df_buf = vec![0.0f32; t_count * d];
    let mut batch_x: Vec<f32> = Vec::new();
    let mut batch_t: Vec<usize> = Vec::new();
    // Pre-cloned condition pool: one request has one condition, so avoid
    // re-cloning (potentially heap-backed) `Cond`s every round (§Perf L3).
    let cond_pool: Vec<Cond> = vec![problem.cond.clone(); t_count + 1];
    let mut batch_out: Vec<f32> = Vec::new();

    let mut last_residual: Vec<Option<f64>> = vec![None; t_count];
    let thresholds: Vec<f64> = (0..t_count).map(|p| coeffs.threshold(p, cfg.tol, d)).collect();

    let mut batch_states: Vec<usize> = Vec::new();
    let mut t2 = t_init - 1;
    let mut t1 = (t2 + 1).saturating_sub(w);
    let mut total_nfe = 0usize;
    let mut records: Vec<IterationRecord> = Vec::new();
    let mut converged = false;

    for iter in 1..=cfg.s_max {
        // --- 1. Batched ε_θ over the active window (one parallel round) ----
        batch_x.clear();
        batch_t.clear();
        batch_states.clear();
        // Equations are clamped at the boundary state t2+1 (see
        // `equations::eval_fk`), so only states [t1+1, t2+1] are needed; the
        // boundary state is frozen and served from the cache once filled.
        let top_needed = (t2 + 1).min(t_count);
        for j in t1 + 1..=top_needed {
            let active = j <= t2;
            if active || !eps_valid[j] {
                batch_states.push(j);
                batch_x.extend_from_slice(xs.row(j));
                batch_t.push(coeffs.train_t[j]);
            }
        }
        batch_out.resize(batch_states.len() * d, 0.0);
        model.eps_batch(
            &batch_x,
            &batch_t,
            &cond_pool[..batch_states.len()],
            cfg.guidance,
            &mut batch_out,
        );
        total_nfe += batch_states.len();
        for (bi, &j) in batch_states.iter().enumerate() {
            eps.set_row(j, &batch_out[bi * d..(bi + 1) * d]);
            eps_valid[j] = true;
        }

        // --- 2. Residuals + convergence front (§2.1) -----------------------
        for p in t1..=t2 {
            last_residual[p] = Some(residual_sq(coeffs, &xs, &eps, &problem.xi, p));
        }
        let mut new_t2: Option<usize> = None;
        for p in (t1..=t2).rev() {
            if last_residual[p].unwrap() > thresholds[p] {
                new_t2 = Some(p);
                break;
            }
        }
        let residual_sum: f64 = last_residual.iter().flatten().sum();
        let max_ratio = (t1..=t2)
            .map(|p| last_residual[p].unwrap() / thresholds[p])
            .fold(0.0f64, f64::max);

        let (nt1, nt2, done) = match new_t2 {
            None if t1 == 0 => (t1, t2, true),
            None => {
                // Whole window converged; slide below it.
                let nt2 = t1 - 1;
                ((nt2 + 1).saturating_sub(w), nt2, false)
            }
            Some(nt2) => ((nt2 + 1).saturating_sub(w), nt2, false),
        };

        let row_residuals: Vec<f64> =
            last_residual.iter().map(|r| r.unwrap_or(f64::NAN)).collect();

        if done {
            converged = true;
            let rec = IterationRecord {
                iter,
                t1,
                t2,
                nfe: batch_states.len(),
                residual_sum,
                max_residual_ratio: max_ratio,
                converged_rows: t_count,
                row_residuals,
            };
            observer(&rec, &xs);
            records.push(rec);
            break;
        }
        t1 = nt1;
        t2 = nt2;

        // --- 3. F^{(k)} and residual vectors over the (new) window ---------
        // First frozen state; without the clamp the equations reach across
        // the front (Definition 2.1 verbatim) — kept only for `ablate`.
        let boundary = if cfg.clamp_boundary { t2 + 1 } else { t_count };
        r_vals.fill(0.0);
        for p in t1..=t2 {
            let row = p * d..(p + 1) * d;
            eval_fk(coeffs, &xs, &eps, &problem.xi, k, boundary, p, &mut f_vals[row.clone()]);
            for i in row.clone() {
                r_vals[i] = f_vals[i] - xs.data[i];
            }
        }

        // --- 4. Anderson history push (Δx^{i-1}, ΔR^{i-1}) ------------------
        if hist_cols > 0 {
            if let Some((p1, p2)) = prev_active {
                dx_buf.fill(0.0);
                df_buf.fill(0.0);
                let lo = t1.max(p1);
                let hi = t2.min(p2);
                if lo <= hi {
                    for i in lo * d..(hi + 1) * d {
                        dx_buf[i] = xs.data[i] - prev_x[i];
                        df_buf[i] = r_vals[i] - prev_r[i];
                    }
                    history.push(&dx_buf, &df_buf);
                }
            }
            prev_x.copy_from_slice(&xs.data[..t_count * d]);
            prev_r.copy_from_slice(&r_vals);
            prev_active = Some((t1, t2));
        }

        // --- 5. Update rule -------------------------------------------------
        apply_update(
            cfg.method,
            &mut xs.data[..t_count * d],
            &f_vals,
            &r_vals,
            &history,
            t1,
            t2,
            t_count,
            d,
            cfg.lambda,
            cfg.safeguard,
        );

        let rec = IterationRecord {
            iter,
            t1,
            t2,
            nfe: batch_states.len(),
            residual_sum,
            max_residual_ratio: max_ratio,
            converged_rows: t_count - (t2 + 1),
            row_residuals,
        };
        let stop = observer(&rec, &xs);
        records.push(rec);
        if stop {
            break;
        }
    }

    let iterations = records.len();
    SolveResult { xs, iterations, total_nfe, converged, records }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::GmmEps;
    use crate::model::Cond;
    use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
    use crate::solver::sequential::sample_sequential;
    use crate::util::proplite::{self, forall, size_in};
    use crate::util::rng::Pcg64;

    fn gmm(d: usize, n_comp: usize, seed: u64) -> GmmEps {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let mut rng = Pcg64::seeded(seed);
        let means: Vec<f32> = (0..n_comp * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        GmmEps::new(means, d, 0.25, ns.alpha_bars.clone())
    }

    fn coeffs(steps: usize, kind: SamplerKind) -> SamplerCoeffs {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        SamplerCoeffs::new(&ns, kind, steps)
    }

    /// Parallel ≡ sequential (Theorem 2.2 / Remark 5.3) for every method.
    #[test]
    fn parallel_matches_sequential_all_methods() {
        forall("parallel_eq_sequential", 6, |rng, case| {
            let steps = size_in(rng, 6, 16);
            let d = size_in(rng, 2, 6);
            let kind = if case % 2 == 0 { SamplerKind::Ddim } else { SamplerKind::Ddpm };
            let sc = coeffs(steps, kind);
            let model = gmm(d, 3, 100 + case);
            let problem = Problem::new(&sc, &model, Cond::Class(1), 7 + case);
            let seq = sample_sequential(&problem, 2.0);
            for method in [Method::FixedPoint, Method::AndersonStd, Method::AndersonUpperTri, Method::Taa] {
                let cfg = SolverConfig {
                    k: size_in(rng, 1, steps),
                    method,
                    m: 3,
                    lambda: 1e-4,
                    safeguard: true,
                    window: steps,
                    tol: 1e-5, // tight: near-exact match expected
                    s_max: 4 * steps,
                    guidance: 2.0,
                    clamp_boundary: true,
                };
                let par = solve(&problem, &cfg);
                if !par.converged {
                    return Err(format!("{} did not converge", method.label()));
                }
                proplite::assert_close(
                    par.xs.row(0),
                    seq.xs.row(0),
                    5e-3,
                    5e-2,
                    &format!("{} vs sequential (k={})", method.label(), cfg.k),
                )?;
            }
            Ok(())
        });
    }

    /// Theorem 3.6 / Song et al. Prop. 1: safeguarded methods converge in
    /// at most T parallel rounds (with full window).
    #[test]
    fn worst_case_t_rounds_with_safeguard() {
        forall("safeguard_T_rounds", 6, |rng, case| {
            let steps = size_in(rng, 4, 12);
            let d = size_in(rng, 2, 4);
            let sc = coeffs(steps, SamplerKind::Ddpm);
            let model = gmm(d, 2, 200 + case);
            let problem = Problem::new(&sc, &model, Cond::Class(0), case);
            for method in [Method::FixedPoint, Method::Taa] {
                let cfg = SolverConfig {
                    k: size_in(rng, 1, steps),
                    method,
                    m: 3,
                    lambda: 1e-4,
                    safeguard: true,
                    window: steps,
                    tol: 1e-4,
                    s_max: steps + 1, // T rounds + the final check round
                    guidance: 1.0,
                    clamp_boundary: true,
                };
                let r = solve(&problem, &cfg);
                if !r.converged {
                    return Err(format!(
                        "{} (k={}) exceeded T+1={} rounds",
                        method.label(),
                        cfg.k,
                        steps + 1
                    ));
                }
            }
            Ok(())
        });
    }

    /// The convergence front only advances (rows never un-freeze).
    #[test]
    fn front_is_monotone() {
        let sc = coeffs(20, SamplerKind::Ddim);
        let model = gmm(4, 3, 5);
        let problem = Problem::new(&sc, &model, Cond::Class(2), 3);
        let cfg = SolverConfig::parataa(20);
        let mut last = 0usize;
        let r = solve_with(&problem, &cfg, |rec, _| {
            assert!(rec.converged_rows >= last, "front went backwards");
            last = rec.converged_rows;
            false
        });
        assert!(r.converged);
    }

    /// TAA converges in (weakly) fewer rounds than plain FP on the same
    /// problem — the paper's headline ordering (Fig. 2).
    #[test]
    fn taa_not_slower_than_fp() {
        let steps = 24;
        let sc = coeffs(steps, SamplerKind::Ddim);
        let model = gmm(6, 4, 11);
        let problem = Problem::new(&sc, &model, Cond::Class(1), 9);
        let k = 6;
        let fp = solve(&problem, &SolverConfig {
            k,
            method: Method::FixedPoint,
            m: 1,
            lambda: 0.0,
            safeguard: false,
            window: steps,
            tol: 1e-3,
            s_max: 3 * steps,
            guidance: 2.0,
            clamp_boundary: true,
        });
        let taa = solve(&problem, &SolverConfig {
            k,
            method: Method::Taa,
            m: 3,
            lambda: 1e-4,
            safeguard: true,
            window: steps,
            tol: 1e-3,
            s_max: 3 * steps,
            guidance: 2.0,
            clamp_boundary: true,
        });
        assert!(fp.converged && taa.converged);
        assert!(
            taa.iterations <= fp.iterations,
            "TAA {} rounds vs FP {} rounds",
            taa.iterations,
            fp.iterations
        );
    }

    /// Sliding windows (w < T) still converge to the sequential solution.
    #[test]
    fn sliding_window_correct() {
        forall("sliding_window", 4, |rng, case| {
            let steps = 16;
            let d = 4;
            let sc = coeffs(steps, SamplerKind::Ddim);
            let model = gmm(d, 3, 300 + case);
            let problem = Problem::new(&sc, &model, Cond::Class(0), 40 + case);
            let seq = sample_sequential(&problem, 1.0);
            let w = size_in(rng, 2, 8);
            let cfg = SolverConfig {
                k: 4,
                method: Method::Taa,
                m: 3,
                lambda: 1e-4,
                safeguard: true,
                window: w,
                tol: 1e-5,
                s_max: 20 * steps,
                guidance: 1.0,
                clamp_boundary: true,
            };
            let par = solve(&problem, &cfg);
            if !par.converged {
                return Err(format!("w={w} did not converge"));
            }
            proplite::assert_close(par.xs.row(0), seq.xs.row(0), 5e-3, 5e-2, "windowed")
        });
    }

    /// Early-stop observer halts the solve.
    #[test]
    fn observer_can_stop() {
        let sc = coeffs(30, SamplerKind::Ddim);
        let model = gmm(4, 2, 8);
        let problem = Problem::new(&sc, &model, Cond::Class(0), 1);
        let cfg = SolverConfig::parataa(30);
        let r = solve_with(&problem, &cfg, |rec, _| rec.iter >= 3);
        assert_eq!(r.iterations, 3);
        assert!(!r.converged);
    }

    /// Trajectory init (§4.2): starting from the solved trajectory of the
    /// *same* problem converges immediately (1 round).
    #[test]
    fn init_from_own_solution_converges_immediately() {
        let sc = coeffs(20, SamplerKind::Ddim);
        let model = gmm(5, 3, 6);
        let mut problem = Problem::new(&sc, &model, Cond::Class(1), 77);
        let cfg = SolverConfig { tol: 1e-4, ..SolverConfig::parataa(20) };
        let first = solve(&problem, &cfg);
        assert!(first.converged);
        problem.init = Some(first.xs.clone());
        let again = solve(&problem, &cfg);
        assert!(again.converged);
        assert_eq!(again.iterations, 1, "warm restart should converge in one round");
    }

    /// NFE accounting: full-window FP does ≈ (w+. . .) evals per round.
    #[test]
    fn nfe_accounting() {
        let steps = 10;
        let sc = coeffs(steps, SamplerKind::Ddim);
        let model = gmm(3, 2, 2);
        let problem = Problem::new(&sc, &model, Cond::Class(0), 5);
        let cfg = SolverConfig::fp_baseline(steps);
        let r = solve(&problem, &cfg);
        assert!(r.converged);
        assert_eq!(
            r.total_nfe,
            r.records.iter().map(|rec| rec.nfe).sum::<usize>()
        );
        // First round evaluates the full window [t1+1, T].
        assert_eq!(r.records[0].nfe, steps);
    }
}
