//! Adaptive sliding-window control — closing the loop between the
//! convergence front and device occupancy.
//!
//! The paper treats the window size w (§2.2) as a static hyperparameter:
//! bigger windows finish in fewer parallel rounds but spend more ε_θ
//! evaluations and more accelerator memory per round (the ParaDiGMS
//! sliding-window trade-off of Shih et al., 2023, reproduced in fig4).
//! In a serving deployment that trade-off is *dynamic*: when the pool has
//! idle capacity, a solve should widen its window and convert spare
//! compute into lookahead; when the pool saturates, narrower windows cut
//! the speculative lookahead rows and free device time for other
//! requests' rounds, at a modest round-count cost.
//!
//! [`WindowController`] implements that policy as a small per-session
//! state machine driven by two signals observed every parallel round:
//!
//! - **convergence velocity** — rows newly frozen by the residual front
//!   this round (Theorem 3.6's safeguard guarantees ≥ 1 once the solve is
//!   under way). A front eating a large fraction of the window per round
//!   means the window is *starving* — growing it turns otherwise-idle
//!   device capacity into useful lookahead rows.
//! - **device occupancy** — a [0, 1] pressure signal fed by the caller
//!   (the coordinator's round drivers derive it from the attached
//!   [`crate::runtime::DevicePool`] stats; it stays 0 — velocity-only
//!   sizing — when nothing supplies it). Above
//!   [`AdaptiveWindow::high_occupancy`] the controller shrinks toward
//!   [`AdaptiveWindow::min_window`].
//!
//! The policy is selected per solve via [`WindowPolicy`] on
//! [`super::SolverConfig`]; the default [`WindowPolicy::Fixed`] leaves the
//! historical static-w behavior bit-identical (golden-tested in
//! `tests/golden_session.rs`).

/// How a solve sizes its sliding window across parallel rounds.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum WindowPolicy {
    /// Static window: `SolverConfig::window` for the whole solve (the
    /// paper's §2.2 setup, and the default — bit-identical to the
    /// pre-controller solver).
    #[default]
    Fixed,
    /// Grow/shrink the window each round from convergence velocity and
    /// device occupancy, within the configured bounds.
    Adaptive(AdaptiveWindow),
}

/// Tuning for [`WindowPolicy::Adaptive`].
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveWindow {
    /// Smallest window the controller will shrink to (≥ 1; clamped to T).
    pub min_window: usize,
    /// Largest window the controller will grow to (clamped to T). This is
    /// also the slot-budget footprint the coordinator reserves for the
    /// session ([`super::SolverConfig::max_window_rows`]).
    pub max_window: usize,
    /// Rows added/removed per grow/shrink decision.
    pub step: usize,
    /// Occupancy above which the pool is considered saturated and the
    /// window shrinks (typical: 0.85).
    pub high_occupancy: f64,
    /// Grow when the front froze at least this fraction of the current
    /// window in one round (typical: 0.25) — the window is converging
    /// faster than it slides, so lookahead rows are cheap.
    pub grow_velocity: f64,
}

impl AdaptiveWindow {
    /// Defaults scaled to a `steps`-step trajectory: start bounds at
    /// `[steps/8, steps]` with `steps/8`-row moves.
    pub fn for_steps(steps: usize) -> Self {
        AdaptiveWindow {
            min_window: (steps / 8).max(2).min(steps.max(1)),
            max_window: steps.max(1),
            step: (steps / 8).max(1),
            high_occupancy: 0.85,
            grow_velocity: 0.25,
        }
    }
}

/// Per-session adaptive window state machine (owned by a
/// [`super::SolverSession`] when its config selects
/// [`WindowPolicy::Adaptive`]).
#[derive(Debug, Clone)]
pub struct WindowController {
    cfg: AdaptiveWindow,
    /// Latest external pressure signal in [0, 1]; 0 (idle) until the
    /// caller reports otherwise, so standalone solves grow freely.
    occupancy: f64,
}

impl WindowController {
    /// Build a controller for a `t_count`-row trajectory; the configured
    /// bounds are clamped to `[1, t_count]` and ordered.
    pub fn new(mut cfg: AdaptiveWindow, t_count: usize) -> Self {
        let t = t_count.max(1);
        cfg.min_window = cfg.min_window.clamp(1, t);
        cfg.max_window = cfg.max_window.clamp(cfg.min_window, t);
        cfg.step = cfg.step.max(1);
        WindowController { cfg, occupancy: 0.0 }
    }

    /// Record the latest device-occupancy signal (clamped to [0, 1]).
    pub fn set_occupancy(&mut self, occupancy: f64) {
        self.occupancy = if occupancy.is_finite() { occupancy.clamp(0.0, 1.0) } else { 0.0 };
    }

    /// Latest occupancy signal the controller is acting on.
    pub fn occupancy(&self) -> f64 {
        self.occupancy
    }

    /// Clamp a starting window into the controller's bounds.
    pub fn clamp(&self, w: usize) -> usize {
        w.clamp(self.cfg.min_window, self.cfg.max_window)
    }

    /// One per-round decision: given how many rows the residual front
    /// froze this round and the current window, return the window for the
    /// next round. Saturated pool ⇒ shrink; fast front + spare capacity ⇒
    /// grow; otherwise hold.
    pub fn decide(&mut self, newly_converged: usize, w: usize) -> usize {
        let w = self.clamp(w);
        if self.occupancy > self.cfg.high_occupancy {
            return w.saturating_sub(self.cfg.step).max(self.cfg.min_window);
        }
        if (newly_converged as f64) >= self.cfg.grow_velocity * w as f64 {
            return (w + self.cfg.step).min(self.cfg.max_window);
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdaptiveWindow {
        AdaptiveWindow {
            min_window: 4,
            max_window: 32,
            step: 4,
            high_occupancy: 0.85,
            grow_velocity: 0.25,
        }
    }

    #[test]
    fn defaults_are_sane() {
        let a = AdaptiveWindow::for_steps(50);
        assert!(a.min_window >= 1 && a.min_window <= a.max_window);
        assert_eq!(a.max_window, 50);
        assert!(a.step >= 1);
        // Degenerate step counts stay in range.
        let tiny = AdaptiveWindow::for_steps(1);
        assert!(tiny.min_window >= 1 && tiny.min_window <= tiny.max_window);
        assert_eq!(WindowPolicy::default(), WindowPolicy::Fixed);
    }

    #[test]
    fn grows_on_fast_convergence() {
        let mut c = WindowController::new(cfg(), 100);
        // 4 of 16 rows froze (= grow_velocity): grow by one step.
        assert_eq!(c.decide(4, 16), 20);
        // Slow front: hold.
        assert_eq!(c.decide(1, 16), 16);
        // Growth saturates at max_window.
        assert_eq!(c.decide(32, 32), 32);
    }

    #[test]
    fn shrinks_under_occupancy_pressure() {
        let mut c = WindowController::new(cfg(), 100);
        c.set_occupancy(0.95);
        assert_eq!(c.decide(8, 16), 12);
        // Shrink saturates at min_window.
        assert_eq!(c.decide(8, 4), 4);
        // Pressure released: fast front grows again.
        c.set_occupancy(0.2);
        assert_eq!(c.decide(8, 12), 16);
    }

    #[test]
    fn bounds_clamp_to_trajectory_length() {
        let c = WindowController::new(cfg(), 10);
        assert_eq!(c.clamp(100), 10);
        assert_eq!(c.clamp(1), 4);
        // min > t_count degenerates to [t, t].
        let c = WindowController::new(
            AdaptiveWindow { min_window: 64, max_window: 128, ..cfg() },
            10,
        );
        assert_eq!(c.clamp(3), 10);
    }

    #[test]
    fn non_finite_occupancy_is_ignored() {
        let mut c = WindowController::new(cfg(), 100);
        c.set_occupancy(f64::NAN);
        assert_eq!(c.occupancy(), 0.0);
        c.set_occupancy(7.0);
        assert_eq!(c.occupancy(), 1.0);
    }
}
