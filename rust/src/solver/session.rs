//! Resumable solver sessions — Algorithm 1 as an event-driven state machine.
//!
//! The paper's Algorithm 1 is one *parallel round* per iteration: a single
//! batched ε_θ call over the active window, followed by the update rule and
//! the window slide. [`SolverSession`] makes that round boundary a
//! first-class API instead of the interior of a blocking loop:
//!
//! ```text
//!   SolverSession::new(problem, cfg)
//!        │
//!        ▼
//!   pending() ──► EpsBatch { x, t, conds, guidance }   (the round's ε job)
//!        │                         │
//!        │          caller evaluates ε_θ — directly, through a
//!        │          [`crate::coordinator::Batcher`], or merged with other
//!        │          sessions' batches into one device call
//!        ▼                         │
//!   resume(eps_out) ◄──────────────┘
//!        │  ──► RoundOutcome { record, done }
//!        ▼
//!   ... repeat until done, then finish() ──► SolveResult
//! ```
//!
//! All window-sliding, residual/convergence-front, safeguard and
//! Anderson-history logic lives here; [`super::driver::solve`] and
//! [`super::driver::solve_with`] are thin wrappers whose output is
//! **bit-identical** to the historical blocking driver (golden-tested in
//! `tests/golden_session.rs`).
//!
//! Because a session never touches the model itself — it only *emits* ε
//! jobs and *consumes* their results — hundreds of sessions can be carried
//! by a handful of round-driver threads that merge their pending batches
//! into single device calls (see `coordinator/server.rs`). This is the
//! continuous-batching shape serving systems use for autoregressive loops,
//! applied to parallel diffusion rounds, and the substrate for
//! draft-and-refine / Parareal-style schemes that interleave rounds across
//! requests.
//!
//! Two optional hooks ride on the round boundary (both inert unless used —
//! the default path stays bit-identical):
//!
//! - [`SolverSession::progress`] reports each advance of the residual
//!   front as a [`FrontAdvance`] — the rows above the front are *final*,
//!   so a serving layer can stream the converged prefix to the client
//!   while the rest of the solve is still running;
//! - [`super::WindowPolicy::Adaptive`] hands the per-round window sizing
//!   to a [`super::WindowController`] driven by convergence velocity and
//!   the device occupancy reported via [`SolverSession::set_occupancy`].

use super::driver::{IterationRecord, SolveResult};
use super::history::History;
use super::strategy::{interpolate_segment, lift_trajectory, SolveStrategy};
use super::update::apply_update_par;
use super::window_ctrl::{WindowController, WindowPolicy};
use super::workspace::Workspace;
use super::{Problem, SolverConfig};
use crate::equations::{bridge_coeffs, eval_fk, residual_sq, States};
use crate::model::Cond;
use crate::schedule::SamplerCoeffs;
use crate::trace::{self, Layer, Name};
use crate::util::threadpool::{chunk_range, RowPool, SyncSlice};

/// One pending ε job: the batched denoiser evaluation the session needs
/// before its next [`SolverSession::resume`]. Slices borrow the session's
/// internal (reused) buffers; callers copy them into merged device calls.
#[derive(Debug)]
pub struct EpsBatch<'s> {
    /// Flattened `[len, d]` row-major stack of window states.
    pub x: &'s [f32],
    /// Per-item training timesteps.
    pub t: &'s [usize],
    /// Per-item conditions (all equal to the session's condition).
    pub conds: &'s [Cond],
    /// Classifier-free guidance scale — a scalar graph input, so batches
    /// from sessions with equal guidance merge bit-exactly.
    pub guidance: f32,
}

impl EpsBatch<'_> {
    /// Number of items (window rows) in this batch. May be zero: a round
    /// whose window is fully served from the ε cache still advances.
    pub fn len(&self) -> usize {
        self.t.len()
    }

    /// True when the round needs no fresh ε evaluations.
    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }
}

/// An advance of the residual/convergence front since the last
/// [`SolverSession::progress`] call.
///
/// The triangular structure (Definition 2.1) makes the front monotone:
/// once a row's residual drops below its threshold it is frozen and never
/// rewritten, so the rows in `newly_converged` already hold their *final*
/// states — a serving layer can deliver them to the client immediately,
/// long before the full solve finishes (streaming prefix delivery).
///
/// Observing progress never perturbs the solve: `progress()` only reads
/// solver state plus a report cursor, so an unobserved session is
/// bit-identical to the historical path.
#[derive(Debug, Clone)]
pub struct FrontAdvance {
    /// State-row indices `[start, end)` that newly crossed the front. Row
    /// indices count *down* toward the final sample x_0, so successive
    /// advances tile `[0, T)` from the top (the x_T side — the earliest
    /// denoising timesteps) downward.
    pub newly_converged: std::ops::Range<usize>,
    /// Last measured residuals of those rows, in `newly_converged` order
    /// (`NaN` for rows frozen by a §4.2 warm start before any evaluation).
    pub residuals: Vec<f64>,
}

/// Multi-fidelity phase state (`None` under [`SolveStrategy::PlainTaa`] —
/// that path is byte-for-byte the single-fidelity solver). Boxed on the
/// session so the plain path pays one pointer of storage.
enum Fidelity {
    /// Draft phase of [`SolveStrategy::DraftRefine`]: a nested PlainTaa
    /// session solves the coarsened grid; when it finishes, its trajectory
    /// is lifted onto the fine grid as the window initialization (the same
    /// hand-off as a §4.2 warm start) and the fine phase runs the plain
    /// path.
    Draft {
        /// The coarse solve. Shares the outer guidance, so its ε batches
        /// co-batch with fine sessions' in the coordinator's merge path.
        session: SolverSession,
        /// Coarse-node → fine-row map from `SamplerCoeffs::coarsen`.
        idx0: Vec<usize>,
        /// Fine per-state ᾱ for the lift.
        abar: Vec<f64>,
    },
    /// [`SolveStrategy::Parareal`]: coarse strided sweeps alternate with
    /// the standard fine parallel-correction rounds. `nodes` holds the
    /// sweep's row list exactly while a coarse batch is pending (emptied
    /// when the sweep resumes, refilled after the next fine round).
    Parareal {
        /// Node stride over the active window (≥ 2, so the first written
        /// node sits strictly below the safeguarded row t2).
        stride: usize,
        /// Sampler η for the bridge coefficients.
        eta: f64,
        /// Fine per-state ᾱ the bridges and segment fills read.
        abar: Vec<f64>,
        /// Descending sweep rows, anchor (t2+1) first, window base (t1)
        /// last. Non-empty ⇔ the pending batch is a coarse batch.
        nodes: Vec<usize>,
    },
}

/// What one [`SolverSession::resume`] produced.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Diagnostics for the round just completed (also appended to the
    /// session's record history, returned by [`SolverSession::finish`]).
    pub record: IterationRecord,
    /// True once the session needs no further rounds: the stopping
    /// criterion held for every row, or `s_max` rounds elapsed.
    pub done: bool,
}

/// A resumable parallel solve: Algorithm 1 with the round boundary
/// externalized.
///
/// The session owns everything the solve needs (coefficients, noise draws,
/// state, history) and none of what it doesn't (no model handle, no
/// threads), so it is `Send` and can migrate between round-driver threads
/// through a run queue.
///
/// # Example
///
/// Drive the state machine by hand and confirm the result is bit-identical
/// to the blocking [`crate::solver::solve`] wrapper:
///
/// ```
/// use parataa::model::{gmm::GmmEps, Cond, EpsModel};
/// use parataa::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
/// use parataa::solver::{self, Problem, SolverConfig, SolverSession};
///
/// let schedule = NoiseSchedule::new(BetaSchedule::Linear, 1000);
/// let model = GmmEps::sd_analog(schedule.alpha_bars.clone());
/// let coeffs = SamplerCoeffs::new(&schedule, SamplerKind::Ddim, 8);
/// let problem = Problem::new(&coeffs, &model, Cond::Class(0), 3);
/// let mut cfg = SolverConfig::parataa(8);
/// cfg.guidance = 2.0;
/// cfg.s_max = 32;
///
/// let mut session = SolverSession::new(&problem, &cfg);
/// let d = session.dim();
/// let mut eps = Vec::new();
/// loop {
///     let n = match session.pending() {
///         None => break,
///         Some(batch) => {
///             eps.resize(batch.len() * d, 0.0);
///             model.eps_batch(batch.x, batch.t, batch.conds, batch.guidance, &mut eps);
///             batch.len()
///         }
///     };
///     if session.resume(&eps[..n * d]).done {
///         break;
///     }
/// }
/// let by_session = session.finish();
/// let by_solve = solver::solve(&problem, &cfg);
/// assert!(by_session.converged);
/// assert_eq!(by_session.xs.data, by_solve.xs.data); // bit-identical
/// assert_eq!(by_session.iterations, by_solve.iterations);
/// assert_eq!(by_session.total_nfe, by_solve.total_nfe);
/// ```
pub struct SolverSession {
    // --- immutable problem data (owned: sessions outlive their Problem) ---
    coeffs: SamplerCoeffs,
    xi: States,
    cfg: SolverConfig,
    d: usize,
    t_count: usize,
    k: usize,
    w: usize,
    hist_cols: usize,
    thresholds: Vec<f64>,
    /// Pre-cloned condition pool: one session has one condition, so avoid
    /// re-cloning (potentially heap-backed) `Cond`s every round (§Perf L3).
    cond_pool: Vec<Cond>,

    // --- solver state ----------------------------------------------------
    xs: States,
    eps: States,
    eps_valid: Vec<bool>,
    history: History,
    prev_x: Vec<f32>,
    prev_r: Vec<f32>,
    prev_active: Option<(usize, usize)>,
    last_residual: Vec<Option<f64>>,

    // Reusable per-round buffers (no allocation in the hot loop).
    f_vals: Vec<f32>,
    r_vals: Vec<f32>,
    dx_buf: Vec<f32>,
    df_buf: Vec<f32>,
    batch_x: Vec<f32>,
    batch_t: Vec<usize>,
    batch_states: Vec<usize>,
    /// Update-path scratch (suffix Grams, ridge/γ/Cholesky buffers): the
    /// session owns it so steady-state rounds allocate nothing inside
    /// the update path. Plain `Vec`s — the session stays `Send`.
    ws: Workspace,
    /// Intra-round worker pool (`None` when `cfg.parallelism <= 1` — the
    /// exact historical single-threaded path, no threads spawned). The
    /// session owns it so thread startup amortizes across every round;
    /// per-row work fans over it in fixed-owner contiguous chunks and all
    /// reductions stay on the solver thread, so results are bitwise
    /// identical at every thread count (see [`SolverConfig::parallelism`]).
    row_pool: Option<RowPool>,

    /// Adaptive window controller (`None` under [`WindowPolicy::Fixed`] —
    /// that path is bit-identical to the pre-controller solver).
    controller: Option<WindowController>,
    /// Lowest row index already reported by [`progress`](Self::progress)
    /// (exclusive upper bound of the next report). Starts at `t_count`:
    /// nothing reported, so the first advance also covers rows frozen by a
    /// §4.2 warm start.
    reported_front: usize,

    /// Multi-fidelity phase state (`None` ⇒ plain single-fidelity rounds;
    /// see [`SolveStrategy`]).
    fidelity: Option<Box<Fidelity>>,

    // --- round accounting -------------------------------------------------
    t1: usize,
    t2: usize,
    /// 1-based index of the round the pending batch belongs to.
    iter: usize,
    total_nfe: usize,
    /// Coarse rounds completed (draft-phase rounds + Parareal sweeps).
    coarse_rounds: usize,
    records: Vec<IterationRecord>,
    converged: bool,
    done: bool,
    /// Process-unique trace track id: every span/instant this session
    /// records carries it, so exporters can rebuild the per-session span
    /// tree (admit → rounds → finalize) and telemetry can join on it.
    trace_id: u64,
}

impl SolverSession {
    /// Start a session for `problem` under `cfg`. Clones the coefficients,
    /// noise draws and (optional) initialization out of the problem so the
    /// session is self-contained; the model is *not* captured — evaluating
    /// the pending batches is the caller's job.
    pub fn new(problem: &Problem, cfg: &SolverConfig) -> SolverSession {
        let coeffs = problem.coeffs.clone();
        let t_count = coeffs.steps;
        let d = problem.model.dim();
        let k = cfg.k.clamp(1, t_count);
        let (w, controller) = match &cfg.window_policy {
            WindowPolicy::Fixed => (cfg.window.clamp(1, t_count), None),
            WindowPolicy::Adaptive(a) => {
                let ctrl = WindowController::new(a.clone(), t_count);
                (ctrl.clamp(cfg.window.clamp(1, t_count)), Some(ctrl))
            }
        };
        let t_init = problem.t_init.unwrap_or(t_count).clamp(1, t_count);

        let mut xs = States::zeros(t_count, d);
        xs.set_row(t_count, problem.xi.row(t_count));
        match (&problem.init, t_init) {
            (Some(init), _) => {
                assert_eq!(init.d, d, "init trajectory dimension mismatch");
                assert_eq!(init.rows(), t_count + 1, "init trajectory length mismatch");
                xs.data[..t_count * d].copy_from_slice(&init.data[..t_count * d]);
            }
            (None, _) => {
                // Standard-Gaussian initialization of all unknowns (§5.1).
                let mut rng = crate::util::rng::Pcg64::new(problem.init_seed(), 0x1717_c0de);
                rng.fill_gaussian(&mut xs.data[..t_count * d]);
            }
        }

        // Anderson history: paper's m counts the iterate window, so m−1
        // difference columns (m = 1 ⇒ plain FP; Appendix C).
        let hist_cols =
            if cfg.method == super::Method::FixedPoint { 0 } else { cfg.m.saturating_sub(1) };

        let thresholds: Vec<f64> =
            (0..t_count).map(|p| coeffs.threshold(p, cfg.tol, d)).collect();
        let t2 = t_init - 1;
        let t1 = (t2 + 1).saturating_sub(w);

        let mut session = SolverSession {
            xi: problem.xi.clone(),
            cfg: cfg.clone(),
            d,
            t_count,
            k,
            w,
            hist_cols,
            thresholds,
            cond_pool: vec![problem.cond.clone(); t_count + 1],
            xs,
            eps: States::zeros(t_count, d),
            eps_valid: vec![false; t_count + 1],
            history: History::new(hist_cols, t_count, d),
            prev_x: vec![0.0f32; t_count * d],
            prev_r: vec![0.0f32; t_count * d],
            prev_active: None,
            last_residual: vec![None; t_count],
            f_vals: vec![0.0f32; t_count * d],
            r_vals: vec![0.0f32; t_count * d],
            dx_buf: vec![0.0f32; t_count * d],
            df_buf: vec![0.0f32; t_count * d],
            batch_x: Vec::new(),
            batch_t: Vec::new(),
            batch_states: Vec::new(),
            ws: Workspace::new(),
            row_pool: (cfg.parallelism > 1).then(|| RowPool::new(cfg.parallelism)),
            controller,
            reported_front: t_count,
            fidelity: None,
            t1,
            t2,
            iter: 1,
            total_nfe: 0,
            coarse_rounds: 0,
            records: Vec::new(),
            converged: false,
            done: cfg.s_max == 0,
            trace_id: trace::next_track_id(),
            coeffs,
        };
        if !session.done {
            session.build_batch();
        }
        match &cfg.strategy {
            SolveStrategy::PlainTaa => {}
            SolveStrategy::DraftRefine(dr) => {
                // An explicit §4.2 init already seeds the window — a draft
                // would only overwrite it, so the strategy degrades to the
                // plain path.
                if problem.init.is_none() && !session.done {
                    let c_steps = dr.resolve_coarse_steps(t_count);
                    let (coarse_coeffs, idx0) = session.coeffs.coarsen(c_steps);
                    // Coarse ξ rows are the fine ξ rows at the nodes, so
                    // the coarse solve starts from the same x_T draw and
                    // its DDPM noise is consistent with the fine grid's.
                    let mut cxi = States::zeros(c_steps, d);
                    for (c, &r) in idx0.iter().enumerate() {
                        cxi.set_row(c, problem.xi.row(r));
                    }
                    let coarse_problem = Problem {
                        coeffs: &coarse_coeffs,
                        model: problem.model,
                        cond: problem.cond.clone(),
                        xi: cxi,
                        init: None,
                        t_init: None,
                        seed: problem.seed,
                    };
                    let mut ccfg = cfg.clone();
                    ccfg.strategy = SolveStrategy::PlainTaa;
                    ccfg.safeguard = true; // ≤ C+1-round draft guarantee
                    // The coarse grid is small; a nested pool would spawn a
                    // second thread set for negligible row counts.
                    ccfg.parallelism = 1;
                    ccfg.window = c_steps;
                    ccfg.window_policy = WindowPolicy::Fixed;
                    ccfg.tol = dr.resolve_tol(cfg.tol);
                    ccfg.s_max = dr.resolve_rounds(c_steps);
                    let inner = SolverSession::new(&coarse_problem, &ccfg);
                    let abar = session.coeffs.state_alpha_bars();
                    session.fidelity =
                        Some(Box::new(Fidelity::Draft { session: inner, idx0, abar }));
                }
            }
            SolveStrategy::Parareal(pr) => {
                if !session.done {
                    session.fidelity = Some(Box::new(Fidelity::Parareal {
                        stride: pr.resolve_stride(session.w),
                        eta: session.coeffs.kind.eta(),
                        abar: session.coeffs.state_alpha_bars(),
                        nodes: Vec::new(),
                    }));
                    // Parareal opens with a coarse sweep: it propagates
                    // real signal from x_T down the Gaussian-initialized
                    // window before the first fine correction.
                    session.maybe_schedule_coarse();
                }
            }
        }
        session
    }

    /// The ε job for the upcoming round, or `None` once the session is
    /// done. Idempotent: repeated calls return the same batch until
    /// [`resume`](Self::resume) consumes it.
    pub fn pending(&self) -> Option<EpsBatch<'_>> {
        if self.done {
            return None;
        }
        if let Some(Fidelity::Draft { session, .. }) = self.fidelity.as_deref() {
            // Draft phase: the coarse session's ε job is this session's
            // pending batch (same guidance, so it merges with fine
            // sessions' batches in the coordinator unchanged).
            return session.pending();
        }
        Some(EpsBatch {
            x: &self.batch_x,
            t: &self.batch_t,
            conds: &self.cond_pool[..self.batch_states.len()],
            guidance: self.cfg.guidance,
        })
    }

    /// Batched ε_θ job over the active window (step 1 of a parallel round).
    /// Equations are clamped at the boundary state t2+1 (see
    /// `equations::eval_fk`), so only states [t1+1, t2+1] are needed; the
    /// boundary state is frozen and served from the cache once filled.
    fn build_batch(&mut self) {
        self.batch_x.clear();
        self.batch_t.clear();
        self.batch_states.clear();
        let top_needed = (self.t2 + 1).min(self.t_count);
        for j in self.t1 + 1..=top_needed {
            let active = j <= self.t2;
            if active || !self.eps_valid[j] {
                self.batch_states.push(j);
                self.batch_x.extend_from_slice(self.xs.row(j));
                self.batch_t.push(self.coeffs.train_t[j]);
            }
        }
    }

    /// Feed the ε results for the pending batch (`[len, d]` row-major, in
    /// batch order) and run the rest of the round: residuals, convergence
    /// front, window slide, Anderson history and the update rule.
    ///
    /// # Panics
    ///
    /// If the session is already done, or `eps_out` does not match the
    /// pending batch's `len × dim`.
    pub fn resume(&mut self, eps_out: &[f32]) -> RoundOutcome {
        assert!(!self.done, "resume() on a finished session");
        match self.fidelity.as_deref() {
            Some(Fidelity::Draft { .. }) => return self.resume_draft(eps_out),
            Some(Fidelity::Parareal { nodes, .. }) if !nodes.is_empty() => {
                return self.resume_coarse_sweep(eps_out)
            }
            _ => {}
        }
        let round_span = trace::begin();
        let d = self.d;
        let n = self.batch_states.len();
        assert_eq!(eps_out.len(), n * d, "eps_out does not match the pending batch");

        self.total_nfe += n;
        for (bi, &j) in self.batch_states.iter().enumerate() {
            self.eps.set_row(j, &eps_out[bi * d..(bi + 1) * d]);
            self.eps_valid[j] = true;
        }

        // --- Residuals + convergence front (§2.1) --------------------------
        let eval_span = trace::begin();
        let (t1, t2) = (self.t1, self.t2);
        let rows = t2 - t1 + 1;
        match self.row_pool.as_ref() {
            Some(pool) if rows > 1 => {
                // Each row's residual has exactly one owner (fixed by
                // `chunk_range`), and the f64 lands in that row's slot, so
                // the result is bitwise chunking-invariant; the front scan
                // below stays sequential on the solver thread.
                let coeffs = &self.coeffs;
                let (xs, eps, xi) = (&self.xs, &self.eps, &self.xi);
                let lr = SyncSlice::new(&mut self.last_residual);
                let chunks = pool.threads();
                pool.run(chunks, &|c| {
                    let (c0, c1) = chunk_range(rows, chunks, c);
                    for r in c0..c1 {
                        let p = t1 + r;
                        // SAFETY: row p is owned by exactly one chunk.
                        let slot = unsafe { &mut lr.slice_mut(p, 1)[0] };
                        *slot = Some(residual_sq(coeffs, xs, eps, xi, p));
                    }
                });
            }
            _ => {
                for p in t1..=t2 {
                    self.last_residual[p] =
                        Some(residual_sq(&self.coeffs, &self.xs, &self.eps, &self.xi, p));
                }
            }
        }
        let mut new_t2: Option<usize> = None;
        for p in (t1..=t2).rev() {
            if self.last_residual[p].unwrap() > self.thresholds[p] {
                new_t2 = Some(p);
                break;
            }
        }
        let residual_sum: f64 = self.last_residual.iter().flatten().sum();
        let max_ratio = (t1..=t2)
            .map(|p| self.last_residual[p].unwrap() / self.thresholds[p])
            .fold(0.0f64, f64::max);

        let (nt1, nt2, done) = match new_t2 {
            None if t1 == 0 => (t1, t2, true),
            None => {
                // Whole window converged; slide below it.
                let nt2 = t1 - 1;
                ((nt2 + 1).saturating_sub(self.w), nt2, false)
            }
            Some(nt2) => ((nt2 + 1).saturating_sub(self.w), nt2, false),
        };

        let row_residuals: Vec<f64> =
            self.last_residual.iter().map(|r| r.unwrap_or(f64::NAN)).collect();

        if done {
            self.converged = true;
            self.done = true;
            let rec = IterationRecord {
                iter: self.iter,
                t1,
                t2,
                nfe: n,
                residual_sum,
                max_residual_ratio: max_ratio,
                converged_rows: self.t_count,
                row_residuals,
            };
            self.records.push(rec.clone());
            // Final front advance: the whole remaining window froze.
            trace::instant(Layer::Solver, Name::FrontAdvance, self.trace_id, (t2 + 1) as i64, 0);
            trace::complete(
                eval_span,
                Layer::Solver,
                Name::RoundEval,
                self.trace_id,
                self.iter as i64,
                rows as i64,
            );
            trace::complete(
                round_span,
                Layer::Solver,
                Name::Round,
                self.trace_id,
                self.iter as i64,
                n as i64,
            );
            return RoundOutcome { record: rec, done: true };
        }
        if nt2 < t2 {
            // Front advanced: rows (nt2, t2] froze this round (Thm 3.6 —
            // the front is monotone, so `b` never increases over a track).
            trace::instant(
                Layer::Solver,
                Name::FrontAdvance,
                self.trace_id,
                (t2 - nt2) as i64,
                (nt2 + 1) as i64,
            );
        }
        self.t1 = nt1;
        self.t2 = nt2;

        // --- F^{(k)} and residual vectors over the (new) window ------------
        // First frozen state; without the clamp the equations reach across
        // the front (Definition 2.1 verbatim) — kept only for `ablate`.
        let boundary = if self.cfg.clamp_boundary { self.t2 + 1 } else { self.t_count };
        self.r_vals.fill(0.0);
        let new_rows = self.t2 - self.t1 + 1;
        match self.row_pool.as_ref() {
            Some(pool) if new_rows > 1 => {
                // `eval_fk` reads shared state and writes only row p of its
                // output; with fixed row owners and disjoint f/r rows the
                // sweep is bitwise identical to the sequential loop.
                let (nt1, k) = (self.t1, self.k);
                let coeffs = &self.coeffs;
                let (xs, eps, xi) = (&self.xs, &self.eps, &self.xi);
                let f_view = SyncSlice::new(&mut self.f_vals);
                let r_view = SyncSlice::new(&mut self.r_vals);
                let chunks = pool.threads();
                pool.run(chunks, &|c| {
                    let (c0, c1) = chunk_range(new_rows, chunks, c);
                    for r in c0..c1 {
                        let p = nt1 + r;
                        // SAFETY: row p of f_vals/r_vals has one owner.
                        let f_row = unsafe { f_view.slice_mut(p * d, d) };
                        let r_row = unsafe { r_view.slice_mut(p * d, d) };
                        eval_fk(coeffs, xs, eps, xi, k, boundary, p, f_row);
                        let x_row = &xs.data[p * d..(p + 1) * d];
                        for i in 0..d {
                            r_row[i] = f_row[i] - x_row[i];
                        }
                    }
                });
            }
            _ => {
                for p in self.t1..=self.t2 {
                    let row = p * d..(p + 1) * d;
                    eval_fk(
                        &self.coeffs,
                        &self.xs,
                        &self.eps,
                        &self.xi,
                        self.k,
                        boundary,
                        p,
                        &mut self.f_vals[row.clone()],
                    );
                    for i in row.clone() {
                        self.r_vals[i] = self.f_vals[i] - self.xs.data[i];
                    }
                }
            }
        }
        trace::complete(
            eval_span,
            Layer::Solver,
            Name::RoundEval,
            self.trace_id,
            self.iter as i64,
            new_rows as i64,
        );
        let update_span = trace::begin();

        // --- Anderson history push (Δx^{i-1}, ΔR^{i-1}) ---------------------
        if self.hist_cols > 0 {
            if let Some((p1, p2)) = self.prev_active {
                self.dx_buf.fill(0.0);
                self.df_buf.fill(0.0);
                let lo = self.t1.max(p1);
                let hi = self.t2.min(p2);
                if lo <= hi {
                    for i in lo * d..(hi + 1) * d {
                        self.dx_buf[i] = self.xs.data[i] - self.prev_x[i];
                        self.df_buf[i] = self.r_vals[i] - self.prev_r[i];
                    }
                    // Ranged push: rows outside [lo, hi] are zero, so the
                    // Gram-cache refresh and correction loop can skip them
                    // (numerically identical to a full-range push).
                    self.history.push_ranged_par(
                        &self.dx_buf,
                        &self.df_buf,
                        lo,
                        hi + 1,
                        self.row_pool.as_ref(),
                    );
                }
            }
            self.prev_x.copy_from_slice(&self.xs.data[..self.t_count * d]);
            self.prev_r.copy_from_slice(&self.r_vals);
            self.prev_active = Some((self.t1, self.t2));
        }

        // --- Update rule ----------------------------------------------------
        apply_update_par(
            self.cfg.method,
            &mut self.xs.data[..self.t_count * d],
            &self.f_vals,
            &self.r_vals,
            &self.history,
            self.t1,
            self.t2,
            self.t_count,
            d,
            self.cfg.lambda,
            self.cfg.safeguard,
            &mut self.ws,
            self.row_pool.as_ref(),
        );
        trace::complete(
            update_span,
            Layer::Solver,
            Name::RoundUpdate,
            self.trace_id,
            self.iter as i64,
            new_rows as i64,
        );
        if self.cfg.safeguard {
            // The §3.2 safeguard pinned the top unconverged row t2 to the
            // plain fixed-point iterate this round.
            trace::instant(
                Layer::Solver,
                Name::Safeguard,
                self.trace_id,
                self.t2 as i64,
                self.iter as i64,
            );
        }

        let rec = IterationRecord {
            iter: self.iter,
            t1: self.t1,
            t2: self.t2,
            nfe: n,
            residual_sum,
            max_residual_ratio: max_ratio,
            converged_rows: self.t_count - (self.t2 + 1),
            row_residuals,
        };
        self.records.push(rec.clone());

        // --- Adaptive window (no-op under WindowPolicy::Fixed) -------------
        // Decided after this round's update but before the next batch is
        // built: rows a grown window adds have never had ε evaluated (the
        // window only ever slides down), so they must enter through a
        // pending batch before anything reads their ε — growing before the
        // update would feed zeroed ε into their F rows and waste a round.
        // `t2 - nt2` is the number of rows the front froze this round (its
        // convergence velocity).
        if let Some(ctrl) = self.controller.as_mut() {
            let next_w = ctrl.decide(t2 - nt2, self.w);
            if next_w != self.w {
                trace::instant(
                    Layer::Solver,
                    Name::WindowResize,
                    self.trace_id,
                    self.w as i64,
                    next_w as i64,
                );
                self.w = next_w;
                self.t1 = (self.t2 + 1).saturating_sub(self.w);
            }
        }

        trace::complete(
            round_span,
            Layer::Solver,
            Name::Round,
            self.trace_id,
            self.iter as i64,
            n as i64,
        );
        self.iter += 1;
        if self.iter > self.cfg.s_max {
            self.done = true; // round budget exhausted; not converged
        } else {
            self.build_batch();
            // Under SolveStrategy::Parareal the next round may instead be
            // a coarse sweep (no-op for every other strategy).
            self.maybe_schedule_coarse();
        }
        RoundOutcome { record: rec, done: self.done }
    }

    /// A draft-phase round ([`SolveStrategy::DraftRefine`]): delegate to
    /// the nested coarse session, account its cost on this session, and —
    /// once the draft finishes (converged or out of draft budget) — lift
    /// its trajectory onto the fine grid and open the fine phase.
    fn resume_draft(&mut self, eps_out: &[f32]) -> RoundOutcome {
        let span = trace::begin();
        let fid = self.fidelity.take().expect("draft state present");
        let (mut inner, idx0, abar) = match *fid {
            Fidelity::Draft { session, idx0, abar } => (session, idx0, abar),
            Fidelity::Parareal { .. } => unreachable!("resume_draft outside the draft phase"),
        };
        let inner_out = inner.resume(eps_out);
        let n = inner_out.record.nfe;
        self.total_nfe += n;
        self.coarse_rounds += 1;
        let rec = IterationRecord {
            iter: self.iter,
            t1: self.t1,
            t2: self.t2,
            nfe: n,
            residual_sum: inner_out.record.residual_sum,
            max_residual_ratio: inner_out.record.max_residual_ratio,
            // The fine front has not moved: draft rounds refine the
            // initialization, they never freeze fine rows.
            converged_rows: self.t_count - (self.t2 + 1),
            row_residuals: self.last_residual.iter().map(|r| r.unwrap_or(f64::NAN)).collect(),
        };
        self.records.push(rec.clone());
        trace::complete(
            span,
            Layer::Solver,
            Name::CoarseRound,
            self.trace_id,
            self.iter as i64,
            n as i64,
        );
        self.iter += 1;
        if inner_out.done {
            // Hand the draft to the fine phase — exactly the §4.2
            // warm-start path, with the init produced in-band instead of
            // donated by a cache.
            let draft = inner.finish();
            lift_trajectory(&abar, &draft.xs, &idx0, &mut self.xs);
            if self.iter > self.cfg.s_max {
                self.done = true; // outer budget exhausted; not converged
            } else {
                self.build_batch();
            }
        } else {
            self.fidelity = Some(Box::new(Fidelity::Draft { session: inner, idx0, abar }));
            if self.iter > self.cfg.s_max {
                self.done = true; // outer budget exhausted mid-draft
            }
        }
        RoundOutcome { record: rec, done: self.done }
    }

    /// After a fine round (or at construction) under
    /// [`SolveStrategy::Parareal`]: if the active window has room for a
    /// strided sweep, replace the pending fine batch with the sweep's ε
    /// sources. No-op for every other strategy.
    fn maybe_schedule_coarse(&mut self) {
        let (stride, mut nodes) = match self.fidelity.as_deref_mut() {
            Some(Fidelity::Parareal { stride, nodes, .. }) => (*stride, std::mem::take(nodes)),
            _ => return,
        };
        nodes.clear();
        let (t1, t2) = (self.t1, self.t2);
        if t2 + 1 - t1 >= stride {
            // Descending sweep rows: the frozen anchor t2+1 (never
            // written), strided interior nodes — the first at t2+1−stride
            // ≤ t2−1, strictly below the safeguarded row — then the
            // window base t1.
            let anchor = t2 + 1;
            let mut r = anchor;
            while r > t1 + stride {
                nodes.push(r);
                r -= stride;
            }
            nodes.push(r);
            if r != t1 {
                nodes.push(t1);
            }
            // The sweep's ε sources: every node it steps *from*. The
            // anchor is frozen, so its ε is served from the cache once
            // filled; interior nodes re-evaluate every sweep.
            self.batch_x.clear();
            self.batch_t.clear();
            self.batch_states.clear();
            for (i, &j) in nodes[..nodes.len() - 1].iter().enumerate() {
                if i == 0 && self.eps_valid[j] {
                    continue;
                }
                self.batch_states.push(j);
                self.batch_x.extend_from_slice(self.xs.row(j));
                self.batch_t.push(self.coeffs.train_t[j]);
            }
        }
        // Non-empty nodes mark the pending batch as a coarse batch.
        if let Some(Fidelity::Parareal { nodes: slot, .. }) = self.fidelity.as_deref_mut() {
            *slot = nodes;
        }
    }

    /// A Parareal coarse round: one strided sequential bridge sweep from
    /// the frozen anchor down the active window — ε batched from the *old*
    /// iterate, new states propagated through the linear term (the
    /// Parareal coarse propagator), intermediate rows re-noised from each
    /// segment's implied (x0, ε) pair. The sweep never writes row t2 or
    /// anything above it, so the residual front stays monotone
    /// (Theorem 3.6); the Anderson history is untouched (any iterate pair
    /// is a valid secant pair, so the next fine round's difference
    /// columns stay consistent).
    fn resume_coarse_sweep(&mut self, eps_out: &[f32]) -> RoundOutcome {
        let span = trace::begin();
        let d = self.d;
        let n = self.batch_states.len();
        assert_eq!(eps_out.len(), n * d, "eps_out does not match the pending batch");
        self.total_nfe += n;
        for (bi, &j) in self.batch_states.iter().enumerate() {
            self.eps.set_row(j, &eps_out[bi * d..(bi + 1) * d]);
            self.eps_valid[j] = true;
        }
        let mut fid = self.fidelity.take().expect("parareal state present");
        if let Fidelity::Parareal { eta, abar, nodes, .. } = &mut *fid {
            let mut x_prev: Vec<f32> = self.xs.row(nodes[0]).to_vec();
            let mut x_new = vec![0.0f32; d];
            for l in 0..nodes.len() - 1 {
                let (hi, lo) = (nodes[l], nodes[l + 1]);
                let (a, b, sg) = bridge_coeffs(abar[hi], abar[lo], *eta);
                let (af, bf, sf) = (a as f32, b as f32, sg as f32);
                {
                    let e = self.eps.row(hi);
                    let xr = self.xi.row(lo);
                    for i in 0..d {
                        x_new[i] = af * x_prev[i] + bf * e[i] + sf * xr[i];
                    }
                }
                self.xs.set_row(lo, &x_new);
                if hi - lo >= 2 {
                    interpolate_segment(abar, lo, hi, &x_new, &x_prev, self.t2, &mut self.xs);
                }
                std::mem::swap(&mut x_prev, &mut x_new);
            }
            nodes.clear();
        }
        self.fidelity = Some(fid);
        self.coarse_rounds += 1;
        // No residuals are measured on a coarse round (its rows are
        // re-evaluated by the next fine round anyway): the record carries
        // the last fine round's convergence picture forward, keeping the
        // telemetry's front monotonicity intact.
        let (residual_sum, max_ratio) = self
            .records
            .last()
            .map(|r| (r.residual_sum, r.max_residual_ratio))
            .unwrap_or((0.0, 0.0));
        let rec = IterationRecord {
            iter: self.iter,
            t1: self.t1,
            t2: self.t2,
            nfe: n,
            residual_sum,
            max_residual_ratio: max_ratio,
            converged_rows: self.t_count - (self.t2 + 1),
            row_residuals: self.last_residual.iter().map(|r| r.unwrap_or(f64::NAN)).collect(),
        };
        self.records.push(rec.clone());
        trace::complete(
            span,
            Layer::Solver,
            Name::CoarseRound,
            self.trace_id,
            self.iter as i64,
            n as i64,
        );
        self.iter += 1;
        if self.iter > self.cfg.s_max {
            self.done = true; // round budget exhausted; not converged
        } else {
            self.build_batch(); // the fine correction round comes next
        }
        RoundOutcome { record: rec, done: self.done }
    }

    /// Consume the session into a [`SolveResult`] (valid at any point —
    /// mid-solve it reports the current trajectory with `converged = false`,
    /// the §4.1 "user accepts the image" early stop).
    pub fn finish(self) -> SolveResult {
        SolveResult {
            iterations: self.records.len(),
            total_nfe: self.total_nfe,
            converged: self.converged,
            records: self.records,
            xs: self.xs,
        }
    }

    /// Feature dimension d of the model this session was built against.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// True once no further rounds are needed ([`pending`](Self::pending)
    /// returns `None`).
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Whether the stopping criterion has been met for every row.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// Parallel rounds completed so far (the paper's "Steps").
    pub fn iterations(&self) -> usize {
        self.records.len()
    }

    /// Total ε_θ evaluations so far.
    pub fn total_nfe(&self) -> usize {
        self.total_nfe
    }

    /// Multi-fidelity rounds completed so far: draft-phase rounds under
    /// [`SolveStrategy::DraftRefine`] plus coarse sweeps under
    /// [`SolveStrategy::Parareal`]. Always 0 under
    /// [`SolveStrategy::PlainTaa`].
    pub fn coarse_rounds(&self) -> usize {
        self.coarse_rounds
    }

    /// The multi-fidelity strategy this session runs under.
    pub fn strategy(&self) -> &SolveStrategy {
        &self.cfg.strategy
    }

    /// Per-round diagnostics so far.
    pub fn records(&self) -> &[IterationRecord] {
        &self.records
    }

    /// Current trajectory estimate x_0..x_T.
    pub fn xs(&self) -> &States {
        &self.xs
    }

    /// The fixed noise draws ξ_0..ξ_T this session solves against.
    pub fn xi(&self) -> &States {
        &self.xi
    }

    /// Classifier-free guidance scale (the batch merge key).
    pub fn guidance(&self) -> f32 {
        self.cfg.guidance
    }

    /// Current sliding-window size w (clamped; varies across rounds under
    /// [`WindowPolicy::Adaptive`]). Serving layers budgeting slots should
    /// use [`SolverConfig::max_window_rows`], the worst-case footprint.
    pub fn window_rows(&self) -> usize {
        self.w
    }

    /// The residual front's advance since the last `progress()` call (or
    /// since construction), `None` if it has not moved. The reported rows
    /// are frozen — their states in [`xs`](Self::xs) are final — so a
    /// streaming layer can deliver them to the client immediately.
    ///
    /// Purely observational: it reads solver state and moves a report
    /// cursor, so never calling it leaves the solve bit-identical
    /// (golden-tested in `tests/golden_session.rs`).
    pub fn progress(&mut self) -> Option<FrontAdvance> {
        let front = if self.converged { 0 } else { self.t2 + 1 };
        if front >= self.reported_front {
            return None;
        }
        let newly_converged = front..self.reported_front;
        let residuals = newly_converged
            .clone()
            .map(|p| self.last_residual[p].unwrap_or(f64::NAN))
            .collect();
        self.reported_front = front;
        Some(FrontAdvance { newly_converged, residuals })
    }

    /// Lowest converged row index: every row in `[converged_front(), T)`
    /// is frozen at its final state. `0` once the whole solve converged.
    pub fn converged_front(&self) -> usize {
        if self.converged {
            0
        } else {
            self.t2 + 1
        }
    }

    /// Report the latest device-occupancy signal in [0, 1] to the adaptive
    /// window controller (the coordinator's round drivers derive it from
    /// the attached pool's stats). No-op under [`WindowPolicy::Fixed`].
    pub fn set_occupancy(&mut self, occupancy: f64) {
        if let Some(ctrl) = self.controller.as_mut() {
            ctrl.set_occupancy(occupancy);
        }
    }

    /// True when this session sizes its window adaptively (callers can
    /// skip computing the occupancy signal otherwise).
    pub fn is_adaptive(&self) -> bool {
        self.controller.is_some()
    }

    /// Process-unique trace track id. Every span/instant this session
    /// records carries it; serving layers reuse it for their own
    /// admit/finalize spans and telemetry so exporters can reassemble the
    /// full per-session tree.
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::GmmEps;
    use crate::model::EpsModel;
    use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
    use crate::solver::{solve, Method};
    use crate::util::rng::Pcg64;

    fn setup(steps: usize) -> (SamplerCoeffs, GmmEps) {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, steps);
        let mut rng = Pcg64::seeded(17);
        let d = 5;
        let means: Vec<f32> = (0..3 * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        (coeffs, GmmEps::new(means, d, 0.25, ns.alpha_bars.clone()))
    }

    fn drive(session: &mut SolverSession, model: &dyn EpsModel) -> usize {
        let d = session.dim();
        let mut eps = Vec::new();
        let mut rounds = 0;
        loop {
            let n = match session.pending() {
                None => break,
                Some(b) => {
                    eps.resize(b.len() * d, 0.0);
                    model.eps_batch(b.x, b.t, b.conds, b.guidance, &mut eps);
                    b.len()
                }
            };
            rounds += 1;
            if session.resume(&eps[..n * d]).done {
                break;
            }
        }
        rounds
    }

    #[test]
    fn manual_drive_matches_solve_bitwise() {
        let (coeffs, model) = setup(12);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(1), 4);
        for method in
            [Method::FixedPoint, Method::AndersonStd, Method::AndersonUpperTri, Method::Taa]
        {
            let cfg = SolverConfig {
                method,
                guidance: 2.0,
                tol: 1e-4,
                s_max: 48,
                ..SolverConfig::parataa(12)
            };
            let mut session = SolverSession::new(&problem, &cfg);
            drive(&mut session, &model);
            let by_session = session.finish();
            let by_solve = solve(&problem, &cfg);
            assert_eq!(by_session.xs.data, by_solve.xs.data, "{}", method.label());
            assert_eq!(by_session.iterations, by_solve.iterations);
            assert_eq!(by_session.total_nfe, by_solve.total_nfe);
            assert_eq!(by_session.converged, by_solve.converged);
        }
    }

    /// The `parallelism` knob must never change a single bit of the
    /// output: per-row outputs have fixed owners and every reduction stays
    /// sequential on the solver thread, so any thread count reproduces the
    /// historical single-threaded trajectory exactly.
    #[test]
    fn parallel_sessions_are_bitwise_identical_to_sequential() {
        let (coeffs, model) = setup(16);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(1), 6);
        let base =
            SolverConfig { guidance: 2.0, tol: 1e-4, s_max: 48, ..SolverConfig::parataa(16) };
        let mut seq_session = SolverSession::new(&problem, &base);
        drive(&mut seq_session, &model);
        let seq = seq_session.finish();
        assert!(seq.converged);
        for threads in [2usize, 4, 8] {
            let cfg = SolverConfig { parallelism: threads, ..base.clone() };
            let mut session = SolverSession::new(&problem, &cfg);
            drive(&mut session, &model);
            let par = session.finish();
            assert_eq!(par.xs.data, seq.xs.data, "threads = {threads}");
            assert_eq!(par.iterations, seq.iterations, "threads = {threads}");
            assert_eq!(par.total_nfe, seq.total_nfe, "threads = {threads}");
            assert_eq!(par.converged, seq.converged, "threads = {threads}");
        }
    }

    #[test]
    fn pending_is_idempotent() {
        let (coeffs, model) = setup(10);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(0), 9);
        let cfg = SolverConfig { guidance: 2.0, ..SolverConfig::parataa(10) };
        let session = SolverSession::new(&problem, &cfg);
        let (a_x, a_t) = {
            let b = session.pending().unwrap();
            (b.x.to_vec(), b.t.to_vec())
        };
        let b = session.pending().unwrap();
        assert_eq!(b.x, &a_x[..]);
        assert_eq!(b.t, &a_t[..]);
        assert_eq!(b.conds.len(), b.t.len());
    }

    #[test]
    fn zero_round_budget_is_done_immediately() {
        let (coeffs, model) = setup(8);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(0), 1);
        let cfg = SolverConfig { s_max: 0, ..SolverConfig::parataa(8) };
        let session = SolverSession::new(&problem, &cfg);
        assert!(session.is_done());
        assert!(session.pending().is_none());
        let r = session.finish();
        assert_eq!(r.iterations, 0);
        assert!(!r.converged);
    }

    #[test]
    fn round_budget_exhaustion_reports_not_converged() {
        let (coeffs, model) = setup(16);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(2), 3);
        let cfg =
            SolverConfig { s_max: 2, tol: 1e-9, guidance: 2.0, ..SolverConfig::parataa(16) };
        let mut session = SolverSession::new(&problem, &cfg);
        let rounds = drive(&mut session, &model);
        assert_eq!(rounds, 2);
        assert!(session.is_done());
        assert!(!session.converged());
        let by_solve = solve(&problem, &cfg);
        assert_eq!(session.finish().xs.data, by_solve.xs.data);
    }

    /// Observing `progress()` every round must not perturb the solve, the
    /// advances must tile [0, T) exactly (disjoint, top-down), and at
    /// least one advance must land strictly before the final round —
    /// the property streaming prefix delivery is built on.
    #[test]
    fn progress_tiles_the_trajectory_without_perturbing_the_solve() {
        let (coeffs, model) = setup(16);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(1), 3);
        let cfg = SolverConfig { guidance: 2.0, s_max: 64, ..SolverConfig::parataa(16) };
        let mut session = SolverSession::new(&problem, &cfg);
        let d = session.dim();
        let mut eps = Vec::new();
        let mut advances: Vec<(usize, std::ops::Range<usize>)> = Vec::new();
        let mut rounds = 0usize;
        loop {
            let n = match session.pending() {
                None => break,
                Some(b) => {
                    eps.resize(b.len() * d, 0.0);
                    model.eps_batch(b.x, b.t, b.conds, b.guidance, &mut eps);
                    b.len()
                }
            };
            rounds += 1;
            let done = session.resume(&eps[..n * d]).done;
            if let Some(adv) = session.progress() {
                assert_eq!(adv.residuals.len(), adv.newly_converged.len());
                advances.push((rounds, adv.newly_converged));
            }
            assert!(session.progress().is_none(), "no double report");
            if done {
                break;
            }
        }
        assert!(session.converged());
        // Advances tile [0, 16) top-down with no gaps or overlaps.
        let mut expect_end = 16;
        for (_, r) in &advances {
            assert_eq!(r.end, expect_end, "advances must be contiguous top-down");
            assert!(r.start < r.end);
            expect_end = r.start;
        }
        assert_eq!(expect_end, 0, "advances must reach the final sample row");
        assert!(
            advances.iter().any(|(round, _)| *round < rounds),
            "some prefix must land strictly before the final round"
        );
        // Observation did not perturb anything: bit-identical to solve().
        let by_solve = solve(&problem, &cfg);
        assert_eq!(session.finish().xs.data, by_solve.xs.data);
    }

    /// The adaptive window policy still converges to the sequential
    /// solution, keeps w inside its bounds, and shrinks under occupancy
    /// pressure.
    #[test]
    fn adaptive_window_converges_within_bounds() {
        use crate::solver::window_ctrl::{AdaptiveWindow, WindowPolicy};
        let (coeffs, model) = setup(24);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(1), 5);
        let adaptive = AdaptiveWindow {
            min_window: 3,
            max_window: 24,
            step: 3,
            high_occupancy: 0.85,
            // One frozen row per round is enough to grow a 6-row window
            // (the safeguard guarantees the front advances), so growth is
            // deterministic in this test.
            grow_velocity: 0.15,
        };
        let cfg = SolverConfig {
            guidance: 2.0,
            tol: 1e-5,
            s_max: 20 * 24,
            window: 6, // start small; the controller may grow it
            window_policy: WindowPolicy::Adaptive(adaptive.clone()),
            ..SolverConfig::parataa(24)
        };
        let mut session = SolverSession::new(&problem, &cfg);
        assert_eq!(session.window_rows(), 6);
        let mut saw_growth = false;
        let d = session.dim();
        let mut eps = Vec::new();
        loop {
            let n = match session.pending() {
                None => break,
                Some(b) => {
                    eps.resize(b.len() * d, 0.0);
                    model.eps_batch(b.x, b.t, b.conds, b.guidance, &mut eps);
                    b.len()
                }
            };
            let done = session.resume(&eps[..n * d]).done;
            let w = session.window_rows();
            assert!((3..=24).contains(&w), "w = {w} escaped its bounds");
            saw_growth |= w > 6;
            if done {
                break;
            }
        }
        assert!(session.converged());
        assert!(saw_growth, "an idle-occupancy solve should grow its window");
        let result = session.finish();
        let seq = crate::solver::sample_sequential(&problem, 2.0);
        crate::util::proplite::assert_close(
            result.xs.row(0),
            seq.xs.row(0),
            5e-3,
            5e-2,
            "adaptive window vs sequential",
        )
        .unwrap();

        // Saturated pool: the controller must shrink toward min_window.
        let mut pressured = SolverSession::new(&problem, &cfg);
        pressured.set_occupancy(1.0);
        drive(&mut pressured, &model);
        assert!(pressured.converged());
        assert_eq!(pressured.window_rows(), adaptive.min_window);
    }

    /// Forcing a mid-run shrink (saturated-pool occupancy from round 3 on)
    /// must not break streaming: the `progress()` advances still tile
    /// `[0, T)` top-down with no gap or overlap, and the window verifiably
    /// shrank while the front kept its monotone advance.
    #[test]
    fn progress_tiles_when_occupancy_forces_mid_run_shrink() {
        use crate::solver::window_ctrl::{AdaptiveWindow, WindowPolicy};
        let steps = 24;
        let (coeffs, model) = setup(steps);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(2), 9);
        let cfg = SolverConfig {
            guidance: 2.0,
            tol: 1e-5,
            s_max: 20 * steps,
            window: steps, // start at the cap so the shrink is observable
            window_policy: WindowPolicy::Adaptive(AdaptiveWindow::for_steps(steps)),
            ..SolverConfig::parataa(steps)
        };
        let mut session = SolverSession::new(&problem, &cfg);
        assert_eq!(session.window_rows(), steps);
        let d = session.dim();
        let mut eps = Vec::new();
        let mut advances: Vec<std::ops::Range<usize>> = Vec::new();
        let mut min_w = steps;
        let mut rounds = 0;
        loop {
            let n = match session.pending() {
                None => break,
                Some(b) => {
                    eps.resize(b.len() * d, 0.0);
                    model.eps_batch(b.x, b.t, b.conds, b.guidance, &mut eps);
                    b.len()
                }
            };
            rounds += 1;
            if rounds == 3 {
                // Pool saturates: every decide() from here shrinks by one
                // step until min_window.
                session.set_occupancy(1.0);
            }
            let done = session.resume(&eps[..n * d]).done;
            min_w = min_w.min(session.window_rows());
            if let Some(adv) = session.progress() {
                advances.push(adv.newly_converged);
            }
            if done {
                break;
            }
        }
        assert!(session.converged(), "shrunken windows must still converge");
        assert!(
            min_w < steps,
            "occupancy 1.0 must actually shrink the window (min stayed {min_w})"
        );
        // The advances tile [0, steps) from the top down: each chunk ends
        // where the previous began, regardless of the shrinking window.
        let mut expect_end = steps;
        for adv in &advances {
            assert_eq!(adv.end, expect_end, "front advances must be contiguous");
            assert!(adv.start < adv.end);
            expect_end = adv.start;
        }
        assert_eq!(expect_end, 0, "the advances must reach the sample row");
    }

    /// Draft-and-refine: the session runs a coarse draft phase first
    /// (visible via `coarse_rounds()`), then converges on the fine grid
    /// to the sequential solution within tolerance.
    #[test]
    fn draft_refine_converges_to_the_sequential_solution() {
        use crate::solver::strategy::DraftRefineConfig;
        let steps = 16;
        let (coeffs, model) = setup(steps);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(1), 11);
        let cfg = SolverConfig {
            guidance: 2.0,
            tol: 1e-4,
            s_max: 8 * steps,
            strategy: SolveStrategy::DraftRefine(DraftRefineConfig::default()),
            ..SolverConfig::parataa(steps)
        };
        let mut session = SolverSession::new(&problem, &cfg);
        drive(&mut session, &model);
        assert!(session.converged());
        assert!(session.coarse_rounds() > 0, "the draft phase must have run");
        assert!(session.coarse_rounds() < session.iterations());
        let result = session.finish();
        let seq = crate::solver::sample_sequential(&problem, 2.0);
        crate::util::proplite::assert_close(
            result.xs.row(0),
            seq.xs.row(0),
            5e-3,
            5e-2,
            "draft-refine vs sequential",
        )
        .unwrap();
    }

    /// Parareal: coarse sweeps interleave with fine rounds, the residual
    /// front never retreats (the sweep writes strictly below the
    /// safeguarded row), and the solve converges to the sequential
    /// solution within tolerance.
    #[test]
    fn parareal_converges_with_a_monotone_front() {
        use crate::solver::strategy::PararealConfig;
        let steps = 16;
        let (coeffs, model) = setup(steps);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(2), 13);
        let cfg = SolverConfig {
            guidance: 2.0,
            tol: 1e-4,
            s_max: 8 * steps,
            strategy: SolveStrategy::Parareal(PararealConfig::default()),
            ..SolverConfig::parataa(steps)
        };
        let mut session = SolverSession::new(&problem, &cfg);
        drive(&mut session, &model);
        assert!(session.converged());
        assert!(session.coarse_rounds() > 0, "coarse sweeps must have run");
        let result = session.finish();
        let mut prev = 0;
        for rec in &result.records {
            assert!(
                rec.converged_rows >= prev,
                "front retreated: {} < {prev} at iter {}",
                rec.converged_rows,
                rec.iter
            );
            prev = rec.converged_rows;
        }
        let seq = crate::solver::sample_sequential(&problem, 2.0);
        crate::util::proplite::assert_close(
            result.xs.row(0),
            seq.xs.row(0),
            5e-3,
            5e-2,
            "parareal vs sequential",
        )
        .unwrap();
    }

    #[test]
    fn early_finish_mid_solve_is_valid() {
        let (coeffs, model) = setup(20);
        let problem = Problem::new(&coeffs, &model, crate::model::Cond::Class(1), 7);
        let cfg = SolverConfig { guidance: 2.0, s_max: 80, ..SolverConfig::parataa(20) };
        let mut session = SolverSession::new(&problem, &cfg);
        let d = session.dim();
        let mut eps = Vec::new();
        for _ in 0..3 {
            let n = {
                let b = session.pending().unwrap();
                eps.resize(b.len() * d, 0.0);
                model.eps_batch(b.x, b.t, b.conds, b.guidance, &mut eps);
                b.len()
            };
            session.resume(&eps[..n * d]);
        }
        assert_eq!(session.iterations(), 3);
        let r = session.finish();
        assert_eq!(r.iterations, 3);
        assert!(!r.converged);
    }
}
