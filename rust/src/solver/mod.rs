//! Parallel diffusion sampling solvers (the paper's contribution).
//!
//! - [`sequential`] — the autoregressive baseline (eq. 6), also the oracle
//!   that parallel methods must match (Theorem 2.2 / Remark 5.3);
//! - [`history`] — Anderson history ring buffers (ΔX, ΔF) with the fused
//!   ΔX+ΔF slots and the incrementally-maintained per-row Gram cache (one
//!   ring push refreshes only the entries involving the overwritten slot);
//! - [`update`] — the update rules: fixed-point (eq. 10), standard Anderson
//!   Acceleration (eq. 12–13), AA+ (upper-triangular extraction, Remark
//!   3.4), and Triangular Anderson Acceleration (Theorem 3.2) with the
//!   Theorem 3.6 safeguard; `apply_update_ws` is the zero-allocation
//!   production path;
//! - [`workspace`] — the session-owned scratch ([`Workspace`]) that makes
//!   steady-state rounds allocation-free;
//! - [`session`] — Algorithm 1 as a resumable state machine
//!   ([`SolverSession`]): sliding window, stopping criterion, history
//!   management, iteration accounting, one `pending()`/`resume()` pair per
//!   parallel round;
//! - [`driver`] — the blocking entry points [`solve`]/`solve_with`, thin
//!   wrappers over a session (bit-identical to the historical loop);
//! - [`window_ctrl`] — the adaptive sliding-window controller
//!   ([`WindowPolicy`]): grows/shrinks w each round from convergence
//!   velocity and device occupancy (default [`WindowPolicy::Fixed`] keeps
//!   the paper's static §2.2 window bit-identically);
//! - [`init`] — trajectory initialization (§4.2).

pub mod driver;
pub mod history;
pub mod init;
pub mod sequential;
pub mod session;
pub mod strategy;
pub mod update;
pub mod window_ctrl;
pub mod workspace;

pub use driver::{solve, IterationRecord, SolveResult};
pub use sequential::{sample_sequential, try_sample_sequential};
pub use session::{EpsBatch, FrontAdvance, RoundOutcome, SolverSession};
pub use strategy::{DraftRefineConfig, PararealConfig, SolveStrategy};
pub use window_ctrl::{AdaptiveWindow, WindowController, WindowPolicy};
pub use workspace::Workspace;

use crate::equations::States;
use crate::model::{Cond, EpsModel};
use crate::schedule::SamplerCoeffs;

/// Which parallel update rule to run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// Plain fixed-point iteration on the order-k system (eq. 10).
    /// With k = window size this is the PL iteration of Shih et al. ("FP");
    /// with a tuned k it is the paper's "FP+".
    FixedPoint,
    /// Standard Anderson Acceleration (eq. 12–13): one global γ per
    /// iteration computed from the full-window Gram.
    AndersonStd,
    /// AA+ — upper-triangular extraction of the standard AA matrix
    /// (Remark 3.4 / Appendix B): global Gram inverse, per-row suffix
    /// projection.
    AndersonUpperTri,
    /// Triangular Anderson Acceleration (Theorem 3.2): per-row γ_t from
    /// suffix Grams — the paper's ParaTAA update.
    Taa,
}

impl Method {
    /// Short display label ("FP", "AA", "AA+", "TAA") used by figures,
    /// benches and the CLI.
    pub fn label(&self) -> &'static str {
        match self {
            Method::FixedPoint => "FP",
            Method::AndersonStd => "AA",
            Method::AndersonUpperTri => "AA+",
            Method::Taa => "TAA",
        }
    }
}

/// Solver configuration (the hyperparameters of Algorithm 1).
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Order k of the nonlinear system (Definition 2.1).
    pub k: usize,
    /// Update rule.
    pub method: Method,
    /// Anderson history size m (paper convention: m = 1 ⇒ no history ⇒
    /// plain FP; the number of difference columns is min(m−1, i)).
    pub m: usize,
    /// Ridge λ stabilizing the Gram solve (Remark 3.3).
    pub lambda: f32,
    /// Apply the Theorem 3.6 safeguard (top unconverged row takes the plain
    /// FP step), guaranteeing convergence within T iterations.
    pub safeguard: bool,
    /// Sliding window size w (§2.2). Clamped to T by the driver.
    pub window: usize,
    /// Stopping tolerance τ (thresholds ε_t = τ²·g²(t)·d, §2.1).
    pub tol: f64,
    /// Maximum parallel iterations s_max.
    pub s_max: usize,
    /// Classifier-free guidance scale.
    pub guidance: f32,
    /// Clamp the order-k equations at the frozen boundary (see
    /// `equations::eval_fk`). `true` is the correct windowed semantics
    /// (Remark 2.4); `false` applies Definition 2.1 verbatim across the
    /// frozen front and is kept for the `ablate` experiment, which shows
    /// the resulting convergence stall.
    pub clamp_boundary: bool,
    /// How the sliding window is sized across rounds. The default
    /// [`WindowPolicy::Fixed`] keeps `window` static for the whole solve
    /// (bit-identical to the pre-controller solver);
    /// [`WindowPolicy::Adaptive`] lets a [`WindowController`] grow/shrink
    /// it each round from convergence velocity and device occupancy.
    pub window_policy: WindowPolicy,
    /// Multi-fidelity strategy (`solver/strategy.rs`). The default
    /// [`SolveStrategy::PlainTaa`] runs single-fidelity rounds,
    /// byte-for-byte the historical path; [`SolveStrategy::DraftRefine`]
    /// seeds the window from a cheap coarse solve, and
    /// [`SolveStrategy::Parareal`] alternates coarse sweeps with fine
    /// parallel-correction rounds.
    pub strategy: SolveStrategy,
    /// Intra-round row-parallelism: the per-round Gram refresh, Anderson
    /// correction, and residual-front evaluation fan across this many
    /// threads (a session-owned `RowPool`; the solver thread participates).
    /// `1` (the default) runs the exact historical single-threaded path
    /// with no pool at all. Results are **bitwise identical** at every
    /// setting — per-row outputs have fixed owners and all reductions stay
    /// sequential on the solver thread (CLI: `--threads N`).
    pub parallelism: usize,
}

impl SolverConfig {
    /// The paper's ParaTAA defaults (Appendix C: m ∈ 2..4, robust k).
    ///
    /// # Example
    ///
    /// Solve an 8-step DDIM trajectory on the analytic SD-analog model and
    /// confirm the parallel solve converged:
    ///
    /// ```
    /// use parataa::model::{gmm::GmmEps, Cond};
    /// use parataa::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
    /// use parataa::solver::{self, Problem, SolverConfig};
    ///
    /// let schedule = NoiseSchedule::new(BetaSchedule::Linear, 1000);
    /// let model = GmmEps::sd_analog(schedule.alpha_bars.clone());
    /// let coeffs = SamplerCoeffs::new(&schedule, SamplerKind::Ddim, 8);
    /// let problem = Problem::new(&coeffs, &model, Cond::Class(0), 3);
    ///
    /// let mut cfg = SolverConfig::parataa(8);
    /// cfg.guidance = 2.0; // the analytic score is stiffer than a trained net
    /// cfg.s_max = 32;
    /// let result = solver::solve(&problem, &cfg);
    /// assert!(result.converged);
    /// assert!(result.iterations >= 1);
    /// ```
    pub fn parataa(steps: usize) -> Self {
        SolverConfig {
            k: (steps / 4).max(2),
            method: Method::Taa,
            m: 3,
            lambda: 1e-4,
            safeguard: true,
            window: steps,
            tol: 1e-3,
            s_max: steps + 1,
            guidance: 5.0,
            clamp_boundary: true,
            window_policy: WindowPolicy::Fixed,
            strategy: SolveStrategy::PlainTaa,
            parallelism: 1,
        }
    }

    /// The Shih et al. baseline: FP with k = w.
    pub fn fp_baseline(steps: usize) -> Self {
        SolverConfig {
            k: steps,
            method: Method::FixedPoint,
            m: 1,
            lambda: 0.0,
            safeguard: false,
            window: steps,
            tol: 1e-3,
            s_max: steps + 1,
            guidance: 5.0,
            clamp_boundary: true,
            window_policy: WindowPolicy::Fixed,
            strategy: SolveStrategy::PlainTaa,
            parallelism: 1,
        }
    }

    /// FP+ — fixed-point with a tuned order k.
    pub fn fp_plus(steps: usize, k: usize) -> Self {
        SolverConfig { k, ..Self::fp_baseline(steps) }
    }

    /// Worst-case sliding-window footprint in rows — what a serving
    /// coordinator must reserve from its slot budget for the whole solve.
    /// `Fixed` holds exactly `window` rows; `Adaptive` may grow up to its
    /// `max_window` bound. Callers clamp to the trajectory length.
    pub fn max_window_rows(&self) -> usize {
        match &self.window_policy {
            WindowPolicy::Fixed => self.window,
            WindowPolicy::Adaptive(a) => a.max_window,
        }
    }
}

/// A sampling problem: one trajectory to solve.
pub struct Problem<'a> {
    /// Sampler coefficients (schedule + step grid) the trajectory solves on.
    pub coeffs: &'a SamplerCoeffs,
    /// Denoiser ε_θ evaluated by the blocking drivers (sessions only
    /// borrow its dimension — they never call it).
    pub model: &'a dyn EpsModel,
    /// Condition ("class" or dense prompt weights).
    pub cond: Cond,
    /// Fixed noise draws ξ_0..ξ_T (row T doubles as the initial state x_T).
    pub xi: States,
    /// Optional initialization trajectory (§4.2): rows 0..T. When absent
    /// the driver initializes all unknowns from standard Gaussians.
    pub init: Option<States>,
    /// Freeze rows ≥ T_init at their initialization values (§4.2's
    /// SDEdit-style splice). Ignored unless `init` is set.
    pub t_init: Option<usize>,
    /// Seed for the Gaussian initialization of the unknowns (and provenance
    /// of the ξ draws when constructed via [`Problem::new`]).
    pub seed: u64,
}

impl<'a> Problem<'a> {
    /// Fresh random problem with noise drawn from `seed`.
    pub fn new(
        coeffs: &'a SamplerCoeffs,
        model: &'a dyn EpsModel,
        cond: Cond,
        seed: u64,
    ) -> Self {
        let d = model.dim();
        let t = coeffs.steps;
        let mut rng = crate::util::rng::Pcg64::new(seed, 0x0d1f_f751);
        let mut xi = States::zeros(t, d);
        rng.fill_gaussian(&mut xi.data);
        // An ODE sampler never consumes ξ_0..ξ_{T-1}, but drawing them keeps
        // the stream layout identical between DDIM and DDPM runs.
        Problem { coeffs, model, cond, xi, init: None, t_init: None, seed }
    }

    /// Seed used for the Gaussian initialization of the unknowns.
    pub fn init_seed(&self) -> u64 {
        self.seed
    }
}
