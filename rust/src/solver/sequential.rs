//! The sequential (autoregressive) sampling baseline — eq. (6).
//!
//! This is both the performance baseline of Table 1 and the *correctness
//! oracle*: Theorem 2.2 guarantees the parallel solvers converge to exactly
//! this trajectory, and the integration tests assert it.

use super::Problem;
use crate::equations::States;
use crate::model::Cond;
use crate::util::error::Result;

/// Result of a sequential rollout.
pub struct SequentialResult {
    /// Full trajectory x_0..x_T.
    pub xs: States,
    /// Number of (serial) denoiser evaluations — always T.
    pub nfe: usize,
}

/// Roll out eq. (6) from x_T = ξ_T down to x_0, one ε_θ call per step.
///
/// Panics if the model fails — the historical contract for direct solver
/// users over infallible models. Callers that need to survive a failing
/// model (e.g. the coordinator's degraded-sequential fallback) use
/// [`try_sample_sequential`].
pub fn sample_sequential(problem: &Problem, guidance: f32) -> SequentialResult {
    try_sample_sequential(problem, guidance).expect("sequential rollout: model failed")
}

/// Fallible twin of [`sample_sequential`]: identical rollout (bitwise —
/// the default `try_eps_batch` wraps `eps_batch`), but a model error
/// surfaces as a classified `Err` instead of a panic.
pub fn try_sample_sequential(problem: &Problem, guidance: f32) -> Result<SequentialResult> {
    let coeffs = problem.coeffs;
    let model = problem.model;
    let t_count = coeffs.steps;
    let d = model.dim();
    let mut xs = States::zeros(t_count, d);
    xs.set_row(t_count, problem.xi.row(t_count));

    let mut eps = vec![0.0f32; d];
    let conds: [Cond; 1] = [problem.cond.clone()];
    for t in (1..=t_count).rev() {
        // ε_θ(x_t, τ_{t-1}) — a single-item "batch": the serial baseline
        // pays one full device round-trip per step, which is exactly the
        // cost structure the paper parallelizes away.
        model.try_eps_batch(xs.row(t), &[coeffs.train_t[t]], &conds, guidance, &mut eps)?;
        let a = coeffs.a[t] as f32;
        let b = coeffs.b[t] as f32;
        let c = coeffs.c[t - 1] as f32;
        let xi_row = problem.xi.row(t - 1);
        let (head, tail) = xs.data.split_at_mut(t * d);
        let x_prev = &mut head[(t - 1) * d..t * d];
        let x_t = &tail[..d];
        for i in 0..d {
            x_prev[i] = a * x_t[i] + b * eps[i] + c * xi_row[i];
        }
    }
    Ok(SequentialResult { xs, nfe: t_count })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gmm::GmmEps;
    use crate::model::{Cond, EpsModel};
    use crate::schedule::{BetaSchedule, NoiseSchedule, SamplerCoeffs, SamplerKind};
    use crate::util::rng::Pcg64;

    fn tiny_model(d: usize, n_comp: usize) -> GmmEps {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let mut rng = Pcg64::seeded(77);
        let means: Vec<f32> = (0..n_comp * d).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        GmmEps::new(means, d, 0.2, ns.alpha_bars.clone())
    }

    #[test]
    fn deterministic_given_seed() {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 25);
        let model = tiny_model(8, 3);
        let p1 = Problem::new(&coeffs, &model, Cond::Class(1), 42);
        let p2 = Problem::new(&coeffs, &model, Cond::Class(1), 42);
        let r1 = sample_sequential(&p1, 2.0);
        let r2 = sample_sequential(&p2, 2.0);
        assert_eq!(r1.xs.data, r2.xs.data);
        assert_eq!(r1.nfe, 25);
    }

    #[test]
    fn different_seeds_produce_different_samples() {
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 25);
        let model = tiny_model(8, 3);
        let r1 = sample_sequential(&Problem::new(&coeffs, &model, Cond::Class(0), 1), 1.0);
        let r2 = sample_sequential(&Problem::new(&coeffs, &model, Cond::Class(0), 2), 1.0);
        let diff: f32 = r1
            .xs
            .row(0)
            .iter()
            .zip(r2.xs.row(0).iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn ddim_sample_lands_near_data_manifold() {
        // With the exact GMM score and enough steps, DDIM should land close
        // to a component mean (within a few data std-devs).
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddim, 100);
        let d = 8;
        let model = tiny_model(d, 3);
        let p = Problem::new(&coeffs, &model, Cond::Class(2), 5);
        let r = sample_sequential(&p, 1.0);
        let x0 = r.xs.row(0);
        // distance to the nearest component mean
        let mut best = f64::INFINITY;
        for c in 0..3 {
            let mu = &model.means[c * d..(c + 1) * d];
            let d2: f64 = x0
                .iter()
                .zip(mu.iter())
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum();
            best = best.min(d2.sqrt());
        }
        assert!(best < 1.0, "sample distance to nearest mode: {best}");
    }

    #[test]
    fn residuals_vanish_on_sequential_trajectory() {
        // The sequential trajectory is the exact solution of the system.
        use crate::equations::residual_sq;
        let ns = NoiseSchedule::new(BetaSchedule::Linear, 1000);
        let coeffs = SamplerCoeffs::new(&ns, SamplerKind::Ddpm, 30);
        let model = tiny_model(6, 2);
        let p = Problem::new(&coeffs, &model, Cond::Class(0), 9);
        let r = sample_sequential(&p, 1.0);
        // Recompute eps at every state to evaluate residuals.
        let mut eps = States::zeros(30, 6);
        let conds = vec![Cond::Class(0); 1];
        for t in 1..=30usize {
            let mut e = vec![0.0f32; 6];
            model.eps_batch(r.xs.row(t), &[coeffs.train_t[t]], &conds, 1.0, &mut e);
            eps.set_row(t, &e);
        }
        for p_row in 0..30 {
            let res = residual_sq(&coeffs, &r.xs, &eps, &p.xi, p_row);
            assert!(res < 1e-8, "residual at row {p_row}: {res}");
        }
    }
}
