//! Fig. 5/13 — qualitative iteration strips and trajectory-init
//! interpolation, emitted as PGM images under `results/fig5/`.

use super::common::{method_config, ModelChoice, Scenario};
use crate::model::Cond;
use crate::schedule::SamplerKind;
use crate::solver::{init::init_from_trajectory, Method, Problem};
use crate::util::cli::Args;
use crate::util::image::{hstack, write_pgm};
use crate::util::table::Table;

/// Generate the four §5.3 rows: P1 random-init, P2 random-init, P2 from
/// P1's trajectory (two T_init values). Each row is a strip of the x₀
/// estimate after rounds 1, 2, 3, 5, 7, plus the converged image.
pub fn fig5(args: &Args) -> Table {
    let model = ModelChoice::parse(&args.get_or("model", "gmm"));
    let steps = args.usize_or("steps", 50);
    let seed = args.u64_or("seed", 11);
    let out_dir = args.get_or("out", "results/fig5");
    let scenario = Scenario::new(model, SamplerKind::Ddim, steps);
    let coeffs = scenario.coeffs();
    let probe_rounds = [1usize, 2, 3, 5, 7];

    // P1 / P2: "a horse photo" vs "an oil painting of a horse" becomes a
    // pair of nearby template blends.
    let p1 = Cond::Class(0);
    let p2 = Cond::Class(0).lerp(&Cond::Class(6), 0.45, 8);

    let donor_cfg = method_config(Method::Taa, steps, None, scenario.guidance);
    let donor_problem = Problem::new(&coeffs, &*scenario.model, p1.clone(), seed);
    let donor = crate::solver::solve(&donor_problem, &donor_cfg);

    let mut t = Table::new(
        "Figure 5: qualitative trajectory-init strips (PGM files)",
        &["row", "setting", "file", "rounds_to_criterion"],
    );
    let settings: Vec<(String, Cond, Option<usize>)> = vec![
        ("p1-random".into(), p1.clone(), None),
        ("p2-random".into(), p2.clone(), None),
        (format!("p2-traj-tinit{}", steps), p2.clone(), Some(steps)),
        (format!("p2-traj-tinit{}", 7 * steps / 10), p2.clone(), Some(7 * steps / 10)),
    ];
    for (i, (label, cond, t_init)) in settings.into_iter().enumerate() {
        let mut problem = Problem::new(&coeffs, &*scenario.model, cond, seed);
        if let Some(ti) = t_init {
            init_from_trajectory(&mut problem, donor.xs.clone(), donor_problem.xi.clone(), ti);
        }
        let mut cfg = method_config(Method::Taa, steps, None, scenario.guidance);
        cfg.s_max = 3 * steps;
        let mut frames: Vec<Vec<f32>> = Vec::new();
        let result = crate::solver::driver::solve_with(&problem, &cfg, |rec, xs| {
            if probe_rounds.contains(&rec.iter) {
                frames.push(xs.row(0).to_vec());
            }
            false
        });
        frames.push(result.xs.row(0).to_vec()); // converged frame
        let (strip, w, h) = hstack(&frames, 16, 16, 2);
        let file = format!("{out_dir}/row{}_{label}.pgm", i + 1);
        write_pgm(&file, &strip, w, h).expect("write pgm");
        t.push_row(vec![
            (i + 1).to_string(),
            label,
            file,
            result.iterations.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_writes_strips() {
        let dir = std::env::temp_dir().join("parataa_fig5_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = Args::parse(
            [
                "f",
                "--model",
                "gmm",
                "--steps",
                "10",
                "--out",
                dir.to_str().unwrap(),
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let t = fig5(&args);
        assert_eq!(t.rows.len(), 4);
        for row in &t.rows {
            assert!(std::path::Path::new(&row[2]).exists(), "missing {}", row[2]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
