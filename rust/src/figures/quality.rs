//! Quality-vs-rounds figures: Fig. 3 (method comparison across scenarios),
//! Fig. 4 (window-size trade-off), and Fig. 14 (trajectory-init CS curves).
//!
//! Each generator runs batches of solves while snapshotting the x₀ estimate
//! after every parallel round, then evaluates FID/IS/CS proxies at each
//! round — exactly the early-stopping evidence of §4.1.

use super::common::{
    fp_plus_k, method_config, reference_samples, solve_with_snapshots, ModelChoice, Scenario,
};
use crate::metrics::{cs_proxy, fid_proxy, is_proxy};
use crate::model::Cond;
use crate::schedule::SamplerKind;
use crate::solver::{init::init_from_trajectory, Method, Problem};
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use crate::util::table::Table;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Per-round stacked snapshots for a batch of solves (padded by repeating
/// each solve's final sample once it converged).
pub struct BatchCurves {
    /// `samples_at[r]` = all x₀ estimates after round r+1, stacked.
    pub samples_at: Vec<Vec<f32>>,
    pub conds: Vec<Cond>,
    /// Per-solve rounds-to-criterion.
    pub rounds: Vec<usize>,
    /// Sequential reference samples (same seeds/conds).
    pub sequential: Vec<f32>,
    /// Wall-clock per parallel solve (seconds).
    pub solve_secs: Vec<f64>,
    /// Wall-clock per sequential rollout (seconds).
    pub seq_secs: Vec<f64>,
}

/// Run `n` solves of `method` in a scenario, collecting snapshot stacks.
pub fn batch_curves(
    scenario: &Scenario,
    method: Method,
    k: Option<usize>,
    n: usize,
    max_rounds: usize,
    seed0: u64,
    pool: &ThreadPool,
) -> BatchCurves {
    let coeffs = Arc::new(scenario.coeffs());
    let model = scenario.model.clone();
    let guidance = scenario.guidance;
    let steps = scenario.steps;

    let jobs: Vec<u64> = (0..n as u64).map(|i| seed0 + i).collect();
    let outs = pool.map(jobs, move |seed| {
        let mut rng = Pcg64::new(seed, 0xc0d);
        let cond = Cond::Class(rng.below(8) as usize);
        let problem = Problem::new(&coeffs, &*model, cond.clone(), seed);
        let mut cfg = method_config(method, steps, k, guidance);
        cfg.s_max = max_rounds;
        let t0 = std::time::Instant::now();
        let snap = solve_with_snapshots(&problem, &cfg);
        let solve_s = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let seq = crate::solver::sample_sequential(&problem, guidance);
        let seq_s = t1.elapsed().as_secs_f64();
        (snap, cond, seq.xs.row(0).to_vec(), solve_s, seq_s)
    });

    let d = scenario.model.dim();
    let mut samples_at = vec![Vec::with_capacity(n * d); max_rounds];
    let mut conds = Vec::with_capacity(n);
    let mut rounds = Vec::with_capacity(n);
    let mut sequential = Vec::with_capacity(n * d);
    let mut solve_secs = Vec::with_capacity(n);
    let mut seq_secs = Vec::with_capacity(n);
    for (snap, cond, seq, solve_s, seq_s) in outs {
        for r in 0..max_rounds {
            let idx = r.min(snap.snapshots.len() - 1);
            samples_at[r].extend_from_slice(&snap.snapshots[idx]);
        }
        conds.push(cond);
        rounds.push(snap.result.iterations);
        sequential.extend_from_slice(&seq);
        solve_secs.push(solve_s);
        seq_secs.push(seq_s);
    }
    BatchCurves { samples_at, conds, rounds, sequential, solve_secs, seq_secs }
}

/// Evaluate the scenario's quality metrics on a sample stack.
pub fn quality_row(scenario: &Scenario, samples: &[f32], conds: &[Cond], reference: &[f32]) -> (f64, f64, f64) {
    let fid = fid_proxy(samples, reference, scenario.classifier.d);
    let is = is_proxy(samples, &scenario.classifier);
    let cs = cs_proxy(samples, conds, &scenario.classifier);
    (fid, is, cs)
}

/// Fig. 3 — quality vs s_max for FP / FP+ / ParaTAA across scenarios.
pub fn fig3(args: &Args) -> Table {
    let model = ModelChoice::parse(&args.get_or("model", ModelChoice::default_name()));
    let n = args.usize_or("samples", 64);
    let seed0 = args.u64_or("seed", 100);
    let pool = ThreadPool::with_available_parallelism();

    let scenarios: Vec<(SamplerKind, usize)> = vec![
        (SamplerKind::Ddim, 25),
        (SamplerKind::Ddim, 50),
        (SamplerKind::Ddim, 100),
        (SamplerKind::Ddpm, 100),
    ];
    let mut t = Table::new(
        "Figure 3: quality vs max rounds (sequential reference in last rows)",
        &["scenario", "method", "round", "fid_proxy", "is_proxy", "cs_proxy"],
    );
    for (kind, steps) in scenarios {
        let scenario = Scenario::new(model, kind, steps);
        let (reference, _) = reference_samples(&scenario.classifier, 512, 9);
        let max_rounds = (steps / 2).max(12);
        for (label, method, k) in [
            ("FP", Method::FixedPoint, Some(steps)),
            ("FP+", Method::FixedPoint, Some(fp_plus_k(steps))),
            ("ParaTAA", Method::Taa, None),
        ] {
            let curves = batch_curves(&scenario, method, k, n, max_rounds, seed0, &pool);
            let mean_rounds =
                curves.rounds.iter().sum::<usize>() as f64 / curves.rounds.len() as f64;
            eprintln!("  {} {label}: mean rounds {mean_rounds:.1}", scenario.label());
            for (r, samples) in curves.samples_at.iter().enumerate() {
                let (fid, is, cs) = quality_row(&scenario, samples, &curves.conds, &reference);
                t.push_row(vec![
                    scenario.label(),
                    label.to_string(),
                    (r + 1).to_string(),
                    format!("{fid:.4}"),
                    format!("{is:.3}"),
                    format!("{cs:.3}"),
                ]);
            }
            // Sequential reference line (round = 0 sentinel).
            let (fid, is, cs) =
                quality_row(&scenario, &curves.sequential, &curves.conds, &reference);
            t.push_row(vec![
                scenario.label(),
                format!("{label}/sequential"),
                "0".to_string(),
                format!("{fid:.4}"),
                format!("{is:.3}"),
                format!("{cs:.3}"),
            ]);
        }
    }
    t
}

/// Fig. 4 — ParaTAA quality vs rounds under different window sizes.
pub fn fig4(args: &Args) -> Table {
    let model = ModelChoice::parse(&args.get_or("model", ModelChoice::default_name()));
    let steps = args.usize_or("steps", 100);
    let n = args.usize_or("samples", 32);
    let windows = args.usize_list("windows", &[10, 20, 50, 100]);
    let seed0 = args.u64_or("seed", 300);
    let pool = ThreadPool::with_available_parallelism();

    let scenario = Scenario::new(model, SamplerKind::Ddim, steps);
    let (reference, _) = reference_samples(&scenario.classifier, 512, 9);
    let mut t = Table::new(
        "Figure 4: ParaTAA under different window sizes (DDIM-100)",
        &["window", "round", "cs_proxy", "fid_proxy", "mean_rounds_to_criterion"],
    );
    for &w in &windows {
        let coeffs = Arc::new(scenario.coeffs());
        let modelref = scenario.model.clone();
        let guidance = scenario.guidance;
        let jobs: Vec<u64> = (0..n as u64).map(|i| seed0 + i).collect();
        let max_rounds = 3 * steps;
        let outs = pool.map(jobs, move |seed| {
            let mut rng = Pcg64::new(seed, 0xc0d);
            let cond = Cond::Class(rng.below(8) as usize);
            let problem = Problem::new(&coeffs, &*modelref, cond.clone(), seed);
            let mut cfg = method_config(Method::Taa, steps, None, guidance);
            cfg.window = w;
            cfg.s_max = max_rounds;
            (solve_with_snapshots(&problem, &cfg), cond)
        });
        let mean_rounds: f64 =
            outs.iter().map(|(s, _)| s.result.iterations).sum::<usize>() as f64 / n as f64;
        eprintln!("  w={w}: mean rounds {mean_rounds:.1}");
        let d = scenario.model.dim();
        let probe: Vec<usize> = (0..max_rounds).step_by(2).collect();
        for &r in &probe {
            let mut stack = Vec::with_capacity(n * d);
            let mut conds = Vec::with_capacity(n);
            for (s, cond) in &outs {
                let idx = r.min(s.snapshots.len() - 1);
                stack.extend_from_slice(&s.snapshots[idx]);
                conds.push(cond.clone());
            }
            let (fid, _is, cs) = quality_row(&scenario, &stack, &conds, &reference);
            t.push_row(vec![
                w.to_string(),
                (r + 1).to_string(),
                format!("{cs:.3}"),
                format!("{fid:.4}"),
                format!("{mean_rounds:.1}"),
            ]);
        }
    }
    t
}

/// Fig. 14 — CS-proxy vs rounds for the three §5.3 init settings.
pub fn fig14(args: &Args) -> Table {
    let steps = args.usize_or("steps", 50);
    let n = args.usize_or("samples", 24);
    let seed0 = args.u64_or("seed", 500);
    let scenario = Scenario::new(ModelChoice::Gmm, SamplerKind::Ddim, steps);
    let coeffs = scenario.coeffs();
    let max_rounds = 12;

    // P1/P2: nearby "prompts" = blended conditions over templates.
    let p1 = |_: &mut Pcg64| Cond::Class(0);
    let p2 = Cond::Class(0).lerp(&Cond::Class(6), 0.35, 8);

    let mut t = Table::new(
        "Figure 14: CS-proxy vs rounds for three initialization settings",
        &["setting", "round", "cs_proxy"],
    );
    let settings: Vec<(String, Option<usize>)> = vec![
        ("random-init".to_string(), None),
        (format!("traj-init Tinit={steps}"), Some(steps)),
        (format!("traj-init Tinit={}", 7 * steps / 10), Some(7 * steps / 10)),
    ];
    let d = scenario.model.dim();
    for (label, t_init) in settings {
        let mut stacks: Vec<Vec<f32>> = vec![Vec::with_capacity(n * d); max_rounds];
        let mut conds = Vec::with_capacity(n);
        for i in 0..n as u64 {
            let seed = seed0 + i;
            let mut rng = Pcg64::new(seed, 0x1417);
            // Solve P1 first (the donor trajectory).
            let p1c = p1(&mut rng);
            let donor_problem = Problem::new(&coeffs, &*scenario.model, p1c, seed);
            let donor_cfg = method_config(Method::Taa, steps, None, scenario.guidance);
            let donor = crate::solver::solve(&donor_problem, &donor_cfg);
            // Solve P2 with the chosen init.
            let mut problem = Problem::new(&coeffs, &*scenario.model, p2.clone(), seed);
            if let Some(ti) = t_init {
                init_from_trajectory(&mut problem, donor.xs.clone(), donor_problem.xi.clone(), ti);
            }
            let mut cfg = method_config(Method::Taa, steps, None, scenario.guidance);
            cfg.s_max = max_rounds;
            let snap = solve_with_snapshots(&problem, &cfg);
            for r in 0..max_rounds {
                let idx = r.min(snap.snapshots.len() - 1);
                stacks[r].extend_from_slice(&snap.snapshots[idx]);
            }
            conds.push(p2.clone());
        }
        for (r, stack) in stacks.iter().enumerate() {
            let cs = cs_proxy(stack, &conds, &scenario.classifier);
            t.push_row(vec![label.clone(), (r + 1).to_string(), format!("{cs:.3}")]);
        }
        eprintln!("  {label}: done");
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_curves_shapes() {
        let scenario = Scenario::new(ModelChoice::Gmm, SamplerKind::Ddim, 8);
        let pool = ThreadPool::new(2);
        let c = batch_curves(&scenario, Method::Taa, None, 3, 6, 42, &pool);
        assert_eq!(c.samples_at.len(), 6);
        assert_eq!(c.samples_at[0].len(), 3 * 256);
        assert_eq!(c.conds.len(), 3);
        assert_eq!(c.sequential.len(), 3 * 256);
    }

    #[test]
    fn fig14_tiny() {
        let args = Args::parse(
            ["f", "--steps", "10", "--samples", "2"].iter().map(|s| s.to_string()),
        );
        let t = fig14(&args);
        assert_eq!(t.rows.len(), 3 * 12);
    }
}
