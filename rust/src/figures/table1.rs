//! Table 1 — the headline comparison: Sequential vs FP vs FP+ vs ParaTAA
//! across the eight scenario columns ({DiT-tiny, SDa} × {DDIM-25/50/100,
//! DDPM-100}), reporting parallel Steps, wall-clock Time and quality.
//!
//! Early-stopping protocol (paper, Table 1 caption): FP reports the mean
//! rounds to the stopping criterion; FP+ and ParaTAA report the first round
//! at which batch quality matches the sequential batch (the Fig. 3 insight),
//! with Time prorated to that round.

use super::common::{fp_plus_k, reference_samples, ModelChoice, Scenario};
use super::quality::{batch_curves, quality_row, BatchCurves};
use crate::schedule::SamplerKind;
use crate::solver::Method;
use crate::util::cli::Args;
use crate::util::table::Table;
use crate::util::threadpool::ThreadPool;

/// One method's Table-1 cell.
pub struct Cell {
    pub steps: f64,
    pub time_s: f64,
    pub fid: f64,
    pub is: f64,
    pub cs: f64,
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

/// Find the early-stop round: first round whose quality matches the
/// sequential batch (CS within 0.3 absolute AND FID within 15% relative +
/// a small absolute floor). Falls back to mean rounds-to-criterion.
fn early_stop_round(
    scenario: &Scenario,
    curves: &BatchCurves,
    reference: &[f32],
    seq_q: (f64, f64, f64),
) -> usize {
    let (seq_fid, _seq_is, seq_cs) = seq_q;
    for (r, stack) in curves.samples_at.iter().enumerate() {
        let (fid, _is, cs) = quality_row(scenario, stack, &curves.conds, reference);
        let fid_ok = fid <= seq_fid * 1.15 + 0.05;
        let cs_ok = (cs - seq_cs).abs() <= 0.3;
        if fid_ok && cs_ok {
            return r + 1;
        }
    }
    mean(&curves.rounds.iter().map(|&r| r as f64).collect::<Vec<_>>()).round() as usize
}

/// Compute one scenario's four rows.
pub fn scenario_rows(
    scenario: &Scenario,
    n: usize,
    seed0: u64,
    pool: &ThreadPool,
) -> Vec<(String, Cell)> {
    let steps = scenario.steps;
    let (reference, _) = reference_samples(&scenario.classifier, 1024, 9);
    let max_rounds = steps + 1;

    let mut rows = Vec::new();
    // Run the three parallel methods (the sequential rollout rides along in
    // each batch; use the first one for the Sequential row).
    let mut seq_cell: Option<Cell> = None;
    for (label, method, k) in [
        ("FP", Method::FixedPoint, Some(steps)),
        ("FP+", Method::FixedPoint, Some(fp_plus_k(steps))),
        ("ParaTAA", Method::Taa, None),
    ] {
        let curves = batch_curves(scenario, method, k, n, max_rounds, seed0, pool);
        let seq_q = quality_row(scenario, &curves.sequential, &curves.conds, &reference);
        if seq_cell.is_none() {
            seq_cell = Some(Cell {
                steps: steps as f64,
                time_s: mean(&curves.seq_secs),
                fid: seq_q.0,
                is: seq_q.1,
                cs: seq_q.2,
            });
        }
        let mean_rounds = mean(&curves.rounds.iter().map(|&r| r as f64).collect::<Vec<_>>());
        let mean_time = mean(&curves.solve_secs);
        let (est_steps, time_s, qr) = if label == "FP" {
            // No early stopping for the FP baseline (paper protocol).
            let q = quality_row(
                scenario,
                curves.samples_at.last().unwrap(),
                &curves.conds,
                &reference,
            );
            (mean_rounds, mean_time, q)
        } else {
            let stop = early_stop_round(scenario, &curves, &reference, seq_q);
            let per_round = mean_time / mean_rounds.max(1.0);
            let q = quality_row(
                scenario,
                &curves.samples_at[(stop - 1).min(curves.samples_at.len() - 1)],
                &curves.conds,
                &reference,
            );
            (stop as f64, per_round * stop as f64, q)
        };
        rows.push((
            label.to_string(),
            Cell { steps: est_steps, time_s, fid: qr.0, is: qr.1, cs: qr.2 },
        ));
        eprintln!("  {} {label}: steps {est_steps:.1}, {time_s:.3}s", scenario.label());
    }
    rows.insert(0, ("Sequential".to_string(), seq_cell.unwrap()));
    rows
}

/// Generate the full Table 1.
pub fn table1(args: &Args) -> Table {
    let n = args.usize_or("samples", 32);
    let seed0 = args.u64_or("seed", 1000);
    let models: Vec<ModelChoice> = match args.get("model") {
        Some(m) => vec![ModelChoice::parse(m)],
        // DiT needs the PJRT backend; default to the analytic column otherwise.
        None if cfg!(feature = "pjrt") => vec![ModelChoice::Dit, ModelChoice::Gmm],
        None => vec![ModelChoice::Gmm],
    };
    let pool = ThreadPool::with_available_parallelism();

    let mut t = Table::new(
        "Table 1: parallel sampling methods across scenarios",
        &["scenario", "method", "steps", "time_s", "fid_proxy", "is_proxy", "cs_proxy", "speedup_x"],
    );
    for model in models {
        for (kind, steps) in [
            (SamplerKind::Ddim, 25),
            (SamplerKind::Ddim, 50),
            (SamplerKind::Ddim, 100),
            (SamplerKind::Ddpm, 100),
        ] {
            let scenario = Scenario::new(model, kind, steps);
            let rows = scenario_rows(&scenario, n, seed0, &pool);
            let seq_time = rows[0].1.time_s;
            for (label, cell) in rows {
                let speedup = seq_time / cell.time_s.max(1e-12);
                t.push_row(vec![
                    scenario.label(),
                    label,
                    format!("{:.1}", cell.steps),
                    format!("{:.4}", cell.time_s),
                    format!("{:.3}", cell.fid),
                    format!("{:.3}", cell.is),
                    format!("{:.3}", cell.cs),
                    format!("{:.2}", speedup),
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_rows_tiny() {
        let scenario = Scenario::new(ModelChoice::Gmm, SamplerKind::Ddim, 10);
        let pool = ThreadPool::new(2);
        let rows = scenario_rows(&scenario, 4, 42, &pool);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].0, "Sequential");
        assert_eq!(rows[0].1.steps, 10.0);
        // Parallel methods should not exceed sequential steps by more than
        // the final verification round (tiny T: parallelism has no headroom).
        for (label, cell) in &rows[1..] {
            assert!(cell.steps <= 11.5, "{label} steps {}", cell.steps);
        }
    }
}
